"""Make `compile.*` importable whether pytest runs from repo root or
from python/ (the final `pytest python/tests/` invocation runs at root)."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
