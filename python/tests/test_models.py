"""L2 model tests: shapes, mask semantics, gradient structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import models

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", list(models.MODELS))
def test_init_and_apply_shapes(name):
    dataset = "snli" if name == "tinytransformer" else "gtsrb"
    m = models.build(name, dataset)
    params = m.init(jax.random.PRNGKey(0))
    assert len(params) > 0
    ex = m.input_spec()
    x = (
        jnp.zeros(ex.shape, jnp.int32)
        if ex.dtype == jnp.int32
        else jax.random.normal(jax.random.PRNGKey(1), ex.shape, jnp.float32)
    )
    qmask = jnp.zeros((m.n_quant_layers,), jnp.float32)
    logits = m.apply(params, x, qmask, jnp.zeros((), jnp.float32))
    assert logits.shape == (m.n_classes,)
    assert len(m.layer_names) == m.n_quant_layers


@pytest.mark.parametrize("name", list(models.MODELS))
def test_mask_zero_equals_fp_path(name):
    # quant_mask = 0 must yield the *exact* fp32 forward: the quantized
    # branch is multiplied by 0.
    dataset = "snli" if name == "tinytransformer" else "cifar"
    m = models.build(name, dataset)
    params = m.init(jax.random.PRNGKey(2))
    ex = m.input_spec()
    if ex.dtype == jnp.int32:
        x = jax.random.randint(jax.random.PRNGKey(3), ex.shape, 0, models.VOCAB)
    else:
        x = jax.random.normal(jax.random.PRNGKey(3), ex.shape, jnp.float32)
    zero = jnp.zeros((m.n_quant_layers,), jnp.float32)
    a = m.apply(params, x, zero, jnp.float32(1.0))
    b = m.apply(params, x, zero, jnp.float32(99.0))  # different seed, same result
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_mask_one_changes_output():
    m = models.build("miniconvnet", "gtsrb")
    params = m.init(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), models.IMG, jnp.float32)
    zero = jnp.zeros((m.n_quant_layers,), jnp.float32)
    ones = jnp.ones((m.n_quant_layers,), jnp.float32)
    a = np.asarray(m.apply(params, x, zero, jnp.float32(1.0)))
    b = np.asarray(m.apply(params, x, ones, jnp.float32(1.0)))
    assert not np.allclose(a, b), "full quantization must perturb logits"


def test_single_layer_masking_is_local():
    # Quantizing only layer i must differ from fp but less than all-layers.
    m = models.build("miniconvnet", "gtsrb")
    params = m.init(jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), models.IMG, jnp.float32)
    zero = np.zeros(m.n_quant_layers, np.float32)
    fp = np.asarray(m.apply(params, x, jnp.asarray(zero), jnp.float32(3.0)))
    one_layer = zero.copy()
    one_layer[0] = 1.0
    a = np.asarray(m.apply(params, x, jnp.asarray(one_layer), jnp.float32(3.0)))
    allq = np.asarray(
        m.apply(params, x, jnp.ones(m.n_quant_layers, np.float32), jnp.float32(3.0))
    )
    d_one = np.abs(a - fp).max()
    d_all = np.abs(allq - fp).max()
    assert d_one > 0
    assert d_all > d_one * 0.5  # all-layers at least comparable perturbation


def test_grads_flow_through_quantized_path():
    m = models.build("miniconvnet", "gtsrb")
    params = m.init(jax.random.PRNGKey(8))
    names = [n for n, _ in params]
    values = [v for _, v in params]
    x = jax.random.normal(jax.random.PRNGKey(9), models.IMG, jnp.float32)
    ones = jnp.ones((m.n_quant_layers,), jnp.float32)

    def loss(vals):
        logits = m.apply(list(zip(names, vals)), x, ones, jnp.float32(5.0))
        return jax.nn.logsumexp(logits) - logits[3]

    grads = jax.grad(loss)(values)
    total = sum(float(jnp.abs(g).sum()) for g in grads)
    assert np.isfinite(total) and total > 0
    # Every conv weight receives gradient.
    for n, g in zip(names, grads):
        if n.endswith("_w"):
            assert float(jnp.abs(g).max()) > 0, f"no grad for {n}"


def test_transformer_handles_tokens():
    m = models.build("tinytransformer", "snli")
    params = m.init(jax.random.PRNGKey(10))
    toks = jax.random.randint(jax.random.PRNGKey(11), (models.SEQ_LEN,), 0, models.VOCAB)
    logits = m.apply(
        params, toks, jnp.ones((m.n_quant_layers,), jnp.float32), jnp.float32(1.0)
    )
    assert logits.shape == (3,)
    assert np.isfinite(np.asarray(logits)).all()
