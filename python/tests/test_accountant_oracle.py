"""Cross-language privacy-accountant oracle.

The Rust RDP accountant (rust/src/privacy/rdp.rs) is validated against an
independent implementation of the Rényi divergence of the Sampled
Gaussian Mechanism computed here by direct numerical integration:

  A(alpha) = E_{z~nu0}[ (nu(z)/nu0(z))^alpha ],
  nu0 = N(0, sigma^2),  nu = (1-q) N(0, sigma^2) + q N(1, sigma^2),
  rdp(alpha) = log(A) / (alpha - 1)

(Mironov et al. 2019, Eq. 3-4 — this is the quantity the closed-form
binomial/series expansions in Rust compute.) The Rust values are obtained
by shelling out to `dpquant accountant --dump`.
"""

import math
import os
import shutil
import subprocess

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
BIN = os.path.join(REPO, "target", "release", "dpquant")


def rdp_numerical(q, sigma, alpha):
    """Direct numerical integration of the SGM Rényi divergence.

    Uses the max of the two directions like the Rust code's underlying
    analysis (Opacus takes E_{nu0}[(nu/nu0)^alpha], which upper-bounds
    both directions for the SGM).
    """
    # Integrate over a wide grid; for large alpha the integrand
    # exp(-z^2/2s^2 + alpha*(2z-1)/2s^2) peaks near z = alpha, so the
    # upper limit must scale with alpha.
    z = np.linspace(-30 * sigma, alpha + 30 * sigma + 1.0, 400_001)
    log_nu0 = -0.5 * ((z / sigma) ** 2) - math.log(sigma * math.sqrt(2 * math.pi))
    log_n1 = -0.5 * (((z - 1.0) / sigma) ** 2) - math.log(sigma * math.sqrt(2 * math.pi))
    # log nu = logsumexp(log(1-q)+log_nu0, log(q)+log_n1)
    a = np.log1p(-q) + log_nu0 if q < 1.0 else np.full_like(log_nu0, -np.inf)
    b = math.log(q) + log_n1
    m = np.maximum(a, b)
    log_nu = m + np.log(np.exp(a - m) + np.exp(b - m))
    # E_{nu0}[(nu/nu0)^alpha] = ∫ nu0 * exp(alpha*(log_nu - log_nu0))
    log_integrand = log_nu0 + alpha * (log_nu - log_nu0)
    # Trapezoid in linear space via stable shift.
    shift = log_integrand.max()
    integral = np.trapezoid(np.exp(log_integrand - shift), z)
    log_a = shift + math.log(integral)
    return log_a / (alpha - 1.0)


@pytest.fixture(scope="module")
def rust_dump():
    if not os.path.exists(BIN) and not shutil.which("dpquant"):
        pytest.skip("dpquant binary not built (cargo build --release)")
    exe = BIN if os.path.exists(BIN) else "dpquant"
    out = subprocess.run(
        [exe, "accountant", "--dump"], capture_output=True, text=True, check=True
    )
    rows = []
    for line in out.stdout.strip().splitlines():
        qv, sv, av, rv = line.split()
        rows.append((float(qv), float(sv), float(av), float(rv)))
    assert rows, "empty dump"
    return rows


def test_rust_rdp_matches_numerical_integration(rust_dump):
    checked = 0
    for q, sigma, alpha, rust_val in rust_dump:
        want = rdp_numerical(q, sigma, alpha)
        if want < 1e-12:
            continue
        rel = abs(rust_val - want) / max(abs(want), 1e-12)
        assert rel < 5e-3, (
            f"q={q} sigma={sigma} alpha={alpha}: rust={rust_val} oracle={want} rel={rel}"
        )
        checked += 1
    assert checked >= 80, f"only {checked} comparisons ran"


def test_full_batch_closed_form(rust_dump):
    # q = 1 rows must equal alpha / (2 sigma^2) exactly.
    for q, sigma, alpha, rust_val in rust_dump:
        if q == 1.0:
            want = alpha / (2 * sigma**2)
            assert abs(rust_val - want) < 1e-9 * max(want, 1.0)


def test_rdp_monotone_in_alpha(rust_dump):
    from collections import defaultdict

    series = defaultdict(list)
    for q, sigma, alpha, rust_val in rust_dump:
        series[(q, sigma)].append((alpha, rust_val))
    for (q, sigma), pts in series.items():
        pts.sort()
        vals = [v for _, v in pts]
        assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:])), (
            f"rdp not monotone for q={q} sigma={sigma}: {vals}"
        )
