"""DP-SGD step graph tests: clipping invariant, masking semantics,
per-sample gradient correctness vs direct autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import dp, models
from compile.model import GraphSpec

jax.config.update("jax_platform_name", "cpu")

B = 4


@pytest.fixture(scope="module")
def spec():
    return GraphSpec("miniconvnet", "cifar", "luq4", B)


@pytest.fixture(scope="module")
def step(spec):
    return jax.jit(spec.train_fn())


def make_args(spec, seed=0, mask=None, qmask=None):
    key = jax.random.PRNGKey(seed)
    ex = spec.example_spec()
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (B,) + ex.shape, jnp.float32)
    y = jax.random.randint(ky, (B,), 0, spec.model.n_classes)
    m = jnp.ones((B,), jnp.float32) if mask is None else jnp.asarray(mask, jnp.float32)
    q = (
        jnp.zeros((spec.model.n_quant_layers,), jnp.float32)
        if qmask is None
        else jnp.asarray(qmask, jnp.float32)
    )
    vals = [v for _, v in spec.params]
    return vals + [x, y, m, q, jnp.float32(seed)]


def test_output_count_and_shapes(spec, step):
    # grads... + loss_sum + correct_sum + rawnorm_sum + rawnorm_max
    out = step(*make_args(spec))
    assert len(out) == len(spec.params) + 4
    for (name, v), g in zip(spec.params, out):
        assert g.shape == v.shape, f"{name}: {g.shape} != {v.shape}"


def test_grad_sum_norm_bounded_by_batch_times_clip(spec, step):
    # Each per-sample grad is clipped to C=1; the sum of B rows has norm
    # at most B*C.
    out = step(*make_args(spec, seed=1))
    grads = out[: len(spec.params)]
    total_sq = sum(float(jnp.sum(g * g)) for g in grads)
    assert np.sqrt(total_sq) <= B * spec.clip_norm + 1e-4


def test_masked_examples_contribute_nothing(spec, step):
    full = step(*make_args(spec, seed=2, mask=[1, 1, 0, 0]))
    # Changing labels of the masked examples must not alter anything.
    args = make_args(spec, seed=2, mask=[1, 1, 0, 0])
    y = np.array(args[len(spec.params) + 1])
    y[2:] = (y[2:] + 1) % spec.model.n_classes
    args[len(spec.params) + 1] = jnp.asarray(y)
    alt = step(*args)
    for a, b in zip(full, alt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_all_masked_gives_zero(spec, step):
    out = step(*make_args(spec, seed=3, mask=[0, 0, 0, 0]))
    n = len(spec.params)
    for g in out[:n]:
        np.testing.assert_array_equal(np.asarray(g), 0.0)
    assert float(out[n]) == 0.0  # loss_sum
    assert float(out[n + 1]) == 0.0  # correct_sum
    assert float(out[n + 2]) == 0.0  # rawnorm_sum (masked out)


def test_matches_manual_per_sample_clipping(spec):
    # Reference computation with plain autodiff + numpy clipping.
    args = make_args(spec, seed=4)
    vals = args[: len(spec.params)]
    x, y = args[len(spec.params)], args[len(spec.params) + 1]
    names = spec.param_names
    loss_fn = dp.make_loss_fn(spec.model)
    qmask = args[len(spec.params) + 3]
    seed = args[len(spec.params) + 4]

    per_grads = []
    for i in range(B):
        g = jax.grad(lambda pv: loss_fn(pv, names, x[i], y[i], qmask, seed)[0])(vals)
        per_grads.append(np.concatenate([np.asarray(t).ravel() for t in g]))
    per_grads = np.stack(per_grads)
    norms = np.linalg.norm(per_grads, axis=1, keepdims=True)
    clipped = per_grads * np.minimum(1.0, spec.clip_norm / np.maximum(norms, 1e-12))
    want = clipped.sum(axis=0)

    out = jax.jit(spec.train_fn())(*args)
    got = np.concatenate([np.asarray(g).ravel() for g in out[: len(spec.params)]])
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_eval_step_counts(spec):
    ev = jax.jit(spec.eval_fn())
    key = jax.random.PRNGKey(5)
    ex = spec.example_spec()
    x = jax.random.normal(key, (B,) + ex.shape, jnp.float32)
    y = jax.random.randint(key, (B,), 0, spec.model.n_classes)
    vals = [v for _, v in spec.params]
    zq = jnp.zeros((spec.model.n_quant_layers,), jnp.float32)
    zs = jnp.float32(0)
    loss_sum, correct = ev(*(vals + [x, y, jnp.ones((B,), jnp.float32), zq, zs]))
    assert float(loss_sum) > 0
    assert 0 <= float(correct) <= B
    # Half-masked: strictly fewer (or equal) counted examples.
    loss2, correct2 = ev(*(vals + [x, y, jnp.asarray([1, 1, 0, 0], jnp.float32), zq, zs]))
    assert float(loss2) <= float(loss_sum) + 1e-6
    assert float(correct2) <= float(correct) + 1e-9


def test_quantized_step_differs_but_close(spec, step):
    fp_out = step(*make_args(spec, seed=6))
    q = np.ones(spec.model.n_quant_layers, np.float32)
    q_out = step(*make_args(spec, seed=6, qmask=q))
    # raw-norm taps present and sane
    n = len(spec.params)
    assert float(fp_out[n + 2]) > 0.0
    assert float(fp_out[n + 3]) <= float(fp_out[n + 2]) + 1e-6
    fp_flat = np.concatenate([np.asarray(g).ravel() for g in fp_out[: len(spec.params)]])
    q_flat = np.concatenate([np.asarray(g).ravel() for g in q_out[: len(spec.params)]])
    assert not np.allclose(fp_flat, q_flat), "quantization must perturb grads"
    # But the clipped-sum scale stays bounded (both obey the clip bound).
    assert np.linalg.norm(q_flat) <= B * spec.clip_norm + 1e-4
