"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Stochastic rounding consumes explicit uniform operands, so kernel-vs-ref
comparisons are exact (same draws), not statistical. Statistical
properties (unbiasedness, Prop-1 variance scaling) are tested separately
with many seeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import clip, fp8, luq, qmatmul, ref, uniform4

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, scale=1.0, offset=0.0):
    k = jax.random.PRNGKey(seed)
    return scale * jax.random.normal(k, shape, jnp.float32) + offset


def uniforms(shape, seed):
    return jax.random.uniform(jax.random.PRNGKey(seed + 1000), shape, jnp.float32)


SHAPES = [(17,), (256,), (2048,), (2049,), (8, 33), (4, 7, 11)]


@pytest.mark.parametrize("shape", SHAPES)
def test_luq4_matches_ref(shape):
    x = rand(shape, 0)
    u = uniforms(shape, 0)
    got = luq.luq4(x, u)
    want = ref.luq4_ref(x, u)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("shape", SHAPES)
def test_uniform4_matches_ref(shape):
    x = rand(shape, 1, scale=3.0)
    u = uniforms(shape, 1)
    got = uniform4.uniform4(x, u)
    want = ref.uniform4_ref(x, u)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("shape", SHAPES)
def test_fp8_matches_ref(shape):
    x = rand(shape, 2, scale=10.0)
    got = fp8.fp8(x)
    want = ref.fp8_ref(x)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([1e-6, 1e-2, 1.0, 37.5, 1e4]),
)
def test_luq4_hypothesis_shapes_scales(n, seed, scale):
    x = rand((n,), seed, scale=scale)
    u = uniforms((n,), seed)
    got = luq.luq4(x, u)
    want = ref.luq4_ref(x, u)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_uniform4_hypothesis(n, seed):
    x = rand((n,), seed, scale=5.0)
    u = uniforms((n,), seed)
    np.testing.assert_allclose(
        uniform4.uniform4(x, u), ref.uniform4_ref(x, u), rtol=1e-6, atol=1e-7
    )


def test_luq4_outputs_on_grid():
    x = rand((512,), 3)
    u = uniforms((512,), 3)
    q = np.asarray(luq.luq4(x, u))
    alpha = float(ref.luq_alpha(jnp.max(jnp.abs(x))))
    nz = q[q != 0.0]
    k = np.log2(np.abs(nz) / alpha)
    np.testing.assert_allclose(k, np.round(k), atol=1e-4)
    assert k.min() >= -1e-4 and k.max() <= 7 + 1e-4


def test_luq4_unbiased_statistically():
    # E[q(x)] ≈ x over many draws (the property Prop. 1 needs).
    x = rand((128,), 4)
    acc = np.zeros(128, np.float64)
    trials = 600
    for t in range(trials):
        u = uniforms((128,), 10_000 + t)
        acc += np.asarray(luq.luq4(x, u), np.float64)
    bias = np.abs(acc / trials - np.asarray(x, np.float64)).max()
    assert bias < 0.05, f"bias={bias}"


def test_luq4_scale_invariance_exact():
    # q(λx) with the same draws = λ q(x): alpha scales with max|x|.
    x = rand((300,), 5)
    u = uniforms((300,), 5)
    q1 = np.asarray(luq.luq4(x, u))
    q4 = np.asarray(luq.luq4(4.0 * x, u))
    np.testing.assert_allclose(q4, 4.0 * q1, rtol=1e-5, atol=1e-7)


def test_luq4_zero_tensor():
    z = jnp.zeros((64,))
    u = uniforms((64,), 6)
    np.testing.assert_array_equal(np.asarray(luq.luq4(z, u)), np.zeros(64))


def test_fp8_idempotent():
    x = rand((400,), 7, scale=3.0)
    once = fp8.fp8(x)
    twice = fp8.fp8(once)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


def test_fp8_saturates():
    x = jnp.array([1e8, -1e8, 6e4], jnp.float32)
    q = np.asarray(fp8.fp8(x))
    np.testing.assert_array_equal(q, [ref.FP8_MAX, -ref.FP8_MAX, ref.FP8_MAX])


@pytest.mark.parametrize("b,d", [(1, 8), (7, 33), (16, 256), (9, 1000)])
def test_clip_rows_matches_ref(b, d):
    g = rand((b, d), 8, scale=2.0)
    got = clip.clip_rows(g, 1.0)
    want = ref.clip_rows_ref(g, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_clip_rows_norm_invariant():
    g = rand((32, 100), 9, scale=5.0)
    clipped = np.asarray(clip.clip_rows(g, 0.7))
    norms = np.linalg.norm(clipped, axis=1)
    assert (norms <= 0.7 * (1 + 1e-5)).all()
    # Rows already under the norm are untouched.
    small = rand((4, 10), 10, scale=0.01)
    np.testing.assert_allclose(
        np.asarray(clip.clip_rows(small, 1.0)), np.asarray(small), rtol=1e-6
    )


@pytest.mark.parametrize(
    "m,k,n", [(8, 8, 8), (32, 32, 32), (33, 65, 17), (64, 128, 32), (1, 5, 3)]
)
def test_qmatmul_fp_path_exact(m, k, n):
    # enabled=0 → plain matmul (up to fp32 reassociation in tiling).
    x = rand((m, k), 11)
    w = rand((k, n), 12)
    ux = uniforms((m, k), 11)
    uw = uniforms((k, n), 12)
    got = qmatmul.qmatmul(x, w, ux, uw, 0.0)
    want = x @ w
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(32, 32, 32), (16, 48, 24)])
def test_qmatmul_quantized_matches_ref(m, k, n):
    x = rand((m, k), 13)
    w = rand((k, n), 14)
    ux = uniforms((m, k), 13)
    uw = uniforms((k, n), 14)
    got = qmatmul.qmatmul(x, w, ux, uw, 1.0)
    want = ref.qmatmul_ref(x, w, ux, uw, 1.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_qmatmul_padding_does_not_leak():
    # Non-multiple shapes: zero padding must not perturb the result.
    x = rand((5, 9), 15)
    w = rand((9, 7), 16)
    ux = uniforms((5, 9), 15)
    uw = uniforms((9, 7), 16)
    got = qmatmul.qmatmul(x, w, ux, uw, 0.0, bm=4, bn=4, bk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    # The same quantization result regardless of block partitioning.
    x = rand((1000,), 17)
    u = uniforms((1000,), 17)
    a = luq.luq4(x, u, block=128)
    b = luq.luq4(x, u, block=2048)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
