"""Quantized layers with custom VJPs (the paper's simulation setup, §A.12
/ Figure 7): the inputs of the forward op AND of both backward ops
(dgrad, wgrad) are quantize-dequantized whenever the layer is enabled for
quantization this epoch.

Each quantizable op is built by `make_qop(op)` where `op(x, w)` is linear
in both operands (dense matmul, conv). The custom VJP:

  fwd : y  = op(Q(x), Q(w))
  bwd : dx, dw = vjp(op at (Q(x), Q(w)))(Q(g))

which quantizes exactly the operand sets the paper's Figure 7 shows
(fwd: x, w; dgrad: g, w; wgrad: g, x).

`enabled` is a traced f32 scalar (one slot of the runtime `quant_mask`
input), so one compiled graph serves every quantization policy — the
coordinator flips layers epoch by epoch without recompiling. `seed` is a
traced f32 scalar; stochastic-rounding draws derive from (seed, layer_id,
operand_tag) and are shared across the vmapped batch (equivalent to
quantizing the batched tensor once, as real hardware would).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import QUANTIZERS

# Block size for the element-wise quantizer kernels inside models: small
# activations/weights are a single grid step.
QBLOCK = 2048


def _draws(seed, layer_id, tag, shape):
    """Uniform draws for stochastic rounding, keyed by (seed, layer, tag)."""
    key = jax.random.PRNGKey(seed.astype(jnp.int32))
    key = jax.random.fold_in(key, layer_id)
    key = jax.random.fold_in(key, tag)
    return jax.random.uniform(key, shape, jnp.float32)


def make_gate_q(quantizer_name):
    """Build `gate_q(x, enabled, seed, layer_id, tag)`: quantize-dequantize
    `x` through the L1 Pallas kernel, blended with the fp path by
    `enabled` ∈ {0,1}."""
    qfn = QUANTIZERS[quantizer_name]

    def gate_q(x, enabled, seed, layer_id, tag):
        u = _draws(seed, layer_id, tag, x.shape)
        qx = qfn(x, u, block=QBLOCK)
        return enabled * qx + (1.0 - enabled) * x

    return gate_q


def make_qop(op, quantizer_name):
    """Wrap a bilinear `op(x, w) -> y` with quantized fwd/dgrad/wgrad.

    Returns `qop(x, w, enabled, seed, layer_id)`.
    `layer_id` must be a static python int (used for PRNG folding).
    """
    gate_q = make_gate_q(quantizer_name)

    @functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
    def qop(x, w, enabled, seed, layer_id):
        qx = gate_q(x, enabled, seed, layer_id, 0)
        qw = gate_q(w, enabled, seed, layer_id, 1)
        return op(qx, qw)

    def qop_fwd(x, w, enabled, seed, layer_id):
        y = qop(x, w, enabled, seed, layer_id)
        return y, (x, w, enabled, seed)

    def qop_bwd(layer_id, res, g):
        x, w, enabled, seed = res
        # Backward operand quantization (dgrad: g, w — wgrad: g, x).
        qg = gate_q(g, enabled, seed, layer_id, 2)
        qx = gate_q(x, enabled, seed, layer_id, 3)
        qw = gate_q(w, enabled, seed, layer_id, 4)
        _, vjp = jax.vjp(op, qx, qw)
        dx, dw = vjp(qg)
        return dx, dw, jnp.zeros(()), jnp.zeros(())

    qop.defvjp(qop_fwd, qop_bwd)
    return qop


# ---------------------------------------------------------------------------
# Concrete bilinear ops (per-example: no batch dimension; the DP step
# vmaps over examples).
# ---------------------------------------------------------------------------


def dense_op(x, w):
    """x: (..., din) @ w: (din, dout)."""
    return x @ w


def conv3x3_op(x, w):
    """x: (H, W, Cin), w: (3, 3, Cin, Cout) — SAME padding, stride 1."""
    return lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]


# ---------------------------------------------------------------------------
# Non-quantized building blocks (cheap elementwise ops the paper leaves in
# full precision — its "overhead ops", §A.13).
# ---------------------------------------------------------------------------


def group_norm(x, scale, bias, groups=4, eps=1e-5):
    """GroupNorm over the channel axis of (H, W, C) — the BN replacement
    standard in DP training (BatchNorm mixes examples and breaks
    per-sample gradients)."""
    h, w, c = x.shape
    g = min(groups, c)
    while c % g:  # largest divisor of c not exceeding `groups`
        g -= 1
    xg = x.reshape(h, w, g, c // g)
    mean = xg.mean(axis=(0, 1, 3), keepdims=True)
    var = xg.var(axis=(0, 1, 3), keepdims=True)
    xn = ((xg - mean) / jnp.sqrt(var + eps)).reshape(h, w, c)
    return xn * scale + bias


def layer_norm(x, scale, bias, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def avg_pool2(x):
    """2x2 average pooling on (H, W, C)."""
    h, w, c = x.shape
    return x.reshape(h // 2, 2, w // 2, 2, c).mean(axis=(1, 3))


def global_avg_pool(x):
    return x.mean(axis=(0, 1))


def relu(x):
    return jnp.maximum(x, 0.0)


def softmax_cross_entropy(logits, label, n_classes):
    """Scalar CE loss for one example."""
    logz = jax.nn.logsumexp(logits)
    onehot_logit = logits[label]
    del n_classes
    return logz - onehot_logit
