"""L2 graph builders: tie a model from `models.py` to the DP-SGD step in
`dp.py` and describe everything the Rust runtime needs (shapes, names,
parameter layout) for the artifact manifest."""

import jax
import jax.numpy as jnp
import numpy as np

from . import dp, models


class GraphSpec:
    """A fully-specified (model, dataset, quantizer, batch) training graph
    ready for AOT lowering."""

    def __init__(self, model_name, dataset, quantizer, batch, clip_norm=1.0, seed=0):
        self.model_name = model_name
        self.dataset = dataset
        self.quantizer = quantizer
        self.batch = batch
        self.clip_norm = clip_norm
        self.model = models.build(model_name, dataset, quantizer)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.param_names = [n for n, _ in self.params]
        self.param_shapes = [tuple(v.shape) for _, v in self.params]

    # ----- example/batch specs -----------------------------------------------
    def example_spec(self):
        return self.model.input_spec()

    def batch_specs(self):
        ex = self.example_spec()
        x = jax.ShapeDtypeStruct((self.batch,) + ex.shape, ex.dtype)
        y = jax.ShapeDtypeStruct((self.batch,), jnp.int32)
        mask = jax.ShapeDtypeStruct((self.batch,), jnp.float32)
        return x, y, mask

    def param_specs(self):
        return [jax.ShapeDtypeStruct(v.shape, v.dtype) for _, v in self.params]

    # ----- lowerable callables -------------------------------------------------
    def train_fn(self):
        step = dp.make_train_step(self.model, self.clip_norm)
        nparams = len(self.params)

        def fn(*args):
            param_values = list(args[:nparams])
            x, y, mask, qmask, seed = args[nparams : nparams + 5]
            return step(param_values, x, y, mask, qmask, seed)

        return fn

    def train_arg_specs(self):
        x, y, mask = self.batch_specs()
        qmask = jax.ShapeDtypeStruct((self.model.n_quant_layers,), jnp.float32)
        seed = jax.ShapeDtypeStruct((), jnp.float32)
        return self.param_specs() + [x, y, mask, qmask, seed]

    def eval_fn(self):
        step = dp.make_eval_step(self.model)
        nparams = len(self.params)

        def fn(*args):
            param_values = list(args[:nparams])
            x, y, mask, qmask, seed = args[nparams : nparams + 5]
            return step(param_values, x, y, mask, qmask, seed)

        return fn

    def eval_arg_specs(self):
        x, y, mask = self.batch_specs()
        qmask = jax.ShapeDtypeStruct((self.model.n_quant_layers,), jnp.float32)
        seed = jax.ShapeDtypeStruct((), jnp.float32)
        return self.param_specs() + [x, y, mask, qmask, seed]

    # ----- initial weights + manifest ------------------------------------------
    def initial_weights_flat(self):
        """Concatenate initial parameter values (f32 little-endian order)."""
        return np.concatenate([np.asarray(v, np.float32).ravel() for _, v in self.params])

    def manifest_entry(self, train_name, eval_name, weights_file):
        ex = self.example_spec()
        dtype = ex.dtype.name if hasattr(ex.dtype, "name") else str(ex.dtype)
        return {
            "model": self.model_name,
            "dataset": self.dataset,
            "quantizer": self.quantizer,
            "batch": self.batch,
            "clip_norm": self.clip_norm,
            "n_classes": self.model.n_classes,
            "n_quant_layers": self.model.n_quant_layers,
            "quant_layer_names": list(self.model.layer_names),
            "example_shape": list(ex.shape),
            "example_dtype": dtype,
            "params": [
                {"name": n, "shape": list(s)}
                for n, s in zip(self.param_names, self.param_shapes)
            ],
            "train_hlo": f"{train_name}.hlo.txt",
            "eval_hlo": f"{eval_name}.hlo.txt",
            "weights": weights_file,
        }
