"""DP-SGD training-step graph: per-sample gradients, global-norm
clipping (through the L1 Pallas clip kernel), masked aggregation.

The graph implements everything inside the paper's Def. 2 *except* noise
addition and the weight update — those happen in Rust in fp32 (§A.17:
noise must be added to full-precision gradients by the coordinator, the
single audited RNG site). Outputs are the per-tensor sums of clipped
per-example gradients plus (masked) loss sum and correct count.

Poisson subsampling produces variable-size batches; the graph has a fixed
physical batch `B` and takes an `example_mask` input that zeroes padding
rows, so one compiled executable serves every batch.
"""

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import clip as clip_kernel


def make_loss_fn(model):
    """Per-example loss: (params_list, x, y, quant_mask, seed) ->
    (loss, correct). `correct` rides along as an aux output so the train
    step needs exactly one forward per example (no second full-precision
    forward — it would double compute and bake a constant quant mask into
    the graph, which XLA 0.5.1's constant folder chokes on)."""

    def loss_fn(param_values, param_names, x, y, quant_mask, seed):
        params = list(zip(param_names, param_values))
        logits = model.apply(params, x, quant_mask, seed)
        loss = L.softmax_cross_entropy(logits, y, model.n_classes)
        correct = (jnp.argmax(logits) == y).astype(jnp.float32)
        return loss, correct

    return loss_fn


def make_train_step(model, clip_norm):
    """Build the DP-SGD step.

    Signature of the returned function (all jnp arrays):
      (param_values..., x_batch, y_batch, example_mask, quant_mask, seed)
        -> (clipped_grad_sums..., loss_sum, correct_sum,
            rawnorm_sum, rawnorm_max)

    The last two outputs are the sum and max over the (masked) batch of
    the *pre-clip* per-sample gradient L2 norms — the quantity Figures
    1b/1c and Table 2 of the paper study (DP noise inflates raw
    gradients in subsequent iterations).

    - `x_batch`: (B, *example_shape); `y_batch`: (B,) int32.
    - `example_mask`: (B,) f32 in {0,1}; padding rows contribute nothing.
    - `quant_mask`: (n_quant_layers,) f32 in {0,1}.
    - `seed`: f32 scalar driving stochastic rounding.
    """
    param_names = [n for n, _ in model.init(jax.random.PRNGKey(0))]
    loss_fn = make_loss_fn(model)

    def step(param_values, x_batch, y_batch, example_mask, quant_mask, seed):
        def per_example(x, y):
            (loss, correct), grads = jax.value_and_grad(
                lambda pv: loss_fn(pv, param_names, x, y, quant_mask, seed),
                has_aux=True,
            )(param_values)
            return loss, grads, correct

        losses, grads, corrects = jax.vmap(per_example)(x_batch, y_batch)

        # Flatten per-sample grads to (B, P) and clip rows to norm C via
        # the L1 Pallas kernel.
        b = x_batch.shape[0]
        flats = [g.reshape(b, -1) for g in grads]
        sizes = [f.shape[1] for f in flats]
        flat = jnp.concatenate(flats, axis=1)
        raw_norms = jnp.sqrt(jnp.sum(flat * flat, axis=1)) * example_mask
        clipped = clip_kernel.clip_rows(flat, clip_norm)

        # Zero padding rows, then sum over the batch.
        summed = jnp.sum(clipped * example_mask[:, None], axis=0)

        # Split back into per-tensor grad sums.
        outs = []
        off = 0
        for g, size in zip(grads, sizes):
            outs.append(summed[off : off + size].reshape(g.shape[1:]))
            off += size

        loss_sum = jnp.sum(losses * example_mask)
        correct_sum = jnp.sum(corrects * example_mask)
        return tuple(outs) + (
            loss_sum,
            correct_sum,
            jnp.sum(raw_norms),
            jnp.max(raw_norms),
        )

    return step


def make_eval_step(model):
    """Evaluation over a (masked) batch.

    (param_values..., x_batch, y_batch, example_mask, quant_mask, seed)
      -> (loss_sum, correct_sum)

    `quant_mask`/`seed` are runtime inputs (all-zeros for the standard
    full-precision eval) rather than baked constants: XLA 0.5.1's
    constant folder recurses into the pallas grid loops when the PRNG
    seed is a literal and aborts with a foreign exception. Keeping them
    as parameters also enables quantized-eval experiments for free.
    """
    param_names = [n for n, _ in model.init(jax.random.PRNGKey(0))]

    def step(param_values, x_batch, y_batch, example_mask, zero_mask, seed):
        def per_example(x, y):
            params = list(zip(param_names, param_values))
            logits = model.apply(params, x, zero_mask, seed)
            loss = L.softmax_cross_entropy(logits, y, model.n_classes)
            correct = (jnp.argmax(logits) == y).astype(jnp.float32)
            return loss, correct

        losses, corrects = jax.vmap(per_example)(x_batch, y_batch)
        return jnp.sum(losses * example_mask), jnp.sum(corrects * example_mask)

    return step
