"""AOT export: lower every (model, dataset, quantizer) training/eval graph
to HLO **text** and write `artifacts/manifest.json` + initial weights.

HLO text — NOT `lowered.compile()` or proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run: `cd python && python -m compile.aot --out ../artifacts`
"""

import argparse
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .model import GraphSpec

jax.config.update("jax_platform_name", "cpu")

# The artifact matrix: the paper's (model, dataset) combinations mapped to
# our stand-ins (DESIGN.md §2), with extra quantizers where the appendix
# evaluates them (A.9: fp8 + uniform4 on ResNet18-class models).
DEFAULT_MATRIX = [
    # (model, dataset, quantizer, physical_batch)
    ("miniconvnet", "gtsrb", "luq4", 64),
    ("miniconvnet", "emnist", "luq4", 64),
    ("miniconvnet", "cifar", "luq4", 64),
    ("miniresnet", "gtsrb", "luq4", 64),
    ("miniresnet", "cifar", "luq4", 64),
    ("miniresnet", "cifar", "uniform4", 64),
    ("miniresnet", "cifar", "fp8", 64),
    ("minidensenet", "gtsrb", "luq4", 64),
    ("minidensenet", "cifar", "luq4", 64),
    ("tinytransformer", "snli", "luq4", 64),
]


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spec(spec: GraphSpec, out_dir: str, manifest: dict, verbose=True):
    tag = f"{spec.model_name}_{spec.dataset}_{spec.quantizer}"
    train_name = f"train_{tag}"
    eval_name = f"eval_{spec.model_name}_{spec.dataset}"
    weights_file = f"weights_{spec.model_name}_{spec.dataset}.bin"

    t0 = time.time()
    train_lowered = jax.jit(spec.train_fn()).lower(*spec.train_arg_specs())
    train_text = to_hlo_text(train_lowered)
    with open(os.path.join(out_dir, f"{train_name}.hlo.txt"), "w") as f:
        f.write(train_text)
    if verbose:
        print(f"  {train_name}: {len(train_text)} chars ({time.time()-t0:.1f}s)")

    # Eval + weights are shared across quantizers of the same
    # (model, dataset); emit once.
    emitted = manifest.setdefault("_emitted_evals", set())
    if eval_name not in emitted:
        t0 = time.time()
        eval_lowered = jax.jit(spec.eval_fn()).lower(*spec.eval_arg_specs())
        eval_text = to_hlo_text(eval_lowered)
        with open(os.path.join(out_dir, f"{eval_name}.hlo.txt"), "w") as f:
            f.write(eval_text)
        flat = spec.initial_weights_flat()
        flat.astype("<f4").tofile(os.path.join(out_dir, weights_file))
        emitted.add(eval_name)
        if verbose:
            print(
                f"  {eval_name}: {len(eval_text)} chars, "
                f"{flat.size} init params ({time.time()-t0:.1f}s)"
            )

    manifest["graphs"][tag] = spec.manifest_entry(train_name, eval_name, weights_file)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated model_dataset_quantizer tags to build (default all)",
    )
    ap.add_argument("--batch", type=int, default=None, help="override physical batch")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"graphs": {}}
    if args.only and os.path.exists(manifest_path):
        # Incremental rebuild: keep other graphs' entries.
        with open(manifest_path) as f:
            manifest = json.load(f)
    only = set(args.only.split(",")) if args.only else None

    for model, dataset, quantizer, batch in DEFAULT_MATRIX:
        tag = f"{model}_{dataset}_{quantizer}"
        if only and tag not in only:
            continue
        if args.batch:
            batch = args.batch
        print(f"lowering {tag} (batch={batch}) ...")
        spec = GraphSpec(model, dataset, quantizer, batch)
        lower_spec(spec, args.out, manifest)

    manifest.pop("_emitted_evals", None)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} with {len(manifest['graphs'])} graphs")


if __name__ == "__main__":
    sys.exit(main())
