"""L1: Pallas quantizer kernels (interpret=True) + pure-jnp oracles.

Public surface:
  luq.luq4        — LUQ-FP4 quantize-dequantize (paper's primary format)
  uniform4.uniform4 — uniform INT4 with stochastic rounding (§A.9.2)
  fp8.fp8         — FP8-E5M2 round-to-nearest-even (§A.9.1)
  clip.clip_rows  — per-sample L2 clipping
  qmatmul.qmatmul — tiled matmul with LUQ-quantized operands
  ref             — the correctness oracles for all of the above
"""

from . import clip, common, fp8, luq, qmatmul, ref, uniform4  # noqa: F401

QUANTIZERS = {
    "luq4": luq.luq4,
    "uniform4": uniform4.uniform4,
    "fp8": fp8.fp8,
}

REFS = {
    "luq4": ref.luq4_ref,
    "uniform4": ref.uniform4_ref,
    "fp8": lambda x, u: ref.fp8_ref(x),
}
