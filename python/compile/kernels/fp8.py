"""FP8-E5M2 Pallas kernel (paper §A.9.1): round-to-nearest-even to
5-exponent/2-mantissa floats via bit manipulation, saturating at 57344.
Deterministic — no random operand. Must match `ref.fp8_ref` exactly."""

import jax.lax as lax
import jax.numpy as jnp

from .common import BLOCK, elementwise_call
from .ref import FP8_MAX, FP8_MIN_NORMAL


def _fp8_kernel(x_ref, o_ref):
    x = x_ref[...]
    clamped = jnp.clip(x, -FP8_MAX, FP8_MAX)
    bits = lax.bitcast_convert_type(clamped, jnp.uint32)
    drop = jnp.uint32(21)
    one = jnp.uint32(1)
    lsb = (bits >> drop) & one
    round_add = (one << (drop - one)) - one + lsb
    rounded = (bits + round_add) & ~((one << drop) - one)
    y = lax.bitcast_convert_type(rounded, jnp.float32)
    y = jnp.clip(y, -FP8_MAX, FP8_MAX)
    sub_step = FP8_MIN_NORMAL / 4.0
    y_sub = jnp.round(y / sub_step) * sub_step
    y = jnp.where(jnp.abs(y) < FP8_MIN_NORMAL, y_sub, y)
    o_ref[...] = jnp.where(x == 0.0, 0.0, y)


def fp8(x, u=None, block=BLOCK, interpret=True):
    """FP8-E5M2 quantize-dequantize. `u` accepted (ignored) for a uniform
    quantizer interface."""
    del u
    x = jnp.asarray(x, jnp.float32)
    return elementwise_call(_fp8_kernel, x, [], block=block, interpret=interpret)
