"""Pure-jnp reference oracles for every L1 Pallas kernel.

These implement the quantizer semantics with plain jax.numpy only — no
pallas — and are the correctness contract: each kernel in this package
must match its oracle bit-for-bit given the same uniform random draws
(stochastic rounding consumes explicit random inputs, so the comparison
is exact, not statistical).

Formats (paper §6 "Low Precision Format", §A.9):
  * LUQ-FP4  — 1 sign + 3 exponent bits: grid {0} ∪ {±alpha·2^k, k=0..7},
    alpha = max|x| / 2^7; stochastic underflow pruning below alpha and
    stochastic log-domain rounding above (Chmiel et al. 2024).
  * uniform4 — 16 evenly spaced levels over [-max, max] with stochastic
    rounding (§A.9.2).
  * fp8 (E5M2) — round-to-nearest-even to 5-exponent/2-mantissa floats,
    saturating at 57344 (§A.9.1). Deterministic.
"""

import jax.numpy as jnp

EXP_LEVELS = 8  # 3 exponent bits
FP8_MAX = 57344.0
FP8_MIN_NORMAL = 2.0 ** -14


def luq_alpha(max_abs):
    """Underflow threshold alpha for a tensor with given max magnitude."""
    return max_abs / (2.0 ** (EXP_LEVELS - 1))


def luq4_ref(x, u):
    """LUQ-FP4 quantize-dequantize. `u` ~ U[0,1), same shape as `x`."""
    x = jnp.asarray(x, jnp.float32)
    max_abs = jnp.max(jnp.abs(x))
    alpha = luq_alpha(max_abs)
    sign = jnp.sign(x)
    mag = jnp.abs(x)

    # Stochastic underflow: |x| < alpha -> sign*alpha w.p. mag/alpha else 0.
    under = jnp.where(u * alpha < mag, sign * alpha, 0.0)

    # Log-domain stochastic rounding for alpha <= |x| <= max.
    safe_mag = jnp.maximum(mag, 1e-30)
    safe_alpha = jnp.maximum(alpha, 1e-30)
    k = jnp.floor(jnp.log2(safe_mag / safe_alpha))
    k = jnp.clip(k, 0.0, float(EXP_LEVELS - 1))
    lo = safe_alpha * jnp.exp2(k)
    hi = safe_alpha * jnp.exp2(k + 1.0)
    top = safe_alpha * (2.0 ** (EXP_LEVELS - 1))
    p_up = (mag - lo) / (hi - lo)
    rounded = jnp.where(u < p_up, hi, lo)
    rounded = jnp.minimum(rounded, top)  # max element maps to itself
    above = sign * rounded

    out = jnp.where(mag < alpha, under, above)
    return jnp.where((mag == 0.0) | (max_abs == 0.0), 0.0, out).astype(jnp.float32)


def uniform4_ref(x, u):
    """Symmetric uniform INT4 (16 levels) with stochastic rounding."""
    x = jnp.asarray(x, jnp.float32)
    max_abs = jnp.max(jnp.abs(x))
    step = 2.0 * max_abs / 15.0
    safe = jnp.where(step == 0.0, 1.0, step)
    t = x / safe
    lo = jnp.floor(t)
    frac = t - lo
    rounded = jnp.where(u < frac, lo + 1.0, lo)
    return jnp.where(step == 0.0, 0.0, rounded * safe).astype(jnp.float32)


def fp8_ref(x):
    """FP8-E5M2 quantize-dequantize, round-to-nearest-even, saturating."""
    x = jnp.asarray(x, jnp.float32)
    clamped = jnp.clip(x, -FP8_MAX, FP8_MAX)
    bits = clamped.view(jnp.uint32)
    drop = jnp.uint32(23 - 2)
    one = jnp.uint32(1)
    lsb = (bits >> drop) & one
    round_add = (one << (drop - one)) - one + lsb
    rounded = (bits + round_add) & ~((one << drop) - one)
    y = rounded.view(jnp.float32)
    y = jnp.clip(y, -FP8_MAX, FP8_MAX)
    # Subnormal band: snap to grid of step 2^-16.
    sub_step = FP8_MIN_NORMAL / 4.0
    y_sub = jnp.round(y / sub_step) * sub_step
    y = jnp.where(jnp.abs(y) < FP8_MIN_NORMAL, y_sub, y)
    return jnp.where(x == 0.0, 0.0, y).astype(jnp.float32)


def clip_rows_ref(g, clip_norm):
    """Per-row (per-sample) L2 clipping: scale row i by min(1, C/||g_i||)."""
    g = jnp.asarray(g, jnp.float32)
    norms = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))
    return g * scale


def qmatmul_ref(x, w, u_x, u_w, enabled):
    """Quantized matmul oracle: LUQ-quantize both operands iff enabled."""
    xq = jnp.where(enabled > 0.5, luq4_ref(x, u_x), x)
    wq = jnp.where(enabled > 0.5, luq4_ref(w, u_w), w)
    return xq @ wq
