"""Quantized matmul Pallas kernel — the MXU-targeted compute hot-spot.

The paper's GPU simulation wraps conv/matmul operands with
quantize-dequantize (Fig. 7). On a TPU-shaped machine the analogous
design is a tiled matmul whose operand tiles are LUQ-quantized on the
VMEM load path, with fp32 accumulation on the MXU:

  grid = (M/bm, N/bn, K/bk)
  x tile (bm, bk) indexed (i, k);  w tile (bk, bn) indexed (k, j)
  o tile (bm, bn) indexed (i, j); accumulated over the k grid axis.

`enabled` is a runtime scalar so the same compiled kernel serves both the
quantized and full-precision paths (DPQuant flips layers epoch-by-epoch).
Per-tensor alphas (max|x|/2^7) are computed in L2 and broadcast; this is
what a production two-pass kernel would do, and it keeps tiles pure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EXP_LEVELS


def _luq_tile(x, u, max_abs):
    """LUQ-FP4 quantize-dequantize one tile (same math as luq.py)."""
    alpha = max_abs / (2.0 ** (EXP_LEVELS - 1))
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    under = jnp.where(u * alpha < mag, sign * alpha, 0.0)
    safe_mag = jnp.maximum(mag, 1e-30)
    safe_alpha = jnp.maximum(alpha, 1e-30)
    k = jnp.clip(jnp.floor(jnp.log2(safe_mag / safe_alpha)), 0.0, float(EXP_LEVELS - 1))
    lo = safe_alpha * jnp.exp2(k)
    hi = safe_alpha * jnp.exp2(k + 1.0)
    top = safe_alpha * (2.0 ** (EXP_LEVELS - 1))
    p_up = (mag - lo) / (hi - lo)
    above = sign * jnp.minimum(jnp.where(u < p_up, hi, lo), top)
    out = jnp.where(mag < alpha, under, above)
    return jnp.where((mag == 0.0) | (max_abs == 0.0), 0.0, out)


def _qmatmul_kernel(x_ref, w_ref, ux_ref, uw_ref, ax_ref, aw_ref, en_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    en = en_ref[0]
    x = x_ref[...]
    w = w_ref[...]
    xq = en * _luq_tile(x, ux_ref[...], ax_ref[0]) + (1.0 - en) * x
    wq = en * _luq_tile(w, uw_ref[...], aw_ref[0]) + (1.0 - en) * w
    # fp32 accumulate — the MXU's native accumulation width for bf16/fp8
    # operands; tiles stay in VMEM across the k loop.
    o_ref[...] += jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def _pad2(x, bm, bn):
    m, n = x.shape
    pm = ((m + bm - 1) // bm) * bm
    pn = ((n + bn - 1) // bn) * bn
    return jnp.pad(x, ((0, pm - m), (0, pn - n)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def qmatmul(x, w, u_x, u_w, enabled, bm=32, bn=32, bk=32, interpret=True):
    """`(x @ w)` with LUQ-FP4-quantized operands when `enabled > 0.5`.

    x: (M, K); w: (K, N); u_x/u_w: uniform draws, same shapes;
    enabled: scalar f32 in {0, 1}.
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, f"shape mismatch {x.shape} @ {w.shape}"

    ax = jnp.max(jnp.abs(x)).reshape(1)
    aw = jnp.max(jnp.abs(w)).reshape(1)
    en = jnp.reshape(jnp.asarray(enabled, jnp.float32), (1,))

    xp = _pad2(x, bm, bk)
    wp = _pad2(w, bk, bn)
    uxp = _pad2(jnp.asarray(u_x, jnp.float32), bm, bk)
    uwp = _pad2(jnp.asarray(u_w, jnp.float32), bk, bn)
    gm, gk = xp.shape[0] // bm, xp.shape[1] // bk
    gn = wp.shape[1] // bn

    scalar = pl.BlockSpec((1,), lambda i, j, k: (0,))
    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            scalar,
            scalar,
            scalar,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=interpret,
    )(xp, wp, uxp, uwp, ax, aw, en)
    return out[:m, :n]
