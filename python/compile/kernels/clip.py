"""Per-sample gradient clipping Pallas kernel.

DP-SGD clips each example's gradient to L2 norm at most C before
aggregation (paper Def. 2). This kernel performs the row-wise rescale
`g_i <- g_i * min(1, C / ||g_i||_2)` over a (batch, dim) matrix of
flattened per-sample gradients.

Schedule: the row dimension is tiled (`ROWS` rows per grid step); the
feature dimension stays whole inside a block — per-sample gradient rows
for our models fit comfortably in VMEM, so the reduction needs no
second pass. Must match `ref.clip_rows_ref`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8


def _clip_kernel(g_ref, c_ref, o_ref):
    g = g_ref[...]
    c = c_ref[0]
    norms = jnp.sqrt(jnp.sum(g * g, axis=1, keepdims=True))
    scale = jnp.minimum(1.0, c / jnp.maximum(norms, 1e-12))
    o_ref[...] = g * scale


def clip_rows(g, clip_norm, rows=ROWS, interpret=True):
    """Clip each row of `g` (batch, dim) to L2 norm at most `clip_norm`."""
    g = jnp.asarray(g, jnp.float32)
    b, d = g.shape
    padded_b = ((b + rows - 1) // rows) * rows
    gp = jnp.pad(g, ((0, padded_b - b), (0, 0)))
    out = pl.pallas_call(
        _clip_kernel,
        grid=(padded_b // rows,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, d), jnp.float32),
        interpret=interpret,
    )(gp, jnp.reshape(jnp.asarray(clip_norm, jnp.float32), (1,)))
    return out[:b]
