"""Shared plumbing for the L1 Pallas kernels.

Every element-wise quantizer kernel follows the same schedule: the tensor
is flattened, padded to a multiple of the block size, and streamed through
VMEM-sized 1-D blocks (`BlockSpec((BLOCK,), ...)`), one grid step per
block. Per-tensor statistics (max|x|) are computed in L2 and passed in as
a (1,)-shaped operand broadcast to every block — this mirrors how a
two-pass TPU kernel would stage the reduction, and keeps the kernel pure.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers identical semantics to plain HLO (see
DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default element-wise block: 8 KiB of f32 per operand — small enough to
# double-buffer in VMEM (~16 MiB) with wide margins at realistic sizes,
# large enough that grid overhead is negligible.
BLOCK = 2048


def pad_flat(x, block=BLOCK):
    """Flatten `x` and zero-pad to a multiple of `block`.

    Returns (padded_1d, original_size).
    """
    flat = jnp.ravel(x)
    n = flat.shape[0]
    padded = ((n + block - 1) // block) * block
    return jnp.pad(flat, (0, padded - n)), n


def unpad(flat, n, shape):
    """Undo `pad_flat`."""
    return jnp.reshape(flat[:n], shape)


def elementwise_call(kernel, x, extras, block=BLOCK, interpret=True):
    """Run an element-wise Pallas `kernel` over `x` with per-block streams.

    `extras` is a list of (array, is_scalar) operands; scalar operands are
    shaped (1,) and broadcast to every block, array operands must have
    x's shape and are streamed with the same BlockSpec.
    """
    xf, n = pad_flat(x, block)
    nblocks = xf.shape[0] // block

    stream_spec = pl.BlockSpec((block,), lambda i: (i,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))

    ops = [xf]
    specs = [stream_spec]
    for arr, is_scalar in extras:
        if is_scalar:
            ops.append(jnp.reshape(arr, (1,)).astype(jnp.float32))
            specs.append(scalar_spec)
        else:
            af, _ = pad_flat(arr, block)
            ops.append(af)
            specs.append(stream_spec)

    out = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=specs,
        out_specs=stream_spec,
        out_shape=jax.ShapeDtypeStruct(xf.shape, jnp.float32),
        interpret=interpret,
    )(*ops)
    return unpad(out, n, x.shape)
