"""Uniform INT4 Pallas kernel (paper §A.9.2): 16 evenly spaced levels
over [-max|x|, max|x|] with stochastic rounding. Must match
`ref.uniform4_ref` exactly given the same draws."""

import jax.numpy as jnp

from .common import BLOCK, elementwise_call


def _uniform4_kernel(x_ref, u_ref, maxabs_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    max_abs = maxabs_ref[0]
    step = 2.0 * max_abs / 15.0
    safe = jnp.where(step == 0.0, 1.0, step)
    t = x / safe
    lo = jnp.floor(t)
    frac = t - lo
    rounded = jnp.where(u < frac, lo + 1.0, lo)
    o_ref[...] = jnp.where(step == 0.0, 0.0, rounded * safe)


def uniform4(x, u, block=BLOCK, interpret=True):
    """Uniform-INT4 quantize-dequantize `x` with uniform draws `u`."""
    x = jnp.asarray(x, jnp.float32)
    max_abs = jnp.max(jnp.abs(x)).reshape(1)
    return elementwise_call(
        _uniform4_kernel, x, [(u, False), (max_abs, True)], block=block, interpret=interpret
    )
