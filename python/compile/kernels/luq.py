"""LUQ-FP4 Pallas kernel: logarithmic unbiased quantization to
1-sign + 3-exponent-bit floats (Chmiel et al. 2024), the paper's primary
format.

Grid semantics (must match `ref.luq4_ref` exactly):
  alpha = max|x| / 2^7
  |x| <  alpha : -> sign(x)*alpha w.p. |x|/alpha, else 0   (stochastic prune)
  |x| >= alpha : stochastic rounding between adjacent octaves
                 lo = alpha*2^k, hi = alpha*2^(k+1), P(up) = (|x|-lo)/(hi-lo)

The per-tensor max is computed in L2 (one jnp.max) and broadcast to every
block as a (1,) operand; the kernel body is pure element-wise VPU work.
Random draws `u` ~ U[0,1) are an explicit operand so the kernel is
deterministic and exactly testable against the oracle.
"""

import jax.numpy as jnp

from .common import BLOCK, elementwise_call
from .ref import EXP_LEVELS


def _luq4_kernel(x_ref, u_ref, maxabs_ref, o_ref):
    x = x_ref[...]
    u = u_ref[...]
    max_abs = maxabs_ref[0]
    alpha = max_abs / (2.0 ** (EXP_LEVELS - 1))

    sign = jnp.sign(x)
    mag = jnp.abs(x)

    # Stochastic underflow pruning (unbiased): E[q] = mag.
    under = jnp.where(u * alpha < mag, sign * alpha, 0.0)

    # Log-domain stochastic rounding between octaves.
    safe_mag = jnp.maximum(mag, 1e-30)
    safe_alpha = jnp.maximum(alpha, 1e-30)
    k = jnp.floor(jnp.log2(safe_mag / safe_alpha))
    k = jnp.clip(k, 0.0, float(EXP_LEVELS - 1))
    lo = safe_alpha * jnp.exp2(k)
    hi = safe_alpha * jnp.exp2(k + 1.0)
    top = safe_alpha * (2.0 ** (EXP_LEVELS - 1))
    p_up = (mag - lo) / (hi - lo)
    rounded = jnp.minimum(jnp.where(u < p_up, hi, lo), top)
    above = sign * rounded

    out = jnp.where(mag < alpha, under, above)
    o_ref[...] = jnp.where((mag == 0.0) | (max_abs == 0.0), 0.0, out)


def luq4(x, u, block=BLOCK, interpret=True):
    """LUQ-FP4 quantize-dequantize `x` with uniform draws `u` (same shape)."""
    x = jnp.asarray(x, jnp.float32)
    max_abs = jnp.max(jnp.abs(x)).reshape(1)
    return elementwise_call(
        _luq4_kernel, x, [(u, False), (max_abs, True)], block=block, interpret=interpret
    )
