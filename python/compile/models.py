"""Model zoo: scaled-down analogues of the paper's networks (see
DESIGN.md §2 for the substitution rationale).

  miniconvnet    — plain CNN, 8 quantizable layers   (≈ ResNet18 stand-in)
  miniresnet     — residual CNN, 10 quantizable layers (ResNet18/50)
  minidensenet   — densely connected CNN, 12 quantizable layers (DenseNet121)
  tinytransformer— frozen embedding + 1 trainable block + head, 7
                   quantizable layers (BERT/SNLI with 12/13 layers frozen)

Every model exposes:
  init(key)            -> params: list[(name, jnp.ndarray)]
  apply(params, x, quant_mask, seed) -> logits   (per-example, no batch dim)
  n_quant_layers       -> number of quant_mask slots
  layer_names          -> names of the quantizable layers (mask order)
  input_spec()         -> ShapeDtypeStruct of one example

All image models share a 16x16x3 input; class count comes from the
dataset. Parameters are a flat ordered list (not a dict) so the Rust
runtime can address tensors positionally.
"""

import jax
import jax.numpy as jnp

from . import layers as L

IMG = (16, 16, 3)
SEQ_LEN = 24
VOCAB = 64


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


class _Base:
    def __init__(self, n_classes, quantizer):
        self.n_classes = n_classes
        self.quantizer = quantizer
        self.qdense = L.make_qop(L.dense_op, quantizer)
        self.qconv = L.make_qop(L.conv3x3_op, quantizer)

    def param_names(self):
        return [n for n, _ in self.init(jax.random.PRNGKey(0))]


class MiniConvNet(_Base):
    """Plain CNN: 6 conv + 2 dense quantizable layers."""

    CHANNELS = [(3, 8), (8, 8), (8, 16), (16, 16), (16, 32), (32, 32)]
    n_quant_layers = 8
    layer_names = [f"conv{i+1}" for i in range(6)] + ["fc1", "fc2"]

    def input_spec(self):
        return jax.ShapeDtypeStruct(IMG, jnp.float32)

    def init(self, key):
        params = []
        for i, (cin, cout) in enumerate(self.CHANNELS):
            key, k1 = jax.random.split(key)
            params.append((f"conv{i+1}_w", _he(k1, (3, 3, cin, cout), 9 * cin)))
            params.append((f"gn{i+1}_scale", jnp.ones((cout,), jnp.float32)))
            params.append((f"gn{i+1}_bias", jnp.zeros((cout,), jnp.float32)))
        key, k1, k2 = jax.random.split(key, 3)
        params.append(("fc1_w", _he(k1, (32, 64), 32)))
        params.append(("fc1_b", jnp.zeros((64,), jnp.float32)))
        params.append(("fc2_w", _he(k2, (64, self.n_classes), 64)))
        params.append(("fc2_b", jnp.zeros((self.n_classes,), jnp.float32)))
        return params

    def apply(self, params, x, quant_mask, seed):
        p = dict(params)
        h = x
        qi = 0
        for i in range(6):
            h = self.qconv(h, p[f"conv{i+1}_w"], quant_mask[qi], seed, qi)
            h = L.group_norm(h, p[f"gn{i+1}_scale"], p[f"gn{i+1}_bias"])
            h = L.relu(h)
            qi += 1
            if i in (1, 3):
                h = L.avg_pool2(h)
        h = L.global_avg_pool(h)
        h = self.qdense(h, p["fc1_w"], quant_mask[qi], seed, qi) + p["fc1_b"]
        h = L.relu(h)
        qi += 1
        h = self.qdense(h, p["fc2_w"], quant_mask[qi], seed, qi) + p["fc2_b"]
        return h


class MiniResNet(_Base):
    """Residual CNN: stem + 4 basic blocks (2 convs each) + fc head.

    10 quantizable layers. Skip connections use 1x1 projections where
    channel counts change (projections stay fp — they are a small
    fraction of compute, like the paper's overhead ops).
    """

    n_quant_layers = 10
    layer_names = (
        ["stem"]
        + [f"block{b+1}_conv{c+1}" for b in range(4) for c in range(2)]
        + ["fc"]
    )
    BLOCKS = [(8, 8), (8, 16), (16, 16), (16, 32)]

    def input_spec(self):
        return jax.ShapeDtypeStruct(IMG, jnp.float32)

    def init(self, key):
        params = []
        key, k = jax.random.split(key)
        params.append(("stem_w", _he(k, (3, 3, 3, 8), 27)))
        params.append(("gn0_scale", jnp.ones((8,), jnp.float32)))
        params.append(("gn0_bias", jnp.zeros((8,), jnp.float32)))
        for b, (cin, cout) in enumerate(self.BLOCKS):
            key, k1, k2, k3 = jax.random.split(key, 4)
            params.append((f"b{b+1}c1_w", _he(k1, (3, 3, cin, cout), 9 * cin)))
            params.append((f"b{b+1}gn1_scale", jnp.ones((cout,), jnp.float32)))
            params.append((f"b{b+1}gn1_bias", jnp.zeros((cout,), jnp.float32)))
            params.append((f"b{b+1}c2_w", _he(k2, (3, 3, cout, cout), 9 * cout)))
            params.append((f"b{b+1}gn2_scale", jnp.ones((cout,), jnp.float32)))
            params.append((f"b{b+1}gn2_bias", jnp.zeros((cout,), jnp.float32)))
            if cin != cout:
                params.append((f"b{b+1}proj_w", _he(k3, (1, 1, cin, cout), cin)))
        key, k = jax.random.split(key)
        params.append(("fc_w", _he(k, (32, self.n_classes), 32)))
        params.append(("fc_b", jnp.zeros((self.n_classes,), jnp.float32)))
        return params

    def apply(self, params, x, quant_mask, seed):
        from jax import lax

        p = dict(params)
        qi = 0
        h = self.qconv(x, p["stem_w"], quant_mask[qi], seed, qi)
        h = L.relu(L.group_norm(h, p["gn0_scale"], p["gn0_bias"]))
        qi += 1
        for b, (cin, cout) in enumerate(self.BLOCKS):
            skip = h
            h = self.qconv(h, p[f"b{b+1}c1_w"], quant_mask[qi], seed, qi)
            h = L.relu(L.group_norm(h, p[f"b{b+1}gn1_scale"], p[f"b{b+1}gn1_bias"]))
            qi += 1
            h = self.qconv(h, p[f"b{b+1}c2_w"], quant_mask[qi], seed, qi)
            h = L.group_norm(h, p[f"b{b+1}gn2_scale"], p[f"b{b+1}gn2_bias"])
            qi += 1
            if cin != cout:
                skip = lax.conv_general_dilated(
                    skip[None],
                    p[f"b{b+1}proj_w"],
                    (1, 1),
                    "SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                )[0]
            h = L.relu(h + skip)
            if b in (0, 2):
                h = L.avg_pool2(h)
        h = L.global_avg_pool(h)
        return self.qdense(h, p["fc_w"], quant_mask[qi], seed, qi) + p["fc_b"]


class MiniDenseNet(_Base):
    """Densely connected CNN: 2 dense blocks of 5 layers (growth 6) with a
    transition conv between them + fc head. 12 quantizable layers."""

    n_quant_layers = 12
    GROWTH = 6
    layer_names = (
        [f"d1_l{i+1}" for i in range(5)]
        + ["trans"]
        + [f"d2_l{i+1}" for i in range(5)]
        + ["fc"]
    )

    def input_spec(self):
        return jax.ShapeDtypeStruct(IMG, jnp.float32)

    def init(self, key):
        params = []
        c = 3
        for i in range(5):
            key, k = jax.random.split(key)
            params.append((f"d1l{i+1}_w", _he(k, (3, 3, c, self.GROWTH), 9 * c)))
            params.append((f"d1gn{i+1}_scale", jnp.ones((self.GROWTH,), jnp.float32)))
            params.append((f"d1gn{i+1}_bias", jnp.zeros((self.GROWTH,), jnp.float32)))
            c += self.GROWTH
        key, k = jax.random.split(key)
        params.append(("trans_w", _he(k, (3, 3, c, 16), 9 * c)))
        params.append(("transgn_scale", jnp.ones((16,), jnp.float32)))
        params.append(("transgn_bias", jnp.zeros((16,), jnp.float32)))
        c = 16
        for i in range(5):
            key, k = jax.random.split(key)
            params.append((f"d2l{i+1}_w", _he(k, (3, 3, c, self.GROWTH), 9 * c)))
            params.append((f"d2gn{i+1}_scale", jnp.ones((self.GROWTH,), jnp.float32)))
            params.append((f"d2gn{i+1}_bias", jnp.zeros((self.GROWTH,), jnp.float32)))
            c += self.GROWTH
        key, k = jax.random.split(key)
        params.append(("fc_w", _he(k, (c, self.n_classes), c)))
        params.append(("fc_b", jnp.zeros((self.n_classes,), jnp.float32)))
        return params

    def apply(self, params, x, quant_mask, seed):
        p = dict(params)
        qi = 0
        h = x
        for i in range(5):
            new = self.qconv(h, p[f"d1l{i+1}_w"], quant_mask[qi], seed, qi)
            new = L.relu(L.group_norm(new, p[f"d1gn{i+1}_scale"], p[f"d1gn{i+1}_bias"]))
            h = jnp.concatenate([h, new], axis=-1)
            qi += 1
        h = self.qconv(h, p["trans_w"], quant_mask[qi], seed, qi)
        h = L.relu(L.group_norm(h, p["transgn_scale"], p["transgn_bias"]))
        h = L.avg_pool2(h)
        qi += 1
        for i in range(5):
            new = self.qconv(h, p[f"d2l{i+1}_w"], quant_mask[qi], seed, qi)
            new = L.relu(L.group_norm(new, p[f"d2gn{i+1}_scale"], p[f"d2gn{i+1}_bias"]))
            h = jnp.concatenate([h, new], axis=-1)
            qi += 1
        h = L.global_avg_pool(h)
        return self.qdense(h, p["fc_w"], quant_mask[qi], seed, qi) + p["fc_b"]


class TinyTransformer(_Base):
    """BERT/SNLI stand-in: frozen token+position embedding, one trainable
    transformer block, mean-pool classifier. 7 quantizable layers
    (wq, wk, wv, wo, mlp_up, mlp_down, classifier).

    Matches the paper's §A.4.2 setup where 12/13 BERT layers are frozen
    and only the last block + head train (under DP-AdamW)."""

    n_quant_layers = 7
    layer_names = ["wq", "wk", "wv", "wo", "mlp_up", "mlp_down", "classifier"]
    D = 32
    HEADS = 2
    MLP = 64

    def input_spec(self):
        return jax.ShapeDtypeStruct((SEQ_LEN,), jnp.int32)

    def __init__(self, n_classes, quantizer):
        super().__init__(n_classes, quantizer)
        # Frozen embedding: deterministic constant baked into the graph
        # (the "pretrained frozen layers").
        ek = jax.random.PRNGKey(1234)
        self.embed = jax.random.normal(ek, (VOCAB, self.D), jnp.float32) * 0.1
        pk = jax.random.PRNGKey(5678)
        self.pos = jax.random.normal(pk, (SEQ_LEN, self.D), jnp.float32) * 0.1

    def init(self, key):
        d, m = self.D, self.MLP
        params = []
        for name in ["wq", "wk", "wv", "wo"]:
            key, k = jax.random.split(key)
            params.append((f"{name}_w", _he(k, (d, d), d)))
        params.append(("ln1_scale", jnp.ones((d,), jnp.float32)))
        params.append(("ln1_bias", jnp.zeros((d,), jnp.float32)))
        key, k1, k2 = jax.random.split(key, 3)
        params.append(("mlp_up_w", _he(k1, (d, m), d)))
        params.append(("mlp_up_b", jnp.zeros((m,), jnp.float32)))
        params.append(("mlp_down_w", _he(k2, (m, d), m)))
        params.append(("mlp_down_b", jnp.zeros((d,), jnp.float32)))
        params.append(("ln2_scale", jnp.ones((d,), jnp.float32)))
        params.append(("ln2_bias", jnp.zeros((d,), jnp.float32)))
        key, k = jax.random.split(key)
        params.append(("cls_w", _he(k, (d, self.n_classes), d)))
        params.append(("cls_b", jnp.zeros((self.n_classes,), jnp.float32)))
        return params

    def apply(self, params, tokens, quant_mask, seed):
        p = dict(params)
        d, nh = self.D, self.HEADS
        hd = d // nh
        h = self.embed[tokens] + self.pos  # (L, D), frozen

        hn = L.layer_norm(h, p["ln1_scale"], p["ln1_bias"])
        q = self.qdense(hn, p["wq_w"], quant_mask[0], seed, 0)
        k = self.qdense(hn, p["wk_w"], quant_mask[1], seed, 1)
        v = self.qdense(hn, p["wv_w"], quant_mask[2], seed, 2)
        ln = h.shape[0]
        q = q.reshape(ln, nh, hd).transpose(1, 0, 2)
        k = k.reshape(ln, nh, hd).transpose(1, 0, 2)
        v = v.reshape(ln, nh, hd).transpose(1, 0, 2)
        att = jax.nn.softmax(q @ k.transpose(0, 2, 1) / jnp.sqrt(hd), axis=-1)
        ctx = (att @ v).transpose(1, 0, 2).reshape(ln, d)
        h = h + self.qdense(ctx, p["wo_w"], quant_mask[3], seed, 3)

        hn = L.layer_norm(h, p["ln2_scale"], p["ln2_bias"])
        up = L.relu(self.qdense(hn, p["mlp_up_w"], quant_mask[4], seed, 4) + p["mlp_up_b"])
        down = self.qdense(up, p["mlp_down_w"], quant_mask[5], seed, 5) + p["mlp_down_b"]
        h = h + down

        pooled = h.mean(axis=0)
        return self.qdense(pooled, p["cls_w"], quant_mask[6], seed, 6) + p["cls_b"]


MODELS = {
    "miniconvnet": MiniConvNet,
    "miniresnet": MiniResNet,
    "minidensenet": MiniDenseNet,
    "tinytransformer": TinyTransformer,
}

# Class counts of the (synthetic stand-ins for the) paper's datasets.
DATASET_CLASSES = {
    "gtsrb": 43,
    "emnist": 47,
    "cifar": 10,
    "snli": 3,
}


def build(model_name, dataset, quantizer="luq4"):
    cls = MODELS[model_name]
    return cls(DATASET_CLASSES[dataset], quantizer)
