//! `cargo bench` harness (criterion is not in the offline crate set, so
//! this is a hand-rolled timing harness with criterion-like output).
//!
//! Benches, one per perf-relevant layer of the stack:
//!   kernels/*         — naive reference vs cache-blocked matmul/conv/
//!                       dense (the DESIGN.md §13 rewrite; same shapes
//!                       as `dpquant bench`)
//!   quantizers/*      — Rust mirrors of LUQ4/uniform4/FP8 (ns/elem)
//!   gaussian          — DP noise generation (the mechanism hot path)
//!   accountant        — RDP curve + ε conversion (per-step budget check)
//!   sampler           — Algorithm 2 layer selection
//!   dataset           — synthetic generator + Poisson batching
//!   mock-train        — coordinator loop against the mock executor
//!   backend/*         — the NATIVE pure-Rust engine: real fwd/bwd step
//!                       latency, fp32 vs quantized per quantizer, plus
//!                       a full native epoch (no artifacts needed)
//!   pjrt-train-step   — the REAL compiled DP-SGD step (needs artifacts;
//!                       skipped with a notice if absent)
//!   pjrt-epoch        — one full epoch end-to-end (needs artifacts)
//!
//! Filter: `cargo bench -- <substring>` (e.g. `cargo bench -- quantizers`).
//! CI smoke: set `DPQUANT_BENCH_QUICK=1` to cap every bench at 2
//! iterations — checks the harness end-to-end without burning minutes.

use dpquant::config::TrainConfig;
use dpquant::coordinator::{train, MockExecutor, StepExecutor, TrainerOptions};
use dpquant::data::{self, Dataset};
use dpquant::privacy::RdpAccountant;
use dpquant::quant::by_name;
use dpquant::util::gaussian::GaussianSampler;
use dpquant::util::rng::Xoshiro256;
use std::time::Instant;

struct Bench {
    filter: Option<String>,
    /// Tiny iteration budget (DPQUANT_BENCH_QUICK): smoke-test mode.
    quick: bool,
}

impl Bench {
    fn run<F: FnMut()>(&self, name: &str, iters: usize, mut f: F) {
        if let Some(flt) = &self.filter {
            if !name.contains(flt.as_str()) {
                return;
            }
        }
        let iters = if self.quick { iters.min(2) } else { iters };
        // Warmup.
        f();
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let total = t0.elapsed().as_secs_f64();
        let per = total / iters as f64;
        let unit = if per < 1e-6 {
            format!("{:.1} ns", per * 1e9)
        } else if per < 1e-3 {
            format!("{:.2} us", per * 1e6)
        } else {
            format!("{:.2} ms", per * 1e3)
        };
        println!("{name:<42} {unit:>12}/iter   ({iters} iters, {total:.2}s total)");
    }
}

fn toy_dataset(n: usize, feats: usize, classes: usize) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for _ in 0..n {
        let c = rng.next_below(classes as u64) as i32;
        for f in 0..feats {
            xs.push(rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 });
        }
        ys.push(c);
    }
    Dataset {
        xs,
        ys,
        example_numel: feats,
        n_classes: classes,
    }
}

fn main() {
    let filter = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-') && a != "bench");
    let quick = std::env::var_os("DPQUANT_BENCH_QUICK").is_some();
    let b = Bench { filter, quick };
    println!("dpquant bench harness (criterion-style, offline)\n");

    // --- L0: the blocked kernels vs their retained naive references ------
    // Same shapes as `dpquant bench --json` so the two surfaces stay
    // comparable; the committed BENCH_native.json tracks these numbers
    // PR over PR.
    {
        use dpquant::backend::tensor;
        let mut krng = Xoshiro256::seed_from_u64(42);
        let mut fill = |buf: &mut [f32]| {
            for v in buf.iter_mut() {
                *v = krng.next_f32() - 0.5;
            }
        };
        for (m, k, n) in [(96usize, 256usize, 96usize), (256, 256, 256)] {
            let mut a = vec![0f32; m * k];
            let mut bm = vec![0f32; k * n];
            fill(&mut a);
            fill(&mut bm);
            let mut out = vec![0f32; m * n];
            b.run(&format!("kernels/matmul-naive/{m}x{k}x{n}"), 30, || {
                tensor::matmul(&a, &bm, m, k, n, &mut out);
            });
            b.run(&format!("kernels/matmul-blocked/{m}x{k}x{n}"), 30, || {
                tensor::matmul_blocked(&a, &bm, m, k, n, &mut out);
            });
        }
        let (h, wd, cin, cout) = (16usize, 16usize, 8usize, 16usize);
        let mut cw = vec![0f32; cout * cin * 9];
        let mut cb = vec![0f32; cout];
        let mut ca = vec![0f32; h * wd * cin];
        let mut cdy = vec![0f32; h * wd * cout];
        fill(&mut cw);
        fill(&mut cb);
        fill(&mut ca);
        fill(&mut cdy);
        let mut cout_buf = vec![0f32; h * wd * cout];
        b.run("kernels/conv3x3-forward-naive/16x16x8x16", 100, || {
            tensor::conv3x3_forward_ref(&cw, &cb, &ca, &mut cout_buf, h, wd, cin, cout);
        });
        b.run("kernels/conv3x3-forward-blocked/16x16x8x16", 100, || {
            tensor::conv3x3_forward(&cw, &cb, &ca, &mut cout_buf, h, wd, cin, cout);
        });
        let mut gw = vec![0f32; cw.len()];
        let mut gb = vec![0f32; cout];
        let mut da = vec![0f32; ca.len()];
        b.run("kernels/conv3x3-backward-naive/16x16x8x16", 100, || {
            gw.fill(0.0);
            gb.fill(0.0);
            tensor::conv3x3_backward_ref(
                &cw, &ca, &cdy, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout,
            );
        });
        b.run("kernels/conv3x3-backward-blocked/16x16x8x16", 100, || {
            gw.fill(0.0);
            gb.fill(0.0);
            tensor::conv3x3_backward(
                &cw, &ca, &cdy, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout,
            );
        });
        let (di, dm) = (1024usize, 96usize);
        let mut dw = vec![0f32; dm * di];
        let mut db = vec![0f32; dm];
        let mut dx = vec![0f32; di];
        fill(&mut dw);
        fill(&mut db);
        fill(&mut dx);
        let mut dout = vec![0f32; dm];
        b.run("kernels/dense-forward-naive/1024x96", 500, || {
            tensor::dense_forward_ref(&dw, Some(&db), &dx, &mut dout);
        });
        b.run("kernels/dense-forward-blocked/1024x96", 500, || {
            tensor::dense_forward(&dw, Some(&db), &dx, &mut dout);
        });
    }

    // --- L1 mirrors: quantizer throughput -------------------------------
    let mut rng = Xoshiro256::seed_from_u64(2);
    let mut g = GaussianSampler::seed_from_u64(3);
    let base: Vec<f32> = (0..65_536).map(|_| g.standard() as f32).collect();
    for name in ["luq4", "uniform4", "fp8"] {
        let q = by_name(name).unwrap();
        let mut buf = base.clone();
        b.run(&format!("quantizers/{name}/64k-elems"), 50, || {
            buf.copy_from_slice(&base);
            q.quantize(&mut buf, &mut rng);
        });
    }

    // --- DP mechanism: noise generation ---------------------------------
    let mut noise_buf = vec![0f32; 25_000]; // ~ miniresnet param count
    b.run("gaussian/fill-25k-params", 200, || {
        g.fill_noise_f32(&mut noise_buf, 1.0);
    });

    // --- Privacy accountant ---------------------------------------------
    b.run("accountant/60-epoch-curve+epsilon", 20, || {
        let mut acc = RdpAccountant::new();
        for e in 0..60u64 {
            if e % 2 == 0 {
                acc.step_analysis(1.0 / 26_640.0, 0.5);
            }
            acc.step_training(1024.0 / 26_640.0, 1.0, 26);
        }
        std::hint::black_box(acc.epsilon(1e-5));
    });
    let mut acc = RdpAccountant::new();
    acc.step_training(0.02, 1.0, 500);
    b.run("accountant/incremental-epsilon", 200, || {
        acc.step_training(0.02, 1.0, 1);
        std::hint::black_box(acc.epsilon(1e-5));
    });

    // --- Scheduler (Algorithm 2) ----------------------------------------
    let scores: Vec<f64> = (0..12).map(|i| (i as f64 * 0.37).sin().abs()).collect();
    let mut srng = Xoshiro256::seed_from_u64(5);
    b.run("sampler/select-9-of-12", 10_000, || {
        std::hint::black_box(dpquant::coordinator::sampler::select_targets(
            &mut srng, &scores, 10.0, 9,
        ));
    });

    // --- Data pipeline ----------------------------------------------------
    b.run("dataset/generate-gtsrb-1k", 5, || {
        std::hint::black_box(data::generate("gtsrb", 1000, 1).unwrap());
    });
    let ds = data::generate("gtsrb", 2048, 1).unwrap();
    let mut drng = Xoshiro256::seed_from_u64(6);
    b.run("dataset/poisson+batch-64-of-2048", 500, || {
        let idx = data::poisson_sample(&mut drng, ds.len(), 64.0 / 2048.0);
        std::hint::black_box(data::make_batches(&ds, &idx, 64));
    });

    // --- Coordinator against the mock (isolates L3 overhead) -------------
    let exec = MockExecutor::new(16, 4, 8, 64);
    let toy = toy_dataset(1024 + 256, 16, 4);
    let (tr, va) = toy.split(256);
    let cfg = TrainConfig {
        epochs: 2,
        batch_size: 64,
        dataset_size: 1024,
        scheduler: "dpquant".into(),
        ..TrainConfig::default()
    };
    b.run("mock-train/2-epochs-dpquant", 10, || {
        std::hint::black_box(train(&exec, &cfg, &tr, &va, &TrainerOptions::default()).unwrap());
    });

    // --- Native backend: real fwd/bwd with on-path quantizer kernels ------
    // Quantized-vs-fp32 step latency is the paper's headline cost axis
    // (Fig. 6 / Table 14): the fp32 row is the baseline, one quantized
    // row per quantizer shows the scalar-kernel overhead (a low-precision
    // ALU would turn that overhead into the modeled ~4x speedup).
    {
        use dpquant::backend::NativeExecutor;
        let bsz = 32usize;
        let nds = data::generate("gtsrb", bsz, 7).unwrap();
        let nbatches = data::eval_batches(&nds, bsz);
        let nbatch = &nbatches[0];
        let mk = |quantizer: &str| {
            let cfg = TrainConfig {
                model: "miniconvnet".into(),
                dataset: "gtsrb".into(),
                quantizer: quantizer.into(),
                physical_batch: bsz,
                ..TrainConfig::default()
            };
            NativeExecutor::from_config(&cfg, nds.example_numel, nds.n_classes).unwrap()
        };
        let fp_exec = mk("luq4");
        let w = fp_exec.initial_weights();
        let nl = fp_exec.n_quant_layers();
        let fp_mask = vec![0f32; nl];
        let mut i = 0f32;
        b.run("backend/native-step/miniconvnet-b32-fp32", 20, || {
            i += 1.0;
            std::hint::black_box(
                fp_exec
                    .train_step(&w, &nbatch.x, &nbatch.y, &nbatch.mask, &fp_mask, i)
                    .unwrap(),
            );
        });
        for qname in ["luq4", "uniform4", "fp8"] {
            let qexec = mk(qname);
            let qw = qexec.initial_weights();
            let q_mask = vec![1f32; qexec.n_quant_layers()];
            let mut j = 0f32;
            b.run(&format!("backend/native-step/miniconvnet-b32-{qname}"), 20, || {
                j += 1.0;
                std::hint::black_box(
                    qexec
                        .train_step(&qw, &nbatch.x, &nbatch.y, &nbatch.mask, &q_mask, j)
                        .unwrap(),
                );
            });
        }
        b.run("backend/native-eval-step/miniconvnet-b32", 20, || {
            std::hint::black_box(
                fp_exec
                    .eval_step(&w, &nbatch.x, &nbatch.y, &nbatch.mask)
                    .unwrap(),
            );
        });

        // One full native epoch through the whole coordinator.
        let nfull = data::generate("gtsrb", 512 + 128, 3).unwrap();
        let (ntr, nva) = nfull.split(128);
        let ncfg = TrainConfig {
            model: "miniconvnet".into(),
            dataset: "gtsrb".into(),
            epochs: 1,
            batch_size: 64,
            dataset_size: 512,
            scheduler: "dpquant".into(),
            ..TrainConfig::default()
        };
        let nexec = mk("luq4");
        b.run("backend/native-epoch/miniconvnet-512-examples", 3, || {
            std::hint::black_box(
                train(&nexec, &ncfg, &ntr, &nva, &TrainerOptions::default()).unwrap(),
            );
        });
    }

    // --- Real PJRT graphs (end-to-end, per paper table timings) ----------
    match dpquant::runtime::Runtime::open("artifacts") {
        Ok(rt) => {
            let graph = rt.load("miniconvnet_gtsrb_luq4").expect("load graph");
            let bsz = graph.batch();
            let real = data::generate("gtsrb", bsz, 2).unwrap();
            let batches = data::eval_batches(&real, bsz);
            let batch = &batches[0];
            let mask = vec![1f32; graph.info.n_quant_layers];
            let w = graph.init_weights.clone();
            let mut i = 0f32;
            b.run("pjrt-train-step/miniconvnet-b64-quantized", 20, || {
                i += 1.0;
                std::hint::black_box(
                    graph
                        .train_step(&w, &batch.x, &batch.y, &batch.mask, &mask, i)
                        .unwrap(),
                );
            });
            let fp_mask = vec![0f32; graph.info.n_quant_layers];
            b.run("pjrt-train-step/miniconvnet-b64-fp", 20, || {
                i += 1.0;
                std::hint::black_box(
                    graph
                        .train_step(&w, &batch.x, &batch.y, &batch.mask, &fp_mask, i)
                        .unwrap(),
                );
            });
            b.run("pjrt-eval-step/miniconvnet-b64", 20, || {
                std::hint::black_box(
                    graph.eval_step(&w, &batch.x, &batch.y, &batch.mask).unwrap(),
                );
            });

            let full = data::generate("gtsrb", 512 + 128, 3).unwrap();
            let (tr, va) = full.split(128);
            let ecfg = TrainConfig {
                epochs: 1,
                batch_size: 64,
                dataset_size: 512,
                scheduler: "dpquant".into(),
                ..TrainConfig::default()
            };
            b.run("pjrt-epoch/miniconvnet-512-examples", 3, || {
                std::hint::black_box(
                    train(&graph, &ecfg, &tr, &va, &TrainerOptions::default()).unwrap(),
                );
            });
        }
        Err(e) => {
            println!("pjrt benches skipped (run `make artifacts` first): {e}");
        }
    }
    println!("\nbench harness done");
}
