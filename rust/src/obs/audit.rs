//! DP audit trail: the `dpquant-audit` v1 JSONL stream.
//!
//! The paper's privacy claim is only as good as the artifacts a run
//! leaves behind: PR 7's traces record *what happened*, but nothing
//! lets a reviewer recompute the DP guarantee after the fact. The audit
//! stream closes that gap. Line 1 is the header
//! `{"format":"dpquant-audit","version":1}`; line 2 is a `"run"` record
//! pinning the config-level DP inputs (δ, base (q, σ, C),
//! scheduler/policy, seed) plus the accountant history already composed
//! before the first audited epoch (`prior` — empty for fresh runs,
//! non-empty when auditing a resumed checkpoint); every following line
//! is an `"epoch"` record carrying the resolved knobs (σ_t, q_t, clip
//! scale, optional per-layer lr scales), the sampled layer mask with
//! its Algorithm 2 draw probabilities, the epoch's accountant *delta*
//! (every training/analysis SGM block, in live order), and the composed
//! (ε, α*) after the epoch.
//!
//! Floats travel as IEEE-754 bit patterns in hex (the checkpoint
//! idiom), so [`replay`] can demand **bitwise** equality: re-driving
//! the recorded blocks through a fresh
//! [`RdpAccountant`](crate::privacy::RdpAccountant) must reproduce the
//! recorded ε timeline to the last bit, or the file is rejected. The
//! per-epoch deltas preserve live event order (analysis before the
//! training steps of the same epoch), so the accountant's
//! coalesce-adjacent-blocks behavior — and therefore its float-sum
//! order — is identical between the live run and the replay.
//!
//! Determinism contract: collecting audit data is pure observation
//! (clones of already-computed state plus the pure Algorithm 2
//! probability function) — it touches no RNG stream and never feeds
//! back into training, so audited and unaudited runs are byte-identical
//! (`tests/audit.rs`). The only wall-clock field
//! (`analysis_seconds`) is zeroed in `--no-timing` mode, making audit
//! files byte-diffable across identical runs. Writes are flushed per
//! line, so a `kill -9`'d daemon loses at most the record being
//! written; [`AuditWriter::resume`] truncates any such torn tail and
//! appends from the recovered epoch, reproducing the uninterrupted
//! file byte for byte.

use crate::config::TrainConfig;
use crate::coordinator::{AuditEpoch, EventSink, TrainEvent};
use crate::privacy::{Mechanism, RdpAccountant, StepRecord};
use crate::util::error::{bail, ensure, err, Context, Result};
use crate::util::json::{self, Json};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::sync::{Mutex, MutexGuard, PoisonError};

use super::{AUDIT_FORMAT, AUDIT_VERSION};

// ---------------------------------------------------------------------
// Writing
// ---------------------------------------------------------------------

struct AuditInner {
    out: Box<dyn Write + Send>,
    /// Set after the first write failure; later lines are dropped so a
    /// full disk degrades auditing, never the run itself.
    failed: bool,
}

impl AuditInner {
    fn write_line(&mut self, line: &str) {
        if self.failed {
            return;
        }
        // Flush per line: records are one per epoch (cheap) and a
        // kill -9'd process must find every completed epoch on disk.
        let r = writeln!(self.out, "{line}").and_then(|()| self.out.flush());
        if let Err(e) = r {
            eprintln!("audit: write failed ({e}); dropping further audit output");
            self.failed = true;
        }
    }
}

/// Writes a `dpquant-audit` v1 file. Interior-mutable (`Mutex`), so the
/// [`AuditSink`] shares it by `&` reference, like [`TraceWriter`]
/// (crate::obs::TraceWriter).
pub struct AuditWriter {
    inner: Mutex<AuditInner>,
    timing: bool,
}

impl AuditWriter {
    /// Create (truncate) `path` and write the header line. With
    /// `timing = false` the one wall-clock field (`analysis_seconds`)
    /// is written as 0, so identical runs produce byte-identical files.
    pub fn create(path: &str, timing: bool) -> Result<Self> {
        let file = File::create(path).with_context(|| format!("creating audit file {path}"))?;
        Ok(Self::from_boxed(Box::new(file), timing))
    }

    /// Wrap an arbitrary writer (tests, in-memory capture).
    pub fn from_boxed(out: Box<dyn Write + Send>, timing: bool) -> Self {
        let w = Self {
            inner: Mutex::new(AuditInner { out, failed: false }),
            timing,
        };
        let header = json::obj(vec![
            ("format", json::s(AUDIT_FORMAT)),
            ("version", json::num(AUDIT_VERSION as f64)),
        ])
        .to_string();
        w.lock().write_line(&header);
        w
    }

    /// Reopen an existing audit file for a resumed session: keep the
    /// header, the run record, and every epoch record with
    /// `epoch < epochs_completed`; drop any later line (the record that
    /// was mid-flight when the process died — the resumed session will
    /// re-emit it identically); append from there. A recovered run's
    /// audit file therefore ends up byte-identical to an uninterrupted
    /// one.
    pub fn resume(path: &str, epochs_completed: usize, timing: bool) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading audit file {path}"))?;
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| err!("{path}: empty audit file"))?;
        let h = json::parse(header).map_err(|e| err!("{path}: invalid header JSON: {e}"))?;
        ensure!(
            h.get("format").and_then(Json::as_str) == Some(AUDIT_FORMAT)
                && h.get("version").and_then(Json::as_f64) == Some(AUDIT_VERSION as f64),
            "{path}: not a {AUDIT_FORMAT} v{AUDIT_VERSION} file"
        );
        let run = lines.next().ok_or_else(|| err!("{path}: missing run record"))?;
        let r = json::parse(run).map_err(|e| err!("{path}: invalid run JSON: {e}"))?;
        ensure!(
            r.get("kind").and_then(Json::as_str) == Some("run"),
            "{path}: line 2 must be the run record"
        );
        let mut kept = format!("{header}\n{run}\n");
        for line in lines {
            let j = json::parse(line).map_err(|e| err!("{path}: invalid epoch JSON: {e}"))?;
            match j.get("epoch").and_then(Json::as_usize) {
                Some(e) if e < epochs_completed => {
                    kept.push_str(line);
                    kept.push('\n');
                }
                _ => break,
            }
        }
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, &kept).with_context(|| format!("rewriting audit {tmp}"))?;
        std::fs::rename(&tmp, path).with_context(|| format!("moving audit {tmp} into place"))?;
        let out = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("reopening audit file {path}"))?;
        Ok(Self {
            inner: Mutex::new(AuditInner {
                out: Box::new(out),
                failed: false,
            }),
            timing,
        })
    }

    fn lock(&self) -> MutexGuard<'_, AuditInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Is the wall-clock field being written (vs zeroed)?
    pub fn timing(&self) -> bool {
        self.timing
    }

    /// Write the run record (line 2): the config-level DP inputs plus
    /// `prior`, the accountant history already composed before the
    /// first audited epoch (empty unless auditing a resumed session).
    pub fn begin_run(&self, cfg: &TrainConfig, train_len: usize, prior: &[StepRecord]) {
        let line = json::obj(vec![
            ("batch_size", json::num(cfg.batch_size as f64)),
            ("beta", hex_f64(cfg.beta)),
            ("clip_norm", hex_f64(cfg.clip_norm)),
            ("delta", hex_f64(cfg.delta)),
            ("epochs", json::num(cfg.epochs as f64)),
            ("kind", json::s("run")),
            ("noise_multiplier", hex_f64(cfg.noise_multiplier)),
            ("policy", json::s(&cfg.policy)),
            ("prior", Json::Arr(prior.iter().map(step_record_json).collect())),
            (
                "sample_rate",
                hex_f64(cfg.batch_size as f64 / train_len.max(1) as f64),
            ),
            ("scheduler", json::s(&cfg.scheduler)),
            ("seed", hex_u64(cfg.seed)),
            ("train_len", json::num(train_len as f64)),
        ])
        .to_string();
        self.lock().write_line(&line);
    }

    /// Write one epoch record.
    pub fn epoch(&self, a: &AuditEpoch) {
        let analysis_seconds = if self.timing { a.analysis_seconds } else { 0.0 };
        let line = json::obj(vec![
            (
                "accounting",
                Json::Arr(a.accounting.iter().map(step_record_json).collect()),
            ),
            ("alpha", hex_f64(a.alpha)),
            ("analysis_seconds", json::num(analysis_seconds)),
            ("clip_norm", hex_f64(a.clip_norm)),
            ("clip_scale", hex_f64(a.clip_scale)),
            (
                "draw_probs",
                Json::Arr(a.draw_probs.iter().map(|&p| hex_f64(p)).collect()),
            ),
            ("epoch", json::num(a.epoch as f64)),
            ("epsilon", hex_f64(a.epsilon)),
            ("kind", json::s("epoch")),
            (
                "lr_scales",
                match &a.lr_scales {
                    Some(s) => Json::Arr(s.iter().map(|&x| hex_f64(x)).collect()),
                    None => Json::Null,
                },
            ),
            (
                "mask",
                Json::Arr(a.mask.iter().map(|&l| json::num(l as f64)).collect()),
            ),
            ("noise_multiplier", hex_f64(a.noise_multiplier)),
            ("sample_rate", hex_f64(a.sample_rate)),
            ("steps", json::num(a.steps as f64)),
            ("truncated", Json::Bool(a.truncated)),
        ])
        .to_string();
        self.lock().write_line(&line);
    }

    /// Flush; errors out if any line was dropped by a write failure.
    pub fn finish(&self) -> Result<()> {
        let mut inner = self.lock();
        ensure!(
            !inner.failed,
            "audit output was truncated by an earlier write failure"
        );
        inner.out.flush().context("flushing audit file")?;
        Ok(())
    }
}

/// An [`EventSink`] that forwards each
/// [`EpochAudited`](TrainEvent::EpochAudited) event to a shared
/// [`AuditWriter`]. Enabled by `dpquant train --audit-out PATH` and by
/// the serving daemon under `--state-dir`.
pub struct AuditSink<'w> {
    writer: &'w AuditWriter,
}

impl<'w> AuditSink<'w> {
    /// Forward epoch-audit events to `writer`.
    pub fn new(writer: &'w AuditWriter) -> Self {
        Self { writer }
    }
}

impl EventSink for AuditSink<'_> {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        if let TrainEvent::EpochAudited { audit } = event {
            self.writer.epoch(audit);
        }
    }
}

// ---------------------------------------------------------------------
// Serialization helpers (the checkpoint hex-float idiom)
// ---------------------------------------------------------------------

fn hex_u64(x: u64) -> Json {
    Json::Str(format!("{x:016x}"))
}

fn hex_f64(x: f64) -> Json {
    hex_u64(x.to_bits())
}

fn step_record_json(r: &StepRecord) -> Json {
    json::obj(vec![
        (
            "mechanism",
            json::s(match r.mechanism {
                Mechanism::Training => "training",
                Mechanism::Analysis => "analysis",
            }),
        ),
        ("noise_multiplier", hex_f64(r.noise_multiplier)),
        ("sample_rate", hex_f64(r.sample_rate)),
        ("steps", hex_u64(r.steps)),
    ])
}

fn field_of<'a>(j: &'a Json, line_no: usize, key: &str) -> Result<&'a Json> {
    j.get(key)
        .ok_or_else(|| err!("audit line {line_no}: missing field '{key}'"))
}

fn hex_u64_of(j: &Json, line_no: usize, what: &str) -> Result<u64> {
    let s = j
        .as_str()
        .ok_or_else(|| err!("audit line {line_no}: '{what}' must be a 16-digit hex string"))?;
    ensure!(
        s.len() == 16,
        "audit line {line_no}: '{what}' must be 16 hex digits, got {} ('{s}')",
        s.len()
    );
    u64::from_str_radix(s, 16)
        .map_err(|e| err!("audit line {line_no}: '{what}': bad hex '{s}': {e}"))
}

fn hex_f64_of(j: &Json, line_no: usize, what: &str) -> Result<f64> {
    Ok(f64::from_bits(hex_u64_of(j, line_no, what)?))
}

fn usize_of(j: &Json, line_no: usize, what: &str) -> Result<usize> {
    j.as_usize()
        .ok_or_else(|| err!("audit line {line_no}: '{what}' must be a non-negative integer"))
}

fn step_record_of(j: &Json, line_no: usize) -> Result<StepRecord> {
    let mechanism = match field_of(j, line_no, "mechanism")?.as_str() {
        Some("training") => Mechanism::Training,
        Some("analysis") => Mechanism::Analysis,
        other => bail!("audit line {line_no}: unknown accounting mechanism {other:?}"),
    };
    let sample_rate = hex_f64_of(field_of(j, line_no, "sample_rate")?, line_no, "sample_rate")?;
    let noise_multiplier = hex_f64_of(
        field_of(j, line_no, "noise_multiplier")?,
        line_no,
        "noise_multiplier",
    )?;
    let steps = hex_u64_of(field_of(j, line_no, "steps")?, line_no, "steps")?;
    ensure!(
        sample_rate.is_finite() && (0.0..=1.0).contains(&sample_rate),
        "audit line {line_no}: sample_rate {sample_rate} is not a probability"
    );
    ensure!(
        noise_multiplier.is_finite() && noise_multiplier >= 0.0,
        "audit line {line_no}: noise_multiplier {noise_multiplier} must be finite and >= 0"
    );
    ensure!(steps >= 1, "audit line {line_no}: accounting steps must be >= 1");
    Ok(StepRecord {
        mechanism,
        sample_rate,
        noise_multiplier,
        steps,
    })
}

// ---------------------------------------------------------------------
// Reading back: `dpquant audit check` / `audit replay`
// ---------------------------------------------------------------------

/// The parsed run record (line 2).
pub struct AuditRun {
    /// The (ε, δ) conversion target every recorded ε used.
    pub delta: f64,
    /// Configured epoch target.
    pub epochs: usize,
    /// Scheduler name (`dpquant`, `static_random`, ...).
    pub scheduler: String,
    /// Adaptive-DP policy name.
    pub policy: String,
    /// Accountant history composed before the first audited epoch.
    pub prior: Vec<StepRecord>,
}

struct EpochLine {
    line_no: usize,
    epoch: usize,
    accounting: Vec<StepRecord>,
    epsilon: f64,
    alpha: f64,
    truncated: bool,
}

fn read_audit(path: &str) -> Result<(AuditRun, Vec<EpochLine>)> {
    let file = File::open(path).with_context(|| format!("opening audit file {path}"))?;
    let mut lines = BufReader::new(file).lines();
    let header = match lines.next() {
        Some(l) => l.with_context(|| format!("reading {path}"))?,
        None => bail!("{path}: empty file (missing {AUDIT_FORMAT} header)"),
    };
    let h = json::parse(&header).map_err(|e| err!("audit line 1: invalid header JSON: {e}"))?;
    ensure!(
        h.get("format").and_then(Json::as_str) == Some(AUDIT_FORMAT),
        "audit line 1: header format is not {AUDIT_FORMAT:?}"
    );
    ensure!(
        h.get("version").and_then(Json::as_f64) == Some(AUDIT_VERSION as f64),
        "audit line 1: unsupported audit version (want {AUDIT_VERSION})"
    );

    let run_line = match lines.next() {
        Some(l) => l.with_context(|| format!("reading {path}"))?,
        None => bail!("audit line 2: missing run record"),
    };
    let r = json::parse(&run_line).map_err(|e| err!("audit line 2: invalid JSON: {e}"))?;
    ensure!(
        r.get("kind").and_then(Json::as_str) == Some("run"),
        "audit line 2: expected the run record (kind \"run\")"
    );
    let delta = hex_f64_of(field_of(&r, 2, "delta")?, 2, "delta")?;
    ensure!(
        delta > 0.0 && delta < 1.0,
        "audit line 2: delta {delta} must lie strictly inside (0, 1)"
    );
    let prior = field_of(&r, 2, "prior")?
        .as_arr()
        .ok_or_else(|| err!("audit line 2: 'prior' must be an array"))?
        .iter()
        .map(|j| step_record_of(j, 2))
        .collect::<Result<Vec<_>>>()?;
    let run = AuditRun {
        delta,
        epochs: usize_of(field_of(&r, 2, "epochs")?, 2, "epochs")?,
        scheduler: field_of(&r, 2, "scheduler")?
            .as_str()
            .ok_or_else(|| err!("audit line 2: 'scheduler' must be a string"))?
            .to_string(),
        policy: field_of(&r, 2, "policy")?
            .as_str()
            .ok_or_else(|| err!("audit line 2: 'policy' must be a string"))?
            .to_string(),
        prior,
    };

    let mut epochs: Vec<EpochLine> = Vec::new();
    for (i, line) in lines.enumerate() {
        let line_no = i + 3;
        let line = line.with_context(|| format!("reading {path}"))?;
        let j = json::parse(&line).map_err(|e| err!("audit line {line_no}: invalid JSON: {e}"))?;
        ensure!(
            j.get("kind").and_then(Json::as_str) == Some("epoch"),
            "audit line {line_no}: expected an epoch record (kind \"epoch\")"
        );
        let epoch = usize_of(field_of(&j, line_no, "epoch")?, line_no, "epoch")?;
        if let Some(prev) = epochs.last() {
            ensure!(
                !prev.truncated,
                "audit line {line_no}: records continue after the truncated epoch {}",
                prev.epoch
            );
            ensure!(
                epoch == prev.epoch + 1,
                "audit line {line_no}: epoch {epoch} does not follow epoch {}",
                prev.epoch
            );
        }
        let accounting = field_of(&j, line_no, "accounting")?
            .as_arr()
            .ok_or_else(|| err!("audit line {line_no}: 'accounting' must be an array"))?
            .iter()
            .map(|rec| step_record_of(rec, line_no))
            .collect::<Result<Vec<_>>>()?;
        let steps = usize_of(field_of(&j, line_no, "steps")?, line_no, "steps")? as u64;
        let accounted: u64 = accounting
            .iter()
            .filter(|rec| rec.mechanism == Mechanism::Training)
            .map(|rec| rec.steps)
            .sum();
        ensure!(
            steps == accounted,
            "audit line {line_no}: 'steps' says {steps} training steps but the accounting \
             delta sums to {accounted}"
        );
        // Knob fields must be well-formed hex floats even though the
        // replay composes only from `accounting`.
        for key in ["noise_multiplier", "sample_rate", "clip_norm", "clip_scale"] {
            let v = hex_f64_of(field_of(&j, line_no, key)?, line_no, key)?;
            ensure!(
                v.is_finite(),
                "audit line {line_no}: '{key}' must be finite, got {v}"
            );
        }
        let mask = field_of(&j, line_no, "mask")?
            .as_arr()
            .ok_or_else(|| err!("audit line {line_no}: 'mask' must be an array"))?
            .iter()
            .map(|l| usize_of(l, line_no, "mask"))
            .collect::<Result<Vec<_>>>()?;
        let draw_probs = field_of(&j, line_no, "draw_probs")?
            .as_arr()
            .ok_or_else(|| err!("audit line {line_no}: 'draw_probs' must be an array"))?
            .iter()
            .map(|p| hex_f64_of(p, line_no, "draw_probs"))
            .collect::<Result<Vec<_>>>()?;
        for &p in &draw_probs {
            ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "audit line {line_no}: draw probability {p} is not in [0, 1]"
            );
        }
        if !draw_probs.is_empty() {
            for &l in &mask {
                ensure!(
                    l < draw_probs.len(),
                    "audit line {line_no}: mask layer {l} is outside the {}-layer \
                     draw-probability vector",
                    draw_probs.len()
                );
            }
        }
        match field_of(&j, line_no, "lr_scales")? {
            Json::Null => {}
            Json::Arr(scales) => {
                for s in scales {
                    let v = hex_f64_of(s, line_no, "lr_scales")?;
                    ensure!(
                        v.is_finite() && v > 0.0,
                        "audit line {line_no}: lr scale {v} must be finite and > 0"
                    );
                }
            }
            _ => bail!("audit line {line_no}: 'lr_scales' must be null or an array"),
        }
        let analysis_seconds = field_of(&j, line_no, "analysis_seconds")?
            .as_f64()
            .ok_or_else(|| err!("audit line {line_no}: 'analysis_seconds' must be a number"))?;
        ensure!(
            analysis_seconds >= 0.0,
            "audit line {line_no}: 'analysis_seconds' must be >= 0"
        );
        epochs.push(EpochLine {
            line_no,
            epoch,
            accounting,
            epsilon: hex_f64_of(field_of(&j, line_no, "epsilon")?, line_no, "epsilon")?,
            alpha: hex_f64_of(field_of(&j, line_no, "alpha")?, line_no, "alpha")?,
            truncated: field_of(&j, line_no, "truncated")?
                .as_bool()
                .ok_or_else(|| err!("audit line {line_no}: 'truncated' must be a bool"))?,
        });
    }
    Ok((run, epochs))
}

/// What [`check`] counted in a valid audit file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AuditStats {
    /// Epoch records.
    pub epochs: u64,
    /// Accounting (SGM-block) records across all epochs.
    pub records: u64,
    /// Analysis-mechanism steps across all epochs (the probe events).
    pub analysis_steps: u64,
    /// Did the run end by privacy-budget truncation?
    pub truncated: bool,
}

/// Validate every line of `path` against the `dpquant-audit` v1 schema:
/// header first, then the run record, then sequential epoch records
/// with well-typed hex floats, probability-shaped draw vectors, masks
/// inside the layer range, and accounting deltas whose training steps
/// sum to the declared per-epoch step count. Errors carry the 1-based
/// line number.
pub fn check(path: &str) -> Result<AuditStats> {
    let (_run, epochs) = read_audit(path)?;
    let mut stats = AuditStats::default();
    for e in &epochs {
        stats.epochs += 1;
        stats.records += e.accounting.len() as u64;
        stats.analysis_steps += e
            .accounting
            .iter()
            .filter(|r| r.mechanism == Mechanism::Analysis)
            .map(|r| r.steps)
            .sum::<u64>();
        stats.truncated = e.truncated;
    }
    Ok(stats)
}

/// The result of a successful [`replay`].
#[derive(Clone, Copy, Debug)]
pub struct AuditReplay {
    /// Epoch records re-composed.
    pub epochs: u64,
    /// Composed ε after the last epoch (bitwise equal to the record).
    pub final_epsilon: f64,
    /// The α* minimizing the conversion at the last epoch.
    pub final_alpha: f64,
}

/// Re-drive every recorded (q, σ, steps) block through a fresh
/// [`RdpAccountant`] — seeded with the run record's `prior` history —
/// and fail unless the replayed (ε, α*) after **every** epoch is
/// bitwise equal to the recorded timeline. This turns the DP guarantee
/// into a checkable artifact: the accountant that admitted the run can
/// be re-instantiated from the file alone.
pub fn replay(path: &str) -> Result<AuditReplay> {
    let (run, epochs) = read_audit(path)?;
    ensure!(!epochs.is_empty(), "{path}: no epoch records to replay");
    let mut acc = RdpAccountant::from_records(&run.prior);
    let (mut eps, mut alpha) = (0.0, 0.0);
    for e in &epochs {
        for rec in &e.accounting {
            acc.record(rec.mechanism, rec.sample_rate, rec.noise_multiplier, rec.steps);
        }
        let (got_eps, got_alpha) = acc.epsilon(run.delta);
        ensure!(
            got_eps.to_bits() == e.epsilon.to_bits(),
            "audit line {}: epoch {}: replayed epsilon {} (bits {:016x}) != recorded {} \
             (bits {:016x})",
            e.line_no,
            e.epoch,
            got_eps,
            got_eps.to_bits(),
            e.epsilon,
            e.epsilon.to_bits()
        );
        ensure!(
            got_alpha.to_bits() == e.alpha.to_bits(),
            "audit line {}: epoch {}: replayed alpha {got_alpha} != recorded {}",
            e.line_no,
            e.epoch,
            e.alpha
        );
        eps = got_eps;
        alpha = got_alpha;
    }
    Ok(AuditReplay {
        epochs: epochs.len() as u64,
        final_epsilon: eps,
        final_alpha: alpha,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dpquant_audit_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn cfg() -> TrainConfig {
        TrainConfig {
            epochs: 2,
            batch_size: 16,
            dataset_size: 256,
            noise_multiplier: 0.6,
            ..TrainConfig::default()
        }
    }

    /// An epoch record whose (ε, α) really is the composition of its
    /// accounting delta on top of `acc` — the shape the session emits.
    fn live_epoch(acc: &mut RdpAccountant, epoch: usize, q: f64, sigma: f64, steps: u64)
        -> AuditEpoch {
        let delta = vec![StepRecord {
            mechanism: Mechanism::Training,
            sample_rate: q,
            noise_multiplier: sigma,
            steps,
        }];
        for r in &delta {
            acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
        }
        let (epsilon, alpha) = acc.epsilon(1e-5);
        AuditEpoch {
            epoch,
            noise_multiplier: sigma,
            sample_rate: q,
            clip_norm: 1.0,
            clip_scale: 1.0,
            lr_scales: None,
            mask: vec![0, 2],
            draw_probs: vec![0.25, 0.25, 0.5],
            accounting: delta,
            steps,
            epsilon,
            alpha,
            analysis_seconds: 1.5,
            truncated: false,
        }
    }

    fn write_sample(path: &str, timing: bool) {
        let w = AuditWriter::create(path, timing).unwrap();
        let mut c = cfg();
        c.delta = 1e-5;
        w.begin_run(&c, 256, &[]);
        let mut acc = RdpAccountant::new();
        w.epoch(&live_epoch(&mut acc, 0, 0.0625, 0.6, 16));
        w.epoch(&live_epoch(&mut acc, 1, 0.0625, 0.8, 16));
        w.finish().unwrap();
    }

    #[test]
    fn check_counts_and_replay_agrees_bitwise() {
        let path = tmp("roundtrip");
        write_sample(&path, true);
        let stats = check(&path).unwrap();
        assert_eq!(stats.epochs, 2);
        assert_eq!(stats.records, 2);
        assert_eq!(stats.analysis_steps, 0);
        assert!(!stats.truncated);
        let r = replay(&path).unwrap();
        assert_eq!(r.epochs, 2);
        let mut acc = RdpAccountant::new();
        acc.step_training(0.0625, 0.6, 16);
        acc.step_training(0.0625, 0.8, 16);
        assert_eq!(r.final_epsilon.to_bits(), acc.epsilon(1e-5).0.to_bits());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn no_timing_files_are_byte_deterministic() {
        let (a, b) = (tmp("det_a"), tmp("det_b"));
        write_sample(&a, false);
        write_sample(&b, false);
        let (ta, tb) = (
            std::fs::read_to_string(&a).unwrap(),
            std::fs::read_to_string(&b).unwrap(),
        );
        assert_eq!(ta, tb);
        assert!(ta.contains("\"analysis_seconds\":0,"), "{ta}");
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn replay_rejects_a_doctored_epsilon_with_its_line_number() {
        let path = tmp("doctored");
        write_sample(&path, false);
        // Flip the last epoch's recorded epsilon by one bit.
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<String> = text.lines().map(String::from).collect();
        let last = lines.last().unwrap().clone();
        let j = json::parse(&last).unwrap();
        let eps_hex = j.get("epsilon").unwrap().as_str().unwrap().to_string();
        let bits = u64::from_str_radix(&eps_hex, 16).unwrap() ^ 1;
        *lines.last_mut().unwrap() = last.replace(&eps_hex, &format!("{bits:016x}"));
        std::fs::write(&path, lines.join("\n") + "\n").unwrap();

        let err = replay(&path).unwrap_err().to_string();
        assert!(err.contains("audit line 4"), "{err}");
        assert!(err.contains("replayed epsilon"), "{err}");
        // check() is structural only — the doctored file still passes it.
        assert!(check(&path).is_ok());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_lines_are_rejected_with_line_numbers() {
        let path = tmp("malformed");
        std::fs::write(&path, "{\"format\":\"other\"}\n").unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");

        let header = format!("{{\"format\":\"{AUDIT_FORMAT}\",\"version\":{AUDIT_VERSION}}}");
        std::fs::write(&path, format!("{header}\n{{\"kind\":\"epoch\"}}\n")).unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("run"), "{err}");

        write_sample(&path, false);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("not json\n");
        std::fs::write(&path, &text).unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("audit line 5"), "{err}");

        // An inconsistent steps-vs-accounting claim is caught, with line.
        write_sample(&path, false);
        let text = std::fs::read_to_string(&path).unwrap();
        let doctored = text.replace("\"steps\":16,", "\"steps\":15,");
        assert_ne!(doctored, text);
        std::fs::write(&path, &doctored).unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("audit line 3"), "{err}");
        assert!(err.contains("sums to"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_drops_the_torn_tail_and_appends_identically() {
        let (full, resumed) = (tmp("resume_full"), tmp("resume_part"));
        write_sample(&full, false);

        // Simulate a crash after epoch 0's record plus a torn epoch-1
        // line: resume(epochs_completed = 1) must drop the tail, then
        // re-appending epoch 1 reproduces the uninterrupted bytes.
        let text = std::fs::read_to_string(&full).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        std::fs::write(&resumed, format!("{}\n{}\n{}\n{}\n", lines[0], lines[1], lines[2], lines[3]))
            .unwrap();
        let w = AuditWriter::resume(&resumed, 1, false).unwrap();
        let mut acc = RdpAccountant::new();
        let _ = live_epoch(&mut acc, 0, 0.0625, 0.6, 16);
        w.epoch(&live_epoch(&mut acc, 1, 0.0625, 0.8, 16));
        w.finish().unwrap();
        assert_eq!(std::fs::read_to_string(&resumed).unwrap(), text);
        assert!(replay(&resumed).is_ok());
        std::fs::remove_file(&full).ok();
        std::fs::remove_file(&resumed).ok();
    }

    #[test]
    fn replay_seeds_from_the_prior_history() {
        let path = tmp("prior");
        let prior = vec![StepRecord {
            mechanism: Mechanism::Training,
            sample_rate: 0.0625,
            noise_multiplier: 0.6,
            steps: 32,
        }];
        let w = AuditWriter::create(&path, false).unwrap();
        let mut c = cfg();
        c.delta = 1e-5;
        w.begin_run(&c, 256, &prior);
        let mut acc = RdpAccountant::from_records(&prior);
        w.epoch(&live_epoch(&mut acc, 2, 0.0625, 0.6, 16));
        w.finish().unwrap();
        let r = replay(&path).unwrap();
        // ε must reflect prior + delta, not the delta alone.
        let mut direct = RdpAccountant::new();
        direct.step_training(0.0625, 0.6, 48);
        assert_eq!(r.final_epsilon.to_bits(), direct.epsilon(1e-5).0.to_bits());
        std::fs::remove_file(&path).ok();
    }
}
