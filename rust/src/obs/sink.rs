//! [`JsonlSink`] — serializes the full [`TrainEvent`] stream into a
//! trace file as `"event"` records (target `"session"`).
//!
//! Every deterministic payload field is written: ε, losses, the
//! quantized-layer set, per-step noise stats. The only wall-clock
//! payloads in the stream (`AnalysisCompleted.seconds` and the epoch
//! record's `train_seconds`/`analysis_seconds`) are zeroed when the
//! writer's timing mode is off, keeping `--no-timing` traces
//! byte-identical across identical runs.

use super::trace::TraceWriter;
use crate::coordinator::{EventSink, TrainEvent};
use crate::util::json::{self, Json};

/// An [`EventSink`] that forwards each event to a shared
/// [`TraceWriter`]. Enabled by `dpquant train --trace-out PATH`.
pub struct JsonlSink<'w> {
    writer: &'w TraceWriter,
}

impl<'w> JsonlSink<'w> {
    /// Forward events to `writer`.
    pub fn new(writer: &'w TraceWriter) -> Self {
        Self { writer }
    }
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| json::num(x as f64)).collect())
}

impl EventSink for JsonlSink<'_> {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        let timing = self.writer.timing();
        let zeroed = |s: f64| if timing { s } else { 0.0 };
        let fields = match event {
            TrainEvent::EpochStarted { epoch } => {
                json::obj(vec![("epoch", json::num(*epoch as f64))])
            }
            TrainEvent::AnalysisCompleted {
                epoch,
                impacts,
                seconds,
            } => json::obj(vec![
                ("epoch", json::num(*epoch as f64)),
                ("impacts", json::arr_f64(impacts)),
                ("seconds", json::num(zeroed(*seconds))),
            ]),
            TrainEvent::PolicySelected { epoch, policy } => json::obj(vec![
                ("epoch", json::num(*epoch as f64)),
                ("layers", usize_arr(&policy.layers)),
                ("n_layers", json::num(policy.n_layers as f64)),
            ]),
            TrainEvent::StepCompleted {
                epoch,
                step,
                examples,
                stats,
                raw_norm_mean,
                raw_norm_max,
            } => json::obj(vec![
                ("epoch", json::num(*epoch as f64)),
                ("examples", json::num(*examples as f64)),
                ("grad_l2", json::num(stats.grad_l2)),
                ("grad_linf", json::num(stats.grad_linf)),
                ("noise_l2", json::num(stats.noise_l2)),
                ("noise_linf", json::num(stats.noise_linf)),
                ("raw_norm_max", json::num(*raw_norm_max)),
                ("raw_norm_mean", json::num(*raw_norm_mean)),
                ("step", json::num(*step as f64)),
            ]),
            TrainEvent::Truncated {
                epoch,
                step,
                epsilon,
            } => json::obj(vec![
                ("epoch", json::num(*epoch as f64)),
                ("epsilon", json::num(*epsilon)),
                ("step", json::num(*step as f64)),
            ]),
            TrainEvent::EpochCompleted { record } => json::obj(vec![
                ("analysis_seconds", json::num(zeroed(record.analysis_seconds))),
                ("epoch", json::num(record.epoch as f64)),
                ("epsilon", json::num(record.epsilon)),
                ("quantized_layers", usize_arr(&record.quantized_layers)),
                ("train_loss", json::num(record.train_loss)),
                ("train_seconds", json::num(zeroed(record.train_seconds))),
                ("val_accuracy", json::num(record.val_accuracy)),
                ("val_loss", json::num(record.val_loss)),
            ]),
            // Audit records have their own stream (`dpquant-audit`, via
            // AuditSink); serializing them here would duplicate the data
            // and change the pinned `dpquant-trace` v1 event shapes.
            TrainEvent::EpochAudited { .. } => return,
        };
        self.writer.event(event.kind(), "session", fields);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::NoiseStats;
    use crate::coordinator::Policy;
    use crate::metrics::EpochRecord;
    use crate::obs::trace;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dpquant_sink_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn feed(sink: &mut JsonlSink<'_>) {
        sink.on_event(&TrainEvent::EpochStarted { epoch: 0 });
        sink.on_event(&TrainEvent::AnalysisCompleted {
            epoch: 0,
            impacts: &[0.5, 0.25],
            seconds: 1.25,
        });
        let policy = Policy {
            n_layers: 2,
            layers: vec![0, 1],
        };
        sink.on_event(&TrainEvent::PolicySelected { epoch: 0, policy: &policy });
        sink.on_event(&TrainEvent::StepCompleted {
            epoch: 0,
            step: 3,
            examples: 16,
            stats: NoiseStats {
                grad_linf: 0.5,
                grad_l2: 1.0,
                noise_linf: 0.25,
                noise_l2: 0.75,
            },
            raw_norm_mean: 2.0,
            raw_norm_max: 4.0,
        });
        let record = EpochRecord {
            epoch: 0,
            train_loss: 0.5,
            val_loss: 0.25,
            val_accuracy: 0.875,
            epsilon: 1.5,
            quantized_layers: vec![1],
            train_seconds: 9.0,
            analysis_seconds: 3.0,
        };
        sink.on_event(&TrainEvent::EpochCompleted { record: &record });
    }

    #[test]
    fn events_serialize_with_deterministic_fields() {
        let path = tmp("fields");
        let w = TraceWriter::create(&path, false).unwrap();
        let mut sink = JsonlSink::new(&w);
        feed(&mut sink);
        w.finish().unwrap();
        let stats = trace::check(&path).unwrap();
        assert_eq!(stats.events, 5);
        assert_eq!(stats.spans, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"name\":\"epoch_started\""), "{text}");
        assert!(text.contains("\"impacts\":[0.5,0.25]"), "{text}");
        assert!(text.contains("\"quantized_layers\":[1]"), "{text}");
        // Wall-clock payloads are zeroed with timing off.
        assert!(text.contains("\"seconds\":0"), "{text}");
        assert!(text.contains("\"train_seconds\":0"), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn timing_mode_keeps_seconds() {
        let path = tmp("timed");
        let w = TraceWriter::create(&path, true).unwrap();
        let mut sink = JsonlSink::new(&w);
        feed(&mut sink);
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"seconds\":1.25"), "{text}");
        assert!(text.contains("\"train_seconds\":9"), "{text}");
        std::fs::remove_file(&path).ok();
    }
}
