//! Observability: a process-wide metrics registry plus span-based
//! trace files, wired through every layer (backend kernels, the
//! coordinator session, the sweep worker pool, and the serve daemon).
//!
//! Two-tier telemetry, by design:
//!
//! - **Hot kernels** (`matmul_blocked`, conv3x3 fwd/bwd, quantizer
//!   passes) record durations into the global [`MetricsRegistry`]
//!   only — never trace lines. They run per sample on worker threads;
//!   per-call trace lines would bloat the file and make line order
//!   nondeterministic. The recording is gated behind a process-wide
//!   flag ([`set_kernel_timing`]) so the off path costs one relaxed
//!   atomic load and a branch.
//! - **Trace files** ([`TraceWriter`], `dpquant-trace` v1) are written
//!   only from the single coordinator thread: the [`JsonlSink`] event
//!   stream plus coarse spans (epoch, checkpoint write). Line order
//!   is therefore deterministic, and with timing off
//!   (`--no-timing`) two identical runs produce byte-identical files.
//!
//! The determinism contract mirrors sweep/serve: observability is
//! pure observation. Training outputs are byte-identical with tracing
//! on or off; timing fields are the only nondeterministic values and
//! are zeroed in `--no-timing` mode. Tier-1 `tests/obs.rs` and CI
//! `trace-smoke` pin both properties.

pub mod audit;
pub mod registry;
pub mod sink;
pub mod trace;

pub use audit::{AuditReplay, AuditSink, AuditStats, AuditWriter};
pub use registry::{Counter, Gauge, Histogram, MetricsRegistry, Timer};
pub use sink::JsonlSink;
pub use trace::{Span, TraceStats, TraceSummaryRow, TraceWriter};

use crate::util::json::{self, Json};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Trace file format tag (line 1 of every trace file).
pub const TRACE_FORMAT: &str = "dpquant-trace";
/// Trace schema version.
pub const TRACE_VERSION: u64 = 1;
/// Metrics snapshot format tag (`--metrics-out` files and
/// `GET /v1/metrics`).
pub const METRICS_FORMAT: &str = "dpquant-metrics";
/// Metrics schema version.
pub const METRICS_VERSION: u64 = 1;
/// DP audit trail format tag (`--audit-out` files, daemon job audit
/// logs, `GET /v1/jobs/{id}/audit`).
pub const AUDIT_FORMAT: &str = "dpquant-audit";
/// Audit schema version.
pub const AUDIT_VERSION: u64 = 1;

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry. Kernels, the worker pool, the HTTP
/// server, and `dpquant bench` all record here; `GET /v1/metrics` and
/// `--metrics-out` snapshot it.
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::new)
}

static KERNEL_TIMING: AtomicBool = AtomicBool::new(false);

/// Enable/disable per-kernel duration recording into [`global`].
/// Off by default; `train`, `serve`, and `bench` turn it on per the
/// `[obs] metrics` config key. Never affects training outputs.
pub fn set_kernel_timing(on: bool) {
    KERNEL_TIMING.store(on, Ordering::Relaxed);
}

/// Is per-kernel duration recording enabled?
pub fn kernel_timing() -> bool {
    KERNEL_TIMING.load(Ordering::Relaxed)
}

/// `Some(Instant::now())` when kernel timing is on — the cheap guard
/// hot kernels use so the off path is one load and a branch.
pub fn maybe_start() -> Option<Instant> {
    if kernel_timing() {
        Some(Instant::now())
    } else {
        None
    }
}

/// The `dpquant-metrics` v1 document for the global registry, as
/// written by `train --metrics-out` / `bench --metrics-out`. The
/// daemon's `GET /v1/metrics` emits the same format with additional
/// job-level fields.
pub fn metrics_doc() -> Json {
    json::obj(vec![
        ("format", json::s(METRICS_FORMAT)),
        ("version", json::num(METRICS_VERSION as f64)),
        ("metrics", global().to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_timing_gate_toggles() {
        set_kernel_timing(false);
        assert!(maybe_start().is_none());
        set_kernel_timing(true);
        assert!(maybe_start().is_some());
        set_kernel_timing(false);
    }

    #[test]
    fn metrics_doc_is_tagged() {
        let doc = metrics_doc();
        assert_eq!(doc.get("format").unwrap().as_str(), Some(METRICS_FORMAT));
        assert_eq!(doc.get("version").unwrap().as_f64(), Some(1.0));
        assert!(doc.get("metrics").unwrap().get("counters").is_some());
    }
}
