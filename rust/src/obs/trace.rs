//! Span-based trace files: one JSON object per line.
//!
//! The `dpquant-trace` v1 schema. Line 1 is the header
//! `{"format":"dpquant-trace","version":1}`; every following line is a
//! record:
//!
//! ```json
//! {"dur_ns":0,"fields":{...},"id":3,"name":"epoch_started",
//!  "parent":2,"start_ns":0,"target":"session","type":"event"}
//! ```
//!
//! `type` is `"span"` (a timed region, written when it closes) or
//! `"event"` (a point record, written immediately; `dur_ns` is 0).
//! Ids are assigned in creation order starting at 1; `parent` is the
//! id of the innermost open span at creation time, or `null`.
//! `start_ns` is relative to writer creation.
//!
//! Determinism contract: with timing disabled
//! ([`TraceWriter::create`] with `timing = false`, the CLI's
//! `--no-timing`), `start_ns`/`dur_ns` are written as 0 and the file
//! is a pure function of the run — two identical runs produce
//! byte-identical traces (CI `trace-smoke` diffs them). Writers are
//! only ever driven from the single coordinator thread, so line order
//! is deterministic too.

use crate::util::error::{bail, ensure, err, Context, Result};
use crate::util::json::{self, Json};
use std::collections::BTreeSet;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

use super::{TRACE_FORMAT, TRACE_VERSION};

struct TraceInner {
    out: Box<dyn Write + Send>,
    next_id: u64,
    /// Stack of open span ids (innermost last).
    open: Vec<u64>,
    /// Set after the first write failure; later lines are dropped so a
    /// full disk degrades observability, never the run itself.
    failed: bool,
}

impl TraceInner {
    fn write_line(&mut self, line: &str) {
        if self.failed {
            return;
        }
        if let Err(e) = writeln!(self.out, "{line}") {
            eprintln!("trace: write failed ({e}); dropping further trace output");
            self.failed = true;
        }
    }
}

/// Writes a `dpquant-trace` v1 file. Interior-mutable (`Mutex`), so
/// sinks and spans share it by `&` reference.
pub struct TraceWriter {
    inner: Mutex<TraceInner>,
    timing: bool,
    t0: Instant,
}

struct LineSpec<'a> {
    kind: &'a str,
    id: u64,
    parent: Option<u64>,
    name: &'a str,
    target: &'a str,
    start_ns: u64,
    dur_ns: u64,
}

fn render_line(spec: &LineSpec<'_>, fields: Json) -> String {
    let fields = match fields {
        Json::Obj(_) => fields,
        _ => json::obj(vec![]),
    };
    json::obj(vec![
        ("dur_ns", json::num(spec.dur_ns as f64)),
        ("fields", fields),
        ("id", json::num(spec.id as f64)),
        ("name", json::s(spec.name)),
        (
            "parent",
            spec.parent.map(|p| json::num(p as f64)).unwrap_or(Json::Null),
        ),
        ("start_ns", json::num(spec.start_ns as f64)),
        ("target", json::s(spec.target)),
        ("type", json::s(spec.kind)),
    ])
    .to_string()
}

impl TraceWriter {
    /// Create (truncate) `path` and write the header line. With
    /// `timing = false` every `start_ns`/`dur_ns` is written as 0, so
    /// identical runs produce byte-identical files.
    pub fn create(path: &str, timing: bool) -> Result<Self> {
        let file =
            File::create(path).with_context(|| format!("creating trace file {path}"))?;
        Ok(Self::from_boxed(Box::new(BufWriter::new(file)), timing))
    }

    /// Wrap an arbitrary writer (tests, in-memory capture).
    pub fn from_boxed(out: Box<dyn Write + Send>, timing: bool) -> Self {
        let w = Self {
            inner: Mutex::new(TraceInner {
                out,
                next_id: 1,
                open: Vec::new(),
                failed: false,
            }),
            timing,
            t0: Instant::now(),
        };
        let header = json::obj(vec![
            ("format", json::s(TRACE_FORMAT)),
            ("version", json::num(TRACE_VERSION as f64)),
        ])
        .to_string();
        w.lock().write_line(&header);
        w
    }

    fn lock(&self) -> MutexGuard<'_, TraceInner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Are real timestamps being written?
    pub fn timing(&self) -> bool {
        self.timing
    }

    fn now_ns(&self) -> u64 {
        if self.timing {
            self.t0.elapsed().as_nanos() as u64
        } else {
            0
        }
    }

    /// Write a point record. `fields` must be a JSON object (anything
    /// else is replaced by `{}`).
    pub fn event(&self, name: &str, target: &str, fields: Json) {
        let start_ns = self.now_ns();
        let mut inner = self.lock();
        let id = inner.next_id;
        inner.next_id += 1;
        let spec = LineSpec {
            kind: "event",
            id,
            parent: inner.open.last().copied(),
            name,
            target,
            start_ns,
            dur_ns: 0,
        };
        let line = render_line(&spec, fields);
        inner.write_line(&line);
    }

    /// Open a timed region. The returned [`Span`] writes its record
    /// when dropped; records created while it is open get it as their
    /// `parent`.
    #[must_use = "the span closes (and writes its line) when dropped"]
    pub fn span(&self, name: &str, target: &str, fields: Json) -> Span<'_> {
        let start_ns = self.now_ns();
        let mut inner = self.lock();
        let id = inner.next_id;
        inner.next_id += 1;
        let parent = inner.open.last().copied();
        inner.open.push(id);
        drop(inner);
        Span {
            writer: self,
            id,
            parent,
            name: name.to_string(),
            target: target.to_string(),
            fields,
            start: Instant::now(),
            start_ns,
        }
    }

    /// Flush buffered lines; errors out if any line was dropped.
    pub fn finish(&self) -> Result<()> {
        let mut inner = self.lock();
        ensure!(!inner.failed, "trace output was truncated by an earlier write failure");
        inner.out.flush().context("flushing trace file")?;
        Ok(())
    }
}

/// RAII timed region from [`TraceWriter::span`]; writes its trace line
/// on drop.
pub struct Span<'w> {
    writer: &'w TraceWriter,
    id: u64,
    parent: Option<u64>,
    name: String,
    target: String,
    fields: Json,
    start: Instant,
    start_ns: u64,
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let dur_ns = if self.writer.timing {
            self.start.elapsed().as_nanos() as u64
        } else {
            0
        };
        let spec = LineSpec {
            kind: "span",
            id: self.id,
            parent: self.parent,
            name: &self.name,
            target: &self.target,
            start_ns: self.start_ns,
            dur_ns,
        };
        let line = render_line(&spec, std::mem::replace(&mut self.fields, Json::Null));
        let mut inner = self.writer.lock();
        if let Some(pos) = inner.open.iter().rposition(|&x| x == self.id) {
            inner.open.remove(pos);
        }
        inner.write_line(&line);
    }
}

// ---------------------------------------------------------------------
// Reading traces back: `dpquant trace check` / `trace summarize`
// ---------------------------------------------------------------------

/// What [`check`] counted in a valid trace file.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceStats {
    /// Span records.
    pub spans: u64,
    /// Event records.
    pub events: u64,
}

/// One row of the [`summarize`] per-target table.
#[derive(Clone, Debug)]
pub struct TraceSummaryRow {
    /// The `target` field shared by the aggregated spans.
    pub target: String,
    /// Spans aggregated.
    pub count: u64,
    /// Sum of `dur_ns`.
    pub total_ns: f64,
    /// Mean `dur_ns`.
    pub mean_ns: f64,
    /// Exact 95th percentile of `dur_ns` (nearest-rank).
    pub p95_ns: f64,
}

struct ParsedLine {
    kind: String,
    target: String,
    dur_ns: f64,
    parent: Option<u64>,
}

fn parse_record(line_no: usize, line: &str) -> Result<(u64, ParsedLine)> {
    let j =
        json::parse(line).map_err(|e| err!("trace line {line_no}: invalid JSON: {e}"))?;
    let kind = match j.get("type").and_then(Json::as_str) {
        Some(k @ ("span" | "event")) => k.to_string(),
        Some(other) => bail!("trace line {line_no}: unknown record type {other:?}"),
        None => bail!("trace line {line_no}: missing \"type\""),
    };
    let id = match j.get("id").and_then(Json::as_f64) {
        Some(v) if v >= 1.0 => v as u64,
        _ => bail!("trace line {line_no}: missing or non-positive \"id\""),
    };
    for key in ["name", "target"] {
        match j.get(key).and_then(Json::as_str) {
            Some(s) if !s.is_empty() => {}
            _ => bail!("trace line {line_no}: missing or empty {key:?}"),
        }
    }
    let mut ns = [0.0f64; 2];
    for (slot, key) in ns.iter_mut().zip(["start_ns", "dur_ns"]) {
        match j.get(key).and_then(Json::as_f64) {
            Some(v) if v >= 0.0 => *slot = v,
            _ => bail!("trace line {line_no}: missing or negative {key:?}"),
        }
    }
    if kind == "event" && ns[1] != 0.0 {
        bail!("trace line {line_no}: event records must have dur_ns 0");
    }
    let parent = match j.get("parent") {
        Some(Json::Null) | None => None,
        Some(p) => match p.as_f64() {
            Some(v) if v >= 1.0 && (v as u64) < id => Some(v as u64),
            _ => bail!("trace line {line_no}: \"parent\" must be null or an earlier id"),
        },
    };
    ensure!(
        j.get("fields").and_then(Json::as_obj).is_some(),
        "trace line {line_no}: \"fields\" must be an object"
    );
    let target = j.get("target").and_then(Json::as_str).unwrap_or("").to_string();
    Ok((
        id,
        ParsedLine {
            kind,
            target,
            dur_ns: ns[1],
            parent,
        },
    ))
}

fn read_trace(path: &str) -> Result<Vec<ParsedLine>> {
    let file = File::open(path).with_context(|| format!("opening trace file {path}"))?;
    let mut lines = BufReader::new(file).lines();
    let header = match lines.next() {
        Some(l) => l.with_context(|| format!("reading {path}"))?,
        None => bail!("{path}: empty file (missing dpquant-trace header)"),
    };
    let h =
        json::parse(&header).map_err(|e| err!("{path}: invalid header JSON: {e}"))?;
    ensure!(
        h.get("format").and_then(Json::as_str) == Some(TRACE_FORMAT),
        "{path}: header format is not {TRACE_FORMAT:?}"
    );
    ensure!(
        h.get("version").and_then(Json::as_f64) == Some(TRACE_VERSION as f64),
        "{path}: unsupported trace version (want {TRACE_VERSION})"
    );
    let mut records = Vec::new();
    let mut span_ids = BTreeSet::new();
    let mut seen_ids = BTreeSet::new();
    for (i, line) in lines.enumerate() {
        let line = line.with_context(|| format!("reading {path}"))?;
        let (id, rec) = parse_record(i + 2, &line)?;
        ensure!(seen_ids.insert(id), "trace line {}: duplicate id {id}", i + 2);
        if rec.kind == "span" {
            span_ids.insert(id);
        }
        records.push(rec);
    }
    for (i, rec) in records.iter().enumerate() {
        if let Some(p) = rec.parent {
            ensure!(
                span_ids.contains(&p),
                "trace line {}: parent {p} is not a span in this file",
                i + 2
            );
        }
    }
    Ok(records)
}

/// Validate every line of `path` against the `dpquant-trace` v1
/// schema: header first, then records with unique ids, well-typed
/// fields, and parents that reference earlier spans.
pub fn check(path: &str) -> Result<TraceStats> {
    let records = read_trace(path)?;
    let mut stats = TraceStats::default();
    for rec in &records {
        if rec.kind == "span" {
            stats.spans += 1;
        } else {
            stats.events += 1;
        }
    }
    Ok(stats)
}

/// Aggregate the spans of `path` into a per-target table, sorted by
/// target name. Events are not aggregated (their `dur_ns` is 0 by
/// schema); [`check`] counts them.
pub fn summarize(path: &str) -> Result<Vec<TraceSummaryRow>> {
    let records = read_trace(path)?;
    let mut by_target: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
    for rec in records {
        if rec.kind == "span" {
            by_target.entry(rec.target).or_default().push(rec.dur_ns);
        }
    }
    let mut rows = Vec::new();
    for (target, mut durs) in by_target {
        durs.sort_by(f64::total_cmp);
        let n = durs.len();
        let total: f64 = durs.iter().sum();
        let p95_idx = ((0.95 * n as f64).ceil() as usize).clamp(1, n) - 1;
        rows.push(TraceSummaryRow {
            target,
            count: n as u64,
            total_ns: total,
            mean_ns: total / n as f64,
            p95_ns: durs[p95_idx],
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("dpquant_trace_{tag}_{}.jsonl", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn write_sample(path: &str, timing: bool) {
        let w = TraceWriter::create(path, timing).unwrap();
        {
            let _outer = w.span("epoch", "session", json::obj(vec![("epoch", json::num(0.0))]));
            w.event("epoch_started", "session", json::obj(vec![("epoch", json::num(0.0))]));
            {
                let _inner = w.span("checkpoint_write", "session", json::obj(vec![]));
            }
        }
        w.event("done", "session", json::obj(vec![]));
        w.finish().unwrap();
    }

    #[test]
    fn schema_checks_and_counts() {
        let path = tmp("schema");
        write_sample(&path, true);
        let stats = check(&path).unwrap();
        assert_eq!(stats, TraceStats { spans: 2, events: 2 });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn parents_nest_spans_and_events() {
        let path = tmp("parents");
        write_sample(&path, false);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].contains("\"format\":\"dpquant-trace\""), "{}", lines[0]);
        // Write order: event(2), inner span(3), outer span(1), event(4).
        let ev = json::parse(lines[1]).unwrap();
        assert_eq!(ev.get("id").unwrap().as_f64(), Some(2.0));
        assert_eq!(ev.get("parent").unwrap().as_f64(), Some(1.0));
        let inner = json::parse(lines[2]).unwrap();
        assert_eq!(inner.get("type").unwrap().as_str(), Some("span"));
        assert_eq!(inner.get("parent").unwrap().as_f64(), Some(1.0));
        let outer = json::parse(lines[3]).unwrap();
        assert_eq!(outer.get("id").unwrap().as_f64(), Some(1.0));
        assert!(matches!(outer.get("parent"), Some(Json::Null)));
        let last = json::parse(lines[4]).unwrap();
        assert!(matches!(last.get("parent"), Some(Json::Null)));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zeroed_timing_is_byte_deterministic() {
        let (a, b) = (tmp("det_a"), tmp("det_b"));
        write_sample(&a, false);
        write_sample(&b, false);
        let (ta, tb) = (
            std::fs::read_to_string(&a).unwrap(),
            std::fs::read_to_string(&b).unwrap(),
        );
        assert_eq!(ta, tb);
        assert!(!ta.lines().skip(1).any(|l| !l.contains("\"dur_ns\":0,")), "{ta}");
        std::fs::remove_file(&a).ok();
        std::fs::remove_file(&b).ok();
    }

    #[test]
    fn summarize_aggregates_per_target() {
        let path = tmp("sum");
        let w = TraceWriter::create(&path, true).unwrap();
        for _ in 0..3 {
            let _s = w.span("epoch", "session", json::obj(vec![]));
        }
        {
            let _k = w.span("write", "checkpoint", json::obj(vec![]));
        }
        w.finish().unwrap();
        let rows = summarize(&path).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].target, "checkpoint");
        assert_eq!(rows[0].count, 1);
        assert_eq!(rows[1].target, "session");
        assert_eq!(rows[1].count, 3);
        assert!(rows[1].p95_ns >= 0.0);
        assert!(rows[1].mean_ns * 3.0 - rows[1].total_ns < 1e-6);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn check_rejects_malformed_files() {
        let path = tmp("bad");
        std::fs::write(&path, "{\"format\":\"other\"}\n").unwrap();
        assert!(check(&path).unwrap_err().to_string().contains("format"));
        std::fs::write(
            &path,
            "{\"format\":\"dpquant-trace\",\"version\":1}\n{\"type\":\"widget\"}\n",
        )
        .unwrap();
        let err = check(&path).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
