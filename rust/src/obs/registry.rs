//! Process-wide metrics registry: named counters, gauges, and
//! fixed-bucket latency histograms.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones over atomics, so hot paths cache one per site and record
//! lock-free; the registry's `Mutex` is touched only on get-or-create
//! and on snapshot. Recording never affects training outputs — the
//! registry is pure observation, read out as a `dpquant-metrics` v1
//! JSON document ([`MetricsRegistry::to_json`]) or a Prometheus-style
//! text exposition ([`MetricsRegistry::to_prometheus`]).

use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Default latency-histogram bucket upper bounds, in nanoseconds:
/// decades from 100 ns to 10 s. Overridable per registry with
/// [`MetricsRegistry::set_default_ns_buckets`] (the `[obs] buckets_ns`
/// config key) or per histogram via [`MetricsRegistry::histogram`].
pub const DEFAULT_NS_BUCKETS: &[f64] = &[
    100.0,
    1_000.0,
    10_000.0,
    100_000.0,
    1_000_000.0,
    10_000_000.0,
    100_000_000.0,
    1_000_000_000.0,
    10_000_000_000.0,
];

/// A monotonically increasing event count. Cloning shares the cell.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins instantaneous reading (f64 bits in an atomic).
/// Cloning shares the cell.
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Overwrite the reading.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Current reading.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

struct HistInner {
    /// Sorted, strictly increasing, finite bucket upper bounds
    /// (value `v` lands in the first bucket with `v <= bound`).
    bounds: Vec<f64>,
    /// One slot per bound plus a trailing overflow slot.
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

/// A fixed-bucket histogram with running count/sum/min/max, recorded
/// lock-free from any thread. Cloning shares the cells.
#[derive(Clone)]
pub struct Histogram(Arc<HistInner>);

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        let mut bounds: Vec<f64> = bounds.iter().copied().filter(|b| b.is_finite()).collect();
        bounds.sort_by(f64::total_cmp);
        bounds.dedup();
        let counts = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Self(Arc::new(HistInner {
            bounds,
            counts,
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }))
    }

    /// Record one observation. Non-finite values are dropped — the
    /// registry must stay serializable as JSON.
    pub fn record(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let i = self
            .0
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.0.bounds.len());
        self.0.counts[i].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.0.sum_bits, v);
        atomic_f64_keep(&self.0.min_bits, v, |new, cur| new < cur);
        atomic_f64_keep(&self.0.max_bits, v, |new, cur| new > cur);
    }

    /// Record a duration, in nanoseconds.
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos() as f64);
    }

    /// RAII timer: records the elapsed nanoseconds on drop.
    #[must_use = "the timer records when dropped; binding it to _ records immediately"]
    pub fn start_timer(&self) -> Timer {
        Timer {
            hist: self.clone(),
            start: Instant::now(),
        }
    }

    /// The bucket upper bounds (sorted, without the overflow slot).
    pub fn bounds(&self) -> &[f64] {
        &self.0.bounds
    }

    /// Per-bucket counts; the last entry is the overflow bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.0.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations.
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.0.sum_bits.load(Ordering::Relaxed))
    }

    /// Smallest observation (0.0 when empty).
    pub fn min(&self) -> f64 {
        let v = f64::from_bits(self.0.min_bits.load(Ordering::Relaxed));
        if v.is_finite() { v } else { 0.0 }
    }

    /// Largest observation (0.0 when empty).
    pub fn max(&self) -> f64 {
        let v = f64::from_bits(self.0.max_bits.load(Ordering::Relaxed));
        if v.is_finite() { v } else { 0.0 }
    }

    /// Mean observation (0.0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 { 0.0 } else { self.sum() / n as f64 }
    }

    /// Estimated 95th percentile: the upper bound of the bucket where
    /// the cumulative count crosses 95%, clamped to the recorded
    /// `[min, max]` so the estimate never leaves the observed range.
    pub fn p95(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = ((0.95 * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.0.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                let est = if i < self.0.bounds.len() {
                    self.0.bounds[i]
                } else {
                    self.max()
                };
                return est.clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Snapshot as the histogram object of the `dpquant-metrics`
    /// schema: per-bucket `{le, count}` rows plus overflow and the
    /// running count/sum/min/max/mean/p95.
    pub fn to_json(&self) -> Json {
        let counts = self.bucket_counts();
        let buckets: Vec<Json> = self
            .0
            .bounds
            .iter()
            .zip(&counts)
            .map(|(&le, &count)| {
                json::obj(vec![("count", json::num(count as f64)), ("le", json::num(le))])
            })
            .collect();
        json::obj(vec![
            ("buckets", Json::Arr(buckets)),
            ("count", json::num(self.count() as f64)),
            ("max", json::num(self.max())),
            ("mean", json::num(self.mean())),
            ("min", json::num(self.min())),
            ("overflow", json::num(*counts.last().expect("overflow slot") as f64)),
            ("p95", json::num(self.p95())),
            ("sum", json::num(self.sum())),
        ])
    }
}

/// RAII guard from [`Histogram::start_timer`]; records the elapsed
/// time into the histogram when dropped.
pub struct Timer {
    hist: Histogram,
    start: Instant,
}

impl Drop for Timer {
    fn drop(&mut self) {
        self.hist.record_duration(self.start.elapsed());
    }
}

fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

fn atomic_f64_keep(cell: &AtomicU64, v: f64, wins: fn(f64, f64) -> bool) {
    let mut cur = cell.load(Ordering::Relaxed);
    while wins(v, f64::from_bits(cur)) {
        match cell.compare_exchange_weak(cur, v.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    histograms: BTreeMap<String, Histogram>,
    default_ns_buckets: Vec<f64>,
}

/// Named counters/gauges/histograms with get-or-create semantics. All
/// methods take `&self`; one registry is shared process-wide through
/// [`crate::obs::global`].
pub struct MetricsRegistry {
    inner: Mutex<RegistryInner>,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRegistry {
    /// An empty registry with the [`DEFAULT_NS_BUCKETS`] defaults.
    pub fn new() -> Self {
        Self {
            inner: Mutex::new(RegistryInner {
                counters: BTreeMap::new(),
                gauges: BTreeMap::new(),
                histograms: BTreeMap::new(),
                default_ns_buckets: DEFAULT_NS_BUCKETS.to_vec(),
            }),
        }
    }

    fn lock(&self) -> MutexGuard<'_, RegistryInner> {
        // A panicking recorder must not take observability down with it.
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Counter {
        self.lock()
            .counters
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        self.lock().gauges.entry(name.to_string()).or_default().clone()
    }

    /// Get or create the histogram `name`. `bounds` only applies on
    /// first creation; an existing histogram keeps its buckets.
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Histogram {
        self.lock()
            .histograms
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds))
            .clone()
    }

    /// Get or create a latency histogram with the registry's default
    /// nanosecond buckets.
    pub fn histogram_ns(&self, name: &str) -> Histogram {
        let mut inner = self.lock();
        if let Some(h) = inner.histograms.get(name) {
            return h.clone();
        }
        let h = Histogram::new(&inner.default_ns_buckets);
        inner.histograms.insert(name.to_string(), h.clone());
        h
    }

    /// Replace the default buckets used by [`Self::histogram_ns`] for
    /// histograms created after this call.
    pub fn set_default_ns_buckets(&self, bounds: &[f64]) {
        let sane: Vec<f64> = bounds.iter().copied().filter(|b| b.is_finite()).collect();
        if !sane.is_empty() {
            self.lock().default_ns_buckets = sane;
        }
    }

    /// Snapshot every metric as the `metrics` object of the
    /// `dpquant-metrics` v1 schema: `counters`/`gauges`/`histograms`
    /// maps keyed by metric name (sorted — `BTreeMap` order).
    pub fn to_json(&self) -> Json {
        let inner = self.lock();
        let mut counters = BTreeMap::new();
        for (name, c) in &inner.counters {
            counters.insert(name.clone(), json::num(c.get() as f64));
        }
        let mut gauges = BTreeMap::new();
        for (name, g) in &inner.gauges {
            gauges.insert(name.clone(), json::num(g.get()));
        }
        let mut histograms = BTreeMap::new();
        for (name, h) in &inner.histograms {
            histograms.insert(name.clone(), h.to_json());
        }
        json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("histograms", Json::Obj(histograms)),
        ])
    }

    /// Prometheus-style text exposition of the same snapshot: `# TYPE`
    /// lines, cumulative `_bucket{le=...}` rows ending in `+Inf`, and
    /// `_sum`/`_count` per histogram. Metric names are sanitized to
    /// `[a-zA-Z0-9_]`.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let inner = self.lock();
        let mut out = String::new();
        for (name, c) in &inner.counters {
            let n = prom_name(name);
            let _ = writeln!(out, "# TYPE {n} counter");
            let _ = writeln!(out, "{n} {}", c.get());
        }
        for (name, g) in &inner.gauges {
            let n = prom_name(name);
            let _ = writeln!(out, "# TYPE {n} gauge");
            let _ = writeln!(out, "{n} {}", g.get());
        }
        for (name, h) in &inner.histograms {
            let n = prom_name(name);
            let _ = writeln!(out, "# TYPE {n} histogram");
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (&le, &count) in h.bounds().iter().zip(&counts) {
                cum += count;
                let _ = writeln!(out, "{n}_bucket{{le=\"{le}\"}} {cum}");
            }
            let _ = writeln!(out, "{n}_bucket{{le=\"+Inf\"}} {}", h.count());
            let _ = writeln!(out, "{n}_sum {}", h.sum());
            let _ = writeln!(out, "{n}_count {}", h.count());
        }
        out
    }
}

fn prom_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = MetricsRegistry::new();
        let c = r.counter("a.count");
        c.inc();
        c.add(4);
        // A second handle to the same name shares the cell.
        assert_eq!(r.counter("a.count").get(), 5);
        let g = r.gauge("a.gauge");
        g.set(2.5);
        assert_eq!(r.gauge("a.gauge").get(), 2.5);
    }

    #[test]
    fn histogram_buckets_and_stats() {
        let h = Histogram::new(&[10.0, 100.0, 1000.0]);
        for v in [1.0, 5.0, 10.0, 50.0, 500.0, 5000.0] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.bucket_counts(), vec![3, 1, 1, 1]);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 5000.0);
        assert_eq!(h.sum(), 5566.0);
        // p95 lands in the overflow bucket -> max, inside [min, max].
        let p95 = h.p95();
        assert!(p95 >= h.min() && p95 <= h.max(), "{p95}");
        // Non-finite observations are dropped, not recorded.
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn histogram_bounds_are_sorted_and_deduped() {
        let h = Histogram::new(&[100.0, 1.0, 100.0, f64::NAN, 10.0]);
        assert_eq!(h.bounds(), &[1.0, 10.0, 100.0]);
    }

    #[test]
    fn empty_histogram_serializes_finite() {
        let h = Histogram::new(&[10.0]);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.p95(), 0.0);
        let s = h.to_json().to_string();
        assert!(!s.contains("inf"), "{s}");
    }

    #[test]
    fn timer_records_on_drop() {
        let r = MetricsRegistry::new();
        let h = r.histogram_ns("t.ns");
        {
            let _t = h.start_timer();
        }
        assert_eq!(h.count(), 1);
        assert!(h.sum() >= 0.0);
    }

    #[test]
    fn snapshot_json_and_prometheus() {
        let r = MetricsRegistry::new();
        r.counter("jobs.done").add(3);
        r.gauge("queue.depth").set(2.0);
        r.histogram("lat.ns", &[10.0, 100.0]).record(50.0);
        let j = r.to_json();
        assert_eq!(j.get("counters").unwrap().get("jobs.done").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("gauges").unwrap().get("queue.depth").unwrap().as_f64(), Some(2.0));
        let h = j.get("histograms").unwrap().get("lat.ns").unwrap();
        assert_eq!(h.get("count").unwrap().as_f64(), Some(1.0));
        let text = r.to_prometheus();
        assert!(text.contains("# TYPE jobs_done counter"), "{text}");
        assert!(text.contains("jobs_done 3"), "{text}");
        assert!(text.contains("lat_ns_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("lat_ns_count 1"), "{text}");
    }

    #[test]
    fn default_bucket_override_applies_to_new_histograms() {
        let r = MetricsRegistry::new();
        let before = r.histogram_ns("h.before");
        assert_eq!(before.bounds(), DEFAULT_NS_BUCKETS);
        r.set_default_ns_buckets(&[1.0, 2.0]);
        assert_eq!(r.histogram_ns("h.after").bounds(), &[1.0, 2.0]);
        // Existing histograms keep their buckets.
        assert_eq!(r.histogram_ns("h.before").bounds(), DEFAULT_NS_BUCKETS);
    }
}
