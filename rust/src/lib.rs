//! # DPQuant
//!
//! A from-scratch reproduction of *DPQuant: Efficient and
//! Differentially-Private Model Training via Dynamic Quantization
//! Scheduling* as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas quantizer kernels (`python/compile/kernels/`),
//!   AOT-lowered into the training graph;
//! * **L2** — JAX DP-training step graphs (`python/compile/`), exported
//!   once as HLO text into `artifacts/`;
//! * **L3** — this crate: the DPQuant coordinator (dynamic quantization
//!   scheduling, Algorithms 1–2), the DP mechanism (fp32 Gaussian noise),
//!   optimizers, the RDP privacy accountant, data pipeline, experiment
//!   harness and CLI. Python never runs on the training path.
//!
//! The coordinator's public API is
//! [`TrainSession`](coordinator::TrainSession): a resumable state
//! machine over the epoch loop with a typed
//! [`TrainEvent`](coordinator::TrainEvent) stream and bit-exact
//! checkpoint/resume (DESIGN.md §10); the batch
//! [`train()`](coordinator::train) entry point is a thin wrapper.
//!
//! The [`backend`] module additionally provides a **native pure-Rust
//! execution engine** (`--backend native`, the default): real
//! forward/backward passes with exact per-sample gradients and the
//! `quant/` kernels applied on the live compute path — so training,
//! experiments and benches run end-to-end with zero artifacts.
//!
//! The [`sweep`] module runs whole evaluation *grids* (quantizer ×
//! quant_fraction × scheduler × seed, the shape of the paper's Fig. 4 /
//! Tab. 8 evidence) on a work-stealing thread pool — one session per
//! worker over `Arc`-shared datasets — aggregating into a deterministic
//! `BENCH_sweep.json` report that is byte-identical at any `--jobs`
//! count (DESIGN.md §11).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod perfmodel;
pub mod privacy;
pub mod quant;
pub mod runtime;
pub mod sweep;
pub mod util;
pub mod xla;
