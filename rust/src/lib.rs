//! # DPQuant
//!
//! A from-scratch reproduction of *DPQuant: Efficient and
//! Differentially-Private Model Training via Dynamic Quantization
//! Scheduling* as a three-layer Rust + JAX + Pallas system:
//!
//! * **L1** — Pallas quantizer kernels (`python/compile/kernels/`),
//!   AOT-lowered into the training graph;
//! * **L2** — JAX DP-training step graphs (`python/compile/`), exported
//!   once as HLO text into `artifacts/`;
//! * **L3** — this crate: the DPQuant coordinator (dynamic quantization
//!   scheduling, Algorithms 1–2), the DP mechanism (fp32 Gaussian noise),
//!   optimizers, the RDP privacy accountant, data pipeline, experiment
//!   harness and CLI. Python never runs on the training path.
//!
//! The coordinator's public API is
//! [`TrainSession`](coordinator::TrainSession): a resumable state
//! machine over the epoch loop with a typed
//! [`TrainEvent`](coordinator::TrainEvent) stream and bit-exact
//! checkpoint/resume (DESIGN.md §10); the batch
//! [`train()`](coordinator::train) entry point is a thin wrapper.
//!
//! The [`backend`] module additionally provides a **native pure-Rust
//! execution engine** (`--backend native`, the default): real
//! forward/backward passes with exact per-sample gradients and the
//! `quant/` kernels applied on the live compute path — so training,
//! experiments and benches run end-to-end with zero artifacts.
//!
//! The [`sweep`] module runs whole evaluation *grids* (quantizer ×
//! quant_fraction × scheduler × seed, the shape of the paper's Fig. 4 /
//! Tab. 8 evidence) on a work-stealing thread pool — one session per
//! worker over `Arc`-shared datasets — aggregating into a deterministic
//! `BENCH_sweep.json` report that is byte-identical at any `--jobs`
//! count (DESIGN.md §11).
//!
//! The [`serve`] module turns training into a **service**: `dpquant
//! serve` runs a zero-dependency HTTP/1.1 daemon whose job manager
//! schedules concurrent `TrainSession`s on a long-lived worker pool,
//! streams epoch progress into per-job ring buffers, and — with a
//! `--state-dir` — checkpoints every job so a killed daemon restarts
//! and finishes them bit-exactly; `dpquant job
//! submit|list|status|events|audit|cancel|wait` is the client
//! (DESIGN.md §12).
//!
//! The [`obs`] module is the observability layer (DESIGN.md §14): a
//! process-wide metrics registry (counters/gauges/latency histograms,
//! fed by the hot kernels, the worker pool, and the HTTP server) plus
//! `dpquant-trace` v1 span/event trace files written by `dpquant
//! train --trace-out` and inspected with `dpquant trace
//! summarize|check`, and the `dpquant-audit` v1 DP audit trail
//! (DESIGN.md §17) written by `--audit-out` (and by every served job
//! under `--state-dir`), whose recorded ε timeline `dpquant audit
//! replay` re-derives bit-exactly through a fresh accountant.
//! Observability is pure observation — outputs are byte-identical
//! with it on or off.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.

#![warn(missing_docs)]

pub mod backend;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod privacy;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod util;
pub mod xla;

/// The version banner `dpquant version` / `dpquant --version` print:
/// crate version plus every on-disk/wire format version this build
/// speaks, so operators can check client/daemon compatibility at a
/// glance (a daemon reports the same list on `GET /v1/healthz`).
pub fn version() -> String {
    format!(
        "dpquant {}\nformats: {} v{}, {} v{}, {} v{}, {} v{}, {} v{}, {} v{}, {} v{}, {} v{}",
        env!("CARGO_PKG_VERSION"),
        coordinator::session::CHECKPOINT_FORMAT,
        coordinator::session::CHECKPOINT_VERSION,
        sweep::report::REPORT_FORMAT,
        sweep::report::REPORT_VERSION,
        serve::api::API_FORMAT,
        serve::api::API_VERSION,
        serve::ledger::LEDGER_FORMAT,
        serve::ledger::LEDGER_VERSION,
        exp::perf::BENCH_FORMAT,
        exp::perf::BENCH_VERSION,
        obs::TRACE_FORMAT,
        obs::TRACE_VERSION,
        obs::METRICS_FORMAT,
        obs::METRICS_VERSION,
        obs::AUDIT_FORMAT,
        obs::AUDIT_VERSION,
    )
}

#[cfg(test)]
mod tests {
    #[test]
    fn version_names_every_format() {
        let v = super::version();
        assert!(v.starts_with("dpquant "), "{v}");
        assert!(v.contains(env!("CARGO_PKG_VERSION")), "{v}");
        assert!(v.contains("dpquant-trainsession v1"), "{v}");
        assert!(v.contains("dpquant-sweep-report v1"), "{v}");
        assert!(v.contains("dpquant-serve-api v1"), "{v}");
        assert!(v.contains("dpquant-serve-ledger v1"), "{v}");
        assert!(v.contains("dpquant-bench v1"), "{v}");
        assert!(v.contains("dpquant-trace v1"), "{v}");
        assert!(v.contains("dpquant-metrics v1"), "{v}");
        assert!(v.contains("dpquant-audit v1"), "{v}");
    }
}
