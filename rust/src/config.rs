//! Experiment configuration: a TOML-subset parser plus the typed config
//! the launcher consumes.
//!
//! The offline crate set has no `toml`/`serde`, so we parse the subset we
//! emit in `configs/*.toml`: `[section]` headers, `key = value` with
//! string / bool / int / float / homogeneous scalar arrays, `#` comments.

use std::collections::BTreeMap;
use std::fmt;

/// A config parsing/validation failure. Implements `std::error::Error`,
/// so call sites propagate with plain `?` into `util::error::Error`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ConfigError(String);

impl ConfigError {
    /// Ad-hoc config error from anything printable.
    pub fn new<M: fmt::Display>(msg: M) -> Self {
        Self(msg.to_string())
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for ConfigError {}

/// A scalar or array value from a config file.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// `true` / `false`.
    Bool(bool),
    /// An integer literal.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// A homogeneous scalar array.
    Array(Vec<Value>),
}

impl Value {
    /// The string value, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The integer value, if this is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Numeric coercion: ints read as floats too.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The element list, if this is a [`Value::Array`].
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed config: `section.key -> Value` (top-level keys live under `""`).
#[derive(Clone, Debug, Default)]
pub struct ConfigFile {
    /// `(section, key) -> value`; top-level keys use section `""`.
    pub entries: BTreeMap<(String, String), Value>,
}

impl ConfigFile {
    /// Parse config text (the TOML subset described in the module docs).
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| {
                    ConfigError::new(format!("line {}: unterminated section", lineno + 1))
                })?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                ConfigError::new(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| ConfigError::new(format!("line {}: {e}", lineno + 1)))?;
            entries.insert((section.clone(), key), val);
        }
        Ok(Self { entries })
    }

    /// Read and parse a config file from disk.
    pub fn load(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::new(format!("{path}: {e}")))?;
        Self::parse(&text)
    }

    /// Look up `section.key` (`""` for top-level keys).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// `section.key` as f64 (ints coerce), or `default`.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }
    /// `section.key` as i64, or `default`.
    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }
    /// `section.key` as a string, or `default`.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }
    /// `section.key` as a bool, or `default`.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Which optimizer drives training (paper: DP-SGD main, DP-Adam §A.5,
/// DP-AdamW for BERT/SNLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain DP-SGD (the paper's main optimizer).
    Sgd,
    /// DP-Adam (paper §A.5).
    Adam,
    /// DP-AdamW (decoupled weight decay; BERT/SNLI runs).
    AdamW,
}

impl OptimizerKind {
    /// Parse an optimizer name (accepts `sgd`/`dp-sgd`-style aliases).
    pub fn parse(s: &str) -> Result<Self, ConfigError> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" | "dp-sgd" | "dpsgd" => Ok(Self::Sgd),
            "adam" | "dp-adam" | "dpadam" => Ok(Self::Adam),
            "adamw" | "dp-adamw" | "dpadamw" => Ok(Self::AdamW),
            other => Err(ConfigError::new(format!("unknown optimizer '{other}'"))),
        }
    }
    /// Canonical lowercase name (inverse of [`OptimizerKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sgd => "sgd",
            Self::Adam => "adam",
            Self::AdamW => "adamw",
        }
    }
}

/// Fully-resolved training/scheduling configuration (paper Table 3 + 5).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Model artifact family (miniresnet / miniconvnet / minidensenet /
    /// tinytransformer).
    pub model: String,
    /// Dataset (gtsrb / emnist / cifar / snli — synthetic generators).
    pub dataset: String,
    /// Quantizer variant baked into the train artifact (luq4 / uniform4 /
    /// fp8).
    pub quantizer: String,
    /// Epochs to train (paper n = 60; scaled default lower).
    pub epochs: usize,
    /// Logical (privacy) batch size — expected Poisson batch size.
    pub batch_size: usize,
    /// DP-SGD noise multiplier σ.
    pub noise_multiplier: f64,
    /// DP-SGD clipping norm C.
    pub clip_norm: f64,
    /// Learning rate η.
    pub lr: f64,
    /// Optimizer family (SGD / Adam / AdamW).
    pub optimizer: OptimizerKind,
    /// Target privacy budget; training truncates when exceeded (None = run
    /// all epochs).
    pub target_epsilon: Option<f64>,
    /// Privacy parameter δ for (ε, δ)-DP reporting.
    pub delta: f64,
    /// Fraction of quantizable layers to quantize each epoch ("percent
    /// quantized" in Table 1).
    pub quant_fraction: f64,
    /// Scheduler: "dpquant" (PLS+LLP), "pls" (sampling only),
    /// "static_random" (fixed random subset), "static_first"/"static_last",
    /// "none" (full precision), "all" (everything quantized).
    pub scheduler: String,
    /// Softmax temperature β (Algorithm 2; Table 9 sweeps this).
    pub beta: f64,
    /// Epochs between loss-impact analyses (n_interval, Table 3).
    pub analysis_interval: usize,
    /// Repetitions R inside Algorithm 1.
    pub analysis_reps: usize,
    /// n_sample (Table 3): expected number of examples in the analysis
    /// probe subsample. The probe rate is `analysis_samples / |D|`, which
    /// keeps the analysis SGM's privacy cost negligible (Fig. 3).
    pub analysis_samples: usize,
    /// σ_measure — noise for loss-difference privatization.
    pub sigma_measure: f64,
    /// C_measure — clip norm for loss-difference privatization.
    pub clip_measure: f64,
    /// EMA decay α in Algorithm 1 step 4.
    pub ema_alpha: f64,
    /// Disable EMA (Table 10 ablation).
    pub ema_enabled: bool,
    /// Dataset size (synthetic generator).
    pub dataset_size: usize,
    /// Validation set size.
    pub val_size: usize,
    /// RNG seed for the whole run.
    pub seed: u64,
    /// Physical batch cap (memory bound; Poisson batches are trimmed/padded
    /// to at most this many examples per executable call).
    pub physical_batch: usize,
    /// Execution backend: "native" (pure-Rust engine, default — needs no
    /// artifacts), "pjrt" (AOT artifacts + XLA runtime), or "mock"
    /// (logistic regression with simulated quantization damage).
    pub backend: String,
    /// Adaptive-DP policy: "static" (the paper's fixed knobs, default),
    /// "noise_decay" (Dynamic DP-SGD σ/C schedules), "rate_schedule"
    /// (DPIS-style sampling-rate schedule), or "layer_lr" (per-layer
    /// learning rates from the privatized EMA scores). DESIGN.md §16.
    pub policy: String,
    /// Final noise multiplier for policy = "noise_decay" (σ at the last
    /// epoch). 0.0 holds σ at `noise_multiplier`.
    pub noise_final: f64,
    /// Final clipping norm for policy = "noise_decay" (C at the last
    /// epoch). 0.0 holds C at `clip_norm`.
    pub clip_final: f64,
    /// Final Poisson sampling rate for policy = "rate_schedule" (q at
    /// the last epoch). 0.0 holds q at `batch_size / dataset_size`.
    pub rate_final: f64,
    /// Interpolation shape for "noise_decay": "linear" or "exp".
    pub decay_shape: String,
    /// Spread of the per-layer lr factors for policy = "layer_lr":
    /// factors span [1 − s/2, 1 + s/2]. Must be in [0, 2).
    pub layer_lr_strength: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            model: "miniconvnet".into(),
            dataset: "gtsrb".into(),
            quantizer: "luq4".into(),
            epochs: 12,
            batch_size: 64,
            noise_multiplier: 1.0,
            clip_norm: 1.0,
            lr: 0.5,
            optimizer: OptimizerKind::Sgd,
            target_epsilon: None,
            delta: 1e-5,
            quant_fraction: 0.75,
            scheduler: "dpquant".into(),
            beta: 10.0,
            analysis_interval: 2,
            analysis_reps: 2,
            analysis_samples: 8,
            sigma_measure: 0.5,
            clip_measure: 0.01,
            ema_alpha: 0.3,
            ema_enabled: true,
            dataset_size: 4096,
            val_size: 1024,
            seed: 0,
            physical_batch: 64,
            backend: "native".into(),
            policy: "static".into(),
            noise_final: 0.0,
            clip_final: 0.0,
            rate_final: 0.0,
            decay_shape: "linear".into(),
            layer_lr_strength: 0.5,
        }
    }
}

/// Every key `TrainConfig::from_file` reads from the `[train]` section.
/// Anything else in that section is a typo (or a key from a different
/// version) — `from_file` warns so a misspelled `quant_fracton` cannot
/// silently run the wrong experiment.
pub const KNOWN_TRAIN_KEYS: &[&str] = &[
    "model",
    "dataset",
    "quantizer",
    "epochs",
    "batch_size",
    "noise_multiplier",
    "clip_norm",
    "lr",
    "optimizer",
    "target_epsilon",
    "delta",
    "quant_fraction",
    "scheduler",
    "beta",
    "analysis_interval",
    "analysis_reps",
    "analysis_samples",
    "sigma_measure",
    "clip_measure",
    "ema_alpha",
    "ema_enabled",
    "dataset_size",
    "val_size",
    "seed",
    "physical_batch",
    "backend",
    "policy",
    "noise_final",
    "clip_final",
    "rate_final",
    "decay_shape",
    "layer_lr_strength",
];

/// The `--key` command-line forms [`TrainConfig::from_args`] reads.
/// Commands that build a config pass these to `Args::require_known`
/// (plus their own extras), and `train --resume` uses the list to
/// reject silently-ignored overrides.
pub const CONFIG_ARG_KEYS: &[&str] = &[
    "config",
    "model",
    "dataset",
    "quantizer",
    "scheduler",
    "optimizer",
    "epochs",
    "batch-size",
    "noise-multiplier",
    "clip-norm",
    "lr",
    "quant-fraction",
    "beta",
    "analysis-interval",
    "sigma-measure",
    "analysis-samples",
    "dataset-size",
    "val-size",
    "seed",
    "target-epsilon",
    "backend",
    "policy",
    "noise-final",
    "clip-final",
    "rate-final",
    "decay-shape",
    "layer-lr-strength",
];

impl TrainConfig {
    /// Keys in the `[train]` section that `from_file` does not read.
    pub fn unknown_keys(cf: &ConfigFile) -> Vec<String> {
        cf.entries
            .keys()
            .filter(|(sec, key)| sec == "train" && !KNOWN_TRAIN_KEYS.contains(&key.as_str()))
            .map(|(_, key)| key.clone())
            .collect()
    }

    /// Sections other than `[train]` that contain trainer keys — almost
    /// certainly a misspelled section header (`[trian]`, `[Train]`):
    /// every key inside one is silently dropped by `from_file`.
    /// `[sweep]` is exempt: it legitimately holds trainer keys as sweep
    /// axes (read by `sweep::grid::GridSpec::from_config`).
    pub fn suspect_sections(cf: &ConfigFile) -> Vec<String> {
        let mut sections: Vec<String> = cf
            .entries
            .keys()
            .filter(|(sec, key)| {
                sec != "train" && sec != "sweep" && KNOWN_TRAIN_KEYS.contains(&key.as_str())
            })
            .map(|(sec, _)| sec.clone())
            .collect();
        sections.dedup();
        sections
    }

    /// Resolve from a parsed file (section `[train]`), falling back to
    /// defaults for missing keys. Unknown keys in `[train]` — and
    /// non-`[train]` sections that hold trainer keys (a misspelled
    /// header) — produce a stderr warning: both would otherwise run the
    /// wrong experiment silently.
    pub fn from_file(cf: &ConfigFile) -> Result<Self, ConfigError> {
        for key in Self::unknown_keys(cf) {
            eprintln!("warning: config key [train] {key} is not recognized and will be ignored");
        }
        for sec in Self::suspect_sections(cf) {
            eprintln!(
                "warning: section [{sec}] contains trainer keys but only [train] is read — \
                 did you mean [train]?"
            );
        }
        let d = Self::default();
        let sec = "train";
        let optimizer = OptimizerKind::parse(&cf.str_or(sec, "optimizer", d.optimizer.name()))?;
        Ok(Self {
            model: cf.str_or(sec, "model", &d.model),
            dataset: cf.str_or(sec, "dataset", &d.dataset),
            quantizer: cf.str_or(sec, "quantizer", &d.quantizer),
            epochs: cf.i64_or(sec, "epochs", d.epochs as i64) as usize,
            batch_size: cf.i64_or(sec, "batch_size", d.batch_size as i64) as usize,
            noise_multiplier: cf.f64_or(sec, "noise_multiplier", d.noise_multiplier),
            clip_norm: cf.f64_or(sec, "clip_norm", d.clip_norm),
            lr: cf.f64_or(sec, "lr", d.lr),
            optimizer,
            target_epsilon: cf.get(sec, "target_epsilon").and_then(Value::as_f64),
            delta: cf.f64_or(sec, "delta", d.delta),
            quant_fraction: cf.f64_or(sec, "quant_fraction", d.quant_fraction),
            scheduler: cf.str_or(sec, "scheduler", &d.scheduler),
            beta: cf.f64_or(sec, "beta", d.beta),
            analysis_interval: cf.i64_or(sec, "analysis_interval", d.analysis_interval as i64)
                as usize,
            analysis_reps: cf.i64_or(sec, "analysis_reps", d.analysis_reps as i64) as usize,
            analysis_samples: cf.i64_or(sec, "analysis_samples", d.analysis_samples as i64)
                as usize,
            sigma_measure: cf.f64_or(sec, "sigma_measure", d.sigma_measure),
            clip_measure: cf.f64_or(sec, "clip_measure", d.clip_measure),
            ema_alpha: cf.f64_or(sec, "ema_alpha", d.ema_alpha),
            ema_enabled: cf.bool_or(sec, "ema_enabled", d.ema_enabled),
            dataset_size: cf.i64_or(sec, "dataset_size", d.dataset_size as i64) as usize,
            val_size: cf.i64_or(sec, "val_size", d.val_size as i64) as usize,
            seed: cf.i64_or(sec, "seed", d.seed as i64) as u64,
            physical_batch: cf.i64_or(sec, "physical_batch", d.physical_batch as i64) as usize,
            backend: cf.str_or(sec, "backend", &d.backend),
            policy: cf.str_or(sec, "policy", &d.policy),
            noise_final: cf.f64_or(sec, "noise_final", d.noise_final),
            clip_final: cf.f64_or(sec, "clip_final", d.clip_final),
            rate_final: cf.f64_or(sec, "rate_final", d.rate_final),
            decay_shape: cf.str_or(sec, "decay_shape", &d.decay_shape),
            layer_lr_strength: cf.f64_or(sec, "layer_lr_strength", d.layer_lr_strength),
        })
    }

    /// Resolve from the command line: `--config file` first (when
    /// given), then individual `--key` overrides on top. Shared by every
    /// config-consuming command (`train`, `eval-only`, `bench-step`,
    /// `sweep`); the accepted keys are [`CONFIG_ARG_KEYS`].
    pub fn from_args(args: &crate::cli::Args) -> crate::util::error::Result<Self> {
        let base = match args.get("config") {
            Some(path) => Self::from_file(&ConfigFile::load(path)?)?,
            None => Self::default(),
        };
        base.with_arg_overrides(args)
    }

    /// Apply the `--key` overrides to an already-resolved base config.
    /// Split from [`TrainConfig::from_args`] for callers that parse the
    /// `--config` file themselves (the sweep also reads its `[sweep]`
    /// section from the same parse).
    pub fn with_arg_overrides(
        mut self,
        args: &crate::cli::Args,
    ) -> crate::util::error::Result<Self> {
        let cfg = &mut self;
        if let Some(v) = args.get("model") {
            cfg.model = v.to_string();
        }
        if let Some(v) = args.get("dataset") {
            cfg.dataset = v.to_string();
        }
        if let Some(v) = args.get("quantizer") {
            cfg.quantizer = v.to_string();
        }
        if let Some(v) = args.get("scheduler") {
            cfg.scheduler = v.to_string();
        }
        if let Some(v) = args.get("optimizer") {
            cfg.optimizer = OptimizerKind::parse(v)?;
        }
        cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
        cfg.batch_size = args.usize_or("batch-size", cfg.batch_size)?;
        cfg.noise_multiplier = args.f64_or("noise-multiplier", cfg.noise_multiplier)?;
        cfg.clip_norm = args.f64_or("clip-norm", cfg.clip_norm)?;
        cfg.lr = args.f64_or("lr", cfg.lr)?;
        cfg.quant_fraction = args.f64_or("quant-fraction", cfg.quant_fraction)?;
        cfg.beta = args.f64_or("beta", cfg.beta)?;
        cfg.analysis_interval = args.usize_or("analysis-interval", cfg.analysis_interval)?;
        cfg.sigma_measure = args.f64_or("sigma-measure", cfg.sigma_measure)?;
        cfg.analysis_samples = args.usize_or("analysis-samples", cfg.analysis_samples)?;
        cfg.dataset_size = args.usize_or("dataset-size", cfg.dataset_size)?;
        cfg.val_size = args.usize_or("val-size", cfg.val_size)?;
        cfg.seed = args.u64_or("seed", cfg.seed)?;
        if let Some(eps) = args.f64_opt("target-epsilon")? {
            cfg.target_epsilon = Some(eps);
        }
        if args.has_flag("no-ema") {
            cfg.ema_enabled = false;
        }
        if let Some(v) = args.get("backend") {
            cfg.backend = v.to_string();
        }
        if let Some(v) = args.get("policy") {
            cfg.policy = v.to_string();
        }
        cfg.noise_final = args.f64_or("noise-final", cfg.noise_final)?;
        cfg.clip_final = args.f64_or("clip-final", cfg.clip_final)?;
        cfg.rate_final = args.f64_or("rate-final", cfg.rate_final)?;
        if let Some(v) = args.get("decay-shape") {
            cfg.decay_shape = v.to_string();
        }
        cfg.layer_lr_strength = args.f64_or("layer-lr-strength", cfg.layer_lr_strength)?;
        Ok(self)
    }

    /// Poisson sampling rate q = B/|D| used by the accountant.
    pub fn sample_rate(&self) -> f64 {
        self.batch_size as f64 / self.dataset_size as f64
    }

    /// Graph tag in the artifact manifest for this config.
    pub fn graph_tag(&self) -> String {
        format!("{}_{}_{}", self.model, self.dataset, self.quantizer)
    }

    /// Train artifact name for this config.
    pub fn train_artifact(&self) -> String {
        format!("train_{}", self.graph_tag())
    }

    /// Eval artifact name for this config.
    pub fn eval_artifact(&self) -> String {
        format!("eval_{}_{}", self.model, self.dataset)
    }
}

/// Every key the `[serve]` config section understands.
pub const KNOWN_SERVE_KEYS: &[&str] = &["addr", "jobs", "state_dir"];

/// Daemon configuration for `dpquant serve`, resolved from the
/// `[serve]` config section with `--addr` / `--jobs` / `--state-dir`
/// flag overrides on top (same layering as [`TrainConfig::from_args`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// Listen address, `host:port`. Port 0 binds an ephemeral port (the
    /// daemon prints the actual one).
    pub addr: String,
    /// Concurrent training jobs — the job manager's long-lived worker
    /// count. Deliberately a small fixed default rather than the core
    /// count: each worker runs a whole training session.
    pub jobs: usize,
    /// Durability directory: job manifests + per-job checkpoints land
    /// here, and a restarted daemon recovers every job from it. `None`
    /// disables persistence (jobs die with the process).
    pub state_dir: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:8117".into(),
            jobs: 2,
            state_dir: None,
        }
    }
}

impl ServeConfig {
    /// Resolve from a parsed file's `[serve]` section, warning on
    /// unknown keys (the `[train]`-section treatment).
    pub fn from_file(cf: &ConfigFile) -> Result<Self, ConfigError> {
        for (sec, key) in cf.entries.keys() {
            if sec == "serve" && !KNOWN_SERVE_KEYS.contains(&key.as_str()) {
                eprintln!(
                    "warning: config key [serve] {key} is not recognized and will be ignored"
                );
            }
        }
        let d = Self::default();
        let jobs = cf.i64_or("serve", "jobs", d.jobs as i64);
        if jobs < 1 {
            return Err(ConfigError::new(format!(
                "[serve] jobs = {jobs}: the daemon needs at least one worker"
            )));
        }
        Ok(Self {
            addr: cf.str_or("serve", "addr", &d.addr),
            jobs: jobs as usize,
            state_dir: cf
                .get("serve", "state_dir")
                .and_then(Value::as_str)
                .map(str::to_string),
        })
    }

    /// Resolve from the command line: `--config file` first (when
    /// given), then `--addr` / `--jobs` / `--state-dir` overrides.
    pub fn from_args(args: &crate::cli::Args) -> crate::util::error::Result<Self> {
        let mut sc = match args.get("config") {
            Some(path) => Self::from_file(&ConfigFile::load(path)?)?,
            None => Self::default(),
        };
        if let Some(addr) = args.get("addr") {
            sc.addr = addr.to_string();
        }
        if let Some(jobs) = args.usize_opt("jobs")? {
            if jobs < 1 {
                return Err(crate::cli::ArgError::new(
                    "--jobs 0: the daemon needs at least one worker",
                )
                .into());
            }
            sc.jobs = jobs;
        }
        if let Some(dir) = args.get("state-dir") {
            sc.state_dir = Some(dir.to_string());
        }
        Ok(sc)
    }
}

/// Every key the `[obs]` config section understands.
pub const KNOWN_OBS_KEYS: &[&str] = &["trace_path", "metrics", "buckets_ns"];

/// Observability configuration, resolved from the `[obs]` config
/// section with the `--trace-out` flag override on top. Applied by
/// the CLI front-ends (`train`, `serve`, `bench`); it never changes
/// training outputs — only what gets recorded about them.
#[derive(Clone, Debug, PartialEq)]
pub struct ObsConfig {
    /// Default trace file for `dpquant train` (the `--trace-out` flag
    /// overrides). `None` disables tracing.
    pub trace_path: Option<String>,
    /// Record per-kernel durations into the global metrics registry
    /// (`crate::obs::set_kernel_timing`). On by default — the off
    /// path of the gate is one atomic load, and recording never
    /// affects outputs.
    pub metrics: bool,
    /// Override the default latency-histogram bucket bounds, in
    /// nanoseconds. `None` keeps `obs::registry::DEFAULT_NS_BUCKETS`.
    pub buckets_ns: Option<Vec<f64>>,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            trace_path: None,
            metrics: true,
            buckets_ns: None,
        }
    }
}

impl ObsConfig {
    /// Resolve from a parsed file's `[obs]` section, warning on
    /// unknown keys (the `[train]`-section treatment).
    pub fn from_file(cf: &ConfigFile) -> Result<Self, ConfigError> {
        for (sec, key) in cf.entries.keys() {
            if sec == "obs" && !KNOWN_OBS_KEYS.contains(&key.as_str()) {
                eprintln!(
                    "warning: config key [obs] {key} is not recognized and will be ignored"
                );
            }
        }
        let d = Self::default();
        let buckets_ns = match cf.get("obs", "buckets_ns") {
            None => None,
            Some(v) => {
                let arr = v.as_array().ok_or_else(|| {
                    ConfigError::new("[obs] buckets_ns must be an array of numbers")
                })?;
                let mut out = Vec::with_capacity(arr.len());
                for item in arr {
                    match item.as_f64() {
                        Some(b) if b.is_finite() && b > 0.0 => out.push(b),
                        _ => {
                            return Err(ConfigError::new(
                                "[obs] buckets_ns entries must be finite numbers > 0",
                            ))
                        }
                    }
                }
                Some(out)
            }
        };
        Ok(Self {
            trace_path: cf
                .get("obs", "trace_path")
                .and_then(Value::as_str)
                .map(str::to_string),
            metrics: cf.bool_or("obs", "metrics", d.metrics),
            buckets_ns,
        })
    }

    /// Resolve from the command line: `--config file` first (when
    /// given), then the `--trace-out` override.
    pub fn from_args(args: &crate::cli::Args) -> crate::util::error::Result<Self> {
        let mut oc = match args.get("config") {
            Some(path) => Self::from_file(&ConfigFile::load(path)?)?,
            None => Self::default(),
        };
        if let Some(path) = args.get("trace-out") {
            oc.trace_path = Some(path.to_string());
        }
        Ok(oc)
    }

    /// Apply the registry-side settings to the process: histogram
    /// bucket overrides (before the first histogram is created) and
    /// the kernel-timing gate.
    pub fn apply(&self) {
        if let Some(buckets) = &self.buckets_ns {
            crate::obs::global().set_default_ns_buckets(buckets);
        }
        crate::obs::set_kernel_timing(self.metrics);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# DPQuant experiment config
[train]
model = "miniresnet"       # residual CNN
dataset = "gtsrb"
epochs = 30
batch_size = 128
noise_multiplier = 1.0
clip_norm = 1.0
lr = 0.5
optimizer = "sgd"
quant_fraction = 0.9
scheduler = "dpquant"
beta = 10.57
analysis_interval = 2
target_epsilon = 8.0
ema_enabled = true
alphas = [1.5, 2.0, 3.0]
"#;

    #[test]
    fn parses_sections_and_types() {
        let cf = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(cf.str_or("train", "model", "x"), "miniresnet");
        assert_eq!(cf.i64_or("train", "epochs", 0), 30);
        assert_eq!(cf.f64_or("train", "beta", 0.0), 10.57);
        assert!(cf.bool_or("train", "ema_enabled", false));
        let arr = cf.get("train", "alphas").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_f64(), Some(2.0));
    }

    #[test]
    fn train_config_resolution() {
        let cf = ConfigFile::parse(SAMPLE).unwrap();
        let tc = TrainConfig::from_file(&cf).unwrap();
        assert_eq!(tc.model, "miniresnet");
        assert_eq!(tc.target_epsilon, Some(8.0));
        assert_eq!(tc.optimizer, OptimizerKind::Sgd);
        assert!((tc.sample_rate() - 128.0 / 4096.0).abs() < 1e-12);
        assert_eq!(tc.train_artifact(), "train_miniresnet_gtsrb_luq4");
        assert_eq!(tc.graph_tag(), "miniresnet_gtsrb_luq4");
        // Missing keys fall back to defaults.
        assert_eq!(tc.analysis_reps, 2);
        assert!((tc.sigma_measure - 0.5).abs() < 1e-12);
        assert_eq!(tc.backend, "native");
        // Explicit backend keys resolve.
        let cf = ConfigFile::parse("[train]\nbackend = \"pjrt\"\n").unwrap();
        assert_eq!(TrainConfig::from_file(&cf).unwrap().backend, "pjrt");
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let cf = ConfigFile::parse("k = \"a#b\" # trailing\n").unwrap();
        assert_eq!(cf.str_or("", "k", ""), "a#b");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        let err = ConfigFile::parse("[oops\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
        let err = ConfigFile::parse("justkey\n").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn unknown_train_keys_detected() {
        let cf = ConfigFile::parse("[train]\nquant_fracton = 0.9\nepochs = 3\n").unwrap();
        assert_eq!(TrainConfig::unknown_keys(&cf), vec!["quant_fracton".to_string()]);
        // Keys outside [train] are other subsystems' business.
        let cf = ConfigFile::parse("[bench]\nreps = 10\n").unwrap();
        assert!(TrainConfig::unknown_keys(&cf).is_empty());
        assert!(TrainConfig::suspect_sections(&cf).is_empty());
        // ...unless they hold trainer keys: that's a misspelled header.
        let cf = ConfigFile::parse("[trian]\nepochs = 99\nnoise_multiplier = 2.0\n").unwrap();
        assert_eq!(TrainConfig::suspect_sections(&cf), vec!["trian".to_string()]);
        // The sample config's keys are all known (minus the alphas array,
        // which documents the array syntax).
        let cf = ConfigFile::parse(SAMPLE).unwrap();
        assert_eq!(TrainConfig::unknown_keys(&cf), vec!["alphas".to_string()]);
    }

    #[test]
    fn known_train_keys_are_exactly_what_from_file_reads() {
        // One entry per KNOWN_TRAIN_KEYS key, every value non-default:
        // (a) none may be reported unknown, and (b) every resolved field
        // must differ from the default — so the allow-list and the
        // `from_file` reads cannot silently drift apart.
        let text = r#"
[train]
model = "k_model"
dataset = "k_dataset"
quantizer = "k_quant"
epochs = 99
batch_size = 98
noise_multiplier = 9.1
clip_norm = 9.2
lr = 9.3
optimizer = "adamw"
target_epsilon = 5.5
delta = 0.123
quant_fraction = 0.77
scheduler = "pls"
beta = 8.8
analysis_interval = 93
analysis_reps = 92
analysis_samples = 91
sigma_measure = 7.7
clip_measure = 6.6
ema_alpha = 0.11
ema_enabled = false
dataset_size = 97
val_size = 96
seed = 95
physical_batch = 94
backend = "mock"
policy = "noise_decay"
noise_final = 0.25
clip_final = 0.5
rate_final = 0.01
decay_shape = "exp"
layer_lr_strength = 0.75
"#;
        let cf = ConfigFile::parse(text).unwrap();
        let keys_in_sample = cf.entries.len();
        assert_eq!(
            keys_in_sample,
            KNOWN_TRAIN_KEYS.len(),
            "sample must cover every known key"
        );
        assert!(TrainConfig::unknown_keys(&cf).is_empty());
        let c = TrainConfig::from_file(&cf).unwrap();
        let d = TrainConfig::default();
        assert_ne!(c.model, d.model);
        assert_ne!(c.dataset, d.dataset);
        assert_ne!(c.quantizer, d.quantizer);
        assert_ne!(c.epochs, d.epochs);
        assert_ne!(c.batch_size, d.batch_size);
        assert_ne!(c.noise_multiplier, d.noise_multiplier);
        assert_ne!(c.clip_norm, d.clip_norm);
        assert_ne!(c.lr, d.lr);
        assert_ne!(c.optimizer, d.optimizer);
        assert_ne!(c.target_epsilon, d.target_epsilon);
        assert_ne!(c.delta, d.delta);
        assert_ne!(c.quant_fraction, d.quant_fraction);
        assert_ne!(c.scheduler, d.scheduler);
        assert_ne!(c.beta, d.beta);
        assert_ne!(c.analysis_interval, d.analysis_interval);
        assert_ne!(c.analysis_reps, d.analysis_reps);
        assert_ne!(c.analysis_samples, d.analysis_samples);
        assert_ne!(c.sigma_measure, d.sigma_measure);
        assert_ne!(c.clip_measure, d.clip_measure);
        assert_ne!(c.ema_alpha, d.ema_alpha);
        assert_ne!(c.ema_enabled, d.ema_enabled);
        assert_ne!(c.dataset_size, d.dataset_size);
        assert_ne!(c.val_size, d.val_size);
        assert_ne!(c.seed, d.seed);
        assert_ne!(c.physical_batch, d.physical_batch);
        assert_ne!(c.backend, d.backend);
        assert_ne!(c.policy, d.policy);
        assert_ne!(c.noise_final, d.noise_final);
        assert_ne!(c.clip_final, d.clip_final);
        assert_ne!(c.rate_final, d.rate_final);
        assert_ne!(c.decay_shape, d.decay_shape);
        assert_ne!(c.layer_lr_strength, d.layer_lr_strength);
    }

    #[test]
    fn from_args_layers_flag_overrides_on_defaults() {
        let args = crate::cli::Args::parse(
            "train --epochs 9 --lr 0.125 --backend mock --no-ema --seed 7"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = TrainConfig::from_args(&args).unwrap();
        assert_eq!(c.epochs, 9);
        assert_eq!(c.lr, 0.125);
        assert_eq!(c.backend, "mock");
        assert!(!c.ema_enabled);
        assert_eq!(c.seed, 7);
        // Untouched keys keep their defaults.
        assert_eq!(c.model, TrainConfig::default().model);
        assert_eq!(c.target_epsilon, None);
    }

    #[test]
    fn sweep_section_is_not_a_suspect_header() {
        // Trainer keys inside [sweep] are sweep axes, not a typo'd
        // [train]; a genuinely misspelled header still warns.
        let cf =
            ConfigFile::parse("[sweep]\nepochs = [1, 2]\nseed = [0, 1]\n[trian]\nlr = 0.5\n")
                .unwrap();
        assert_eq!(TrainConfig::suspect_sections(&cf), vec!["trian".to_string()]);
    }

    #[test]
    fn serve_config_resolution_and_overrides() {
        // Defaults with no [serve] section.
        let d = ServeConfig::from_file(&ConfigFile::parse("").unwrap()).unwrap();
        assert_eq!(d, ServeConfig::default());
        assert_eq!(d.jobs, 2);
        assert!(d.state_dir.is_none());

        // File values resolve.
        let cf = ConfigFile::parse(
            "[serve]\naddr = \"0.0.0.0:9000\"\njobs = 4\nstate_dir = \"/tmp/dpq\"\n",
        )
        .unwrap();
        let sc = ServeConfig::from_file(&cf).unwrap();
        assert_eq!(sc.addr, "0.0.0.0:9000");
        assert_eq!(sc.jobs, 4);
        assert_eq!(sc.state_dir.as_deref(), Some("/tmp/dpq"));

        // Zero workers is rejected, not clamped.
        let cf = ConfigFile::parse("[serve]\njobs = 0\n").unwrap();
        assert!(ServeConfig::from_file(&cf).unwrap_err().to_string().contains("jobs"));

        // Flag overrides land on top of defaults.
        let args = crate::cli::Args::parse(
            "serve --addr 127.0.0.1:0 --jobs 3 --state-dir /tmp/sd"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let sc = ServeConfig::from_args(&args).unwrap();
        assert_eq!(sc.addr, "127.0.0.1:0");
        assert_eq!(sc.jobs, 3);
        assert_eq!(sc.state_dir.as_deref(), Some("/tmp/sd"));
        let bad = crate::cli::Args::parse(
            "serve --jobs 0".split_whitespace().map(String::from),
        )
        .unwrap();
        assert!(ServeConfig::from_args(&bad).is_err());
    }

    #[test]
    fn obs_config_resolution_and_overrides() {
        // Defaults with no [obs] section: no trace, metrics on.
        let d = ObsConfig::from_file(&ConfigFile::parse("").unwrap()).unwrap();
        assert_eq!(d, ObsConfig::default());
        assert!(d.trace_path.is_none());
        assert!(d.metrics);
        assert!(d.buckets_ns.is_none());

        // File values resolve, covering every KNOWN_OBS_KEYS key.
        let cf = ConfigFile::parse(
            "[obs]\ntrace_path = \"/tmp/t.jsonl\"\nmetrics = false\nbuckets_ns = [1000, 1000000]\n",
        )
        .unwrap();
        assert_eq!(
            cf.entries.len(),
            KNOWN_OBS_KEYS.len(),
            "sample must cover every known key"
        );
        let oc = ObsConfig::from_file(&cf).unwrap();
        assert_eq!(oc.trace_path.as_deref(), Some("/tmp/t.jsonl"));
        assert!(!oc.metrics);
        assert_eq!(oc.buckets_ns.as_deref(), Some(&[1000.0, 1_000_000.0][..]));

        // Malformed buckets are rejected, not clamped.
        let cf = ConfigFile::parse("[obs]\nbuckets_ns = [0]\n").unwrap();
        assert!(ObsConfig::from_file(&cf)
            .unwrap_err()
            .to_string()
            .contains("buckets_ns"));

        // --trace-out lands on top of defaults.
        let args = crate::cli::Args::parse(
            "train --trace-out /tmp/run.jsonl".split_whitespace().map(String::from),
        )
        .unwrap();
        let oc = ObsConfig::from_args(&args).unwrap();
        assert_eq!(oc.trace_path.as_deref(), Some("/tmp/run.jsonl"));
        assert!(oc.metrics);
    }

    #[test]
    fn optimizer_aliases() {
        assert_eq!(OptimizerKind::parse("DP-AdamW").unwrap(), OptimizerKind::AdamW);
        assert_eq!(OptimizerKind::parse("dpsgd").unwrap(), OptimizerKind::Sgd);
        assert!(OptimizerKind::parse("lion").is_err());
    }
}
