//! API stub for the `xla` (PJRT) crate the runtime layer targets.
//!
//! The real backend — `PjRtClient::cpu()` compiling HLO text exported by
//! `python/compile/aot.py` — comes from the `xla` crate, which is not in
//! the offline crate set. This module mirrors the exact API surface
//! `runtime/mod.rs` uses so the whole crate builds, tests and lints with
//! **zero external dependencies**; every operation that would need a live
//! PJRT backend returns a descriptive [`Error`] instead.
//!
//! In practice nothing ever reaches those errors unless real artifacts
//! exist: [`crate::runtime::Runtime::open`] fails earlier (and the test
//! suite skips, loudly) when `artifacts/manifest.json` is absent. The
//! **working offline path is `--backend native`** — the pure-Rust engine
//! in [`crate::backend`] executes real training steps with no artifacts
//! and no PJRT at all. When a real `xla` crate is vendored, delete this
//! module, add the dependency, and drop the `use crate::xla;` line in
//! `runtime/mod.rs` — no other code changes.

use std::fmt;

/// Error type matching the real crate's shape (`std::error::Error`, so it
/// flows through `util::error::Error` via `?`).
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias for XLA-stub operations.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: no PJRT/XLA backend in this build (offline stub — use `--backend native` \
         for the pure-Rust training engine, or vendor the real `xla` crate to execute \
         compiled graphs)"
    ))
}

/// Stub of the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    /// Open the CPU PJRT client. Always fails in the offline stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Compile a computation. Always fails in the offline stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub of an HLO module parsed from the text interchange format.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the offline stub.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parsing {path}")))
    }
}

/// Stub of a buildable XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module (carries no state in the stub).
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stub of a compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Run the executable. Always fails in the offline stub.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of a device buffer returned by `execute`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy device buffer to host. Always fails in the offline stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub of a host literal. Pure-data constructors succeed (they carry no
/// backend state); reads that would require an executed computation fail.
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    /// A rank-1 literal from host data (pure data; succeeds).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    /// Reshape the literal (pure metadata; succeeds).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    /// Destructure a tuple literal. Always fails in the offline stub.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Read the literal as host values. Always fails in the offline stub.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_entry_point_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("PJRT"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_data_ops_are_inert_but_usable() {
        let lit = Literal::vec1(&[1f32, 2.0, 3.0]).reshape(&[3]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
        let _scalar: Literal = Literal::from(0.5f32);
    }

    #[test]
    fn stub_errors_flow_into_crate_errors() {
        use crate::util::error::{Context, Result};
        fn open() -> Result<PjRtClient> {
            let client = PjRtClient::cpu().context("opening runtime")?;
            Ok(client)
        }
        let e = open().unwrap_err();
        assert_eq!(format!("{e}"), "opening runtime");
        assert!(e.root_cause().contains("PjRtClient::cpu"), "{}", e.root_cause());
    }
}
