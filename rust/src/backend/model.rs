//! The native model zoo: layer specs, parameter bookkeeping,
//! initialization, and the hand-derived per-sample forward/backward pass
//! with on-path quantization hooks.
//!
//! A model is a chain of [`LayerSpec`]s ending in a logits layer; loss
//! is softmax cross-entropy. Every spec is **one quantizable layer** (the
//! unit Algorithms 1–2 schedule over): when a layer is masked in the
//! step's [`QuantEpilogue`] it runs low-precision — its weight tensor is
//! quantize-dequantized by the epilogue's prologue hook (the executor
//! passes a borrowed view mixing quantized and fp32 tensors) and the
//! gradient tensor entering its backward computation is
//! quantize-dequantized per sample at the point the producing kernel
//! emits it. Biases stay fp32 (they are O(width) of the O(width²)
//! weights and the paper's kernels likewise keep accumulators
//! high-precision).
//!
//! Weight arguments are generic over `W: AsRef<[f32]>` so callers can
//! pass owned tensors (`&[Vec<f32>]`) or the executor's borrowed views
//! (`&[&[f32]]`) without copying.

use super::tensor;
use super::QuantEpilogue;
use crate::util::rng::Xoshiro256;

/// One quantizable layer of the native zoo.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// 3x3 same-padding conv (HWC) + ReLU, optionally followed by 2x2
    /// average pooling. `h`/`w` are the *input* spatial dims.
    Conv3x3 {
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        pool: bool,
    },
    /// Fully-connected layer, optional bias and ReLU.
    Dense {
        input: usize,
        output: usize,
        bias: bool,
        relu: bool,
    },
}

impl LayerSpec {
    /// Number of input activations this layer consumes.
    pub fn in_numel(&self) -> usize {
        match self {
            LayerSpec::Conv3x3 { h, w, cin, .. } => h * w * cin,
            LayerSpec::Dense { input, .. } => *input,
        }
    }

    /// Number of output activations this layer produces.
    pub fn out_numel(&self) -> usize {
        match self {
            LayerSpec::Conv3x3 { h, w, cout, pool } => {
                if *pool {
                    (h / 2) * (w / 2) * cout
                } else {
                    h * w * cout
                }
            }
            LayerSpec::Dense { output, .. } => *output,
        }
    }

    /// Shapes of this layer's parameter tensors (weight first, then
    /// bias when present).
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        match self {
            LayerSpec::Conv3x3 { cin, cout, .. } => {
                vec![vec![*cout, *cin, 3, 3], vec![*cout]]
            }
            LayerSpec::Dense {
                input,
                output,
                bias,
                ..
            } => {
                let mut v = vec![vec![*output, *input]];
                if *bias {
                    v.push(vec![*output]);
                }
                v
            }
        }
    }

    /// Fan-in used for He-uniform initialization.
    pub fn fan_in(&self) -> usize {
        match self {
            LayerSpec::Conv3x3 { cin, .. } => cin * 9,
            LayerSpec::Dense { input, .. } => *input,
        }
    }

    /// Human-readable tag (DESIGN.md / debug output).
    pub fn name(&self) -> String {
        match self {
            LayerSpec::Conv3x3 {
                cin, cout, pool, ..
            } => format!(
                "conv3x3_{cin}to{cout}{}",
                if *pool { "_pool" } else { "" }
            ),
            LayerSpec::Dense { input, output, .. } => format!("dense_{input}to{output}"),
        }
    }
}

/// A fully-specified native model: validated layer chain + parameter
/// layout. Runtime weights live outside (as `Vec<Vec<f32>>`, one entry
/// per parameter tensor) so the executor matches the `StepExecutor`
/// contract exactly.
#[derive(Clone, Debug)]
pub struct Model {
    specs: Vec<LayerSpec>,
    /// Output dimension of the final (logits) layer.
    pub n_classes: usize,
    /// Flattened input feature count the first layer expects.
    pub input_numel: usize,
    /// Multiplier applied to raw features at the model input (1.0 for
    /// images; `1/VOCAB` for token-id sequences so logits start sane).
    pub input_scale: f32,
    /// `param_start[l]` = index of layer `l`'s weight tensor in the
    /// flat parameter list.
    param_start: Vec<usize>,
    param_shapes: Vec<Vec<usize>>,
}

impl Model {
    /// Validate the chain (each layer's input numel must equal the
    /// previous output) and precompute the parameter layout.
    pub fn new(
        specs: Vec<LayerSpec>,
        input_numel: usize,
        input_scale: f32,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("model needs at least one layer".into());
        }
        let mut cur = input_numel;
        let mut param_start = Vec::with_capacity(specs.len());
        let mut param_shapes: Vec<Vec<usize>> = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            if s.in_numel() != cur {
                return Err(format!(
                    "layer {i} ({}) expects {} inputs, previous layer produces {cur}",
                    s.name(),
                    s.in_numel()
                ));
            }
            cur = s.out_numel();
            param_start.push(param_shapes.len());
            param_shapes.extend(s.param_shapes());
        }
        Ok(Self {
            specs,
            n_classes: cur,
            input_numel,
            input_scale,
            param_start,
            param_shapes,
        })
    }

    /// Zoo lookup. `logreg` and `mlp` are native-first; the artifact
    /// model tags (`miniconvnet` / `miniresnet` / `minidensenet` /
    /// `tinytransformer`) map onto the mini-CNN when the input is
    /// image-shaped (16x16x3, the `data/synth.rs` contract) and onto
    /// the MLP otherwise — so every config that works against the
    /// compiled graphs also runs natively. Unknown names are an error
    /// (a typo must not silently train a different model).
    pub fn by_name(name: &str, input_numel: usize, n_classes: usize) -> Result<Self, String> {
        use crate::data::synth::{C, H, W};
        match name {
            "logreg" => Self::new(
                vec![LayerSpec::Dense {
                    input: input_numel,
                    output: n_classes,
                    bias: false,
                    relu: false,
                }],
                input_numel,
                1.0,
            ),
            "mlp" | "tinytransformer" => Self::mlp(input_numel, n_classes),
            "miniconvnet" | "miniresnet" | "minidensenet" => {
                if input_numel == H * W * C {
                    Self::mini_cnn(n_classes)
                } else {
                    Self::mlp(input_numel, n_classes)
                }
            }
            other => Err(format!(
                "unknown model '{other}' for the native backend (expected logreg | mlp | \
                 miniconvnet | miniresnet | minidensenet | tinytransformer)"
            )),
        }
    }

    /// 5-layer ReLU MLP over flattened features.
    pub fn mlp(input_numel: usize, n_classes: usize) -> Result<Self, String> {
        let mut specs = Vec::new();
        let mut cur = input_numel;
        for &hdim in &[96usize, 64, 48, 32] {
            specs.push(LayerSpec::Dense {
                input: cur,
                output: hdim,
                bias: true,
                relu: true,
            });
            cur = hdim;
        }
        specs.push(LayerSpec::Dense {
            input: cur,
            output: n_classes,
            bias: true,
            relu: false,
        });
        Self::new(specs, input_numel, 1.0)
    }

    /// Mini-CNN over the 16x16x3 synthetic image shape: two conv+pool
    /// stages then a 3-layer head — 5 quantizable layers.
    pub fn mini_cnn(n_classes: usize) -> Result<Self, String> {
        use crate::data::synth::{C, H, W};
        let specs = vec![
            LayerSpec::Conv3x3 {
                h: H,
                w: W,
                cin: C,
                cout: 8,
                pool: true,
            },
            LayerSpec::Conv3x3 {
                h: H / 2,
                w: W / 2,
                cin: 8,
                cout: 16,
                pool: true,
            },
            LayerSpec::Dense {
                input: (H / 4) * (W / 4) * 16,
                output: 96,
                bias: true,
                relu: true,
            },
            LayerSpec::Dense {
                input: 96,
                output: 48,
                bias: true,
                relu: true,
            },
            LayerSpec::Dense {
                input: 48,
                output: n_classes,
                bias: true,
                relu: false,
            },
        ];
        Self::new(specs, H * W * C, 1.0)
    }

    /// The validated layer chain.
    pub fn specs(&self) -> &[LayerSpec] {
        &self.specs
    }

    /// Number of quantizable layers (the scheduling unit).
    pub fn n_layers(&self) -> usize {
        self.specs.len()
    }

    /// Shapes of every parameter tensor, in flat-list order.
    pub fn param_shapes(&self) -> &[Vec<usize>] {
        &self.param_shapes
    }

    /// Element counts of every parameter tensor, in flat-list order.
    pub fn param_numels(&self) -> Vec<usize> {
        self.param_shapes
            .iter()
            .map(|s| s.iter().product())
            .collect()
    }

    /// Total trainable parameter count.
    pub fn total_params(&self) -> usize {
        self.param_numels().iter().sum()
    }

    /// Index of layer `l`'s weight tensor in the parameter list.
    pub fn weight_index(&self, l: usize) -> usize {
        self.param_start[l]
    }

    /// Zeroed gradient buffers, one per parameter tensor.
    pub fn zero_grads(&self) -> Vec<Vec<f32>> {
        self.param_numels().iter().map(|&n| vec![0.0; n]).collect()
    }

    /// Deterministic He-uniform weights (biases zero) from a seed.
    pub fn init_weights(&self, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x6D0D_E15E);
        let mut out = Vec::with_capacity(self.param_shapes.len());
        for spec in &self.specs {
            for (ti, shape) in spec.param_shapes().iter().enumerate() {
                if ti == 0 {
                    out.push(tensor::Tensor::he_uniform(shape, spec.fan_in(), &mut rng).data);
                } else {
                    out.push(vec![0.0; shape.iter().product()]);
                }
            }
        }
        out
    }

    /// One layer's forward for one sample. Returns `(output, pre_pool)`
    /// where `pre_pool` is the post-ReLU pre-pooling activation a
    /// pooled conv layer's backward needs.
    fn layer_forward<W: AsRef<[f32]>>(
        &self,
        l: usize,
        weights: &[W],
        a: &[f32],
    ) -> (Vec<f32>, Option<Vec<f32>>) {
        let p0 = self.param_start[l];
        match &self.specs[l] {
            LayerSpec::Conv3x3 {
                h,
                w,
                cin,
                cout,
                pool,
            } => {
                let mut y = vec![0.0; h * w * cout];
                tensor::conv3x3_forward(
                    weights[p0].as_ref(),
                    weights[p0 + 1].as_ref(),
                    a,
                    &mut y,
                    *h,
                    *w,
                    *cin,
                    *cout,
                );
                tensor::relu_inplace(&mut y);
                if *pool {
                    let mut p = vec![0.0; (h / 2) * (w / 2) * cout];
                    tensor::avgpool2_forward(&y, &mut p, *h, *w, *cout);
                    (p, Some(y))
                } else {
                    (y, None)
                }
            }
            LayerSpec::Dense {
                input,
                output,
                bias,
                relu,
            } => {
                assert_eq!(a.len(), *input, "dense input numel");
                let b = if *bias {
                    Some(weights[p0 + 1].as_ref())
                } else {
                    None
                };
                let mut y = vec![0.0; *output];
                tensor::dense_forward(weights[p0].as_ref(), b, a, &mut y);
                if *relu {
                    tensor::relu_inplace(&mut y);
                }
                (y, None)
            }
        }
    }

    /// Full-precision forward for one sample; returns the logits.
    pub fn forward<W: AsRef<[f32]>>(&self, weights: &[W], x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.input_numel, "input numel");
        let mut a: Vec<f32> = x.iter().map(|&v| v * self.input_scale).collect();
        for l in 0..self.specs.len() {
            a = self.layer_forward(l, weights, &a).0;
        }
        a
    }

    /// Exact per-sample forward + backward. Gradients are accumulated
    /// into `grads` (zeroed by the caller); returns `(loss, correct)`.
    ///
    /// `weights` should already hold quantized tensors for masked layers
    /// (the executor runs the [`QuantEpilogue`] weight prologue once per
    /// call and passes borrowed views). When `epilogue` is `Some`, the
    /// gradient tensor a masked layer consumes is additionally
    /// quantize-dequantized **where its producing kernel emits it** —
    /// after the softmax for the last layer, after the upstream layer's
    /// input-gradient GEMM otherwise. That is the same tensor, the same
    /// values and the same RNG draw order as the old separate
    /// whole-tensor pass at the consumer's loop top, so the fusion is
    /// bit-identical; it injects the backward-path quantization error
    /// the scheduler's loss-impact analysis measures.
    pub fn forward_backward<W: AsRef<[f32]>>(
        &self,
        weights: &[W],
        x: &[f32],
        label: usize,
        grads: &mut [Vec<f32>],
        epilogue: Option<&QuantEpilogue>,
        rng: &mut Xoshiro256,
    ) -> (f32, bool) {
        let n = self.specs.len();
        if let Some(epi) = epilogue {
            assert_eq!(epi.n_layers(), n, "quant mask len");
        }
        assert_eq!(grads.len(), self.param_shapes.len(), "grad tensor count");
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(n + 1);
        acts.push(x.iter().map(|&v| v * self.input_scale).collect());
        let mut prepool: Vec<Option<Vec<f32>>> = Vec::with_capacity(n);
        for l in 0..n {
            let (out, pp) = self.layer_forward(l, weights, acts.last().unwrap());
            acts.push(out);
            prepool.push(pp);
        }
        let (loss, correct, mut dy) = tensor::softmax_xent(&acts[n], label);
        // Epilogue at the producer: the softmax emits the gradient the
        // last layer consumes.
        if let Some(epi) = epilogue {
            epi.grad_epilogue(n - 1, &mut dy, rng);
        }
        for l in (0..n).rev() {
            let p0 = self.param_start[l];
            let need_da = l > 0;
            match &self.specs[l] {
                LayerSpec::Dense {
                    input, bias, relu, ..
                } => {
                    if *relu {
                        tensor::relu_backward_mask(&acts[l + 1], &mut dy);
                    }
                    let (head, tail) = grads.split_at_mut(p0 + 1);
                    let gw = head.last_mut().unwrap();
                    let gb = if *bias { Some(&mut tail[0][..]) } else { None };
                    let mut da = if need_da { vec![0.0; *input] } else { Vec::new() };
                    tensor::dense_backward(
                        weights[p0].as_ref(),
                        &acts[l],
                        &dy,
                        gw,
                        gb,
                        if need_da { Some(&mut da) } else { None },
                    );
                    if need_da {
                        dy = da;
                    }
                }
                LayerSpec::Conv3x3 {
                    h,
                    w,
                    cin,
                    cout,
                    pool,
                } => {
                    let mut d = if *pool {
                        let mut full = vec![0.0; h * w * cout];
                        tensor::avgpool2_backward(&dy, &mut full, *h, *w, *cout);
                        full
                    } else {
                        std::mem::take(&mut dy)
                    };
                    let relu_out = prepool[l].as_deref().unwrap_or(&acts[l + 1]);
                    tensor::relu_backward_mask(relu_out, &mut d);
                    let (head, tail) = grads.split_at_mut(p0 + 1);
                    let gw = head.last_mut().unwrap();
                    let gb = &mut tail[0];
                    let mut da = if need_da {
                        vec![0.0; h * w * cin]
                    } else {
                        Vec::new()
                    };
                    tensor::conv3x3_backward(
                        weights[p0].as_ref(),
                        &acts[l],
                        &d,
                        gw,
                        gb,
                        if need_da { Some(&mut da) } else { None },
                        *h,
                        *w,
                        *cin,
                        *cout,
                    );
                    if need_da {
                        dy = da;
                    }
                }
            }
            // Epilogue at the producer: this layer's input-gradient GEMM
            // just emitted the tensor layer l-1 consumes.
            if need_da {
                if let Some(epi) = epilogue {
                    epi.grad_epilogue(l - 1, &mut dy, rng);
                }
            }
        }
        (loss, correct)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;

    #[test]
    fn zoo_shapes_chain() {
        let lr = Model::by_name("logreg", 10, 4).unwrap();
        assert_eq!(lr.n_layers(), 1);
        assert_eq!(lr.param_numels(), vec![40]);

        let mlp = Model::by_name("mlp", 20, 5).unwrap();
        assert_eq!(mlp.n_layers(), 5);
        assert_eq!(mlp.n_classes, 5);
        // weight + bias per layer.
        assert_eq!(mlp.param_shapes().len(), 10);

        let cnn = Model::by_name("miniconvnet", 16 * 16 * 3, 10).unwrap();
        assert_eq!(cnn.n_layers(), 5);
        assert_eq!(cnn.n_classes, 10);
        assert!(cnn.total_params() > 10_000);
        // miniresnet maps to the same CNN; non-image inputs fall back
        // to the MLP.
        assert_eq!(
            Model::by_name("miniresnet", 16 * 16 * 3, 10).unwrap().total_params(),
            cnn.total_params()
        );
        let seq = Model::by_name("tinytransformer", 24, 3).unwrap();
        assert_eq!(seq.n_classes, 3);
        // Typos fail fast instead of silently training another model.
        assert!(Model::by_name("miniconvnt", 16 * 16 * 3, 10).is_err());
    }

    #[test]
    fn chain_validation_rejects_mismatches() {
        let bad = Model::new(
            vec![
                LayerSpec::Dense {
                    input: 8,
                    output: 4,
                    bias: true,
                    relu: true,
                },
                LayerSpec::Dense {
                    input: 5, // should be 4
                    output: 2,
                    bias: true,
                    relu: false,
                },
            ],
            8,
            1.0,
        );
        assert!(bad.is_err());
    }

    #[test]
    fn init_deterministic_and_scaled() {
        let m = Model::by_name("mlp", 12, 3).unwrap();
        let a = m.init_weights(7);
        let b = m.init_weights(7);
        assert_eq!(a, b);
        let c = m.init_weights(8);
        assert_ne!(a, c);
        // Biases zero, weights bounded by the He limit of the widest
        // fan-in.
        for (t, shape) in a.iter().zip(m.param_shapes()) {
            if shape.len() == 1 {
                assert!(t.iter().all(|&v| v == 0.0));
            } else {
                assert!(t.iter().any(|&v| v != 0.0));
            }
        }
    }

    #[test]
    fn forward_finite_and_shaped() {
        let m = Model::by_name("miniconvnet", 16 * 16 * 3, 7).unwrap();
        let w = m.init_weights(1);
        let x: Vec<f32> = (0..m.input_numel).map(|i| (i % 17) as f32 / 17.0).collect();
        let logits = m.forward(&w, &x);
        assert_eq!(logits.len(), 7);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    /// End-to-end gradient check: per-sample grads from
    /// `forward_backward` vs central finite differences of the loss,
    /// over a small MLP-like chain (keeps runtime tiny).
    #[test]
    fn full_model_gradients_match_finite_differences() {
        let m = Model::new(
            vec![
                LayerSpec::Dense {
                    input: 6,
                    output: 5,
                    bias: true,
                    relu: true,
                },
                LayerSpec::Dense {
                    input: 5,
                    output: 3,
                    bias: true,
                    relu: false,
                },
            ],
            6,
            1.0,
        )
        .unwrap();
        let w = m.init_weights(3);
        let x: Vec<f32> = vec![0.4, -0.3, 0.8, 0.1, -0.6, 0.5];
        let label = 1usize;
        let mut grads = m.zero_grads();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let (loss, _correct) = m.forward_backward(&w, &x, label, &mut grads, None, &mut rng);
        assert!(loss > 0.0);
        let eps = 1e-2f32;
        for t in 0..w.len() {
            for i in 0..w[t].len() {
                let mut hi = w.clone();
                hi[t][i] += eps;
                let mut lo = w.clone();
                lo[t][i] -= eps;
                let lh = tensor::softmax_xent(&m.forward(&hi, &x), label).0;
                let ll = tensor::softmax_xent(&m.forward(&lo, &x), label).0;
                let num = (lh - ll) / (2.0 * eps);
                assert!(
                    (grads[t][i] - num).abs() < 2e-2 + 0.05 * num.abs(),
                    "param {t}[{i}]: analytic {} vs numeric {num}",
                    grads[t][i]
                );
            }
        }
    }

    /// Same check through a conv+pool stage.
    #[test]
    fn conv_model_gradients_match_finite_differences() {
        let m = Model::new(
            vec![
                LayerSpec::Conv3x3 {
                    h: 4,
                    w: 4,
                    cin: 2,
                    cout: 3,
                    pool: true,
                },
                LayerSpec::Dense {
                    input: 2 * 2 * 3,
                    output: 3,
                    bias: true,
                    relu: false,
                },
            ],
            4 * 4 * 2,
            1.0,
        )
        .unwrap();
        let w = m.init_weights(5);
        let x: Vec<f32> = (0..32).map(|i| ((i * 13 % 11) as f32 / 11.0) - 0.4).collect();
        let label = 2usize;
        let mut grads = m.zero_grads();
        let mut rng = Xoshiro256::seed_from_u64(2);
        m.forward_backward(&w, &x, label, &mut grads, None, &mut rng);
        let eps = 1e-2f32;
        // Check the conv weight tensor (index 0) and conv bias (1).
        for t in [0usize, 1] {
            for i in 0..w[t].len() {
                let mut hi = w.clone();
                hi[t][i] += eps;
                let mut lo = w.clone();
                lo[t][i] -= eps;
                let lh = tensor::softmax_xent(&m.forward(&hi, &x), label).0;
                let ll = tensor::softmax_xent(&m.forward(&lo, &x), label).0;
                let num = (lh - ll) / (2.0 * eps);
                assert!(
                    (grads[t][i] - num).abs() < 2e-2 + 0.05 * num.abs(),
                    "param {t}[{i}]: analytic {} vs numeric {num}",
                    grads[t][i]
                );
            }
        }
    }

    #[test]
    fn quantized_backward_perturbs_gradients() {
        let m = Model::by_name("mlp", 10, 4).unwrap();
        let w = m.init_weights(9);
        let x: Vec<f32> = (0..10).map(|i| 0.1 * i as f32).collect();
        let q = quant::by_name("luq4").unwrap();
        let mut base = m.zero_grads();
        let mut rng = Xoshiro256::seed_from_u64(4);
        m.forward_backward(&w, &x, 0, &mut base, None, &mut rng);
        let mut qg = m.zero_grads();
        let ones = vec![1f32; m.n_layers()];
        let epi = QuantEpilogue::new(q.as_ref(), &ones, 0.0);
        let mut rng2 = Xoshiro256::seed_from_u64(4);
        m.forward_backward(&w, &x, 0, &mut qg, Some(&epi), &mut rng2);
        let diff: f32 = base
            .iter()
            .flatten()
            .zip(qg.iter().flatten())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.0, "quantized backward must differ");
    }
}
