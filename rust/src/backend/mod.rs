//! Native pure-Rust execution backend: a real training engine behind
//! the [`StepExecutor`] trait, with **zero artifacts and zero external
//! dependencies**.
//!
//! * [`tensor`]   — blocked contiguous-f32 kernels (tiled matmul,
//!   conv-lite, pooling, ReLU, softmax-xent) with hand-derived backward
//!   passes and retained straight-line references;
//! * [`model`]    — the model zoo (logreg, MLP, mini-CNN) over the
//!   `data/synth.rs` shapes, per-sample forward/backward;
//! * [`parallel`] — scoped-thread microbatch parallelism.
//!
//! [`NativeExecutor`] computes **exact per-sample gradients** and clips
//! them (Σ of clipped per-sample grads — the same contract the compiled
//! PJRT graphs and `MockExecutor` expose), and runs the `quant/` kernels
//! **fused into the compute path** through a [`QuantEpilogue`]: a masked
//! layer's weight tensor is quantize-dequantized once per step as the
//! GEMM *prologue* (unmasked tensors are borrowed, never copied), and
//! the gradient tensor a masked layer consumes is quantize-dequantized
//! per sample at the point its producing GEMM emits it (the *epilogue*).
//! With an all-zero `quant_mask` the step is exact fp32 — the parity
//! tests pin this against hand-computed gradients and against
//! `MockExecutor`, and `tests/kernel_blocking.rs` pins the fused path
//! against separate whole-tensor quantize passes.
//!
//! Backend selection (`--backend native|pjrt|mock`) lives here too, so
//! `cli.rs`/`exp/` pick an executor through one entry point.

pub mod model;
pub mod parallel;
pub mod tensor;

use crate::config::TrainConfig;
use crate::coordinator::executor::{MockExecutor, StepExecutor};
use crate::quant::{self, Quantizer};
use crate::runtime::{EvalOutput, Runtime, TrainOutput};
use crate::util::error::{ensure, err, Error, Result};
use crate::util::rng::Xoshiro256;
use model::Model;

/// The pure-Rust training engine.
pub struct NativeExecutor {
    model: Model,
    init: Vec<Vec<f32>>,
    batch: usize,
    clip_norm: f32,
    quantizer: Box<dyn Quantizer>,
    threads: usize,
}

impl NativeExecutor {
    /// Build from an explicit model (tests / custom zoos).
    pub fn new(
        model: Model,
        batch: usize,
        clip_norm: f32,
        quantizer: Box<dyn Quantizer>,
        init_seed: u64,
    ) -> Self {
        assert!(batch > 0, "physical batch must be positive");
        let init = model.init_weights(init_seed);
        Self {
            model,
            init,
            batch,
            clip_norm,
            quantizer,
            threads: parallel::default_threads(),
        }
    }

    /// Resolve the model zoo + quantizer from a training config and the
    /// dataset's shape. This is the no-artifacts replacement for
    /// `Runtime::open` + `load`.
    pub fn from_config(cfg: &TrainConfig, example_numel: usize, n_classes: usize) -> Result<Self> {
        ensure!(
            cfg.physical_batch > 0,
            "native backend: physical_batch must be positive"
        );
        let mut model = Model::by_name(&cfg.model, example_numel, n_classes).map_err(Error::msg)?;
        if cfg.dataset == "snli" {
            // Token ids arrive as raw f32 in [0, VOCAB); scale into [0, 1)
            // so first-layer activations start sane.
            model.input_scale = 1.0 / crate::data::synth::VOCAB as f32;
        }
        let quantizer = quant::by_name(&cfg.quantizer)
            .ok_or_else(|| err!("unknown quantizer '{}' for the native backend", cfg.quantizer))?;
        Ok(Self::new(
            model,
            cfg.physical_batch,
            cfg.clip_norm as f32,
            quantizer,
            cfg.seed,
        ))
    }

    /// Override the worker-thread count (defaults to
    /// [`parallel::default_threads`]).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The resolved model (layer specs + parameter layout).
    pub fn model(&self) -> &Model {
        &self.model
    }

    /// The quantizer this executor fuses into masked layers.
    pub fn quantizer(&self) -> &dyn Quantizer {
        self.quantizer.as_ref()
    }

    /// Per-sample RNG stream: keyed by (step seed, sample index) so the
    /// result is independent of the thread partition. Public so the
    /// fused-vs-separate parity tests can replay the exact stochastic
    /// rounding stream of a step.
    pub fn sample_rng(seed: f32, i: usize) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(
            (seed.to_bits() as u64 ^ 0x51E9_D5A1_0000_0000)
                ^ (i as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F),
        )
    }
}

/// Fused quantization hooks for a native train step: the per-layer
/// quantize-dequantize decisions (`quant_mask`), the quantizer, and the
/// step seed, bundled so the model's per-sample backward can apply
/// gradient quantization **at the GEMM that produces the tensor**
/// instead of as a separate whole-tensor pass.
///
/// Two hooks:
/// * **weight prologue** ([`QuantEpilogue::quantize_weight`] /
///   [`QuantEpilogue::quantized_weight_store`]) — once per step, build a
///   quantize-dequantized copy of each *masked* layer's weight tensor
///   (biases and unmasked tensors are borrowed untouched — the old path
///   cloned the full weight set);
/// * **grad epilogue** ([`QuantEpilogue::grad_epilogue`]) — per sample,
///   quantize-dequantize the gradient tensor entering a masked layer's
///   backward, applied where the producing kernel emits it.
///
/// RNG streams are pinned: the weight prologue draws from the same
/// per-layer stream `quantize_masked_weights` has always derived from
/// the step seed, and the grad epilogue consumes the caller's per-sample
/// RNG in the same order as the old separate pass — so the fusion is
/// bit-identical to the pre-fusion pipeline (pinned by
/// `tests/kernel_blocking.rs`).
pub struct QuantEpilogue<'a> {
    quantizer: &'a dyn Quantizer,
    quant_mask: &'a [f32],
    seed: f32,
}

impl<'a> QuantEpilogue<'a> {
    /// Bundle a quantizer + per-layer mask + step seed.
    pub fn new(quantizer: &'a dyn Quantizer, quant_mask: &'a [f32], seed: f32) -> Self {
        Self {
            quantizer,
            quant_mask,
            seed,
        }
    }

    /// Number of schedulable layers the mask covers.
    pub fn n_layers(&self) -> usize {
        self.quant_mask.len()
    }

    /// Does layer `l` run low-precision this step?
    pub fn is_masked(&self, l: usize) -> bool {
        self.quant_mask[l] > 0.0
    }

    /// Is any layer masked? (All-zero masks make the whole step exact
    /// fp32; the executor skips the hooks entirely.)
    pub fn any_masked(&self) -> bool {
        self.quant_mask.iter().any(|&m| m > 0.0)
    }

    /// The pinned per-layer weight-quantization stream (keyed by step
    /// seed and layer index; independent of batch content and threads).
    fn weight_rng(&self, l: usize) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(
            (self.seed.to_bits() as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ ((l as u64 + 1) << 32),
        )
    }

    /// GEMM weight prologue for masked layer `l`: quantize-dequantized
    /// copy of `w` under the pinned per-layer stream.
    pub fn quantize_weight(&self, l: usize, w: &[f32]) -> Vec<f32> {
        let t = crate::obs::maybe_start();
        let mut qw = w.to_vec();
        self.quantizer.quantize(&mut qw, &mut self.weight_rng(l));
        if let Some(t0) = t {
            static H: std::sync::OnceLock<crate::obs::Histogram> = std::sync::OnceLock::new();
            H.get_or_init(|| crate::obs::global().histogram_ns("kernel.quant_weight_ns"))
                .record_duration(t0.elapsed());
        }
        qw
    }

    /// Run the weight prologue over a whole model: `Some(quantized)` for
    /// each masked layer's weight tensor, `None` (borrow the fp32
    /// original) everywhere else.
    pub fn quantized_weight_store(
        &self,
        model: &Model,
        weights: &[Vec<f32>],
    ) -> Vec<Option<Vec<f32>>> {
        let mut store: Vec<Option<Vec<f32>>> = vec![None; weights.len()];
        for (l, &m) in self.quant_mask.iter().enumerate() {
            if m > 0.0 {
                let wi = model.weight_index(l);
                store[wi] = Some(self.quantize_weight(l, &weights[wi]));
            }
        }
        store
    }

    /// GEMM gradient epilogue: quantize-dequantize the gradient tensor
    /// just produced for (i.e. about to be consumed by) layer `l`, iff
    /// `l` is masked. `rng` is the per-sample stream
    /// ([`NativeExecutor::sample_rng`]); unmasked layers draw nothing,
    /// keeping the stream position identical to the pre-fusion pipeline.
    pub fn grad_epilogue(&self, l: usize, grad: &mut [f32], rng: &mut Xoshiro256) {
        if self.quant_mask[l] > 0.0 {
            let t = crate::obs::maybe_start();
            self.quantizer.quantize(grad, rng);
            if let Some(t0) = t {
                static H: std::sync::OnceLock<crate::obs::Histogram> = std::sync::OnceLock::new();
                H.get_or_init(|| crate::obs::global().histogram_ns("kernel.quant_grad_ns"))
                    .record_duration(t0.elapsed());
            }
        }
    }
}

/// Quantize-dequantize the weight tensor of every masked layer exactly
/// as the hot path's [`QuantEpilogue`] prologue does before a train step
/// (biases stay fp32). Public so the quant-on-live-path property tests
/// exercise the real code; returns a full owned weight set (the executor
/// itself borrows unmasked tensors instead).
pub fn quantize_masked_weights(
    model: &Model,
    weights: &[Vec<f32>],
    quant_mask: &[f32],
    quantizer: &dyn Quantizer,
    seed: f32,
) -> Vec<Vec<f32>> {
    let epi = QuantEpilogue::new(quantizer, quant_mask, seed);
    let store = epi.quantized_weight_store(model, weights);
    weights
        .iter()
        .zip(store)
        .map(|(w, q)| q.unwrap_or_else(|| w.clone()))
        .collect()
}

impl StepExecutor for NativeExecutor {
    fn n_quant_layers(&self) -> usize {
        self.model.n_layers()
    }

    fn physical_batch(&self) -> usize {
        self.batch
    }

    fn param_sizes(&self) -> Vec<usize> {
        self.model.param_numels()
    }

    fn initial_weights(&self) -> Vec<Vec<f32>> {
        self.init.clone()
    }

    fn quant_weight_params(&self) -> Option<Vec<usize>> {
        // Layer l's weights live in the tensor the quant epilogue also
        // targets; biases are separate tensors and stay unmapped.
        Some(
            (0..self.model.n_layers())
                .map(|l| self.model.weight_index(l))
                .collect(),
        )
    }

    fn train_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        quant_mask: &[f32],
        seed: f32,
    ) -> Result<TrainOutput> {
        let en = self.model.input_numel;
        ensure!(
            x.len() == self.batch * en,
            "native train_step: x has {} values, want batch {} x {}",
            x.len(),
            self.batch,
            en
        );
        ensure!(
            y.len() == self.batch && mask.len() == self.batch,
            "native train_step: y/mask length != batch {}",
            self.batch
        );
        ensure!(
            quant_mask.len() == self.model.n_layers(),
            "native train_step: quant_mask has {} entries, model has {} layers",
            quant_mask.len(),
            self.model.n_layers()
        );

        let epi = QuantEpilogue::new(self.quantizer.as_ref(), quant_mask, seed);
        let any_q = epi.any_masked();
        // Weight prologue: quantized copies for masked layers only;
        // every other tensor is borrowed straight from `weights`.
        let qstore = if any_q {
            epi.quantized_weight_store(&self.model, weights)
        } else {
            Vec::new()
        };
        let wviews: Vec<&[f32]> = weights
            .iter()
            .enumerate()
            .map(|(i, w)| {
                qstore
                    .get(i)
                    .and_then(|q| q.as_deref())
                    .unwrap_or(w.as_slice())
            })
            .collect();
        let epi_ref = if any_q { Some(&epi) } else { None };

        let chunks = parallel::map_chunks(self.batch, self.threads, |rows| {
            let mut grad_sums = self.model.zero_grads();
            let mut gbuf = self.model.zero_grads();
            let mut loss_sum = 0f32;
            let mut correct_sum = 0f32;
            let mut raw_norm_sum = 0f32;
            let mut raw_norm_max = 0f32;
            for i in rows {
                if mask[i] == 0.0 {
                    continue;
                }
                for g in gbuf.iter_mut() {
                    g.fill(0.0);
                }
                let mut rng = Self::sample_rng(seed, i);
                let (loss, correct) = self.model.forward_backward(
                    &wviews,
                    &x[i * en..(i + 1) * en],
                    y[i] as usize,
                    &mut gbuf,
                    epi_ref,
                    &mut rng,
                );
                loss_sum += loss;
                if correct {
                    correct_sum += 1.0;
                }
                // Exact per-sample clip: ‖g_i‖₂ ≤ C before accumulation.
                let norm: f32 =
                    gbuf.iter().flat_map(|g| g.iter()).map(|&v| v * v).sum::<f32>().sqrt();
                raw_norm_sum += norm;
                raw_norm_max = raw_norm_max.max(norm);
                let scale = (self.clip_norm / norm.max(1e-12)).min(1.0);
                for (acc, g) in grad_sums.iter_mut().zip(&gbuf) {
                    for (a, &v) in acc.iter_mut().zip(g) {
                        *a += v * scale;
                    }
                }
            }
            (grad_sums, loss_sum, correct_sum, raw_norm_sum, raw_norm_max)
        });

        let mut it = chunks.into_iter();
        let (mut grad_sums, mut loss_sum, mut correct_sum, mut raw_norm_sum, mut raw_norm_max) =
            it.next().expect("map_chunks yields at least one chunk");
        for (g, l, c, rs, rm) in it {
            for (acc, part) in grad_sums.iter_mut().zip(&g) {
                for (a, &v) in acc.iter_mut().zip(part) {
                    *a += v;
                }
            }
            loss_sum += l;
            correct_sum += c;
            raw_norm_sum += rs;
            raw_norm_max = raw_norm_max.max(rm);
        }
        Ok(TrainOutput {
            grad_sums,
            loss_sum,
            correct_sum,
            raw_norm_sum,
            raw_norm_max,
        })
    }

    fn eval_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOutput> {
        let en = self.model.input_numel;
        ensure!(
            x.len() == self.batch * en && y.len() == self.batch && mask.len() == self.batch,
            "native eval_step: batch shape mismatch"
        );
        let chunks = parallel::map_chunks(self.batch, self.threads, |rows| {
            let mut loss_sum = 0f32;
            let mut correct_sum = 0f32;
            for i in rows {
                if mask[i] == 0.0 {
                    continue;
                }
                let logits = self.model.forward(weights, &x[i * en..(i + 1) * en]);
                let (loss, correct, _) = tensor::softmax_xent(&logits, y[i] as usize);
                loss_sum += loss;
                if correct {
                    correct_sum += 1.0;
                }
            }
            (loss_sum, correct_sum)
        });
        let (loss_sum, correct_sum) = chunks
            .into_iter()
            .fold((0f32, 0f32), |(l, c), (pl, pc)| (l + pl, c + pc));
        Ok(EvalOutput {
            loss_sum,
            correct_sum,
        })
    }
}

/// Open the executor selected by `cfg.backend`:
///
/// * `"native"` — this module's pure-Rust engine (default; needs no
///   artifacts and no external runtime);
/// * `"pjrt"` (alias `"xla"`) — AOT artifacts + the PJRT runtime (fails
///   with a pointer back to `--backend native` while `xla.rs` is a
///   stub);
/// * `"mock"` — the logistic-regression mock with *simulated*
///   quantization damage (unit-test substrate).
pub fn open_executor(
    cfg: &TrainConfig,
    example_numel: usize,
    n_classes: usize,
    artifacts_dir: &str,
) -> Result<Box<dyn StepExecutor>> {
    match cfg.backend.as_str() {
        "native" => Ok(Box::new(NativeExecutor::from_config(cfg, example_numel, n_classes)?)),
        "pjrt" | "xla" => {
            let rt = Runtime::open(artifacts_dir)?;
            Ok(Box::new(rt.load(&cfg.graph_tag())?))
        }
        "mock" => {
            let mut exec = MockExecutor::new(example_numel, n_classes, 8, cfg.physical_batch);
            exec.clip_norm = cfg.clip_norm as f32;
            Ok(Box::new(exec))
        }
        other => Err(err!("unknown backend '{other}' (expected native | pjrt | mock)")),
    }
}

/// Open an executor for one **sweep worker** (`sweep/` runs whole grid
/// points in parallel, one executor per worker).
///
/// Differences from [`open_executor`]:
///
/// * the native engine is pinned to **one** internal thread — sweep
///   parallelism is across grid points, and the native backend's float
///   sums are deterministic only *per* worker-thread count, so pinning
///   makes every grid point's result independent of `--jobs` and of
///   `DPQUANT_THREADS` (the sweep determinism contract, DESIGN.md §11);
/// * artifact-backed backends are rejected: sweep workers must be
///   self-contained, and the PJRT runtime is not shareable across
///   threads.
pub fn open_sweep_executor(
    cfg: &TrainConfig,
    example_numel: usize,
    n_classes: usize,
) -> Result<Box<dyn StepExecutor>> {
    match cfg.backend.as_str() {
        "native" => Ok(Box::new(
            NativeExecutor::from_config(cfg, example_numel, n_classes)?.with_threads(1),
        )),
        "mock" => {
            let mut exec = MockExecutor::new(example_numel, n_classes, 8, cfg.physical_batch);
            exec.clip_norm = cfg.clip_norm as f32;
            Ok(Box::new(exec))
        }
        "pjrt" | "xla" => Err(err!(
            "sweep workers need an artifact-free backend; use --backend native or mock, \
             not '{}'",
            cfg.backend
        )),
        other => Err(err!("unknown backend '{other}' (sweep supports native | mock)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_exec(quantizer: &str, clip: f32, batch: usize) -> NativeExecutor {
        let cfg = TrainConfig {
            model: "mlp".into(),
            quantizer: quantizer.into(),
            clip_norm: clip as f64,
            physical_batch: batch,
            seed: 11,
            ..TrainConfig::default()
        };
        NativeExecutor::from_config(&cfg, 12, 4).unwrap()
    }

    fn toy_batch(exec: &NativeExecutor, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let b = exec.physical_batch();
        let en = exec.model().input_numel;
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut x = vec![0f32; b * en];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let c = rng.next_below(4) as i32;
            y[i] = c;
            for f in 0..en {
                x[i * en + f] = rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 };
            }
        }
        (x, y, vec![1.0; b])
    }

    #[test]
    fn clip_bound_holds_and_masked_rows_skip() {
        let exec = small_exec("luq4", 1.0, 8);
        let w = exec.initial_weights();
        let (x, y, mut mask) = toy_batch(&exec, 1);
        let zero = vec![0f32; exec.n_quant_layers()];
        let full = exec.train_step(&w, &x, &y, &mask, &zero, 0.0).unwrap();
        let norm: f32 = full.grad_sums.iter().flatten().map(|&g| g * g).sum::<f32>().sqrt();
        assert!(norm <= 8.0 + 1e-3, "norm={norm}");
        // Masking half the rows halves loss contributions.
        for m in mask.iter_mut().skip(4) {
            *m = 0.0;
        }
        let half = exec.train_step(&w, &x, &y, &mask, &zero, 0.0).unwrap();
        assert!(half.loss_sum < full.loss_sum);
        assert!(half.correct_sum <= full.correct_sum);
        // Eval agrees with train-side loss accounting on the same rows.
        let ev = exec.eval_step(&w, &x, &y, &mask).unwrap();
        assert!((ev.loss_sum - half.loss_sum).abs() < 1e-3);
        assert!((ev.correct_sum - half.correct_sum).abs() < 1e-6);
    }

    #[test]
    fn deterministic_for_fixed_thread_count_and_seeded_quantization() {
        let exec = small_exec("luq4", 1.0, 6).with_threads(2);
        let w = exec.initial_weights();
        let (x, y, mask) = toy_batch(&exec, 2);
        let ones = vec![1f32; exec.n_quant_layers()];
        let a = exec.train_step(&w, &x, &y, &mask, &ones, 3.0).unwrap();
        let b = exec.train_step(&w, &x, &y, &mask, &ones, 3.0).unwrap();
        assert_eq!(a.grad_sums, b.grad_sums);
        assert_eq!(a.loss_sum, b.loss_sum);
        // A different step seed re-rolls the stochastic rounding.
        let c = exec.train_step(&w, &x, &y, &mask, &ones, 4.0).unwrap();
        assert_ne!(a.grad_sums, c.grad_sums);
    }

    #[test]
    fn thread_partition_only_reorders_float_sums() {
        let e1 = small_exec("uniform4", 1.0, 12).with_threads(1);
        let e4 = small_exec("uniform4", 1.0, 12).with_threads(4);
        let w = e1.initial_weights();
        let (x, y, mask) = toy_batch(&e1, 3);
        let ones = vec![1f32; e1.n_quant_layers()];
        let a = e1.train_step(&w, &x, &y, &mask, &ones, 5.0).unwrap();
        let b = e4.train_step(&w, &x, &y, &mask, &ones, 5.0).unwrap();
        for (ga, gb) in a.grad_sums.iter().zip(&b.grad_sums) {
            for (va, vb) in ga.iter().zip(gb) {
                assert!((va - vb).abs() < 1e-4, "{va} vs {vb}");
            }
        }
        assert!((a.loss_sum - b.loss_sum).abs() < 1e-3);
        assert_eq!(a.correct_sum, b.correct_sum);
    }

    #[test]
    fn quantized_step_differs_from_fp32() {
        let exec = small_exec("luq4", 1.0, 8);
        let w = exec.initial_weights();
        let (x, y, mask) = toy_batch(&exec, 4);
        let zero = vec![0f32; exec.n_quant_layers()];
        let ones = vec![1f32; exec.n_quant_layers()];
        let fp = exec.train_step(&w, &x, &y, &mask, &zero, 1.0).unwrap();
        let q = exec.train_step(&w, &x, &y, &mask, &ones, 1.0).unwrap();
        let diff: f32 = fp
            .grad_sums
            .iter()
            .flatten()
            .zip(q.grad_sums.iter().flatten())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.0, "quantization must perturb the step");
    }

    #[test]
    fn epilogue_weight_store_matches_public_pass() {
        // The borrow-based store and the owned public helper must agree
        // tensor for tensor.
        let exec = small_exec("luq4", 1.0, 4);
        let model = exec.model();
        let w = exec.initial_weights();
        let mut mask = vec![0f32; exec.n_quant_layers()];
        mask[0] = 1.0;
        mask[2] = 1.0;
        let epi = QuantEpilogue::new(exec.quantizer(), &mask, 1.5);
        let store = epi.quantized_weight_store(model, &w);
        let owned = quantize_masked_weights(model, &w, &mask, exec.quantizer(), 1.5);
        for (i, (orig, got)) in w.iter().zip(&owned).enumerate() {
            match &store[i] {
                Some(q) => assert_eq!(q, got, "tensor {i}: store vs owned pass"),
                None => assert_eq!(orig, got, "tensor {i}: unmasked must be untouched"),
            }
        }
        // Masked weight tensors are Some, everything else None.
        for l in 0..exec.n_quant_layers() {
            assert_eq!(store[model.weight_index(l)].is_some(), mask[l] > 0.0);
        }
    }

    #[test]
    fn open_executor_variants() {
        let cfg = TrainConfig::default(); // backend = native
        let exec = open_executor(&cfg, 16 * 16 * 3, 10, "no-such-dir").unwrap();
        assert_eq!(exec.n_quant_layers(), 5);
        let mock_cfg = TrainConfig {
            backend: "mock".into(),
            ..TrainConfig::default()
        };
        let mock = open_executor(&mock_cfg, 8, 3, "no-such-dir").unwrap();
        assert_eq!(mock.param_sizes(), vec![24]);
        let bad = TrainConfig {
            backend: "tpu".into(),
            ..TrainConfig::default()
        };
        let e = open_executor(&bad, 8, 3, "no-such-dir").unwrap_err();
        assert!(format!("{e}").contains("unknown backend"), "{e}");
        let pjrt = TrainConfig {
            backend: "pjrt".into(),
            ..TrainConfig::default()
        };
        let e = open_executor(&pjrt, 8, 3, "no-such-dir").unwrap_err();
        assert!(format!("{e:#}").contains("manifest.json"), "{e:#}");
    }

    #[test]
    fn bad_shapes_error_not_panic() {
        let exec = small_exec("fp8", 1.0, 4);
        let w = exec.initial_weights();
        let err = exec
            .train_step(&w, &[0.0; 4], &[0; 4], &[1.0; 4], &[0.0; 5], 0.0)
            .unwrap_err();
        assert!(format!("{err}").contains("train_step"), "{err}");
    }
}
