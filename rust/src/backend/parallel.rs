//! Scoped-thread microbatch parallelism for the native backend.
//!
//! Per-sample gradient work is embarrassingly parallel across the rows
//! of a physical batch: each sample's forward/backward touches only
//! shared read-only state (weights, inputs, specs) plus thread-local
//! buffers. We split the batch into contiguous chunks, run each chunk on
//! a `std::thread::scope` worker, and merge partial results **in chunk
//! order** — so for a fixed thread count the result is bit-for-bit
//! deterministic (per-sample RNG streams are keyed by sample index, not
//! by thread).
//!
//! For coarse-grained jobs of uneven duration (whole training runs),
//! static chunking wastes wall-clock; `sweep::pool::run_ordered` is the
//! work-stealing generalization of this module used by the sweep
//! orchestrator.

/// Worker-thread count: the `DPQUANT_THREADS` env var wins, else the
/// machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("DPQUANT_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Split `0..n` into at most `threads` contiguous chunks and run `f` on
/// each in its own scoped thread, returning results in chunk order.
/// `threads <= 1` (or `n <= 1`) degenerates to a plain call on the
/// current thread — no spawn overhead for tiny batches.
pub fn map_chunks<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(std::ops::Range<usize>) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads == 1 {
        return vec![f(0..n)];
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
            .filter(|&(lo, hi)| lo < hi)
            .map(|(lo, hi)| s.spawn(move || f(lo..hi)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("native backend worker panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_index_once() {
        for n in [0usize, 1, 2, 7, 16, 33] {
            for threads in [1usize, 2, 3, 8, 64] {
                let ranges = map_chunks(n, threads, |r| r.collect::<Vec<_>>());
                let flat: Vec<usize> = ranges.into_iter().flatten().collect();
                assert_eq!(flat, (0..n).collect::<Vec<_>>(), "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_sum_matches_serial() {
        let data: Vec<u64> = (0..1000).map(|i| i * i).collect();
        let serial: u64 = data.iter().sum();
        for threads in [1usize, 2, 5, 16] {
            let partials = map_chunks(data.len(), threads, |r| -> u64 {
                r.map(|i| data[i]).sum()
            });
            assert_eq!(partials.iter().sum::<u64>(), serial);
        }
    }

    #[test]
    fn single_thread_no_spawn_path() {
        let out = map_chunks(5, 1, |r| r.len());
        assert_eq!(out, vec![5]);
        let empty = map_chunks(0, 4, |r| r.len());
        assert_eq!(empty, vec![0]);
    }

    #[test]
    fn env_override_parses() {
        // default_threads never returns 0 regardless of the env.
        assert!(default_threads() >= 1);
    }
}
