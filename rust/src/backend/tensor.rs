//! Tensor kernels for the native backend: contiguous `f32` buffers plus
//! the dense / conv-lite / pooling / activation / loss primitives the
//! model zoo composes into real forward and backward passes.
//!
//! The hot-path kernels (`matmul_blocked`, `dense_forward`,
//! `dense_backward`, `conv3x3_forward`, `conv3x3_backward`) are
//! **cache-blocked and autovectorizer-friendly**: MC/KC/NC macro-blocking
//! with an MR x NR register micro-kernel for the GEMM, tap-range clamping
//! plus per-call weight repacking for the convolutions. Every blocked
//! kernel preserves the *per-output-element accumulation order* of its
//! retained straight-line reference (`matmul`, `*_ref`), so results are
//! bit-identical for finite inputs wherever the determinism contract
//! pins them (DESIGN.md §13 spells out which paths are bit-pinned vs
//! tolerance-pinned). No SIMD intrinsics, no unsafe: speed comes from
//! independent accumulator chains, contiguous inner loops the
//! autovectorizer can widen without reassociating, and reduced memory
//! traffic.
//!
//! The backward functions are the hand-derived adjoints of the forwards;
//! unit tests check them against central finite differences, and
//! `tests/kernel_blocking.rs` checks blocked-vs-reference parity over
//! randomized shapes.
//!
//! Layout conventions:
//! * images are HWC (`[(y*W + x)*C + c]`), matching `data/synth.rs`;
//! * dense weights are `[out][in]` row-major;
//! * conv weights are `[cout][cin][ky][kx]` with a 3x3 kernel and same
//!   padding (stride 1).

use crate::obs::{self, Histogram};
use crate::util::rng::Xoshiro256;
use std::sync::OnceLock;
use std::time::Instant;

/// Record `start.elapsed()` into the lazily-created global histogram
/// `name` — the shared tail of every instrumented kernel. `start` is
/// `None` when kernel timing is off ([`obs::maybe_start`]), making the
/// disabled path one branch. Recording never touches kernel outputs.
fn record_kernel(start: Option<Instant>, hist: &'static OnceLock<Histogram>, name: &'static str) {
    if let Some(t0) = start {
        hist.get_or_init(|| obs::global().histogram_ns(name))
            .record_duration(t0.elapsed());
    }
}

/// A contiguous f32 tensor with an explicit row-major shape. The hot
/// path passes raw slices; `Tensor` carries shape metadata for
/// initialization, parameter bookkeeping and the property tests.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Flat row-major storage (`shape.iter().product()` elements).
    pub data: Vec<f32>,
    /// Row-major dimensions, outermost first.
    pub shape: Vec<usize>,
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    /// Wrap an existing buffer; panics if `data.len()` mismatches `shape`.
    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "tensor data/shape mismatch"
        );
        Self {
            data,
            shape: shape.to_vec(),
        }
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// He-style uniform init: `U(-sqrt(6/fan_in), +sqrt(6/fan_in))` —
    /// keeps activation scale roughly constant through ReLU stacks.
    pub fn he_uniform(shape: &[usize], fan_in: usize, rng: &mut Xoshiro256) -> Self {
        let lim = (6.0 / fan_in.max(1) as f32).sqrt();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (2.0 * rng.next_f32() - 1.0) * lim).collect();
        Self {
            data,
            shape: shape.to_vec(),
        }
    }
}

// ---------------------------------------------------------------------
// Blocked GEMM
// ---------------------------------------------------------------------

/// Row-block of the LHS kept hot across one K-block (MC x KC f32 = 64 KiB).
pub const MC: usize = 64;
/// K-dimension block: the span each register tile accumulates between a
/// load and a store of its `out` entries. Larger KC amortizes the
/// load/store of the accumulator tile; KC x NC f32 = 128 KiB of `b`
/// panel stays L2-resident.
pub const KC: usize = 256;
/// Column-block of the RHS reused across every row block.
pub const NC: usize = 128;
/// Register-tile rows: independent accumulator chains per column, hiding
/// FMA latency without reassociating any single chain.
const MR: usize = 4;
/// Register-tile columns: one 8-wide SIMD vector per row chain.
const NR: usize = 8;

/// `out[m×n] = a[m×k] · b[k×n]` — the straight-line **reference** GEMM
/// (row-update form, `j` innermost). Retained as the parity baseline for
/// [`matmul_blocked`] and as the "naive" side of `dpquant bench`; the
/// hot path routes through the blocked kernels instead.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    assert_eq!(out.len(), m * n, "matmul out shape");
    out.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// `out[m×n] = a[m×k] · b[k×n]`, cache-blocked (overwrite form). See
/// [`matmul_blocked_into`] for the accumulate form and the exactness
/// contract.
pub fn matmul_blocked(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(out.len(), m * n, "matmul out shape");
    let t = obs::maybe_start();
    out.fill(0.0);
    matmul_blocked_into(a, b, m, k, n, out);
    static H: OnceLock<Histogram> = OnceLock::new();
    record_kernel(t, &H, "kernel.matmul_blocked_ns");
}

/// `out[m×n] += a[m×k] · b[k×n]`, cache-blocked: [`MC`]/[`KC`]/[`NC`]
/// macro-blocking around an `MR x NR` register micro-kernel.
///
/// Bit-exactness: each output element's contributions are added in
/// ascending-`p` order onto the existing `out` value — the identical
/// chain the reference [`matmul`] builds (including its skip of
/// zero-valued `a` entries) — so for finite inputs the result is
/// bit-identical to `out + matmul(a, b)`. The speedup comes from the
/// accumulator tile living in registers across a whole K-block (the
/// reference stores and reloads the output row once per `p`) and from
/// `a`-panel/`b`-panel reuse, not from reassociation.
pub fn matmul_blocked_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    assert_eq!(out.len(), m * n, "matmul out shape");
    for jc in (0..n).step_by(NC) {
        let nc = NC.min(n - jc);
        for pc in (0..k).step_by(KC) {
            let kc = KC.min(k - pc);
            for ic in (0..m).step_by(MC) {
                let mc = MC.min(m - ic);
                for ir in (0..mc).step_by(MR) {
                    let mr = MR.min(mc - ir);
                    for jr in (0..nc).step_by(NR) {
                        let nr = NR.min(nc - jr);
                        let (i0, j0) = (ic + ir, jc + jr);
                        if mr == MR && nr == NR {
                            micro_full(a, b, k, n, i0, j0, pc, kc, out);
                        } else {
                            micro_edge(a, b, k, n, i0, j0, pc, kc, mr, nr, out);
                        }
                    }
                }
            }
        }
    }
}

/// Full `MR x NR` register tile over one K-block. The accumulator array
/// stays in registers; each of the MR row chains is strictly sequential
/// in `p` (no reassociation — the bit-exactness contract) while the NR
/// columns are independent lanes the autovectorizer widens.
#[inline]
#[allow(clippy::too_many_arguments)]
fn micro_full(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    kc: usize,
    out: &mut [f32],
) {
    let mut acc = [[0f32; NR]; MR];
    for (r, accr) in acc.iter_mut().enumerate() {
        let base = (i0 + r) * n + j0;
        accr.copy_from_slice(&out[base..base + NR]);
    }
    for p in pc..pc + kc {
        let bbase = p * n + j0;
        let brow: &[f32; NR] = b[bbase..bbase + NR].try_into().unwrap();
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = a[(i0 + r) * k + p];
            if av == 0.0 {
                // Matches the reference kernel's sparse-row skip (big for
                // post-ReLU gradients); identity on the add chain anyway.
                continue;
            }
            for (s, &bv) in accr.iter_mut().zip(brow) {
                *s += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let base = (i0 + r) * n + j0;
        out[base..base + NR].copy_from_slice(accr);
    }
}

/// Remainder tile (`mr < MR` and/or `nr < NR`): same accumulation order
/// as [`micro_full`], generic loop bounds.
#[inline]
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
fn micro_edge(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    i0: usize,
    j0: usize,
    pc: usize,
    kc: usize,
    mr: usize,
    nr: usize,
    out: &mut [f32],
) {
    let mut acc = [[0f32; NR]; MR];
    for r in 0..mr {
        for c in 0..nr {
            acc[r][c] = out[(i0 + r) * n + j0 + c];
        }
    }
    for p in pc..pc + kc {
        let bbase = p * n + j0;
        for r in 0..mr {
            let av = a[(i0 + r) * k + p];
            if av == 0.0 {
                continue;
            }
            for c in 0..nr {
                acc[r][c] += av * b[bbase + c];
            }
        }
    }
    for r in 0..mr {
        for c in 0..nr {
            out[(i0 + r) * n + j0 + c] = acc[r][c];
        }
    }
}

// ---------------------------------------------------------------------
// Dense layer
// ---------------------------------------------------------------------

/// Dense forward for one sample: `out = W·a (+ b)` with `W` as
/// `[out][in]` row-major. Routed through the blocked GEMM
/// ([`matmul_blocked_into`] with `n = 1`): the bias seeds the
/// accumulator exactly like the reference, so the result is
/// bit-identical to [`dense_forward_ref`] — but the micro-kernel runs
/// [`MR`] independent accumulator chains where the reference's single
/// chain is FMA-latency-bound.
pub fn dense_forward(w: &[f32], b: Option<&[f32]>, a: &[f32], out: &mut [f32]) {
    let input = a.len();
    let output = out.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    let t = obs::maybe_start();
    match b {
        Some(bb) => out.copy_from_slice(bb),
        None => out.fill(0.0),
    }
    matmul_blocked_into(w, a, output, input, 1, out);
    static H: OnceLock<Histogram> = OnceLock::new();
    record_kernel(t, &H, "kernel.dense_forward_ns");
}

/// Straight-line reference for [`dense_forward`] (parity tests and
/// `dpquant bench` baseline).
pub fn dense_forward_ref(w: &[f32], b: Option<&[f32]>, a: &[f32], out: &mut [f32]) {
    let input = a.len();
    let output = out.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    for (o, slot) in out.iter_mut().enumerate() {
        let row = &w[o * input..(o + 1) * input];
        let mut acc = b.map_or(0.0, |bb| bb[o]);
        for (&wi, &ai) in row.iter().zip(a) {
            acc += wi * ai;
        }
        *slot = acc;
    }
}

/// Dense backward for one sample. `gw`/`gb` are *accumulated into*
/// (callers zero per-sample buffers); `da`, when present, is overwritten
/// with the gradient w.r.t. the layer input.
///
/// The input-gradient `da = dy · W` runs through the blocked GEMM
/// (`1 x output x input`); its ascending-`k` accumulation is the
/// reference's ascending-`o` loop, so all three outputs are
/// bit-identical to [`dense_backward_ref`]. The weight-gradient update
/// is a contiguous rank-1 AXPY per nonzero `dy` row, which the
/// autovectorizer already widens.
pub fn dense_backward(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    mut gb: Option<&mut [f32]>,
    da: Option<&mut [f32]>,
) {
    let input = a.len();
    let output = dy.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    assert_eq!(gw.len(), input * output, "dense grad shape");
    for (o, &d) in dy.iter().enumerate() {
        if let Some(gb) = gb.as_deref_mut() {
            gb[o] += d;
        }
        if d == 0.0 {
            continue;
        }
        let grow = &mut gw[o * input..(o + 1) * input];
        for (g, &ai) in grow.iter_mut().zip(a) {
            *g += d * ai;
        }
    }
    if let Some(da) = da {
        assert_eq!(da.len(), input, "dense da shape");
        da.fill(0.0);
        matmul_blocked_into(dy, w, 1, output, input, da);
    }
}

/// Straight-line reference for [`dense_backward`] (parity tests and
/// `dpquant bench` baseline).
pub fn dense_backward_ref(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    mut gb: Option<&mut [f32]>,
    da: Option<&mut [f32]>,
) {
    let input = a.len();
    let output = dy.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    assert_eq!(gw.len(), input * output, "dense grad shape");
    for (o, &d) in dy.iter().enumerate() {
        if let Some(gb) = gb.as_deref_mut() {
            gb[o] += d;
        }
        if d == 0.0 {
            continue;
        }
        let grow = &mut gw[o * input..(o + 1) * input];
        for (g, &ai) in grow.iter_mut().zip(a) {
            *g += d * ai;
        }
    }
    if let Some(da) = da {
        assert_eq!(da.len(), input, "dense da shape");
        da.fill(0.0);
        for (o, &d) in dy.iter().enumerate() {
            if d == 0.0 {
                continue;
            }
            let row = &w[o * input..(o + 1) * input];
            for (x, &wi) in da.iter_mut().zip(row) {
                *x += d * wi;
            }
        }
    }
}

// ---------------------------------------------------------------------
// 3x3 convolution
// ---------------------------------------------------------------------

/// 3x3 same-padding convolution over one HWC image (stride 1), blocked:
///
/// * the valid tap range `(ky0..ky1, kx0..kx1)` is clamped per row /
///   column, so interior pixels run the full 3x3 with **no per-pixel
///   bounds checks** (the reference tests `sy < h` per tap per pixel);
/// * weights are repacked per call from `[cout][cin][3][3]` to
///   `[ky][kx][cin][cout]`, turning the reference's stride-9 scalar
///   gather into a contiguous `cout`-long AXPY the autovectorizer
///   widens;
/// * the `cout` accumulators live in one hot row buffer seeded with the
///   bias.
///
/// Per output element the tap contributions are added in the same
/// `(ky, kx, ci)` order as [`conv3x3_forward_ref`], so results are
/// bit-identical for finite inputs.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_forward(
    w: &[f32],
    b: &[f32],
    a: &[f32],
    out: &mut [f32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(out.len(), h * wd * cout, "conv output shape");
    assert_eq!(w.len(), cout * cin * 9, "conv weight shape");
    assert_eq!(b.len(), cout, "conv bias shape");
    let t = obs::maybe_start();
    // Repack [cout][cin][3][3] -> [ky][kx][cin][cout].
    let mut wp = vec![0f32; w.len()];
    for co in 0..cout {
        for ci in 0..cin {
            for koff in 0..9 {
                wp[(koff * cin + ci) * cout + co] = w[(co * cin + ci) * 9 + koff];
            }
        }
    }
    let mut acc = vec![0f32; cout];
    for y in 0..h {
        let (ky0, ky1) = (usize::from(y == 0), if y + 1 == h { 2 } else { 3 });
        for x in 0..wd {
            let (kx0, kx1) = (usize::from(x == 0), if x + 1 == wd { 2 } else { 3 });
            acc.copy_from_slice(b);
            for ky in ky0..ky1 {
                let sy = y + ky - 1;
                for kx in kx0..kx1 {
                    let sx = x + kx - 1;
                    let abase = (sy * wd + sx) * cin;
                    let tap = (ky * 3 + kx) * cin;
                    for ci in 0..cin {
                        let av = a[abase + ci];
                        let wrow = &wp[(tap + ci) * cout..(tap + ci + 1) * cout];
                        for (s, &wv) in acc.iter_mut().zip(wrow) {
                            *s += wv * av;
                        }
                    }
                }
            }
            let obase = (y * wd + x) * cout;
            out[obase..obase + cout].copy_from_slice(&acc);
        }
    }
    static H: OnceLock<Histogram> = OnceLock::new();
    record_kernel(t, &H, "kernel.conv3x3_forward_ns");
}

/// Straight-line reference for [`conv3x3_forward`] (parity tests and
/// `dpquant bench` baseline).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_forward_ref(
    w: &[f32],
    b: &[f32],
    a: &[f32],
    out: &mut [f32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(out.len(), h * wd * cout, "conv output shape");
    assert_eq!(w.len(), cout * cin * 9, "conv weight shape");
    assert_eq!(b.len(), cout, "conv bias shape");
    for y in 0..h {
        for x in 0..wd {
            let obase = (y * wd + x) * cout;
            for co in 0..cout {
                let mut acc = b[co];
                let wbase = co * cin * 9;
                for ky in 0..3usize {
                    // `y + ky - 1` via wrapping: out-of-range wraps to a
                    // huge value and fails the `< h` bound check.
                    let sy = (y + ky).wrapping_sub(1);
                    if sy >= h {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = (x + kx).wrapping_sub(1);
                        if sx >= wd {
                            continue;
                        }
                        let abase = (sy * wd + sx) * cin;
                        let koff = ky * 3 + kx;
                        for ci in 0..cin {
                            acc += w[wbase + ci * 9 + koff] * a[abase + ci];
                        }
                    }
                }
                out[obase + co] = acc;
            }
        }
    }
}

/// Backward of [`conv3x3_forward`] for one sample: accumulates `gw`/`gb`
/// and (when present) overwrites `da` with the input gradient.
///
/// Blocked form: tap-range clamping (no per-pixel bounds checks in the
/// interior), weights repacked to `[cout][ky][kx][cin]` for a contiguous
/// `cin`-long dual AXPY (`gw` and `da` updated in one pass), and the
/// weight gradient accumulated into a packed scratch buffer unpacked
/// once at the end. `gb` and `da` are bit-identical to
/// [`conv3x3_backward_ref`]; `gw` is bit-identical when it enters zeroed
/// (the executor's per-sample convention — a pre-accumulated `gw` lands
/// within one rounding step of the reference, tolerance-pinned per
/// DESIGN.md §13).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_backward(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    mut da: Option<&mut [f32]>,
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(dy.len(), h * wd * cout, "conv dy shape");
    assert_eq!(gw.len(), cout * cin * 9, "conv grad shape");
    assert_eq!(gb.len(), cout, "conv bias grad shape");
    let t = obs::maybe_start();
    let need_da = da.is_some();
    if let Some(d) = da.as_deref_mut() {
        assert_eq!(d.len(), h * wd * cin, "conv da shape");
        d.fill(0.0);
    }
    // Repack [cout][cin][3][3] -> [cout][ky][kx][cin] (only needed for
    // the input-gradient update).
    let mut wp = vec![0f32; if need_da { w.len() } else { 0 }];
    if need_da {
        for co in 0..cout {
            for ci in 0..cin {
                for koff in 0..9 {
                    wp[(co * 9 + koff) * cin + ci] = w[(co * cin + ci) * 9 + koff];
                }
            }
        }
    }
    // Packed weight-gradient scratch, same [cout][ky][kx][cin] layout.
    let mut gp = vec![0f32; gw.len()];
    for y in 0..h {
        let (ky0, ky1) = (usize::from(y == 0), if y + 1 == h { 2 } else { 3 });
        for x in 0..wd {
            let (kx0, kx1) = (usize::from(x == 0), if x + 1 == wd { 2 } else { 3 });
            let obase = (y * wd + x) * cout;
            for co in 0..cout {
                let d = dy[obase + co];
                if d == 0.0 {
                    continue;
                }
                gb[co] += d;
                for ky in ky0..ky1 {
                    let sy = y + ky - 1;
                    for kx in kx0..kx1 {
                        let sx = x + kx - 1;
                        let abase = (sy * wd + sx) * cin;
                        let pbase = (co * 9 + ky * 3 + kx) * cin;
                        let arow = &a[abase..abase + cin];
                        let gprow = &mut gp[pbase..pbase + cin];
                        if let Some(dd) = da.as_deref_mut() {
                            let wrow = &wp[pbase..pbase + cin];
                            let darow = &mut dd[abase..abase + cin];
                            for ci in 0..cin {
                                gprow[ci] += d * arow[ci];
                                darow[ci] += d * wrow[ci];
                            }
                        } else {
                            for (g, &av) in gprow.iter_mut().zip(arow) {
                                *g += d * av;
                            }
                        }
                    }
                }
            }
        }
    }
    for co in 0..cout {
        for koff in 0..9 {
            for ci in 0..cin {
                gw[(co * cin + ci) * 9 + koff] += gp[(co * 9 + koff) * cin + ci];
            }
        }
    }
    static H: OnceLock<Histogram> = OnceLock::new();
    record_kernel(t, &H, "kernel.conv3x3_backward_ns");
}

/// Straight-line reference for [`conv3x3_backward`] (parity tests and
/// `dpquant bench` baseline).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_backward_ref(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    mut da: Option<&mut [f32]>,
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(dy.len(), h * wd * cout, "conv dy shape");
    assert_eq!(gw.len(), cout * cin * 9, "conv grad shape");
    assert_eq!(gb.len(), cout, "conv bias grad shape");
    if let Some(d) = da.as_deref_mut() {
        assert_eq!(d.len(), h * wd * cin, "conv da shape");
        d.fill(0.0);
    }
    for y in 0..h {
        for x in 0..wd {
            let obase = (y * wd + x) * cout;
            for co in 0..cout {
                let d = dy[obase + co];
                if d == 0.0 {
                    continue;
                }
                gb[co] += d;
                let wbase = co * cin * 9;
                for ky in 0..3usize {
                    let sy = (y + ky).wrapping_sub(1);
                    if sy >= h {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = (x + kx).wrapping_sub(1);
                        if sx >= wd {
                            continue;
                        }
                        let abase = (sy * wd + sx) * cin;
                        let koff = ky * 3 + kx;
                        for ci in 0..cin {
                            gw[wbase + ci * 9 + koff] += d * a[abase + ci];
                            if let Some(dd) = da.as_deref_mut() {
                                dd[abase + ci] += d * w[wbase + ci * 9 + koff];
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// Pooling / activation / loss
// ---------------------------------------------------------------------

/// 2x2 average pooling over an HWC image (`h`, `wd` must be even).
pub fn avgpool2_forward(a: &[f32], out: &mut [f32], h: usize, wd: usize, c: usize) {
    assert!(h % 2 == 0 && wd % 2 == 0, "avgpool2 needs even dims");
    assert_eq!(a.len(), h * wd * c, "avgpool input shape");
    assert_eq!(out.len(), (h / 2) * (wd / 2) * c, "avgpool output shape");
    let w2 = wd / 2;
    for y in 0..h / 2 {
        for x in 0..w2 {
            for ch in 0..c {
                let s = a[((2 * y) * wd + 2 * x) * c + ch]
                    + a[((2 * y) * wd + 2 * x + 1) * c + ch]
                    + a[((2 * y + 1) * wd + 2 * x) * c + ch]
                    + a[((2 * y + 1) * wd + 2 * x + 1) * c + ch];
                out[(y * w2 + x) * c + ch] = 0.25 * s;
            }
        }
    }
}

/// Backward of [`avgpool2_forward`]: each output grad spreads equally
/// over its 2x2 window. `h`, `wd` are the *input* dims; `da` is
/// overwritten in full.
pub fn avgpool2_backward(dy: &[f32], da: &mut [f32], h: usize, wd: usize, c: usize) {
    assert_eq!(dy.len(), (h / 2) * (wd / 2) * c, "avgpool dy shape");
    assert_eq!(da.len(), h * wd * c, "avgpool da shape");
    let w2 = wd / 2;
    for y in 0..h {
        for x in 0..wd {
            for ch in 0..c {
                da[(y * wd + x) * c + ch] = 0.25 * dy[((y / 2) * w2 + x / 2) * c + ch];
            }
        }
    }
}

/// ReLU forward, in place.
pub fn relu_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// ReLU backward: zero the upstream grads wherever the (post-ReLU)
/// activation was clamped.
pub fn relu_backward_mask(out: &[f32], dy: &mut [f32]) {
    assert_eq!(out.len(), dy.len(), "relu mask shape");
    for (d, &o) in dy.iter_mut().zip(out) {
        if o <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Numerically-stable softmax cross-entropy for one sample. Returns
/// `(loss, correct, dlogits)`. Argmax tie-breaking (last max wins)
/// deliberately matches `MockExecutor` so the parity tests can compare
/// `correct_sum` exactly.
pub fn softmax_xent(logits: &[f32], label: usize) -> (f32, bool, Vec<f32>) {
    assert!(label < logits.len(), "label out of range");
    let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - maxl).exp()).collect();
    let z: f32 = exps.iter().sum();
    let loss = z.ln() + maxl - logits[label];
    // total_cmp orders like partial_cmp on real values but cannot panic
    // on NaN logits (a diverged run must surface as bad numbers in the
    // returned loss, not kill a worker thread).
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    let mut d: Vec<f32> = exps.iter().map(|&e| e / z).collect();
    d[label] -= 1.0;
    (loss, argmax == label, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(seed)
    }

    fn rand_vec(n: usize, scale: f32, r: &mut Xoshiro256) -> Vec<f32> {
        (0..n).map(|_| (2.0 * r.next_f32() - 1.0) * scale).collect()
    }

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
        let mut blocked = [0f32; 4];
        matmul_blocked(&a, &b, 2, 2, 2, &mut blocked);
        assert_eq!(blocked, out);
    }

    #[test]
    fn blocked_matmul_bit_identical_across_tile_remainders() {
        // Shapes straddling every remainder case of the MR x NR tile and
        // the KC block; the full randomized sweep lives in
        // tests/kernel_blocking.rs.
        let mut r = rng(11);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (4, 8, 8),
            (5, 7, 9),
            (3, KC + 5, 17),
            (MR + 1, 31, NR + 3),
            (32, 64, 40),
        ] {
            let a = rand_vec(m * k, 1.0, &mut r);
            let b = rand_vec(k * n, 1.0, &mut r);
            let mut naive = vec![0f32; m * n];
            matmul(&a, &b, m, k, n, &mut naive);
            let mut blocked = vec![0f32; m * n];
            matmul_blocked(&a, &b, m, k, n, &mut blocked);
            for (i, (x, y)) in naive.iter().zip(&blocked).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "({m},{k},{n}) elem {i}: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn dense_forward_matches_matmul() {
        let mut r = rng(1);
        let (input, output) = (7, 5);
        let w = rand_vec(input * output, 1.0, &mut r);
        let a = rand_vec(input, 1.0, &mut r);
        let mut out = vec![0f32; output];
        dense_forward(&w, None, &a, &mut out);
        let mut mm = vec![0f32; output];
        matmul(&w, &a, output, input, 1, &mut mm);
        for (x, y) in out.iter().zip(&mm) {
            assert!((x - y).abs() < 1e-6);
        }
        // And bit-identical to the straight-line reference.
        let mut rf = vec![0f32; output];
        dense_forward_ref(&w, None, &a, &mut rf);
        assert_eq!(out, rf);
    }

    /// Central finite differences of `f` at `xs[i]`.
    fn fdiff<F: FnMut(&[f32]) -> f32>(xs: &[f32], i: usize, eps: f32, mut f: F) -> f32 {
        let mut hi = xs.to_vec();
        hi[i] += eps;
        let mut lo = xs.to_vec();
        lo[i] -= eps;
        (f(&hi) - f(&lo)) / (2.0 * eps)
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        let mut r = rng(2);
        let (input, output) = (6, 4);
        let w = rand_vec(input * output, 0.6, &mut r);
        let b = rand_vec(output, 0.3, &mut r);
        let a = rand_vec(input, 1.0, &mut r);
        // Scalar objective: L = c · (W a + b).
        let c = rand_vec(output, 1.0, &mut r);
        let loss = |wv: &[f32], bv: &[f32], av: &[f32]| -> f32 {
            let mut y = vec![0f32; output];
            dense_forward(wv, Some(bv), av, &mut y);
            y.iter().zip(&c).map(|(yi, ci)| yi * ci).sum()
        };
        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; b.len()];
        let mut da = vec![0f32; a.len()];
        dense_backward(&w, &a, &c, &mut gw, Some(&mut gb), Some(&mut da));
        let eps = 1e-2;
        for i in 0..w.len() {
            let num = fdiff(&w, i, eps, |wv| loss(wv, &b, &a));
            assert!((gw[i] - num).abs() < 2e-2, "gw[{i}]: {} vs {num}", gw[i]);
        }
        for i in 0..b.len() {
            let num = fdiff(&b, i, eps, |bv| loss(&w, bv, &a));
            assert!((gb[i] - num).abs() < 2e-2, "gb[{i}]: {} vs {num}", gb[i]);
        }
        for i in 0..a.len() {
            let num = fdiff(&a, i, eps, |av| loss(&w, &b, av));
            assert!((da[i] - num).abs() < 2e-2, "da[{i}]: {} vs {num}", da[i]);
        }
    }

    #[test]
    fn conv_blocked_bit_identical_to_reference() {
        // Odd spatial dims + channel counts off the SIMD width; the full
        // randomized sweep lives in tests/kernel_blocking.rs.
        let (h, wd, cin, cout) = (5usize, 3usize, 3usize, 5usize);
        let mut r = rng(12);
        let w = rand_vec(cout * cin * 9, 0.5, &mut r);
        let b = rand_vec(cout, 0.2, &mut r);
        let a = rand_vec(h * wd * cin, 1.0, &mut r);
        let mut y = vec![0f32; h * wd * cout];
        conv3x3_forward(&w, &b, &a, &mut y, h, wd, cin, cout);
        let mut yr = vec![0f32; h * wd * cout];
        conv3x3_forward_ref(&w, &b, &a, &mut yr, h, wd, cin, cout);
        assert_eq!(y, yr);
        let dy = rand_vec(h * wd * cout, 1.0, &mut r);
        let (mut gw, mut gb, mut da) =
            (vec![0f32; w.len()], vec![0f32; cout], vec![0f32; a.len()]);
        conv3x3_backward(&w, &a, &dy, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout);
        let (mut gwr, mut gbr, mut dar) =
            (vec![0f32; w.len()], vec![0f32; cout], vec![0f32; a.len()]);
        conv3x3_backward_ref(&w, &a, &dy, &mut gwr, &mut gbr, Some(&mut dar), h, wd, cin, cout);
        assert_eq!(gw, gwr);
        assert_eq!(gb, gbr);
        assert_eq!(da, dar);
    }

    #[test]
    fn conv_backward_matches_finite_differences() {
        let (h, wd, cin, cout) = (4usize, 4usize, 2usize, 3usize);
        let mut r = rng(3);
        let w = rand_vec(cout * cin * 9, 0.4, &mut r);
        let b = rand_vec(cout, 0.2, &mut r);
        let a = rand_vec(h * wd * cin, 1.0, &mut r);
        let c = rand_vec(h * wd * cout, 1.0, &mut r);
        let loss = |wv: &[f32], av: &[f32]| -> f32 {
            let mut y = vec![0f32; h * wd * cout];
            conv3x3_forward(wv, &b, av, &mut y, h, wd, cin, cout);
            y.iter().zip(&c).map(|(yi, ci)| yi * ci).sum()
        };
        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; b.len()];
        let mut da = vec![0f32; a.len()];
        conv3x3_backward(&w, &a, &c, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout);
        let eps = 1e-2;
        for i in 0..w.len() {
            let num = fdiff(&w, i, eps, |wv| loss(wv, &a));
            assert!((gw[i] - num).abs() < 3e-2, "gw[{i}]: {} vs {num}", gw[i]);
        }
        for i in 0..a.len() {
            let num = fdiff(&a, i, eps, |av| loss(&w, av));
            assert!((da[i] - num).abs() < 3e-2, "da[{i}]: {} vs {num}", da[i]);
        }
        // gb is just the per-channel sum of dy.
        for co in 0..cout {
            let expect: f32 = (0..h * wd).map(|p| c[p * cout + co]).sum();
            assert!((gb[co] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn avgpool_roundtrip_and_gradient() {
        let (h, wd, c) = (4usize, 4usize, 2usize);
        let mut r = rng(4);
        let a = rand_vec(h * wd * c, 1.0, &mut r);
        let mut out = vec![0f32; (h / 2) * (wd / 2) * c];
        avgpool2_forward(&a, &mut out, h, wd, c);
        // A constant image pools to the same constant.
        let ones = vec![1.5f32; h * wd * c];
        let mut pooled = vec![0f32; out.len()];
        avgpool2_forward(&ones, &mut pooled, h, wd, c);
        assert!(pooled.iter().all(|&v| (v - 1.5).abs() < 1e-6));
        // Backward spreads each grad by 1/4: column sums preserved.
        let dy = rand_vec(out.len(), 1.0, &mut r);
        let mut da = vec![0f32; a.len()];
        avgpool2_backward(&dy, &mut da, h, wd, c);
        let dy_sum: f32 = dy.iter().sum();
        let da_sum: f32 = da.iter().sum();
        assert!((dy_sum - da_sum).abs() < 1e-4, "{dy_sum} vs {da_sum}");
    }

    #[test]
    fn relu_forward_backward() {
        let mut xs = vec![-1.0f32, 0.0, 2.0, -0.5, 3.0];
        relu_inplace(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0, 0.0, 3.0]);
        let mut dy = vec![1.0f32; 5];
        relu_backward_mask(&xs, &mut dy);
        assert_eq!(dy, vec![0.0, 0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn softmax_xent_properties() {
        let logits = [0.3f32, -1.0, 2.0];
        let (loss, correct, d) = softmax_xent(&logits, 2);
        assert!(loss > 0.0 && loss.is_finite());
        assert!(correct);
        // dlogits sums to zero and d[label] < 0.
        let s: f32 = d.iter().sum();
        assert!(s.abs() < 1e-6, "sum={s}");
        assert!(d[2] < 0.0 && d[0] > 0.0);
        // Wrong label: not correct, higher loss.
        let (loss0, correct0, _) = softmax_xent(&logits, 1);
        assert!(!correct0);
        assert!(loss0 > loss);
    }

    #[test]
    fn softmax_xent_gradient_matches_finite_differences() {
        let logits = vec![0.5f32, -0.2, 1.1, 0.0];
        let (_, _, d) = softmax_xent(&logits, 1);
        let eps = 1e-2;
        for i in 0..logits.len() {
            let mut hi = logits.clone();
            hi[i] += eps;
            let mut lo = logits.clone();
            lo[i] -= eps;
            let num = (softmax_xent(&hi, 1).0 - softmax_xent(&lo, 1).0) / (2.0 * eps);
            assert!((d[i] - num).abs() < 1e-3, "d[{i}]: {} vs {num}", d[i]);
        }
    }

    #[test]
    fn tensor_helpers() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let u = Tensor::from_vec(vec![1.0; 12], &[3, 4]);
        assert_eq!(u.shape, vec![3, 4]);
        let mut r = rng(5);
        let he = Tensor::he_uniform(&[8, 4], 4, &mut r);
        let lim = (6.0f32 / 4.0).sqrt();
        assert!(he.data.iter().all(|&v| v.abs() <= lim));
        assert!(he.data.iter().any(|&v| v != 0.0));
    }
}
