//! Tiny tensor kernels for the native backend: contiguous `f32` buffers
//! plus the dense / conv-lite / pooling / activation / loss primitives
//! the model zoo composes into real forward and backward passes.
//!
//! Everything is scalar Rust (no SIMD intrinsics, no allocation inside
//! the inner loops beyond caller-owned buffers), written for exactness:
//! the backward functions are the hand-derived adjoints of the forwards,
//! and the unit tests check them against central finite differences.
//!
//! Layout conventions:
//! * images are HWC (`[(y*W + x)*C + c]`), matching `data/synth.rs`;
//! * dense weights are `[out][in]` row-major;
//! * conv weights are `[cout][cin][ky][kx]` with a 3x3 kernel and same
//!   padding (stride 1).

use crate::util::rng::Xoshiro256;

/// A contiguous f32 tensor with an explicit row-major shape. The hot
/// path passes raw slices; `Tensor` carries shape metadata for
/// initialization, parameter bookkeeping and the property tests.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            data: vec![0.0; shape.iter().product()],
            shape: shape.to_vec(),
        }
    }

    pub fn from_vec(data: Vec<f32>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "tensor data/shape mismatch"
        );
        Self {
            data,
            shape: shape.to_vec(),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// He-style uniform init: `U(-sqrt(6/fan_in), +sqrt(6/fan_in))` —
    /// keeps activation scale roughly constant through ReLU stacks.
    pub fn he_uniform(shape: &[usize], fan_in: usize, rng: &mut Xoshiro256) -> Self {
        let lim = (6.0 / fan_in.max(1) as f32).sqrt();
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| (2.0 * rng.next_f32() - 1.0) * lim).collect();
        Self {
            data,
            shape: shape.to_vec(),
        }
    }
}

/// `out[m×n] = a[m×k] · b[k×n]` (row-major, accumulate-free overwrite).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul lhs shape");
    assert_eq!(b.len(), k * n, "matmul rhs shape");
    assert_eq!(out.len(), m * n, "matmul out shape");
    out.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Dense forward for one sample: `out = W·a (+ b)` with `W` as
/// `[out][in]` row-major.
pub fn dense_forward(w: &[f32], b: Option<&[f32]>, a: &[f32], out: &mut [f32]) {
    let input = a.len();
    let output = out.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    for (o, slot) in out.iter_mut().enumerate() {
        let row = &w[o * input..(o + 1) * input];
        let mut acc = b.map_or(0.0, |bb| bb[o]);
        for (&wi, &ai) in row.iter().zip(a) {
            acc += wi * ai;
        }
        *slot = acc;
    }
}

/// Dense backward for one sample. `gw`/`gb` are *accumulated into*
/// (callers zero per-sample buffers); `da`, when present, is overwritten
/// with the gradient w.r.t. the layer input.
pub fn dense_backward(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    mut gb: Option<&mut [f32]>,
    da: Option<&mut [f32]>,
) {
    let input = a.len();
    let output = dy.len();
    assert_eq!(w.len(), input * output, "dense weight shape");
    assert_eq!(gw.len(), input * output, "dense grad shape");
    for (o, &d) in dy.iter().enumerate() {
        if let Some(gb) = gb.as_deref_mut() {
            gb[o] += d;
        }
        if d == 0.0 {
            continue;
        }
        let grow = &mut gw[o * input..(o + 1) * input];
        for (g, &ai) in grow.iter_mut().zip(a) {
            *g += d * ai;
        }
    }
    if let Some(da) = da {
        assert_eq!(da.len(), input, "dense da shape");
        da.fill(0.0);
        for (o, &d) in dy.iter().enumerate() {
            if d == 0.0 {
                continue;
            }
            let row = &w[o * input..(o + 1) * input];
            for (x, &wi) in da.iter_mut().zip(row) {
                *x += d * wi;
            }
        }
    }
}

/// 3x3 same-padding convolution over one HWC image (stride 1).
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_forward(
    w: &[f32],
    b: &[f32],
    a: &[f32],
    out: &mut [f32],
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(out.len(), h * wd * cout, "conv output shape");
    assert_eq!(w.len(), cout * cin * 9, "conv weight shape");
    assert_eq!(b.len(), cout, "conv bias shape");
    for y in 0..h {
        for x in 0..wd {
            let obase = (y * wd + x) * cout;
            for co in 0..cout {
                let mut acc = b[co];
                let wbase = co * cin * 9;
                for ky in 0..3usize {
                    // `y + ky - 1` via wrapping: out-of-range wraps to a
                    // huge value and fails the `< h` bound check.
                    let sy = (y + ky).wrapping_sub(1);
                    if sy >= h {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = (x + kx).wrapping_sub(1);
                        if sx >= wd {
                            continue;
                        }
                        let abase = (sy * wd + sx) * cin;
                        let koff = ky * 3 + kx;
                        for ci in 0..cin {
                            acc += w[wbase + ci * 9 + koff] * a[abase + ci];
                        }
                    }
                }
                out[obase + co] = acc;
            }
        }
    }
}

/// Backward of [`conv3x3_forward`] for one sample: accumulates `gw`/`gb`
/// and (when present) overwrites `da` with the input gradient.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_backward(
    w: &[f32],
    a: &[f32],
    dy: &[f32],
    gw: &mut [f32],
    gb: &mut [f32],
    mut da: Option<&mut [f32]>,
    h: usize,
    wd: usize,
    cin: usize,
    cout: usize,
) {
    assert_eq!(a.len(), h * wd * cin, "conv input shape");
    assert_eq!(dy.len(), h * wd * cout, "conv dy shape");
    assert_eq!(gw.len(), cout * cin * 9, "conv grad shape");
    assert_eq!(gb.len(), cout, "conv bias grad shape");
    if let Some(d) = da.as_deref_mut() {
        assert_eq!(d.len(), h * wd * cin, "conv da shape");
        d.fill(0.0);
    }
    for y in 0..h {
        for x in 0..wd {
            let obase = (y * wd + x) * cout;
            for co in 0..cout {
                let d = dy[obase + co];
                if d == 0.0 {
                    continue;
                }
                gb[co] += d;
                let wbase = co * cin * 9;
                for ky in 0..3usize {
                    let sy = (y + ky).wrapping_sub(1);
                    if sy >= h {
                        continue;
                    }
                    for kx in 0..3usize {
                        let sx = (x + kx).wrapping_sub(1);
                        if sx >= wd {
                            continue;
                        }
                        let abase = (sy * wd + sx) * cin;
                        let koff = ky * 3 + kx;
                        for ci in 0..cin {
                            gw[wbase + ci * 9 + koff] += d * a[abase + ci];
                            if let Some(dd) = da.as_deref_mut() {
                                dd[abase + ci] += d * w[wbase + ci * 9 + koff];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// 2x2 average pooling over an HWC image (`h`, `wd` must be even).
pub fn avgpool2_forward(a: &[f32], out: &mut [f32], h: usize, wd: usize, c: usize) {
    assert!(h % 2 == 0 && wd % 2 == 0, "avgpool2 needs even dims");
    assert_eq!(a.len(), h * wd * c, "avgpool input shape");
    assert_eq!(out.len(), (h / 2) * (wd / 2) * c, "avgpool output shape");
    let w2 = wd / 2;
    for y in 0..h / 2 {
        for x in 0..w2 {
            for ch in 0..c {
                let s = a[((2 * y) * wd + 2 * x) * c + ch]
                    + a[((2 * y) * wd + 2 * x + 1) * c + ch]
                    + a[((2 * y + 1) * wd + 2 * x) * c + ch]
                    + a[((2 * y + 1) * wd + 2 * x + 1) * c + ch];
                out[(y * w2 + x) * c + ch] = 0.25 * s;
            }
        }
    }
}

/// Backward of [`avgpool2_forward`]: each output grad spreads equally
/// over its 2x2 window. `h`, `wd` are the *input* dims; `da` is
/// overwritten in full.
pub fn avgpool2_backward(dy: &[f32], da: &mut [f32], h: usize, wd: usize, c: usize) {
    assert_eq!(dy.len(), (h / 2) * (wd / 2) * c, "avgpool dy shape");
    assert_eq!(da.len(), h * wd * c, "avgpool da shape");
    let w2 = wd / 2;
    for y in 0..h {
        for x in 0..wd {
            for ch in 0..c {
                da[(y * wd + x) * c + ch] = 0.25 * dy[((y / 2) * w2 + x / 2) * c + ch];
            }
        }
    }
}

/// ReLU forward, in place.
pub fn relu_inplace(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

/// ReLU backward: zero the upstream grads wherever the (post-ReLU)
/// activation was clamped.
pub fn relu_backward_mask(out: &[f32], dy: &mut [f32]) {
    assert_eq!(out.len(), dy.len(), "relu mask shape");
    for (d, &o) in dy.iter_mut().zip(out) {
        if o <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Numerically-stable softmax cross-entropy for one sample. Returns
/// `(loss, correct, dlogits)`. Argmax tie-breaking (last max wins)
/// deliberately matches `MockExecutor` so the parity tests can compare
/// `correct_sum` exactly.
pub fn softmax_xent(logits: &[f32], label: usize) -> (f32, bool, Vec<f32>) {
    assert!(label < logits.len(), "label out of range");
    let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - maxl).exp()).collect();
    let z: f32 = exps.iter().sum();
    let loss = z.ln() + maxl - logits[label];
    // total_cmp orders like partial_cmp on real values but cannot panic
    // on NaN logits (a diverged run must surface as bad numbers in the
    // returned loss, not kill a worker thread).
    let argmax = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    let mut d: Vec<f32> = exps.iter().map(|&e| e / z).collect();
    d[label] -= 1.0;
    (loss, argmax == label, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng(seed: u64) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(seed)
    }

    fn rand_vec(n: usize, scale: f32, r: &mut Xoshiro256) -> Vec<f32> {
        (0..n).map(|_| (2.0 * r.next_f32() - 1.0) * scale).collect()
    }

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0f32; 4];
        matmul(&a, &b, 2, 2, 2, &mut out);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn dense_forward_matches_matmul() {
        let mut r = rng(1);
        let (input, output) = (7, 5);
        let w = rand_vec(input * output, 1.0, &mut r);
        let a = rand_vec(input, 1.0, &mut r);
        let mut out = vec![0f32; output];
        dense_forward(&w, None, &a, &mut out);
        let mut mm = vec![0f32; output];
        matmul(&w, &a, output, input, 1, &mut mm);
        for (x, y) in out.iter().zip(&mm) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    /// Central finite differences of `f` at `xs[i]`.
    fn fdiff<F: FnMut(&[f32]) -> f32>(xs: &[f32], i: usize, eps: f32, mut f: F) -> f32 {
        let mut hi = xs.to_vec();
        hi[i] += eps;
        let mut lo = xs.to_vec();
        lo[i] -= eps;
        (f(&hi) - f(&lo)) / (2.0 * eps)
    }

    #[test]
    fn dense_backward_matches_finite_differences() {
        let mut r = rng(2);
        let (input, output) = (6, 4);
        let w = rand_vec(input * output, 0.6, &mut r);
        let b = rand_vec(output, 0.3, &mut r);
        let a = rand_vec(input, 1.0, &mut r);
        // Scalar objective: L = c · (W a + b).
        let c = rand_vec(output, 1.0, &mut r);
        let loss = |wv: &[f32], bv: &[f32], av: &[f32]| -> f32 {
            let mut y = vec![0f32; output];
            dense_forward(wv, Some(bv), av, &mut y);
            y.iter().zip(&c).map(|(yi, ci)| yi * ci).sum()
        };
        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; b.len()];
        let mut da = vec![0f32; a.len()];
        dense_backward(&w, &a, &c, &mut gw, Some(&mut gb), Some(&mut da));
        let eps = 1e-2;
        for i in 0..w.len() {
            let num = fdiff(&w, i, eps, |wv| loss(wv, &b, &a));
            assert!((gw[i] - num).abs() < 2e-2, "gw[{i}]: {} vs {num}", gw[i]);
        }
        for i in 0..b.len() {
            let num = fdiff(&b, i, eps, |bv| loss(&w, bv, &a));
            assert!((gb[i] - num).abs() < 2e-2, "gb[{i}]: {} vs {num}", gb[i]);
        }
        for i in 0..a.len() {
            let num = fdiff(&a, i, eps, |av| loss(&w, &b, av));
            assert!((da[i] - num).abs() < 2e-2, "da[{i}]: {} vs {num}", da[i]);
        }
    }

    #[test]
    fn conv_backward_matches_finite_differences() {
        let (h, wd, cin, cout) = (4usize, 4usize, 2usize, 3usize);
        let mut r = rng(3);
        let w = rand_vec(cout * cin * 9, 0.4, &mut r);
        let b = rand_vec(cout, 0.2, &mut r);
        let a = rand_vec(h * wd * cin, 1.0, &mut r);
        let c = rand_vec(h * wd * cout, 1.0, &mut r);
        let loss = |wv: &[f32], av: &[f32]| -> f32 {
            let mut y = vec![0f32; h * wd * cout];
            conv3x3_forward(wv, &b, av, &mut y, h, wd, cin, cout);
            y.iter().zip(&c).map(|(yi, ci)| yi * ci).sum()
        };
        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; b.len()];
        let mut da = vec![0f32; a.len()];
        conv3x3_backward(&w, &a, &c, &mut gw, &mut gb, Some(&mut da), h, wd, cin, cout);
        let eps = 1e-2;
        for i in 0..w.len() {
            let num = fdiff(&w, i, eps, |wv| loss(wv, &a));
            assert!((gw[i] - num).abs() < 3e-2, "gw[{i}]: {} vs {num}", gw[i]);
        }
        for i in 0..a.len() {
            let num = fdiff(&a, i, eps, |av| loss(&w, av));
            assert!((da[i] - num).abs() < 3e-2, "da[{i}]: {} vs {num}", da[i]);
        }
        // gb is just the per-channel sum of dy.
        for co in 0..cout {
            let expect: f32 = (0..h * wd).map(|p| c[p * cout + co]).sum();
            assert!((gb[co] - expect).abs() < 1e-4);
        }
    }

    #[test]
    fn avgpool_roundtrip_and_gradient() {
        let (h, wd, c) = (4usize, 4usize, 2usize);
        let mut r = rng(4);
        let a = rand_vec(h * wd * c, 1.0, &mut r);
        let mut out = vec![0f32; (h / 2) * (wd / 2) * c];
        avgpool2_forward(&a, &mut out, h, wd, c);
        // A constant image pools to the same constant.
        let ones = vec![1.5f32; h * wd * c];
        let mut pooled = vec![0f32; out.len()];
        avgpool2_forward(&ones, &mut pooled, h, wd, c);
        assert!(pooled.iter().all(|&v| (v - 1.5).abs() < 1e-6));
        // Backward spreads each grad by 1/4: column sums preserved.
        let dy = rand_vec(out.len(), 1.0, &mut r);
        let mut da = vec![0f32; a.len()];
        avgpool2_backward(&dy, &mut da, h, wd, c);
        let dy_sum: f32 = dy.iter().sum();
        let da_sum: f32 = da.iter().sum();
        assert!((dy_sum - da_sum).abs() < 1e-4, "{dy_sum} vs {da_sum}");
    }

    #[test]
    fn relu_forward_backward() {
        let mut xs = vec![-1.0f32, 0.0, 2.0, -0.5, 3.0];
        relu_inplace(&mut xs);
        assert_eq!(xs, vec![0.0, 0.0, 2.0, 0.0, 3.0]);
        let mut dy = vec![1.0f32; 5];
        relu_backward_mask(&xs, &mut dy);
        assert_eq!(dy, vec![0.0, 0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn softmax_xent_properties() {
        let logits = [0.3f32, -1.0, 2.0];
        let (loss, correct, d) = softmax_xent(&logits, 2);
        assert!(loss > 0.0 && loss.is_finite());
        assert!(correct);
        // dlogits sums to zero and d[label] < 0.
        let s: f32 = d.iter().sum();
        assert!(s.abs() < 1e-6, "sum={s}");
        assert!(d[2] < 0.0 && d[0] > 0.0);
        // Wrong label: not correct, higher loss.
        let (loss0, correct0, _) = softmax_xent(&logits, 1);
        assert!(!correct0);
        assert!(loss0 > loss);
    }

    #[test]
    fn softmax_xent_gradient_matches_finite_differences() {
        let logits = vec![0.5f32, -0.2, 1.1, 0.0];
        let (_, _, d) = softmax_xent(&logits, 1);
        let eps = 1e-2;
        for i in 0..logits.len() {
            let mut hi = logits.clone();
            hi[i] += eps;
            let mut lo = logits.clone();
            lo[i] -= eps;
            let num = (softmax_xent(&hi, 1).0 - softmax_xent(&lo, 1).0) / (2.0 * eps);
            assert!((d[i] - num).abs() < 1e-3, "d[{i}]: {} vs {num}", d[i]);
        }
    }

    #[test]
    fn tensor_helpers() {
        let t = Tensor::zeros(&[2, 3]);
        assert_eq!(t.numel(), 6);
        let u = Tensor::from_vec(vec![1.0; 12], &[3, 4]);
        assert_eq!(u.shape, vec![3, 4]);
        let mut r = rng(5);
        let he = Tensor::he_uniform(&[8, 4], 4, &mut r);
        let lim = (6.0f32 / 4.0).sqrt();
        assert!(he.data.iter().all(|&v| v.abs() <= lim));
        assert!(he.data.iter().any(|&v| v != 0.0));
    }
}
