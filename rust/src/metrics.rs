//! Run metrics: per-epoch records, summaries, CSV/JSON emission.
//!
//! Every trainer run produces a [`RunRecord`]; the experiment harness
//! aggregates them into the tables/figures of the paper and writes both
//! human-readable tables (stdout) and machine-readable JSON under
//! `results/`.

use crate::util::json::{self, Json};
use std::io::Write as _;

/// One epoch's worth of telemetry.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training loss over the epoch's batches.
    pub train_loss: f64,
    /// Mean validation loss after the epoch.
    pub val_loss: f64,
    /// Validation accuracy after the epoch.
    pub val_accuracy: f64,
    /// ε consumed so far (training + analysis).
    pub epsilon: f64,
    /// Layers quantized this epoch (indices into the model's layer list).
    pub quantized_layers: Vec<usize>,
    /// Wall-clock seconds for the epoch (train only).
    pub train_seconds: f64,
    /// Wall-clock seconds spent in analysis before this epoch (0 if none).
    pub analysis_seconds: f64,
}

/// A whole training run.
#[derive(Clone, Debug, Default)]
pub struct RunRecord {
    /// Run identifier (`model_dataset_quantizer_scheduler_k_seed`).
    pub name: String,
    /// One-line summary of the config that produced the run.
    pub config_summary: String,
    /// Per-epoch telemetry, in order.
    pub epochs: Vec<EpochRecord>,
    /// Final ε at the end of the run.
    pub final_epsilon: f64,
    /// ε attributable to analysis alone.
    pub analysis_epsilon: f64,
    /// Validation accuracy after the last epoch.
    pub final_accuracy: f64,
    /// Best validation accuracy over the run.
    pub best_accuracy: f64,
}

impl RunRecord {
    /// Append an epoch and fold it into the final/best aggregates.
    pub fn push(&mut self, rec: EpochRecord) {
        self.best_accuracy = self.best_accuracy.max(rec.val_accuracy);
        self.final_accuracy = rec.val_accuracy;
        self.final_epsilon = rec.epsilon;
        self.epochs.push(rec);
    }

    /// The run as a JSON object (what `results/*.json` stores).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("config", json::s(&self.config_summary)),
            ("final_epsilon", json::num(self.final_epsilon)),
            ("analysis_epsilon", json::num(self.analysis_epsilon)),
            ("final_accuracy", json::num(self.final_accuracy)),
            ("best_accuracy", json::num(self.best_accuracy)),
            (
                "epochs",
                Json::Arr(
                    self.epochs
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("epoch", json::num(e.epoch as f64)),
                                ("train_loss", json::num(e.train_loss)),
                                ("val_loss", json::num(e.val_loss)),
                                ("val_accuracy", json::num(e.val_accuracy)),
                                ("epsilon", json::num(e.epsilon)),
                                (
                                    "quantized_layers",
                                    Json::Arr(
                                        e.quantized_layers
                                            .iter()
                                            .map(|&i| json::num(i as f64))
                                            .collect(),
                                    ),
                                ),
                                ("train_seconds", json::num(e.train_seconds)),
                                ("analysis_seconds", json::num(e.analysis_seconds)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// The canonical end-of-run summary line for this record — see
    /// [`final_metrics_line`].
    pub fn final_line(&self) -> String {
        final_metrics_line(
            self.final_accuracy,
            self.final_epsilon,
            self.analysis_epsilon,
            self.epochs.len(),
        )
    }

    /// Write JSON to `results/<name>.json` (creates the directory).
    pub fn save(&self, dir: &str) -> std::io::Result<String> {
        std::fs::create_dir_all(dir)?;
        let path = format!("{dir}/{}.json", self.name.replace(['/', ' '], "_"));
        let mut f = std::fs::File::create(&path)?;
        f.write_all(self.to_json().to_string().as_bytes())?;
        Ok(path)
    }
}

/// The canonical `final: ...` summary line. ONE definition, shared by
/// `dpquant train`'s closing print and `dpquant job status/wait` (which
/// rebuilds it from the daemon's JSON summary) — CI's `serve-smoke` job
/// diffs the two byte-for-byte, so the format must never fork.
pub fn final_metrics_line(
    final_accuracy: f64,
    final_epsilon: f64,
    analysis_epsilon: f64,
    epochs: usize,
) -> String {
    format!(
        "final: val_acc={final_accuracy:.4} eps={final_epsilon:.3} \
         (analysis eps alone: {analysis_epsilon:.3}) epochs={epochs}"
    )
}

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|&x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Simple fixed-width table printer for experiment output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// A table with the given column headers and no rows yet.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    /// Append a row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }
    /// Render as aligned plain text (headers, rule, rows).
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
    /// Print [`Table::render`] to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_record_tracks_best() {
        let mut r = RunRecord {
            name: "t".into(),
            ..Default::default()
        };
        for (i, acc) in [(0, 0.4), (1, 0.7), (2, 0.6)] {
            r.push(EpochRecord {
                epoch: i,
                train_loss: 1.0,
                val_loss: 1.0,
                val_accuracy: acc,
                epsilon: i as f64,
                quantized_layers: vec![i],
                train_seconds: 0.1,
                analysis_seconds: 0.0,
            });
        }
        assert_eq!(r.best_accuracy, 0.7);
        assert_eq!(r.final_accuracy, 0.6);
        assert_eq!(r.final_epsilon, 2.0);
        // JSON round-trips through the parser.
        let parsed = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(
            parsed.get("best_accuracy").unwrap().as_f64().unwrap(),
            0.7
        );
        assert_eq!(
            parsed.get("epochs").unwrap().as_arr().unwrap().len(),
            3
        );
    }

    #[test]
    fn final_line_formats_like_the_cli() {
        let mut r = RunRecord::default();
        r.push(EpochRecord {
            epoch: 0,
            train_loss: 0.5,
            val_loss: 0.5,
            val_accuracy: 0.8125,
            epsilon: 2.25,
            quantized_layers: vec![],
            train_seconds: 0.0,
            analysis_seconds: 0.0,
        });
        r.analysis_epsilon = 0.125;
        assert_eq!(
            r.final_line(),
            "final: val_acc=0.8125 eps=2.250 (analysis eps alone: 0.125) epochs=1"
        );
        assert_eq!(
            r.final_line(),
            final_metrics_line(0.8125, 2.25, 0.125, 1),
            "free function and method must agree"
        );
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(vec!["resnet".into(), "81.2".into()]);
        t.row(vec!["m".into(), "7".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("model"));
    }
}
