//! Synthetic dataset generators + the DP data pipeline.
//!
//! The paper trains on GTSRB / EMNIST / CIFAR-10 / SNLI. Those corpora
//! are not available offline, so we generate procedural class-structured
//! stand-ins (DESIGN.md §2): every class has a deterministic prototype
//! and each example is a jittered, noisy rendering of its prototype —
//! learnable by a small CNN but not linearly trivial. SNLI's stand-in
//! encodes an actual premise/hypothesis relation over token halves.
//!
//! The pipeline half implements **Poisson subsampling** (each example
//! enters a batch independently with probability q = B/|D|) — the
//! sampling scheme DP-SGD's privacy accounting assumes, as provided by
//! Opacus in the paper's implementation (§6 "Implementation").

pub mod synth;

use crate::util::error::{err, Result};
use crate::util::rng::Xoshiro256;

/// An in-memory dataset: row-major examples + labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n * example_numel` feature values (token ids stored as f32 for
    /// the sequence datasets; the runtime converts).
    pub xs: Vec<f32>,
    /// Labels, one per example.
    pub ys: Vec<i32>,
    /// Feature values per example.
    pub example_numel: usize,
    /// Number of label classes.
    pub n_classes: usize,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.ys.len()
    }
    /// Is the dataset empty?
    pub fn is_empty(&self) -> bool {
        self.ys.is_empty()
    }
    /// Example `i`'s feature slice.
    pub fn example(&self, i: usize) -> &[f32] {
        &self.xs[i * self.example_numel..(i + 1) * self.example_numel]
    }

    /// Split into (train, val): the first `n - val` examples train.
    pub fn split(mut self, val: usize) -> (Dataset, Dataset) {
        assert!(val < self.len());
        let train_n = self.len() - val;
        let val_xs = self.xs.split_off(train_n * self.example_numel);
        let val_ys = self.ys.split_off(train_n);
        let val_ds = Dataset {
            xs: val_xs,
            ys: val_ys,
            example_numel: self.example_numel,
            n_classes: self.n_classes,
        };
        (self, val_ds)
    }
}

/// Generate a dataset by name. `image_shape`/`seq_len` must match the
/// compiled graph (16x16x3 images, 24-token sequences).
pub fn generate(name: &str, n: usize, seed: u64) -> Result<Dataset> {
    match name {
        "gtsrb" => Ok(synth::images(n, 43, seed, synth::ImageStyle::Signs)),
        "emnist" => Ok(synth::images(n, 47, seed, synth::ImageStyle::Glyphs)),
        "cifar" => Ok(synth::images(n, 10, seed, synth::ImageStyle::Objects)),
        "snli" => Ok(synth::sequence_pairs(n, seed)),
        other => Err(err!("unknown dataset '{other}' (gtsrb|emnist|cifar|snli)")),
    }
}

/// Generate the deterministic (train, val) pair described by a config's
/// `(dataset, dataset_size, val_size, seed)` tuple — the single
/// definition of "the same data" shared by the CLI's `train`, the
/// sweep's dataset cache, and the serving daemon's resume path (a
/// resumed session must see byte-identical examples).
pub fn train_val(name: &str, train: usize, val: usize, seed: u64) -> Result<(Dataset, Dataset)> {
    Ok(generate(name, train + val, seed)?.split(val))
}

/// Poisson subsampling: each of `0..n` included independently w.p. `q`.
pub fn poisson_sample(rng: &mut Xoshiro256, n: usize, q: f64) -> Vec<usize> {
    (0..n).filter(|_| rng.bernoulli(q)).collect()
}

/// A fixed-size physical batch (padded with masked rows).
pub struct Batch {
    /// `batch x example_numel` features (masked rows zeroed).
    pub x: Vec<f32>,
    /// Labels (masked rows carry class 0).
    pub y: Vec<i32>,
    /// 1.0 for real rows, 0.0 for padding.
    pub mask: Vec<f32>,
    /// Number of real (unmasked) examples.
    pub real: usize,
}

/// Pack `indices` into physical batches of size `physical`, padding the
/// last one. An empty `indices` yields no batches.
pub fn make_batches(ds: &Dataset, indices: &[usize], physical: usize) -> Vec<Batch> {
    indices
        .chunks(physical)
        .map(|chunk| {
            let mut x = vec![0f32; physical * ds.example_numel];
            let mut y = vec![0i32; physical];
            let mut mask = vec![0f32; physical];
            for (row, &idx) in chunk.iter().enumerate() {
                x[row * ds.example_numel..(row + 1) * ds.example_numel]
                    .copy_from_slice(ds.example(idx));
                y[row] = ds.ys[idx];
                mask[row] = 1.0;
            }
            Batch {
                x,
                y,
                mask,
                real: chunk.len(),
            }
        })
        .collect()
}

/// Sequential (non-private) batches over the whole dataset — used for
/// evaluation.
pub fn eval_batches(ds: &Dataset, physical: usize) -> Vec<Batch> {
    let all: Vec<usize> = (0..ds.len()).collect();
    make_batches(ds, &all, physical)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_have_class_structure() {
        for name in ["gtsrb", "emnist", "cifar", "snli"] {
            let ds = generate(name, 200, 1).unwrap();
            assert_eq!(ds.len(), 200);
            assert!(ds.n_classes > 1);
            // Labels in range, all classes hit eventually for small
            // n_classes.
            assert!(ds.ys.iter().all(|&y| (y as usize) < ds.n_classes));
            // Features finite.
            assert!(ds.xs.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate("cifar", 50, 7).unwrap();
        let b = generate("cifar", 50, 7).unwrap();
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
        let c = generate("cifar", 50, 8).unwrap();
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn same_class_examples_more_similar() {
        // Class structure: intra-class distance < inter-class distance on
        // average (the property that makes the task learnable).
        let ds = generate("gtsrb", 400, 3).unwrap();
        let mut intra = (0.0, 0usize);
        let mut inter = (0.0, 0usize);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let d: f32 = ds
                    .example(i)
                    .iter()
                    .zip(ds.example(j))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if ds.ys[i] == ds.ys[j] {
                    intra.0 += d as f64;
                    intra.1 += 1;
                } else {
                    inter.0 += d as f64;
                    inter.1 += 1;
                }
            }
        }
        if intra.1 > 0 && inter.1 > 0 {
            let intra_mean = intra.0 / intra.1 as f64;
            let inter_mean = inter.0 / inter.1 as f64;
            assert!(
                intra_mean < inter_mean * 0.8,
                "intra={intra_mean} inter={inter_mean}"
            );
        }
    }

    #[test]
    fn poisson_rate() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let n = 10_000;
        let q = 0.05;
        let mut total = 0usize;
        let reps = 50;
        for _ in 0..reps {
            total += poisson_sample(&mut rng, n, q).len();
        }
        let mean = total as f64 / reps as f64;
        assert!((mean - q * n as f64).abs() < 30.0, "mean batch {mean}");
    }

    #[test]
    fn batches_pad_and_mask() {
        let ds = generate("cifar", 20, 2).unwrap();
        let batches = make_batches(&ds, &[0, 3, 5, 7, 9], 4);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].real, 4);
        assert_eq!(batches[1].real, 1);
        assert_eq!(batches[1].mask, vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(batches[1].y[0], ds.ys[9]);
        // Padding rows zero.
        let en = ds.example_numel;
        assert!(batches[1].x[en..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn split_partitions() {
        let ds = generate("emnist", 100, 4).unwrap();
        let (tr, va) = ds.split(30);
        assert_eq!(tr.len(), 70);
        assert_eq!(va.len(), 30);
    }

    #[test]
    fn snli_tokens_in_vocab() {
        let ds = generate("snli", 100, 6).unwrap();
        assert!(ds.xs.iter().all(|&t| (0.0..64.0).contains(&t)));
        assert_eq!(ds.example_numel, 24);
        assert_eq!(ds.n_classes, 3);
    }
}
