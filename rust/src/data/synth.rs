//! Procedural synthetic data: class-conditional generators whose samples
//! carry real class structure (prototype + jitter + noise), standing in
//! for GTSRB / EMNIST / CIFAR-10 / SNLI (DESIGN.md §2).

use super::Dataset;
use crate::util::gaussian::GaussianSampler;
use crate::util::rng::Xoshiro256;

/// Image height of the synthetic image datasets.
pub const H: usize = 16;
/// Image width of the synthetic image datasets.
pub const W: usize = 16;
/// Channels of the synthetic image datasets.
pub const C: usize = 3;
/// Token count per synthetic sequence example (SNLI stand-in).
pub const SEQ_LEN: usize = 24;
/// Vocabulary size of the synthetic sequence dataset.
pub const VOCAB: usize = 64;

/// What kind of prototypes to draw — purely cosmetic variation between
/// the image dataset stand-ins (different spatial statistics).
#[derive(Clone, Copy, Debug)]
pub enum ImageStyle {
    /// Traffic-sign-like: strong geometric shape + border (GTSRB).
    Signs,
    /// Glyph-like: thin strokes, single channel replicated (EMNIST).
    Glyphs,
    /// Object-like: smooth colored blobs (CIFAR).
    Objects,
}

fn class_prototype(class: usize, style: ImageStyle, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seed_from_u64(seed ^ (class as u64).wrapping_mul(0x9E37));
    let mut img = vec![0f32; H * W * C];
    match style {
        ImageStyle::Signs => {
            // A centered geometric figure: ring / triangle / bar chosen by
            // class bits, with class-colored fill.
            let shape = class % 3;
            let col = [
                0.3 + 0.7 * ((class / 3) % 3) as f32 / 2.0,
                0.3 + 0.7 * ((class / 9) % 3) as f32 / 2.0,
                0.3 + 0.7 * ((class / 27) % 3) as f32 / 2.0,
            ];
            let r0 = 3.0 + (class % 5) as f32 * 0.7;
            for y in 0..H {
                for x in 0..W {
                    let dy = y as f32 - H as f32 / 2.0 + 0.5;
                    let dx = x as f32 - W as f32 / 2.0 + 0.5;
                    let r = (dx * dx + dy * dy).sqrt();
                    let inside = match shape {
                        0 => (r - r0).abs() < 1.6,                     // ring
                        1 => dy > -r0 && dy < r0 * 0.8 && dx.abs() < (r0 - dy) * 0.6, // triangle
                        _ => dx.abs() < 1.8 || dy.abs() < 1.8,         // cross
                    };
                    if inside {
                        for c in 0..C {
                            img[(y * W + x) * C + c] = col[c];
                        }
                    }
                }
            }
        }
        ImageStyle::Glyphs => {
            // Random thin-stroke polyline, same in all channels.
            let mut px = rng.next_below(W as u64) as f32;
            let mut py = rng.next_below(H as u64) as f32;
            for _ in 0..6 {
                let nx = rng.next_below(W as u64) as f32;
                let ny = rng.next_below(H as u64) as f32;
                let steps = 24;
                for s in 0..=steps {
                    let t = s as f32 / steps as f32;
                    let x = (px + (nx - px) * t).round() as isize;
                    let y = (py + (ny - py) * t).round() as isize;
                    if (0..W as isize).contains(&x) && (0..H as isize).contains(&y) {
                        for c in 0..C {
                            img[(y as usize * W + x as usize) * C + c] = 1.0;
                        }
                    }
                }
                px = nx;
                py = ny;
            }
        }
        ImageStyle::Objects => {
            // Sum of 3 colored Gaussian blobs at class-determined spots.
            for b in 0..3 {
                let cx = rng.next_below(W as u64) as f32;
                let cy = rng.next_below(H as u64) as f32;
                let sigma = 2.0 + rng.next_f32() * 3.0;
                let col = [rng.next_f32(), rng.next_f32(), rng.next_f32()];
                for y in 0..H {
                    for x in 0..W {
                        let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                        let v = (-d2 / (2.0 * sigma * sigma)).exp();
                        for c in 0..C {
                            img[(y * W + x) * C + c] += v * col[c];
                        }
                    }
                }
                let _ = b;
            }
        }
    }
    img
}

/// Render one jittered example of `proto`: random brightness, ±2 px
/// translation, additive Gaussian noise.
fn render(proto: &[f32], rng: &mut Xoshiro256, g: &mut GaussianSampler) -> Vec<f32> {
    let bright = 0.7 + 0.6 * rng.next_f32();
    let dx = rng.next_below(5) as isize - 2;
    let dy = rng.next_below(5) as isize - 2;
    let mut out = vec![0f32; H * W * C];
    for y in 0..H {
        for x in 0..W {
            let sy = y as isize - dy;
            let sx = x as isize - dx;
            if (0..H as isize).contains(&sy) && (0..W as isize).contains(&sx) {
                for c in 0..C {
                    out[(y * W + x) * C + c] =
                        proto[(sy as usize * W + sx as usize) * C + c] * bright;
                }
            }
        }
    }
    for v in out.iter_mut() {
        *v += 0.08 * g.standard() as f32;
    }
    out
}

/// Generate `n` image examples over `n_classes` classes.
pub fn images(n: usize, n_classes: usize, seed: u64, style: ImageStyle) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut g = GaussianSampler::new(rng.split(0xA0A0));
    let protos: Vec<Vec<f32>> = (0..n_classes)
        .map(|c| class_prototype(c, style, seed))
        .collect();
    let mut xs = Vec::with_capacity(n * H * W * C);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.next_below(n_classes as u64) as usize;
        xs.extend(render(&protos[class], &mut rng, &mut g));
        ys.push(class as i32);
    }
    Dataset {
        xs,
        ys,
        example_numel: H * W * C,
        n_classes,
    }
}

/// SNLI-like sequence pairs over a 64-token vocabulary: 12 premise +
/// 12 hypothesis tokens, label ∈ {entailment, contradiction, neutral}.
///
/// * entailment    — hypothesis is a shuffled subset of the premise;
/// * contradiction — hypothesis tokens are the premise's "antonyms"
///                   (id + VOCAB/2 mod VOCAB);
/// * neutral       — hypothesis is fresh random tokens.
///
/// The relation is only visible by *comparing* the two halves, which is
/// what the attention block must learn.
pub fn sequence_pairs(n: usize, seed: u64) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let half = SEQ_LEN / 2;
    let mut xs = Vec::with_capacity(n * SEQ_LEN);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        // Premise avoids the top half of the vocab so "antonyms" are
        // distinguishable.
        let premise: Vec<u32> = (0..half)
            .map(|_| rng.next_below((VOCAB / 2) as u64) as u32)
            .collect();
        let label = rng.next_below(3) as usize;
        let hypothesis: Vec<u32> = match label {
            0 => {
                // entailment: shuffled copy
                let mut h = premise.clone();
                rng.shuffle(&mut h);
                h
            }
            1 => {
                // contradiction: antonym mapping
                premise.iter().map(|&t| t + (VOCAB / 2) as u32).collect()
            }
            _ => (0..half)
                .map(|_| rng.next_below(VOCAB as u64) as u32)
                .collect(),
        };
        xs.extend(premise.iter().map(|&t| t as f32));
        xs.extend(hypothesis.iter().map(|&t| t as f32));
        ys.push(label as i32);
    }
    Dataset {
        xs,
        ys,
        example_numel: SEQ_LEN,
        n_classes: 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prototypes_differ_between_classes() {
        for style in [ImageStyle::Signs, ImageStyle::Glyphs, ImageStyle::Objects] {
            let a = class_prototype(0, style, 1);
            let b = class_prototype(1, style, 1);
            assert_ne!(a, b);
        }
    }

    #[test]
    fn entailment_pairs_share_tokens() {
        let ds = sequence_pairs(300, 9);
        let half = SEQ_LEN / 2;
        for i in 0..ds.len() {
            if ds.ys[i] == 0 {
                let ex = ds.example(i);
                let mut p: Vec<i32> = ex[..half].iter().map(|&t| t as i32).collect();
                let mut h: Vec<i32> = ex[half..].iter().map(|&t| t as i32).collect();
                p.sort_unstable();
                h.sort_unstable();
                assert_eq!(p, h, "entailment must be a permutation");
            }
            if ds.ys[i] == 1 {
                let ex = ds.example(i);
                for j in 0..half {
                    assert_eq!(ex[half + j] as i32, ex[j] as i32 + (VOCAB / 2) as i32);
                }
            }
        }
    }

    #[test]
    fn images_bounded() {
        let ds = images(50, 10, 3, ImageStyle::Objects);
        assert!(ds.xs.iter().all(|&v| v > -2.0 && v < 4.0));
    }
}
