//! Shared low-level utilities: error handling, PRNG, Gaussian sampling,
//! special functions, and a minimal JSON codec (offline crate set has no
//! anyhow / rand / statrs / serde).

pub mod error;
pub mod gaussian;
pub mod json;
pub mod rng;
pub mod special;
