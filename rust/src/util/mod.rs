//! Shared low-level utilities: PRNG, Gaussian sampling, special
//! functions, and a minimal JSON codec (offline crate set has no rand /
//! statrs / serde).

pub mod gaussian;
pub mod json;
pub mod rng;
pub mod special;
