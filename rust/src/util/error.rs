//! Homegrown error handling (the offline crate set has no `anyhow`).
//!
//! [`Error`] is a chain of human-readable frames: the first frame is the
//! outermost context, the last is the root cause. Converting any
//! `std::error::Error` into an [`Error`] (via `?` or `From`) walks its
//! `source()` chain so no causal information is lost. The [`Context`]
//! extension trait adds frames to fallible expressions, and the [`err!`],
//! [`bail!`] and [`ensure!`] macros build or return ad-hoc errors.
//!
//! The API deliberately mirrors the `anyhow` subset this crate used to
//! depend on, so call sites migrate mechanically:
//!
//! * `anyhow::Result<T>`            -> `util::error::Result<T>`
//! * `anyhow!(...)`                 -> `err!(...)`
//! * `anyhow::Error::msg`           -> `Error::msg`
//! * `.context(...)/.with_context`  -> unchanged (this `Context` trait)
//! * `"{e:#}"`                      -> unchanged (full chain, `: `-joined)

use std::fmt;

/// Crate-wide result type, defaulting to the chained [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A chain of error messages, outermost context first.
///
/// Deliberately *not* `std::error::Error` itself: that keeps the blanket
/// `From<E: std::error::Error>` impl coherent (the same trick `anyhow`
/// uses).
pub struct Error {
    /// Never empty. `frames[0]` is the outermost message, the last entry
    /// the root cause.
    frames: Vec<String>,
}

impl Error {
    /// Ad-hoc error from anything printable.
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Self {
            frames: vec![msg.to_string()],
        }
    }

    /// Wrap with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, ctx: C) -> Self {
        self.frames.insert(0, ctx.to_string());
        self
    }

    /// Iterate the chain from the outermost message to the root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    /// `{}` prints the outermost message; `{:#}` the whole chain joined
    /// with `": "` (matching `anyhow`'s alternate formatting, which
    /// `main.rs` relies on).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(&self.frames[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames[0])?;
        if self.frames.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, frame) in self.frames[1..].iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

/// Any standard error converts by flattening its `source()` chain into
/// message frames, so `?` keeps working across error types.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Self { frames }
    }
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Extension trait attaching context frames to fallible expressions.
pub trait Context<T>: private::Sealed {
    /// Wrap the error (if any) with an outer message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    /// Like [`Context::context`], but the message is built lazily (only
    /// on the error path).
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (plus args) or from any single
/// printable expression — the drop-in for `anyhow!`.
#[macro_export]
macro_rules! err {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`err!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::err!($($tt)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)+) => {
        if !($cond) {
            return Err($crate::err!($($tt)*));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
}

// Make the macros importable alongside the types:
// `use crate::util::error::{bail, ensure, err, ...}`.
pub use crate::{bail, ensure, err};

#[cfg(test)]
mod tests {
    use super::*;
    use std::io;

    /// A std error with an explicit `source()` — a two-frame chain.
    /// (Note `io::Error::other(..)` would NOT work here: io's Custom repr
    /// delegates `source()` to the payload, hiding the wrapper level.)
    #[derive(Debug)]
    struct Wrapped {
        inner: io::Error,
    }

    impl fmt::Display for Wrapped {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("wrapped io failure")
        }
    }

    impl std::error::Error for Wrapped {
        fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
            Some(&self.inner)
        }
    }

    fn io_chain() -> Wrapped {
        Wrapped {
            inner: io::Error::new(io::ErrorKind::NotFound, "manifest.json missing"),
        }
    }

    #[test]
    fn msg_and_display() {
        let e = Error::msg("boom");
        assert_eq!(format!("{e}"), "boom");
        assert_eq!(format!("{e:#}"), "boom");
        assert_eq!(e.root_cause(), "boom");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "middle", "root"]);
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn std_source_chain_preserved() {
        let e: Error = io_chain().into();
        let frames: Vec<&str> = e.chain().collect();
        assert_eq!(frames.len(), 2, "{frames:?}");
        assert_eq!(frames[1], "manifest.json missing");
        assert_eq!(e.root_cause(), "manifest.json missing");
    }

    #[test]
    fn context_on_std_result() {
        let r: Result<(), Wrapped> = Err(io_chain());
        let e = r.context("opening artifacts").unwrap_err();
        assert_eq!(format!("{e}"), "opening artifacts");
        assert_eq!(e.root_cause(), "manifest.json missing");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u32, Error> = Ok(7);
        let called = std::cell::Cell::new(false);
        let v = ok
            .with_context(|| {
                called.set(true);
                "never built"
            })
            .unwrap();
        assert_eq!(v, 7);
        assert!(!called.get(), "closure must not run on the Ok path");

        let bad: Result<u32, Error> = Err(Error::msg("root"));
        let e = bad.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 3: root");
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        let e = none.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn err_macro_forms() {
        assert_eq!(format!("{}", err!("plain")), "plain");
        assert_eq!(format!("{}", err!("got {} of {}", 2, 3)), "got 2 of 3");
        let n = 4;
        assert_eq!(format!("{}", err!("inline {n}")), "inline 4");
        let s = String::from("owned message");
        assert_eq!(format!("{}", err!(s)), "owned message");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too large: {x}");
            }
            Ok(x * 2)
        }
        assert_eq!(f(21).unwrap(), 42);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too large: 101");

        fn g(x: i32) -> Result<()> {
            ensure!(x == 0);
            Ok(())
        }
        assert_eq!(format!("{}", g(1).unwrap_err()), "condition failed: x == 0");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn read_missing() -> Result<String> {
            let text = std::fs::read_to_string("/definitely/not/a/real/path/xyz")
                .context("reading config")?;
            Ok(text)
        }
        let e = read_missing().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert!(e.chain().count() >= 2);
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("0: root"), "{dbg}");
    }
}
