//! Deterministic pseudo-random number generation.
//!
//! The offline crate set has no `rand`, so we implement xoshiro256++
//! (Blackman & Vigna) seeded through SplitMix64. All randomness in the
//! coordinator — Poisson subsampling, DP noise, layer sampling — flows
//! through [`Xoshiro256`], so whole experiments are reproducible from a
//! single `u64` seed.

/// SplitMix64 step, used to expand a single `u64` seed into a full
/// xoshiro state (the construction recommended by the xoshiro authors).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator: fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Create a generator from a single seed via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is invalid (fixed point); splitmix64 of any seed
        // cannot produce four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    /// The raw generator state, for checkpointing. Restoring via
    /// [`Xoshiro256::from_state`] continues the stream bit-exactly.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Xoshiro256::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        // Same all-zero guard as seeding: the zero state is a fixed point.
        if s == [0, 0, 0, 0] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    /// Derive an independent stream for a subsystem. `tag` should be a
    /// distinct constant per use-site (e.g. hash of a name).
    pub fn split(&mut self, tag: u64) -> Self {
        let mixed = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Self::seed_from_u64(mixed)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our
    /// purposes: modulo bias is negligible for n << 2^64 but we reject to
    /// be exact).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let threshold = n.wrapping_neg() % n; // 2^64 mod n
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` uniformly (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_variance() {
        let mut r = Xoshiro256::seed_from_u64(7);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 3e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 3e-3, "var={var}");
    }

    #[test]
    fn next_below_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            assert_eq!(s.len(), 8);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Xoshiro256::seed_from_u64(5);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
