//! Minimal JSON reader/writer (offline crate set has no serde facade).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, produced by
//! `python/compile/aot.py`), for metrics output, for the versioned
//! checkpoint/report formats, and — since the serving daemon — as the
//! **wire format** of the `dpquant-serve-api` HTTP protocol. That last
//! role means the parser must assume *hostile* input, not just our own
//! emitters:
//!
//! * nesting depth is capped at [`MAX_DEPTH`] (bounded recursion — a
//!   `[[[[...` bomb errors out instead of overflowing the stack);
//! * numbers that overflow `f64` (`1e999`) are rejected rather than
//!   silently becoming `inf` (which the writer could not re-emit as
//!   valid JSON);
//! * truncated documents, bad escapes, and bad `\u` hex all return
//!   positioned errors, never panic (note the input is `&str`, so it is
//!   valid UTF-8 by construction; multi-byte slicing is still
//!   bounds-checked defensively);
//! * duplicate object keys resolve **last-wins** (documented, tested).
//!
//! Floats that must survive bit-exactly (checkpoints, summaries) travel
//! as IEEE-754 bit patterns in hex strings, not as numbers — see
//! `coordinator/session.rs`. Plain `Json::Num` round-trips exactly too
//! (Rust's shortest-round-trip float formatting), but hex is immune to
//! foreign re-serializers.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON `true` / `false`.
    Bool(bool),
    /// A JSON number (always finite — the parser rejects overflow).
    Num(f64),
    /// A JSON string.
    Str(String),
    /// A JSON array.
    Arr(Vec<Json>),
    /// A JSON object (sorted keys — serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The number, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number truncated to usize, if this is a [`Json::Num`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The string, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean, if this is a [`Json::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key/value map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `None` on non-objects and missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (`value.to_string()` comes with it for free).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Maximum container nesting the parser accepts. Deep enough for every
/// document we emit (checkpoints nest ~4 levels), shallow enough that
/// recursion can never overflow the stack on adversarial input.
pub const MAX_DEPTH: usize = 128;

/// Parse a JSON document. Returns an error string with byte position on
/// malformed input; never panics.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.pos)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or("eof in \\u")? as char;
                            code = code * 16
                                + d.to_digit(16)
                                    .ok_or_else(|| format!("bad hex at {}", self.pos))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(format!("bad escape at byte {}", self.pos)),
                },
                Some(c) => {
                    // Collect UTF-8 continuation bytes verbatim. The
                    // input is a `&str`, so sequences are well-formed by
                    // construction — but bounds-check anyway so a future
                    // bytes-based entry point cannot turn a truncated
                    // sequence into a slice panic.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(format!("truncated UTF-8 sequence at byte {start}"));
                        }
                        self.pos = end;
                        s.push_str(
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|e| format!("invalid UTF-8 at byte {start}: {e}"))?,
                        );
                    }
                }
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let v: f64 = text
            .parse()
            .map_err(|e| format!("bad number '{text}': {e}"))?;
        // `str::parse` maps overflow to ±inf; as a wire format we must
        // reject it (the writer could never re-emit it as valid JSON).
        if !v.is_finite() {
            return Err(format!("number '{text}' overflows f64"));
        }
        Ok(Json::Num(v))
    }

    /// Containers (not scalar leaves) count toward [`MAX_DEPTH`]: a
    /// scalar at the bottom of exactly `MAX_DEPTH` containers is legal.
    fn check_depth(&self, depth: usize) -> Result<(), String> {
        if depth >= MAX_DEPTH {
            return Err(format!(
                "nesting deeper than {MAX_DEPTH} levels at byte {}",
                self.pos
            ));
        }
        Ok(())
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.check_depth(depth)?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.check_depth(depth)?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            // Duplicate keys: last one wins (RFC 8259 leaves this
            // implementation-defined; we pick the common behavior and
            // pin it with a test).
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

/// Builder helpers for metric emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
/// An array of numbers.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}
/// A number value.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}
/// A string value.
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(
            v.get("b").unwrap().get("c").unwrap().as_str().unwrap(),
            "hi\nthere"
        );
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-0.5e-2").unwrap(), Json::Num(-0.005));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A café ⚡""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A café ⚡");
        let out = Json::Str("tab\t\"q\"".into()).to_string();
        assert_eq!(parse(&out).unwrap().as_str().unwrap(), "tab\t\"q\"");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(num(3.0).to_string(), "3");
        assert_eq!(num(3.25).to_string(), "3.25");
    }

    #[test]
    fn depth_is_bounded_not_a_stack_overflow() {
        // Within the cap: fine.
        let mut ok = String::new();
        for _ in 0..MAX_DEPTH {
            ok.push('[');
        }
        for _ in 0..MAX_DEPTH {
            ok.push(']');
        }
        assert!(parse(&ok).is_ok());
        // One past the cap: a positioned error, not a crash. (The
        // 100k-bracket bomb lives in tests/json_wire.rs.)
        let deep = format!("[{ok}]");
        let err = parse(&deep).unwrap_err();
        assert!(err.contains("nesting deeper"), "{err}");
    }

    #[test]
    fn overflowing_numbers_rejected() {
        assert!(parse("1e999").unwrap_err().contains("overflows"));
        assert!(parse("-1e999").unwrap_err().contains("overflows"));
        // Large but representable is fine.
        assert_eq!(parse("1e308").unwrap(), Json::Num(1e308));
    }
}
