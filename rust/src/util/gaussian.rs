//! Gaussian sampling for the DP mechanism.
//!
//! Per §A.17 of the paper, noise is sampled and added in full fp32/fp64
//! precision *before* any quantized computation touches the gradients, so
//! the vulnerability profile matches standard DP-SGD. This module is the
//! single source of Gaussian noise in the coordinator.

use super::rng::Xoshiro256;

/// Marsaglia polar-method Gaussian sampler with one cached deviate.
///
/// Polar Box-Muller avoids trig calls and is numerically well behaved;
/// the cached second deviate halves the cost on the optimizer hot path
/// where we draw one sample per parameter.
#[derive(Clone, Debug)]
pub struct GaussianSampler {
    rng: Xoshiro256,
    cached: Option<f64>,
}

impl GaussianSampler {
    /// New sampler owning its RNG stream.
    pub fn new(rng: Xoshiro256) -> Self {
        Self { rng, cached: None }
    }

    /// Convenience: seed directly.
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(Xoshiro256::seed_from_u64(seed))
    }

    /// Full sampler state (RNG state + the polar method's cached second
    /// deviate), for checkpointing. The cached deviate matters: dropping
    /// it would shift every subsequent draw by one.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.rng.state(), self.cached)
    }

    /// Rebuild a sampler from a captured [`GaussianSampler::state`];
    /// continues the deviate stream bit-exactly.
    pub fn from_state(rng: [u64; 4], cached: Option<f64>) -> Self {
        Self {
            rng: Xoshiro256::from_state(rng),
            cached,
        }
    }

    /// Standard normal deviate.
    #[inline]
    pub fn standard(&mut self) -> f64 {
        if let Some(z) = self.cached.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.rng.next_f64() - 1.0;
            let v = 2.0 * self.rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.cached = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal deviate with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.standard()
    }

    /// Fill a slice with `N(0, std²)` noise in fp32 (the precision the
    /// gradients live in), computed from fp64 deviates.
    pub fn fill_noise_f32(&mut self, out: &mut [f32], std: f64) {
        for o in out.iter_mut() {
            *o = (std * self.standard()) as f32;
        }
    }

    /// Add `N(0, std²)` noise to a parameter slice in place (fp32).
    pub fn add_noise_f32(&mut self, xs: &mut [f32], std: f64) {
        for x in xs.iter_mut() {
            *x += (std * self.standard()) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(n: usize, seed: u64) -> (f64, f64, f64, f64) {
        let mut g = GaussianSampler::seed_from_u64(seed);
        let (mut m1, mut m2, mut m3, mut m4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = g.standard();
            m1 += z;
            m2 += z * z;
            m3 += z * z * z;
            m4 += z * z * z * z;
        }
        let nf = n as f64;
        (m1 / nf, m2 / nf, m3 / nf, m4 / nf)
    }

    #[test]
    fn standard_normal_moments() {
        let (m1, m2, m3, m4) = moments(400_000, 17);
        assert!(m1.abs() < 0.01, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.02, "var={m2}");
        assert!(m3.abs() < 0.05, "skew={m3}");
        assert!((m4 - 3.0).abs() < 0.1, "kurtosis={m4}");
    }

    #[test]
    fn scaled_normal() {
        let mut g = GaussianSampler::seed_from_u64(5);
        let n = 200_000;
        let (mut s, mut ss) = (0.0, 0.0);
        for _ in 0..n {
            let z = g.normal(3.0, 2.0);
            s += z;
            ss += z * z;
        }
        let mean = s / n as f64;
        let var = ss / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.02);
        assert!((var - 4.0).abs() < 0.08);
    }

    #[test]
    fn fill_noise_matches_std() {
        let mut g = GaussianSampler::seed_from_u64(23);
        let mut buf = vec![0f32; 100_000];
        g.fill_noise_f32(&mut buf, 0.5);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.01);
        assert!((var - 0.25).abs() < 0.01);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = GaussianSampler::seed_from_u64(1);
        let mut b = GaussianSampler::seed_from_u64(1);
        for _ in 0..100 {
            assert_eq!(a.standard(), b.standard());
        }
    }

    #[test]
    fn tail_probability_sane() {
        // P(|Z| > 2) ≈ 0.0455
        let mut g = GaussianSampler::seed_from_u64(99);
        let n = 200_000;
        let tail = (0..n).filter(|_| g.standard().abs() > 2.0).count() as f64 / n as f64;
        assert!((tail - 0.0455).abs() < 0.004, "tail={tail}");
    }
}
