//! Special functions needed by the RDP accountant.
//!
//! Offline we have no `statrs`/`libm` extras, so we implement the pieces
//! the Sampled-Gaussian-Mechanism analysis needs: `ln_gamma` (Lanczos),
//! regularized incomplete gamma (for a double-precision `erfc`),
//! `log_erfc` with an asymptotic branch, stable `logsumexp` /
//! `log_sub_exp`, and log-binomial coefficients.

/// Lanczos approximation of `ln Γ(x)` for `x > 0` (g = 7, n = 9).
/// Relative error is ~1e-15 over the domain we use.
pub fn ln_gamma(x: f64) -> f64 {
    // Coefficients for g=7, n=9 (Godfrey / Numerical Recipes style).
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    assert!(x > 0.0, "ln_gamma domain: x={x}");
    if x < 0.5 {
        // Reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// `ln C(n, k)` for integer n ≥ k ≥ 0 via `ln_gamma`.
pub fn log_binom(n: u64, k: u64) -> f64 {
    assert!(k <= n);
    if k == 0 || k == n {
        return 0.0;
    }
    ln_gamma(n as f64 + 1.0) - ln_gamma(k as f64 + 1.0) - ln_gamma((n - k) as f64 + 1.0)
}

/// Regularized lower incomplete gamma P(a, x) by series expansion
/// (converges fast for x < a + 1).
fn gamma_p_series(a: f64, x: f64) -> f64 {
    let gln = ln_gamma(a);
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-16 {
            break;
        }
    }
    sum * (-x + a * x.ln() - gln).exp()
}

/// Regularized upper incomplete gamma Q(a, x) by continued fraction
/// (converges fast for x > a + 1). Modified Lentz algorithm.
fn gamma_q_cf(a: f64, x: f64) -> f64 {
    let gln = ln_gamma(a);
    const TINY: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / TINY;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < TINY {
            d = TINY;
        }
        c = b + an / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x + a * x.ln() - gln).exp() * h
}

/// Complementary error function, double precision, via the incomplete
/// gamma identity `erfc(x) = Q(1/2, x²)` for `x ≥ 0` and the reflection
/// `erfc(-x) = 2 - erfc(x)`.
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x == 0.0 {
        return 1.0;
    }
    let x2 = x * x;
    if x2 < 1.5 {
        1.0 - gamma_p_series(0.5, x2)
    } else {
        gamma_q_cf(0.5, x2)
    }
}

/// Error function.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// `ln erfc(x)` with an asymptotic branch that stays finite where
/// `erfc` underflows (x ≳ 26). Mirrors the accountant's needs: the
/// fractional-α series evaluates tails at large arguments.
pub fn log_erfc(x: f64) -> f64 {
    if x < 20.0 {
        let e = erfc(x);
        if e > 0.0 {
            return e.ln();
        }
    }
    // Asymptotic: erfc(x) ~ exp(-x²)/(x√π) · (1 - 1/(2x²) + 3/(4x⁴) - 15/(8x⁶))
    let ix2 = 1.0 / (x * x);
    -x * x - (x * std::f64::consts::PI.sqrt()).ln()
        + (1.0 - 0.5 * ix2 + 0.75 * ix2 * ix2 - 1.875 * ix2 * ix2 * ix2).ln_1p_safe()
}

trait Ln1pSafe {
    fn ln_1p_safe(self) -> f64;
}
impl Ln1pSafe for f64 {
    #[inline]
    fn ln_1p_safe(self) -> f64 {
        (self - 1.0).ln_1p()
    }
}

/// Stable `ln(exp(a) + exp(b))`; `-inf` inputs behave as exp = 0.
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    hi + (lo - hi).exp().ln_1p()
}

/// Stable `ln(exp(a) - exp(b))`; requires `a ≥ b`.
pub fn log_sub_exp(a: f64, b: f64) -> f64 {
    assert!(a >= b, "log_sub_exp requires a >= b (a={a}, b={b})");
    if b == f64::NEG_INFINITY {
        return a;
    }
    if a == b {
        return f64::NEG_INFINITY;
    }
    // ln(exp(a) - exp(b)) = a + ln(1 - exp(b - a))
    a + (-((b - a).exp())).ln_1p()
}

/// Stable logsumexp over a slice.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

/// Standard normal CDF.
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(3)=2, Γ(4)=6, Γ(0.5)=√π
        assert!((ln_gamma(1.0) - 0.0).abs() < 1e-12);
        assert!((ln_gamma(2.0) - 0.0).abs() < 1e-12);
        assert!((ln_gamma(3.0) - 2f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(4.0) - 6f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-12);
        // Γ(10) = 362880
        assert!((ln_gamma(10.0) - 362880f64.ln()).abs() < 1e-10);
    }

    #[test]
    fn ln_gamma_recurrence() {
        // Γ(x+1) = x Γ(x)
        for &x in &[0.3, 0.7, 1.5, 3.2, 7.9, 25.0, 100.5] {
            let lhs = ln_gamma(x + 1.0);
            let rhs = x.ln() + ln_gamma(x);
            assert!((lhs - rhs).abs() < 1e-10, "x={x}: {lhs} vs {rhs}");
        }
    }

    #[test]
    fn log_binom_small() {
        assert!((log_binom(5, 2) - 10f64.ln()).abs() < 1e-10);
        assert!((log_binom(10, 5) - 252f64.ln()).abs() < 1e-10);
        assert_eq!(log_binom(7, 0), 0.0);
        assert_eq!(log_binom(7, 7), 0.0);
    }

    #[test]
    fn erfc_known_values() {
        // Reference values (Abramowitz & Stegun / mpmath).
        let cases = [
            (0.0, 1.0),
            (0.5, 0.4795001221869535),
            (1.0, 0.15729920705028513),
            (2.0, 0.004677734981063127),
            (3.0, 2.209049699858544e-5),
            (-1.0, 1.8427007929497148),
        ];
        for (x, want) in cases {
            let got = erfc(x);
            assert!(
                (got - want).abs() < 1e-12 * want.abs().max(1.0),
                "erfc({x}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn log_erfc_continuity_at_branch() {
        // The series/asymptotic switch must be smooth.
        for &x in &[5.0, 10.0, 15.0, 19.9, 20.1, 25.0, 30.0, 50.0] {
            let le = log_erfc(x);
            // Compare against the asymptotic leading term; ratio → 1.
            let lead = -x * x - (x * std::f64::consts::PI.sqrt()).ln();
            assert!(
                (le - lead).abs() < 0.05,
                "log_erfc({x}) = {le}, leading = {lead}"
            );
        }
        // And small-x agreement with direct computation.
        for &x in &[0.0, 0.5, 1.0, 2.0, 4.0] {
            assert!((log_erfc(x) - erfc(x).ln()).abs() < 1e-12);
        }
    }

    #[test]
    fn log_add_sub_exp() {
        let a = 1.0f64;
        let b = 0.2f64;
        let add = log_add_exp(a, b);
        assert!((add - (a.exp() + b.exp()).ln()).abs() < 1e-12);
        let sub = log_sub_exp(a, b);
        assert!((sub - (a.exp() - b.exp()).ln()).abs() < 1e-12);
        assert_eq!(log_add_exp(f64::NEG_INFINITY, b), b);
        assert_eq!(log_sub_exp(a, f64::NEG_INFINITY), a);
        assert_eq!(log_sub_exp(a, a), f64::NEG_INFINITY);
    }

    #[test]
    fn logsumexp_matches_naive() {
        let xs = [0.1f64, -2.0, 3.5, 1.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-12);
        // Large values don't overflow.
        let big = [1000.0, 1000.0];
        assert!((logsumexp(&big) - (1000.0 + 2f64.ln())).abs() < 1e-12);
    }

    #[test]
    fn norm_cdf_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-14);
        assert!((norm_cdf(1.959963984540054) - 0.975).abs() < 1e-9);
        assert!((norm_cdf(-1.0) - 0.15865525393145707).abs() < 1e-12);
    }
}
