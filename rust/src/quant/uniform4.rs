//! Uniform 4-bit quantizer with stochastic rounding (paper §A.9.2):
//! the value range `[-max, max]` is discretized into 2⁴ = 16 evenly
//! spaced levels; values round stochastically to an adjacent level so
//! the quantizer is unbiased.

use super::Quantizer;
use crate::util::rng::Xoshiro256;

/// Number of levels for 4 bits.
pub const LEVELS: u32 = 16;

/// Symmetric uniform INT4 quantizer with stochastic rounding.
pub struct Uniform4;

impl Uniform4 {
    /// Grid step for a tensor with max magnitude `max_abs`.
    #[inline]
    pub fn step(max_abs: f32) -> f32 {
        2.0 * max_abs / (LEVELS - 1) as f32
    }

    /// Quantize one value with grid step `step`, stochastic draw `u`.
    #[inline]
    pub fn quantize_one(x: f32, step: f32, u: f32) -> f32 {
        if step == 0.0 {
            return 0.0;
        }
        let t = x / step;
        let lo = t.floor();
        let frac = t - lo;
        let rounded = if u < frac { lo + 1.0 } else { lo };
        rounded * step
    }
}

impl Quantizer for Uniform4 {
    fn name(&self) -> &'static str {
        "uniform4"
    }
    fn bits(&self) -> u32 {
        4
    }
    fn quantize(&self, xs: &mut [f32], rng: &mut Xoshiro256) {
        let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if max_abs == 0.0 {
            return;
        }
        let step = Self::step(max_abs);
        for x in xs.iter_mut() {
            *x = Self::quantize_one(*x, step, rng.next_f32());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outputs_are_multiples_of_step() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut xs: Vec<f32> = (0..256).map(|i| ((i as f32).sin()) * 5.0).collect();
        let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let step = Uniform4::step(max_abs);
        Uniform4.quantize(&mut xs, &mut rng);
        for &v in &xs {
            let k = v / step;
            assert!((k - k.round()).abs() < 1e-4, "{v} not on grid");
        }
    }

    #[test]
    fn single_value_unbiased() {
        let step = 0.4f32;
        let mut rng = Xoshiro256::seed_from_u64(21);
        for &x in &[0.13f32, -0.31, 0.55, 1.9] {
            let trials = 200_000;
            let mut sum = 0f64;
            for _ in 0..trials {
                sum += Uniform4::quantize_one(x, step, rng.next_f32()) as f64;
            }
            let mean = sum / trials as f64;
            assert!((mean - x as f64).abs() < 0.005, "x={x} mean={mean}");
        }
    }

    #[test]
    fn error_bounded_by_step() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 * 0.11).cos() * 2.0).collect();
        let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let step = Uniform4::step(max_abs);
        let mut q = xs.clone();
        Uniform4.quantize(&mut q, &mut rng);
        for (a, b) in xs.iter().zip(&q) {
            assert!((a - b).abs() <= step * 1.0001, "|{a}-{b}| > step {step}");
        }
    }

    #[test]
    fn grid_values_fixed_points() {
        // Exact grid values quantize to themselves regardless of u.
        let step = 0.25f32;
        for k in -7..=7 {
            let x = k as f32 * step;
            assert_eq!(Uniform4::quantize_one(x, step, 0.0), x);
            assert_eq!(Uniform4::quantize_one(x, step, 0.999), x);
        }
    }
}
