//! FP8-E5M2 quantize-dequantize (paper §A.9.1).
//!
//! E5M2 shares the f16 exponent range (bias 15) with a 2-bit mantissa.
//! We implement round-to-nearest-even by operating on the f32 bit
//! pattern: keep the top 2 mantissa bits, round the remaining 21 bits.
//! Deterministic (the paper's FP8 results need no stochastic rounding —
//! at 8 bits DP training shows no degradation, Table 11).

use super::Quantizer;
use crate::util::rng::Xoshiro256;

/// Largest finite E5M2 value: 2¹⁵ × 1.75 = 57344.
pub const MAX_E5M2: f32 = 57344.0;
/// Smallest positive normal E5M2 value: 2⁻¹⁴.
pub const MIN_NORMAL_E5M2: f32 = 6.103515625e-5;

/// FP8-E5M2 quantizer (round-to-nearest-even, saturating).
pub struct Fp8E5M2;

impl Fp8E5M2 {
    /// Quantize-dequantize one f32 value to the E5M2 grid.
    pub fn quantize_one(x: f32) -> f32 {
        if x == 0.0 || x.is_nan() {
            return x;
        }
        let clamped = x.clamp(-MAX_E5M2, MAX_E5M2);
        // Flush sub-minimal values toward the subnormal grid: E5M2
        // subnormals are k·2⁻¹⁶ for k=1..3; emulate by scaling.
        let bits = clamped.to_bits();
        // Round mantissa to 2 bits: add half-ulp-at-2-bits with
        // round-to-nearest-even tie handling on the 21 dropped bits.
        const DROP: u32 = 23 - 2;
        let lsb = (bits >> DROP) & 1;
        let rounded = bits
            .wrapping_add((1u32 << (DROP - 1)) - 1 + lsb)
            & !((1u32 << DROP) - 1);
        let y = f32::from_bits(rounded);
        // Saturate if rounding overflowed past the max exponent.
        if y.abs() > MAX_E5M2 {
            return MAX_E5M2.copysign(y);
        }
        // Handle the subnormal band (|x| < 2^-14): snap to the E5M2
        // subnormal grid of step 2^-16 (round-to-nearest).
        if y.abs() < MIN_NORMAL_E5M2 {
            let step = MIN_NORMAL_E5M2 / 4.0;
            return (y / step).round() * step;
        }
        y
    }
}

impl Quantizer for Fp8E5M2 {
    fn name(&self) -> &'static str {
        "fp8"
    }
    fn bits(&self) -> u32 {
        8
    }
    fn quantize(&self, xs: &mut [f32], _rng: &mut Xoshiro256) {
        for x in xs.iter_mut() {
            *x = Self::quantize_one(*x);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_preserved() {
        // Powers of two and 2-bit mantissas are exactly representable.
        for &x in &[1.0f32, 2.0, 0.5, 1.25, 1.5, 1.75, -3.0, 96.0, 57344.0] {
            assert_eq!(Fp8E5M2::quantize_one(x), x, "{x} should be exact");
        }
    }

    #[test]
    fn rounds_to_nearest() {
        // 1.1 lies between 1.0 and 1.25 → rounds to 1.0 (nearer).
        assert_eq!(Fp8E5M2::quantize_one(1.1), 1.0);
        // 1.2 is nearer 1.25.
        assert_eq!(Fp8E5M2::quantize_one(1.2), 1.25);
        // Ties round to even mantissa: 1.125 → 1.0 (mantissa 00 is even).
        assert_eq!(Fp8E5M2::quantize_one(1.125), 1.0);
        // 1.375 ties between 1.25 (01) and 1.5 (10) → even is 1.5.
        assert_eq!(Fp8E5M2::quantize_one(1.375), 1.5);
    }

    #[test]
    fn saturates_at_max() {
        assert_eq!(Fp8E5M2::quantize_one(1e6), MAX_E5M2);
        assert_eq!(Fp8E5M2::quantize_one(-1e6), -MAX_E5M2);
        assert_eq!(Fp8E5M2::quantize_one(60000.0), MAX_E5M2);
    }

    #[test]
    fn relative_error_bound() {
        // Normal range: relative error ≤ 2^-3 = 12.5%.
        for i in 0..1000 {
            let x = (i as f32 * 0.013 + 0.001) * if i % 2 == 0 { 1.0 } else { -1.0 };
            let q = Fp8E5M2::quantize_one(x);
            let rel = (x - q).abs() / x.abs();
            assert!(rel <= 0.125 + 1e-6, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn zero_and_signs() {
        assert_eq!(Fp8E5M2::quantize_one(0.0), 0.0);
        assert_eq!(Fp8E5M2::quantize_one(-1.2), -1.25);
    }

    #[test]
    fn subnormal_band_snaps() {
        let tiny = 3e-5f32; // below MIN_NORMAL
        let q = Fp8E5M2::quantize_one(tiny);
        let step = MIN_NORMAL_E5M2 / 4.0;
        assert!((q / step - (q / step).round()).abs() < 1e-3);
    }
}
