//! LUQ-FP4: logarithmic unbiased quantization to a 4-bit format
//! (1 sign + 3 exponent bits), after Chmiel et al. 2024 — the paper's
//! primary low-precision format (§6, "Low Precision Format").
//!
//! Given a tensor with max magnitude `M`, the representable grid is
//! `{0} ∪ {± α·2^k : k = 0..7}` with `α = M / 2^7`, i.e. eight
//! octaves below the max. Two stochastic steps keep the quantizer
//! unbiased:
//!
//! 1. **Stochastic underflow pruning**: `|x| < α` becomes `sign(x)·α`
//!    with probability `|x|/α`, else 0.
//! 2. **Stochastic logarithmic rounding**: `|x| ∈ [α·2^k, α·2^{k+1}]`
//!    rounds up with probability `(|x| − lo)/(hi − lo)` (linear-domain
//!    unbiased stochastic rounding between adjacent grid points).
//!
//! Scale invariance holds because `α` is derived from `‖x‖∞`.

use super::Quantizer;
use crate::util::rng::Xoshiro256;

/// Number of exponent levels: 3 exponent bits → 8 octaves.
pub const EXP_LEVELS: i32 = 8;

/// LUQ-FP4 quantizer.
pub struct LuqFp4;

impl LuqFp4 {
    /// The underflow threshold α for a tensor with max magnitude `max_abs`.
    #[inline]
    pub fn alpha(max_abs: f32) -> f32 {
        max_abs / (1u32 << (EXP_LEVELS - 1)) as f32
    }

    /// Quantize one value given the tensor threshold `alpha`.
    #[inline]
    pub fn quantize_one(x: f32, alpha: f32, u: f32) -> f32 {
        if x == 0.0 || alpha == 0.0 {
            return 0.0;
        }
        let sign = if x < 0.0 { -1.0 } else { 1.0 };
        let mag = x.abs();
        if mag < alpha {
            // Stochastic underflow: unbiased prune-or-promote.
            return if u < mag / alpha { sign * alpha } else { 0.0 };
        }
        // log2(mag/alpha) ∈ [0, 7]; stochastic round between octaves.
        let k = (mag / alpha).log2().floor().min((EXP_LEVELS - 1) as f32);
        let lo = alpha * (2f32).powi(k as i32);
        let hi = alpha * (2f32).powi(k as i32 + 1);
        if mag >= hi {
            // mag == max (top of grid) or fp edge case.
            return sign * hi.min(alpha * (2f32).powi(EXP_LEVELS - 1));
        }
        let p_up = (mag - lo) / (hi - lo);
        if u < p_up {
            sign * hi
        } else {
            sign * lo
        }
    }
}

impl Quantizer for LuqFp4 {
    fn name(&self) -> &'static str {
        "luq4"
    }
    fn bits(&self) -> u32 {
        4
    }
    fn quantize(&self, xs: &mut [f32], rng: &mut Xoshiro256) {
        let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if max_abs == 0.0 {
            return;
        }
        let alpha = Self::alpha(max_abs);
        for x in xs.iter_mut() {
            let u = rng.next_f32();
            *x = Self::quantize_one(*x, alpha, u);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{empirical_bias, empirical_variance};

    #[test]
    fn outputs_on_grid() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut xs: Vec<f32> = (0..512)
            .map(|i| ((i as f32 * 0.37).sin() * 3.0) as f32)
            .collect();
        let max_abs = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let alpha = LuqFp4::alpha(max_abs);
        LuqFp4.quantize(&mut xs, &mut rng);
        for &v in &xs {
            if v == 0.0 {
                continue;
            }
            let k = (v.abs() / alpha).log2();
            assert!(
                (k - k.round()).abs() < 1e-5 && (0.0..=7.0).contains(&k.round()),
                "value {v} not on grid (k={k})"
            );
        }
    }

    #[test]
    fn per_value_unbiased() {
        // E[q(x)] = x for a single value in the underflow region and in a
        // rounding interval.
        let alpha = 0.5f32;
        let trials = 200_000;
        let mut rng = Xoshiro256::seed_from_u64(77);
        for &x in &[0.2f32, 0.3, 0.6, 1.3, -0.9, -0.05] {
            let mut sum = 0f64;
            for _ in 0..trials {
                sum += LuqFp4::quantize_one(x, alpha, rng.next_f32()) as f64;
            }
            let mean = sum / trials as f64;
            assert!(
                (mean - x as f64).abs() < 0.01,
                "x={x}: E[q]={mean}"
            );
        }
    }

    #[test]
    fn zero_and_max_fixed_points() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        assert_eq!(LuqFp4::quantize_one(0.0, 0.5, rng.next_f32()), 0.0);
        // The max element must map to itself (it sits on the top grid
        // point by construction of alpha).
        let mut xs = vec![2.0f32, -0.3, 0.7];
        LuqFp4.quantize(&mut xs, &mut rng);
        assert_eq!(xs[0], 2.0);
    }

    #[test]
    fn variance_below_gridstep_squared() {
        // Var per coordinate is at most (hi-lo)²/4 ≤ (max/2)²/4.
        let x: Vec<f32> = (0..128).map(|i| ((i * 31 % 97) as f32 / 97.0) * 2.0 - 1.0).collect();
        let var = empirical_variance(&LuqFp4, &x, 2000, 3);
        assert!(var > 0.0 && var < 0.25, "var={var}");
        let bias = empirical_bias(&LuqFp4, &x, 4000, 5);
        assert!(bias < 0.05, "bias={bias}");
    }

    #[test]
    fn all_zero_tensor_noop() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut xs = vec![0f32; 16];
        LuqFp4.quantize(&mut xs, &mut rng);
        assert!(xs.iter().all(|&v| v == 0.0));
    }
}
