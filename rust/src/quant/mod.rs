//! Rust mirrors of the quantizers (L1 owns the in-graph Pallas versions;
//! these mirrors power property tests, the Figure-1 variance micro-studies
//! and the perf model, and document the exact numerics).
//!
//! All training-path quantizers are **unbiased** (`E[q(x)] = x`) and
//! **scale-invariant** (`q(λx) = λ q(x)` in distribution), the two
//! properties Proposition 1 needs for `Var(q(x)) = Θ(‖x‖∞²)`.

pub mod luq;
pub mod uniform4;
pub mod fp8;

use crate::util::rng::Xoshiro256;

/// A tensor quantizer: quantize-dequantize a slice in place.
///
/// `Send + Sync` is a supertrait so trait objects can be shared across
/// the native backend's scoped worker threads (all implementations are
/// stateless unit structs; per-call randomness comes from the `rng`
/// argument).
pub trait Quantizer: Send + Sync {
    /// Short identifier (matches artifact naming: luq4 / uniform4 / fp8).
    fn name(&self) -> &'static str;
    /// Nominal bit width (speedup modeling).
    fn bits(&self) -> u32;
    /// Quantize-dequantize `xs` in place. `rng` drives stochastic rounding
    /// (deterministic quantizers ignore it).
    fn quantize(&self, xs: &mut [f32], rng: &mut Xoshiro256);
}

/// Look up a quantizer by name.
pub fn by_name(name: &str) -> Option<Box<dyn Quantizer>> {
    match name {
        "luq4" => Some(Box::new(luq::LuqFp4)),
        "uniform4" => Some(Box::new(uniform4::Uniform4)),
        "fp8" => Some(Box::new(fp8::Fp8E5M2)),
        _ => None,
    }
}

/// Empirical quantization variance of `q` on `x`: mean over coordinates of
/// Var over `trials` of `q(x)_i`. Used by the Prop-1 tests and Fig-1-style
/// studies.
pub fn empirical_variance(q: &dyn Quantizer, x: &[f32], trials: usize, seed: u64) -> f64 {
    let n = x.len();
    let mut mean = vec![0f64; n];
    let mut m2 = vec![0f64; n];
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut buf = vec![0f32; n];
    for t in 0..trials {
        buf.copy_from_slice(x);
        q.quantize(&mut buf, &mut rng);
        for i in 0..n {
            let v = buf[i] as f64;
            let d = v - mean[i];
            mean[i] += d / (t + 1) as f64;
            m2[i] += d * (v - mean[i]);
        }
    }
    m2.iter().map(|&s| s / (trials - 1) as f64).sum::<f64>() / n as f64
}

/// Empirical bias `‖E[q(x)] − x‖∞` (should vanish for unbiased quantizers).
pub fn empirical_bias(q: &dyn Quantizer, x: &[f32], trials: usize, seed: u64) -> f64 {
    let n = x.len();
    let mut acc = vec![0f64; n];
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut buf = vec![0f32; n];
    for _ in 0..trials {
        buf.copy_from_slice(x);
        q.quantize(&mut buf, &mut rng);
        for i in 0..n {
            acc[i] += buf[i] as f64;
        }
    }
    acc.iter()
        .zip(x)
        .map(|(&a, &v)| (a / trials as f64 - v as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gauss_vec(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut g = crate::util::gaussian::GaussianSampler::seed_from_u64(seed);
        (0..n).map(|_| scale * g.standard() as f32).collect()
    }

    #[test]
    fn stochastic_quantizers_unbiased() {
        let x = gauss_vec(256, 1.0, 3);
        for name in ["luq4", "uniform4"] {
            let q = by_name(name).unwrap();
            let bias = empirical_bias(q.as_ref(), &x, 4000, 11);
            // Max |x| ~ 3; per-coordinate SE of the mean with var ~ grid²
            // is well under 0.05 at 4000 trials.
            assert!(bias < 0.08, "{name} bias = {bias}");
        }
    }

    #[test]
    fn prop1_variance_scales_with_inf_norm_squared() {
        // Proposition 1: Var(q(x)) = Θ(‖x‖∞²). Scaling x by λ must scale
        // the empirical variance by ~λ².
        for name in ["luq4", "uniform4"] {
            let q = by_name(name).unwrap();
            let x1 = gauss_vec(128, 1.0, 5);
            let x4: Vec<f32> = x1.iter().map(|&v| 4.0 * v).collect();
            let v1 = empirical_variance(q.as_ref(), &x1, 3000, 7);
            let v4 = empirical_variance(q.as_ref(), &x4, 3000, 7);
            let ratio = v4 / v1;
            assert!(
                (ratio - 16.0).abs() < 3.0,
                "{name}: Var ratio {ratio}, want ~16"
            );
        }
    }

    #[test]
    fn fp8_low_error() {
        // FP8-E5M2 relative error ≤ 2^-3 per element (2 mantissa bits).
        let q = by_name("fp8").unwrap();
        let x = gauss_vec(512, 2.0, 9);
        let mut y = x.clone();
        let mut rng = Xoshiro256::seed_from_u64(1);
        q.quantize(&mut y, &mut rng);
        for (a, b) in x.iter().zip(&y) {
            let rel = (a - b).abs() / a.abs().max(1e-6);
            assert!(rel <= 0.13, "x={a} q={b} rel={rel}");
        }
    }

    #[test]
    fn by_name_unknown() {
        assert!(by_name("int2").is_none());
    }
}
