//! `dpquant` — launcher CLI for the DPQuant reproduction.
//!
//! Subcommands:
//!   train            — run one training job (scheduler, model, dataset
//!                      and DP parameters from flags or --config file).
//!                      `--checkpoint-every N` snapshots the full
//!                      session (weights, optimizer moments, RDP curve,
//!                      EMA scores, RNG streams) every N epochs;
//!                      `--resume <ckpt>` continues a snapshot
//!                      bit-exactly (`--epochs` is the only override —
//!                      everything else comes from the checkpoint);
//!                      `--trace-out PATH` writes a `dpquant-trace` v1
//!                      file of the full event stream (`--no-timing`
//!                      zeroes its clock fields so files diff), and
//!                      `--metrics-out PATH` snapshots the metrics
//!                      registry after the run — both pure observation,
//!                      outputs stay byte-identical
//!   eval-only        — evaluate a model's initial weights
//!   list             — list compiled graphs in the artifact manifest
//!   accountant       — privacy-accountant utilities (`--dump` emits RDP
//!                      values for the Python numerical-integration
//!                      oracle; otherwise composes a training schedule)
//!   exp <id>         — regenerate a paper table/figure (fig1a..tab14)
//!   sweep            — expand a config grid (`--grid` and/or a `[sweep]`
//!                      config section) and train every point on a
//!                      work-stealing thread pool (`--jobs N`), writing a
//!                      deterministic JSON report (default
//!                      BENCH_sweep.json) and a Pareto table; `--jobs N`
//!                      output is byte-identical to `--jobs 1` (pass
//!                      `--no-timing` to zero the wall-clock fields so
//!                      whole files diff)
//!   serve            — run the DP-training job daemon: HTTP/1.1 API on
//!                      `--addr`, up to `--jobs N` concurrent sessions,
//!                      checkpoint-backed durability under `--state-dir`
//!                      (a killed daemon restarts and finishes every
//!                      in-flight job bit-exactly)
//!   job              — client verbs against a running daemon:
//!                      `submit|list|status|events|cancel|wait`
//!                      (`--addr`, default 127.0.0.1:8117); `submit
//!                      --tenant ID` charges the job to a ledger tenant
//!   tenant           — budget-ledger verbs against a running daemon:
//!                      `create ID --budget-epsilon EPS [--delta D]`,
//!                      `list`, `status ID` (remaining ε printed at
//!                      full precision so scripts can diff it across a
//!                      daemon restart)
//!   cost             — predict a config's privacy cost *without
//!                      training*: the composed (ε, α) the ledger will
//!                      reserve for it, the training-only ε, and the
//!                      analysis overhead (same `--key` surface as
//!                      train)
//!   loadgen          — loopback load generator: N tenants × M jobs
//!                      against an embedded daemon (budgets sized so
//!                      ~half the jobs hit 403), reporting accept/reject
//!                      counts and submit/wait latency percentiles as a
//!                      `dpquant-bench` "serve"-family JSON
//!                      (BENCH_serve.json, `--check`-validatable)
//!   trace            — trace-file utilities: `trace check PATH`
//!                      validates every line against the
//!                      `dpquant-trace` v1 schema, `trace summarize
//!                      PATH` aggregates spans into a per-target table
//!                      (count, total/mean/p95 ns)
//!   audit            — DP audit-trail utilities: `audit check PATH`
//!                      validates a `dpquant-audit` v1 file (written by
//!                      `train --audit-out` and by served jobs), `audit
//!                      replay PATH` re-drives every recorded
//!                      (q, σ, steps) block through a fresh accountant
//!                      and fails unless the replayed ε timeline is
//!                      bitwise equal to the recorded one
//!   version          — crate version + the on-disk/wire format versions
//!                      this build speaks (also `--version`)
//!   bench-step       — time one train step, fp32 vs fully quantized
//!   bench            — the per-PR performance snapshot: naive-vs-blocked
//!                      kernel timings, quantizer ns/elem, native
//!                      steps/sec (fp32 vs each quantizer); `--json PATH`
//!                      writes a `dpquant-bench` v1 blob (DESIGN.md §13),
//!                      `--check FILE` validates one instead of measuring
//!                      (rejecting `provisional: true` snapshots unless
//!                      `--allow-provisional`), `--metrics-out PATH`
//!                      snapshots the metrics registry the measurements
//!                      also feed; `bench diff OLD NEW` and `bench trend
//!                      A B C...` compare snapshots per key with
//!                      regression thresholds (`--fail-threshold`,
//!                      default 10% kernel-ns; `--warn-threshold` for
//!                      steps/sec) and exit nonzero on regression
//!
//! Model-executing subcommands (train, eval-only, bench-step, exp,
//! sweep) take `--backend native|pjrt|mock`; `serve` reads `backend`
//! from its `--config` file, and `bench` always times the native engine.
//! The default, `native`, is the pure-Rust engine in `backend/` — real
//! forward/backward with per-sample clipping and on-path quantizers,
//! needing **no artifacts**. `pjrt` targets the AOT artifacts + XLA
//! runtime (requires `make artifacts` and a vendored `xla` crate).
//!
//! Unknown or misspelled commands and `--flags` are hard errors (with a
//! nearest-match suggestion), so a typo cannot silently run the wrong
//! experiment.
//!
//! Examples:
//!   dpquant train --model miniconvnet --dataset gtsrb --scheduler dpquant \
//!       --quant-fraction 0.9 --epochs 12 --target-epsilon 8
//!   dpquant train --epochs 8 --checkpoint-every 2 --checkpoint-path results/ck.json
//!   dpquant train --resume results/ck.json --epochs 16
//!   dpquant sweep --grid "quantizer=luq4,fp8;quant_fraction=0.5,0.75;seed=0..2" --jobs 4
//!   dpquant serve --addr 127.0.0.1:8117 --jobs 2 --state-dir serve-state
//!   dpquant job submit --epochs 4 --seed 7 && dpquant job wait 1
//!   dpquant exp fig3
//!   dpquant exp tab1 --scale 0.25

use dpquant::backend;
use dpquant::cli::Args;
use dpquant::config::{ObsConfig, TrainConfig};
use dpquant::coordinator::{
    Checkpoint, EpochOutcome, EventSink, MultiSink, StepExecutor, TraceSink, TrainSession,
    VerboseSink,
};
use dpquant::data::{self, Dataset};
use dpquant::exp;
use dpquant::obs::{self, JsonlSink, TraceWriter};
use dpquant::privacy::{default_alphas, rdp_sgm_step, rdp_to_epsilon, RdpAccountant};
use dpquant::runtime::Runtime;
use dpquant::util::error::{err, Result};
use dpquant::util::json;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Options shared by every command that builds a `TrainConfig` (the
/// `--key` forms `TrainConfig::from_args` reads).
const CONFIG_OPTS: &[&str] = dpquant::config::CONFIG_ARG_KEYS;

fn spec(base: &[&'static str], extra: &[&'static str]) -> Vec<&'static str> {
    base.iter().chain(extra.iter()).copied().collect()
}

/// Every top-level command, for the unknown-command did-you-mean.
const COMMANDS: &[&str] = &[
    "train",
    "eval-only",
    "list",
    "accountant",
    "exp",
    "sweep",
    "serve",
    "job",
    "tenant",
    "cost",
    "loadgen",
    "trace",
    "audit",
    "version",
    "bench-step",
    "bench",
];

fn dispatch(args: &Args) -> Result<()> {
    // `dpquant --version` / `-V`-style probe, honored regardless of
    // position so scripts can always check compatibility.
    if args.command().is_none() && args.has_flag("version") {
        println!("{}", dpquant::version());
        return Ok(());
    }
    match args.command() {
        Some("train") => {
            let opts = spec(
                CONFIG_OPTS,
                &[
                    "artifacts",
                    "results",
                    "checkpoint-every",
                    "checkpoint-path",
                    "resume",
                    "trace-out",
                    "metrics-out",
                    "audit-out",
                ],
            );
            args.require_known("train", &opts, &["no-ema", "stats", "quiet", "no-timing"])?;
            cmd_train(args)
        }
        Some("eval-only") => {
            let opts = spec(CONFIG_OPTS, &["artifacts"]);
            args.require_known("eval-only", &opts, &["no-ema"])?;
            cmd_eval_only(args)
        }
        Some("list") => {
            args.require_known("list", &["artifacts"], &[])?;
            cmd_list(args)
        }
        Some("accountant") => {
            args.require_known(
                "accountant",
                &["q", "sigma", "steps", "delta", "analysis-steps", "sigma-measure"],
                &["dump"],
            )?;
            cmd_accountant(args)
        }
        Some("exp") => {
            args.require_known(
                "exp",
                &[
                    "scale",
                    "seeds",
                    "model",
                    "dataset",
                    "quantizer",
                    "epochs",
                    "dataset-size",
                    "noise-multiplier",
                    "lr",
                    "backend",
                    "artifacts",
                    "subsets",
                    "fraction",
                    "speedup-factor",
                    "analysis-frac",
                    "reps",
                ],
                &[],
            )?;
            exp::run(args)
        }
        Some("sweep") => {
            let opts = spec(CONFIG_OPTS, &["grid", "jobs", "out", "trace-out", "metrics-out"]);
            args.require_known("sweep", &opts, &["no-ema", "no-timing", "quiet"])?;
            dpquant::sweep::run(args)
        }
        Some("serve") => {
            args.require_known("serve", &["config", "addr", "jobs", "state-dir"], &[])?;
            dpquant::serve::run_serve(args)
        }
        Some("job") => {
            // Per-verb option validation happens inside run() — submit
            // accepts the full train-config surface, the others don't.
            dpquant::serve::client::run(args)
        }
        Some("tenant") => {
            // Per-verb option validation happens inside run_tenant.
            dpquant::serve::client::run_tenant(args)
        }
        Some("cost") => {
            args.require_known("cost", CONFIG_OPTS, &["no-ema"])?;
            cmd_cost(args)
        }
        Some("loadgen") => {
            // Option validation happens inside run_loadgen.
            dpquant::serve::loadgen::run_loadgen(args)
        }
        Some("trace") => {
            args.require_known("trace", &[], &[])?;
            cmd_trace(args)
        }
        Some("audit") => {
            args.require_known("audit", &[], &[])?;
            cmd_audit(args)
        }
        Some("version") => {
            args.require_known("version", &[], &[])?;
            println!("{}", dpquant::version());
            Ok(())
        }
        Some("bench-step") => {
            let opts = spec(CONFIG_OPTS, &["artifacts", "reps"]);
            args.require_known("bench-step", &opts, &["no-ema"])?;
            cmd_bench_step(args)
        }
        Some("bench") => match args.subcommand() {
            // Trend engine: compare committed dpquant-bench snapshots.
            Some("diff") | Some("trend") => {
                args.require_known("bench", &["fail-threshold", "warn-threshold"], &[])?;
                exp::trend::run(args)
            }
            _ => {
                args.require_known(
                    "bench",
                    &["json", "reps", "check", "metrics-out"],
                    &["allow-provisional"],
                )?;
                exp::perf::bench(args)
            }
        },
        Some(other) => Err(dpquant::cli::unknown_command_error("command", other, COMMANDS).into()),
        None => {
            println!(
                "usage: dpquant <train|eval-only|list|accountant|exp|sweep|serve|job|tenant|\
                 cost|loadgen|trace|audit|version|bench-step|bench> [flags]\n\
                 model-executing commands take --backend native|pjrt|mock (default: native)"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

/// Regenerate the datasets a config describes (identical on resume —
/// generation is deterministic from the config's dataset/sizes/seed;
/// `data::train_val` is the shared definition the sweep and the serving
/// daemon use too).
fn open_data(cfg: &TrainConfig) -> Result<(Dataset, Dataset)> {
    data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed)
}

fn cmd_train(args: &Args) -> Result<()> {
    let verbose = !args.has_flag("quiet");
    let (session, exec, train_ds, val_ds) = if let Some(path) = args.get("resume") {
        // Everything comes from the checkpoint; `--epochs` is the one
        // supported override (extend or shorten the run). Any other
        // config flag would be silently ignored — make that a hard
        // error rather than let a run spend the wrong privacy budget.
        for key in CONFIG_OPTS {
            if *key != "epochs" && args.get(key).is_some() {
                return Err(err!(
                    "--{key} cannot be combined with --resume: the configuration comes from \
                     the checkpoint, and --epochs is the only supported override"
                ));
            }
        }
        if args.has_flag("no-ema") {
            return Err(err!(
                "--no-ema cannot be combined with --resume: the configuration comes from \
                 the checkpoint"
            ));
        }
        let ckpt = Checkpoint::load(path)?;
        let cfg = ckpt.config().clone();
        let (train_ds, val_ds) = open_data(&cfg)?;
        let exec = backend::open_executor(
            &cfg,
            train_ds.example_numel,
            train_ds.n_classes,
            &artifacts_dir(args),
        )?;
        let mut session = TrainSession::resume_from(ckpt, exec.as_ref())?;
        if let Some(epochs) = args.usize_opt("epochs")? {
            if session.is_truncated() {
                eprintln!(
                    "warning: ignoring --epochs {epochs}: the checkpointed session already \
                     reached its privacy budget and cannot run further epochs"
                );
            } else {
                session.set_epochs(epochs);
            }
        }
        if verbose {
            if session.is_truncated() {
                println!(
                    "resumed {path}: {} epochs completed; session hit its privacy budget \
                     (no further epochs will run)",
                    session.epochs_completed()
                );
            } else {
                println!(
                    "resumed {path}: {} epochs completed, running to epoch {}",
                    session.epochs_completed(),
                    session.config().epochs
                );
            }
        }
        (session, exec, train_ds, val_ds)
    } else {
        let cfg = TrainConfig::from_args(args)?;
        let (train_ds, val_ds) = open_data(&cfg)?;
        let exec = backend::open_executor(
            &cfg,
            train_ds.example_numel,
            train_ds.n_classes,
            &artifacts_dir(args),
        )?;
        let session = TrainSession::builder(cfg.clone()).build(exec.as_ref(), &train_ds)?;
        if verbose {
            println!(
                "backend={} model={} dataset={} quantizer={} scheduler={}",
                cfg.backend, cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler
            );
        }
        (session, exec, train_ds, val_ds)
    };
    run_session(args, session, exec.as_ref(), &train_ds, &val_ds)
}

/// Drive a session epoch by epoch, checkpointing on the requested
/// cadence, then print + save the run record.
fn run_session(
    args: &Args,
    mut session: TrainSession,
    exec: &dyn StepExecutor,
    train_ds: &Dataset,
    val_ds: &Dataset,
) -> Result<()> {
    let verbose = !args.has_flag("quiet");
    let ckpt_every = args.usize_or("checkpoint-every", 0)?;
    let ckpt_path = args.str_or("checkpoint-path", "results/checkpoint.json");
    if args.get("checkpoint-path").is_some() && ckpt_every == 0 {
        return Err(err!(
            "--checkpoint-path without --checkpoint-every N never writes a checkpoint; \
             pass --checkpoint-every to set the cadence"
        ));
    }

    // Observability is pure observation: the trace writer and metrics
    // registry never feed back into the run, so outputs are
    // byte-identical with or without them (pinned by tests/obs.rs).
    let obs_cfg = ObsConfig::from_args(args)?;
    obs_cfg.apply();
    let timing = !args.has_flag("no-timing");
    let writer = match &obs_cfg.trace_path {
        Some(path) => Some(TraceWriter::create(path, timing)?),
        None => None,
    };
    let mut jsonl = writer.as_ref().map(JsonlSink::new);

    // The DP audit trail (`dpquant-audit` v1): run record now, one
    // record per epoch via the sink. On `--resume` the accountant
    // already carries history — recorded as the run's `prior` blocks so
    // `audit replay` composes from the same starting point.
    let audit_path = args.get("audit-out");
    let audit_writer = match audit_path {
        Some(path) => {
            let w = obs::AuditWriter::create(path, timing)?;
            w.begin_run(session.config(), train_ds.len(), session.accountant_history());
            Some(w)
        }
        None => None,
    };
    let mut audit_sink = audit_writer.as_ref().map(obs::AuditSink::new);

    let mut trace_sink = TraceSink::default();
    let mut verbose_sink = VerboseSink;
    let mut sinks: Vec<&mut dyn EventSink> = Vec::new();
    if let Some(j) = jsonl.as_mut() {
        sinks.push(j);
    }
    if let Some(a) = audit_sink.as_mut() {
        sinks.push(a);
    }
    if args.has_flag("stats") {
        sinks.push(&mut trace_sink);
    }
    if verbose {
        sinks.push(&mut verbose_sink);
    }
    let mut sink = MultiSink::new(sinks);

    loop {
        let outcome = {
            // Coarse span around the whole epoch; the JsonlSink's event
            // records are written inside it and get it as their parent.
            let _epoch_span = writer.as_ref().map(|w| {
                w.span(
                    "step_epoch",
                    "session",
                    json::obj(vec![(
                        "epoch",
                        json::num(session.epochs_completed() as f64),
                    )]),
                )
            });
            session.step_epoch(exec, train_ds, val_ds, &mut sink)?
        };
        match outcome {
            EpochOutcome::Finished => break,
            EpochOutcome::Completed { .. } | EpochOutcome::Truncated { .. } => {
                if ckpt_every > 0 && session.epochs_completed() % ckpt_every == 0 {
                    {
                        let _ckpt_span = writer.as_ref().map(|w| {
                            w.span(
                                "checkpoint_write",
                                "session",
                                json::obj(vec![("path", json::s(&ckpt_path))]),
                            )
                        });
                        session.checkpoint(&ckpt_path)?;
                    }
                    if verbose {
                        println!(
                            "checkpoint: {ckpt_path} (after epoch {})",
                            session.epochs_completed()
                        );
                    }
                }
            }
        }
    }

    if let Some(w) = &writer {
        w.finish()?;
        if verbose {
            if let Some(path) = &obs_cfg.trace_path {
                println!("trace written: {path}");
            }
        }
    }
    if let Some(w) = &audit_writer {
        w.finish()?;
        if verbose {
            if let Some(path) = audit_path {
                println!("audit written: {path}");
            }
        }
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, format!("{}\n", obs::metrics_doc()))
            .map_err(|e| err!("writing metrics snapshot {path}: {e}"))?;
        if verbose {
            println!("metrics written: {path}");
        }
    }

    let (record, _weights, _accountant) = session.finish();
    // The one shared formatter: `dpquant job status` rebuilds this line
    // from the daemon's JSON and CI diffs the two byte-for-byte.
    println!("{}", record.final_line());
    let path = record.save(&args.str_or("results", "results"))?;
    println!("saved {path}");
    Ok(())
}

/// `dpquant trace <check|summarize> PATH` — validate or aggregate a
/// `dpquant-trace` v1 file.
fn cmd_trace(args: &Args) -> Result<()> {
    let usage = "usage: dpquant trace <summarize|check> PATH";
    let path = args.positional.get(2);
    match args.subcommand() {
        Some("summarize") => {
            let path = path.ok_or_else(|| err!("{usage}"))?;
            let rows = obs::trace::summarize(path)?;
            let mut t = dpquant::metrics::Table::new(&[
                "target", "count", "total_ns", "mean_ns", "p95_ns",
            ]);
            for r in &rows {
                t.row(vec![
                    r.target.clone(),
                    r.count.to_string(),
                    format!("{:.0}", r.total_ns),
                    format!("{:.0}", r.mean_ns),
                    format!("{:.0}", r.p95_ns),
                ]);
            }
            t.print();
            Ok(())
        }
        Some("check") => {
            let path = path.ok_or_else(|| err!("{usage}"))?;
            let stats = obs::trace::check(path)?;
            println!(
                "ok: {path} is {} v{} ({} spans, {} events)",
                obs::TRACE_FORMAT,
                obs::TRACE_VERSION,
                stats.spans,
                stats.events
            );
            Ok(())
        }
        Some(other) => Err(dpquant::cli::unknown_command_error(
            "trace subcommand",
            other,
            &["summarize", "check"],
        )
        .into()),
        None => Err(err!("{usage}")),
    }
}

/// `dpquant audit <check|replay> PATH` — validate a `dpquant-audit` v1
/// file, or re-compose its ε timeline through a fresh accountant and
/// demand bitwise agreement (DESIGN.md §17).
fn cmd_audit(args: &Args) -> Result<()> {
    let usage = "usage: dpquant audit <check|replay> PATH";
    let path = args.positional.get(2);
    match args.subcommand() {
        Some("check") => {
            let path = path.ok_or_else(|| err!("{usage}"))?;
            let stats = obs::audit::check(path)?;
            println!(
                "ok: {path} is {} v{} ({} epochs, {} accounting blocks, {} analysis steps{})",
                obs::AUDIT_FORMAT,
                obs::AUDIT_VERSION,
                stats.epochs,
                stats.records,
                stats.analysis_steps,
                if stats.truncated { ", truncated at budget" } else { "" }
            );
            Ok(())
        }
        Some("replay") => {
            let path = path.ok_or_else(|| err!("{usage}"))?;
            let replay = obs::audit::replay(path)?;
            println!(
                "replay ok: {path}: {} epochs re-composed bitwise; final epsilon = {} \
                 at alpha = {}",
                replay.epochs, replay.final_epsilon, replay.final_alpha
            );
            Ok(())
        }
        Some(other) => Err(dpquant::cli::unknown_command_error(
            "audit subcommand",
            other,
            &["check", "replay"],
        )
        .into()),
        None => Err(err!("{usage}")),
    }
}

fn cmd_eval_only(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let ds = data::generate(&cfg.dataset, cfg.val_size, cfg.seed)?;
    let exec = backend::open_executor(&cfg, ds.example_numel, ds.n_classes, &artifacts_dir(args))?;
    let weights = exec.initial_weights();
    let (loss, acc) = dpquant::coordinator::trainer::evaluate(exec.as_ref(), &weights, &ds)?;
    println!("init weights ({} backend): loss={loss:.4} acc={acc:.4}", cfg.backend);
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut t = dpquant::metrics::Table::new(&[
        "tag", "model", "dataset", "quantizer", "batch", "layers", "params",
    ]);
    for (tag, g) in &rt.manifest.graphs {
        t.row(vec![
            tag.clone(),
            g.model.clone(),
            g.dataset.clone(),
            g.quantizer.clone(),
            g.batch.to_string(),
            g.n_quant_layers.to_string(),
            g.total_params().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    if args.has_flag("dump") {
        // Machine-readable RDP values for the Python oracle test:
        // lines of "q sigma alpha rdp".
        let qs = [0.001, 0.01, 0.05, 0.2, 1.0];
        let sigmas = [0.5, 1.0, 2.0, 5.0];
        let alphas = [1.5, 2.0, 3.0, 4.5, 8.0, 16.0, 32.0];
        for &q in &qs {
            for &sigma in &sigmas {
                for &alpha in &alphas {
                    println!("{q} {sigma} {alpha} {:.12e}", rdp_sgm_step(q, sigma, alpha));
                }
            }
        }
        return Ok(());
    }
    // Compose a schedule: ε for (q, σ, steps) + optional analysis steps.
    let q = args.f64_or("q", 0.02)?;
    let sigma = args.f64_or("sigma", 1.0)?;
    let steps = args.u64_or("steps", 1000)?;
    let delta = args.f64_or("delta", 1e-5)?;
    let analysis_steps = args.u64_or("analysis-steps", 0)?;
    let sigma_measure = args.f64_or("sigma-measure", 0.5)?;

    let mut acc = RdpAccountant::new();
    acc.step_training(q, sigma, steps);
    for _ in 0..analysis_steps {
        acc.step_analysis(q, sigma_measure);
    }
    let (eps, alpha) = acc.epsilon(delta);
    println!("epsilon = {eps:.4} at alpha = {alpha} (delta = {delta})");
    if analysis_steps > 0 {
        println!(
            "analysis fraction of budget = {:.4}",
            acc.analysis_fraction(delta)
        );
    }
    // Also show the training-only conversion for reference.
    let alphas = default_alphas();
    let curve: Vec<f64> = alphas
        .iter()
        .map(|&a| steps as f64 * rdp_sgm_step(q, sigma, a))
        .collect();
    let (eps_train, _) = rdp_to_epsilon(&alphas, &curve, delta);
    println!("training-only epsilon = {eps_train:.4}");
    Ok(())
}

/// `dpquant cost [--key value ...]` — predict the privacy cost the
/// ledger would reserve for this config, without training anything.
/// Pure arithmetic over the config ([`dpquant::serve::ledger::schedule_cost`]
/// → [`RdpAccountant::predict`]), so the printed composed ε is exactly
/// the estimate a `POST /v1/jobs` admission check uses.
fn cmd_cost(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let cost = dpquant::serve::ledger::schedule_cost(&cfg);
    println!(
        "schedule: {} training steps (q={}, sigma={}), {} analysis steps (q={}, sigma={})",
        cost.train_steps,
        cost.sample_rate,
        cost.noise_multiplier,
        cost.analysis_steps,
        cost.analysis_rate,
        cost.analysis_sigma
    );
    // Adaptive policies expand into a heterogeneous block sequence; list
    // it whenever it differs from the single-block static shape above.
    let training_blocks: Vec<_> = cost
        .records()
        .iter()
        .filter(|r| r.mechanism == dpquant::privacy::Mechanism::Training)
        .collect();
    if training_blocks.len() > 1 {
        println!("adaptive training schedule (policy = {}):", cfg.policy);
        for (i, r) in training_blocks.iter().enumerate() {
            println!(
                "  block {i}: {} steps at q={}, sigma={}",
                r.steps, r.sample_rate, r.noise_multiplier
            );
        }
    }
    println!(
        "composed epsilon = {} at alpha = {} (delta = {})",
        cost.epsilon, cost.alpha, cost.delta
    );
    println!("training-only epsilon = {}", cost.train_epsilon);
    if cost.epsilon > 0.0 {
        println!(
            "analysis overhead = {:.4}% of the composed budget",
            (cost.epsilon - cost.train_epsilon) / cost.epsilon * 100.0
        );
    }
    Ok(())
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let cfg = TrainConfig::from_args(args)?;
    let ds_probe = data::generate(&cfg.dataset, 1, cfg.seed)?;
    let exec = backend::open_executor(
        &cfg,
        ds_probe.example_numel,
        ds_probe.n_classes,
        &artifacts_dir(args),
    )?;
    let b = exec.physical_batch();
    let ds = data::generate(&cfg.dataset, b, cfg.seed)?;
    let batches = data::eval_batches(&ds, b);
    let batch = &batches[0];
    let nl = exec.n_quant_layers();
    let reps = args.usize_or("reps", 20)?;
    let weights = exec.initial_weights();
    let tag = format!("{}_{}_{}", cfg.model, cfg.dataset, cfg.quantizer);

    // fp32 step vs fully-quantized step, so the quantization overhead
    // (or the modeled low-precision speedup target) is visible directly.
    for (label, mask) in [("fp32", vec![0f32; nl]), ("quantized", vec![1f32; nl])] {
        exec.train_step(&weights, &batch.x, &batch.y, &batch.mask, &mask, 0.0)?; // warmup
        let t0 = std::time::Instant::now();
        for i in 0..reps {
            exec.train_step(&weights, &batch.x, &batch.y, &batch.mask, &mask, i as f32)?;
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "{tag} [{} backend, {label}]: train_step {:.2} ms/batch ({b} examples, {:.1} ex/s)",
            cfg.backend,
            per * 1e3,
            b as f64 / per
        );
    }
    Ok(())
}
