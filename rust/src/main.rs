//! `dpquant` — launcher CLI for the DPQuant reproduction.
//!
//! Subcommands:
//!   train            — run one training job (scheduler, model, dataset
//!                      and DP parameters from flags or --config file)
//!   eval-only        — evaluate a model's initial weights
//!   list             — list compiled graphs in the artifact manifest
//!   accountant       — privacy-accountant utilities (`--dump` emits RDP
//!                      values for the Python numerical-integration
//!                      oracle; otherwise composes a training schedule)
//!   exp <id>         — regenerate a paper table/figure (fig1a..tab14)
//!   bench-step       — time one train step, fp32 vs fully quantized
//!
//! Every model-executing subcommand takes `--backend native|pjrt|mock`.
//! The default, `native`, is the pure-Rust engine in `backend/` — real
//! forward/backward with per-sample clipping and on-path quantizers,
//! needing **no artifacts**. `pjrt` targets the AOT artifacts + XLA
//! runtime (requires `make artifacts` and a vendored `xla` crate).
//!
//! Examples:
//!   dpquant train --model miniconvnet --dataset gtsrb --scheduler dpquant \
//!       --quant-fraction 0.9 --epochs 12 --target-epsilon 8
//!   dpquant train --backend native --model mlp --dataset cifar
//!   dpquant exp fig3
//!   dpquant exp tab1 --scale 0.25

use dpquant::backend;
use dpquant::cli::Args;
use dpquant::config::{ConfigFile, OptimizerKind, TrainConfig};
use dpquant::coordinator::{train, StepExecutor, TrainerOptions};
use dpquant::data;
use dpquant::exp;
use dpquant::privacy::{default_alphas, rdp_sgm_step, rdp_to_epsilon, RdpAccountant};
use dpquant::runtime::Runtime;
use dpquant::util::error::{err, Error, Result};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command() {
        Some("train") => cmd_train(args),
        Some("eval-only") => cmd_eval_only(args),
        Some("list") => cmd_list(args),
        Some("accountant") => cmd_accountant(args),
        Some("exp") => exp::run(args),
        Some("bench-step") => cmd_bench_step(args),
        Some(other) => Err(err!("unknown command '{other}' (see README)")),
        None => {
            println!(
                "usage: dpquant <train|eval-only|list|accountant|exp|bench-step> [flags]\n\
                 model-executing commands take --backend native|pjrt|mock (default: native)"
            );
            Ok(())
        }
    }
}

/// Build a TrainConfig from `--config file` + flag overrides.
fn config_from_args(args: &Args) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let cf = ConfigFile::load(path).map_err(Error::msg)?;
            TrainConfig::from_file(&cf).map_err(Error::msg)?
        }
        None => TrainConfig::default(),
    };
    if let Some(v) = args.get("model") {
        cfg.model = v.to_string();
    }
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.get("quantizer") {
        cfg.quantizer = v.to_string();
    }
    if let Some(v) = args.get("scheduler") {
        cfg.scheduler = v.to_string();
    }
    if let Some(v) = args.get("optimizer") {
        cfg.optimizer = OptimizerKind::parse(v).map_err(Error::msg)?;
    }
    cfg.epochs = args.usize_or("epochs", cfg.epochs).map_err(Error::msg)?;
    cfg.batch_size = args.usize_or("batch-size", cfg.batch_size).map_err(Error::msg)?;
    cfg.noise_multiplier = args
        .f64_or("noise-multiplier", cfg.noise_multiplier)
        .map_err(Error::msg)?;
    cfg.clip_norm = args.f64_or("clip-norm", cfg.clip_norm).map_err(Error::msg)?;
    cfg.lr = args.f64_or("lr", cfg.lr).map_err(Error::msg)?;
    cfg.quant_fraction = args
        .f64_or("quant-fraction", cfg.quant_fraction)
        .map_err(Error::msg)?;
    cfg.beta = args.f64_or("beta", cfg.beta).map_err(Error::msg)?;
    cfg.analysis_interval = args
        .usize_or("analysis-interval", cfg.analysis_interval)
        .map_err(Error::msg)?;
    cfg.sigma_measure = args
        .f64_or("sigma-measure", cfg.sigma_measure)
        .map_err(Error::msg)?;
    cfg.analysis_samples = args
        .usize_or("analysis-samples", cfg.analysis_samples)
        .map_err(Error::msg)?;
    cfg.dataset_size = args
        .usize_or("dataset-size", cfg.dataset_size)
        .map_err(Error::msg)?;
    cfg.val_size = args.usize_or("val-size", cfg.val_size).map_err(Error::msg)?;
    cfg.seed = args.u64_or("seed", cfg.seed).map_err(Error::msg)?;
    if let Some(eps) = args.f64_opt("target-epsilon").map_err(Error::msg)? {
        cfg.target_epsilon = Some(eps);
    }
    if args.has_flag("no-ema") {
        cfg.ema_enabled = false;
    }
    if let Some(v) = args.get("backend") {
        cfg.backend = v.to_string();
    }
    Ok(cfg)
}

fn artifacts_dir(args: &Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let full = data::generate(&cfg.dataset, cfg.dataset_size + cfg.val_size, cfg.seed)
        .map_err(Error::msg)?;
    let (train_ds, val_ds) = full.split(cfg.val_size);
    let exec = backend::open_executor(
        &cfg,
        train_ds.example_numel,
        train_ds.n_classes,
        &artifacts_dir(args),
    )?;

    let opts = TrainerOptions {
        collect_step_stats: args.has_flag("stats"),
        verbose: !args.has_flag("quiet"),
    };
    if opts.verbose {
        println!(
            "backend={} model={} dataset={} quantizer={} scheduler={}",
            cfg.backend, cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler
        );
    }
    let res = train(exec.as_ref(), &cfg, &train_ds, &val_ds, &opts)?;
    println!(
        "final: val_acc={:.4} eps={:.3} (analysis eps alone: {:.3}) epochs={}",
        res.record.final_accuracy,
        res.record.final_epsilon,
        res.record.analysis_epsilon,
        res.record.epochs.len()
    );
    let path = res.record.save(&args.str_or("results", "results"))?;
    println!("saved {path}");
    Ok(())
}

fn cmd_eval_only(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let ds = data::generate(&cfg.dataset, cfg.val_size, cfg.seed).map_err(Error::msg)?;
    let exec = backend::open_executor(&cfg, ds.example_numel, ds.n_classes, &artifacts_dir(args))?;
    let weights = exec.initial_weights();
    let (loss, acc) = dpquant::coordinator::trainer::evaluate(exec.as_ref(), &weights, &ds)?;
    println!("init weights ({} backend): loss={loss:.4} acc={acc:.4}", cfg.backend);
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    let mut t = dpquant::metrics::Table::new(&[
        "tag", "model", "dataset", "quantizer", "batch", "layers", "params",
    ]);
    for (tag, g) in &rt.manifest.graphs {
        t.row(vec![
            tag.clone(),
            g.model.clone(),
            g.dataset.clone(),
            g.quantizer.clone(),
            g.batch.to_string(),
            g.n_quant_layers.to_string(),
            g.total_params().to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_accountant(args: &Args) -> Result<()> {
    if args.has_flag("dump") {
        // Machine-readable RDP values for the Python oracle test:
        // lines of "q sigma alpha rdp".
        let qs = [0.001, 0.01, 0.05, 0.2, 1.0];
        let sigmas = [0.5, 1.0, 2.0, 5.0];
        let alphas = [1.5, 2.0, 3.0, 4.5, 8.0, 16.0, 32.0];
        for &q in &qs {
            for &sigma in &sigmas {
                for &alpha in &alphas {
                    println!("{q} {sigma} {alpha} {:.12e}", rdp_sgm_step(q, sigma, alpha));
                }
            }
        }
        return Ok(());
    }
    // Compose a schedule: ε for (q, σ, steps) + optional analysis steps.
    let q = args.f64_or("q", 0.02).map_err(Error::msg)?;
    let sigma = args.f64_or("sigma", 1.0).map_err(Error::msg)?;
    let steps = args.u64_or("steps", 1000).map_err(Error::msg)?;
    let delta = args.f64_or("delta", 1e-5).map_err(Error::msg)?;
    let analysis_steps = args.u64_or("analysis-steps", 0).map_err(Error::msg)?;
    let sigma_measure = args.f64_or("sigma-measure", 0.5).map_err(Error::msg)?;

    let mut acc = RdpAccountant::new();
    acc.step_training(q, sigma, steps);
    for _ in 0..analysis_steps {
        acc.step_analysis(q, sigma_measure);
    }
    let (eps, alpha) = acc.epsilon(delta);
    println!("epsilon = {eps:.4} at alpha = {alpha} (delta = {delta})");
    if analysis_steps > 0 {
        println!(
            "analysis fraction of budget = {:.4}",
            acc.analysis_fraction(delta)
        );
    }
    // Also show the training-only conversion for reference.
    let alphas = default_alphas();
    let curve: Vec<f64> = alphas
        .iter()
        .map(|&a| steps as f64 * rdp_sgm_step(q, sigma, a))
        .collect();
    let (eps_train, _) = rdp_to_epsilon(&alphas, &curve, delta);
    println!("training-only epsilon = {eps_train:.4}");
    Ok(())
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let ds_probe = data::generate(&cfg.dataset, 1, cfg.seed).map_err(Error::msg)?;
    let exec = backend::open_executor(
        &cfg,
        ds_probe.example_numel,
        ds_probe.n_classes,
        &artifacts_dir(args),
    )?;
    let b = exec.physical_batch();
    let ds = data::generate(&cfg.dataset, b, cfg.seed).map_err(Error::msg)?;
    let batches = data::eval_batches(&ds, b);
    let batch = &batches[0];
    let nl = exec.n_quant_layers();
    let reps = args.usize_or("reps", 20).map_err(Error::msg)?;
    let weights = exec.initial_weights();
    let tag = format!("{}_{}_{}", cfg.model, cfg.dataset, cfg.quantizer);

    // fp32 step vs fully-quantized step, so the quantization overhead
    // (or the modeled low-precision speedup target) is visible directly.
    for (label, mask) in [("fp32", vec![0f32; nl]), ("quantized", vec![1f32; nl])] {
        exec.train_step(&weights, &batch.x, &batch.y, &batch.mask, &mask, 0.0)?; // warmup
        let t0 = std::time::Instant::now();
        for i in 0..reps {
            exec.train_step(&weights, &batch.x, &batch.y, &batch.mask, &mask, i as f32)?;
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "{tag} [{} backend, {label}]: train_step {:.2} ms/batch ({b} examples, {:.1} ex/s)",
            cfg.backend,
            per * 1e3,
            b as f64 / per
        );
    }
    Ok(())
}
