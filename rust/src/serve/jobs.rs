//! The job manager: concurrent, durable training jobs over
//! [`TrainSession`].
//!
//! **Lifecycle.** `submit` validates the config through
//! `session::validate_config` (same gate as the CLI and the sweep),
//! assigns a monotonically increasing id, and enqueues the job on a
//! long-lived [`WorkerPool`](crate::sweep::pool::WorkerPool) of
//! `--jobs N` workers — a *stream* pool, so jobs keep arriving while
//! earlier ones run. Status advances `queued → running → done`
//! (or `failed` / `cancelled`). Each worker owns its executor, session,
//! and datasets; like sweep workers it opens the backend through
//! `backend::open_sweep_executor`, which pins the native engine to one
//! internal thread — so a job's result is a pure function of its
//! config, byte-identical to `DPQUANT_THREADS=1 dpquant train` with the
//! same flags and independent of how many jobs run concurrently.
//!
//! **Tenancy.** A submit may name a tenant; admission then goes through
//! the [`BudgetLedger`](super::ledger::BudgetLedger): the job's
//! estimated RDP cost is *reserved* against the tenant's lifetime
//! (ε, δ) budget (rejected with [`SubmitError::Exhausted`] when it
//! doesn't fit), the *actual* accountant history is debited on
//! successful completion, and cancel/failure/panic refunds the
//! reservation. Every refusal path bumps a
//! `serve.jobs_rejected.<reason>` counter (`validation`, `backend`,
//! `tenant`, `budget`) so `/v1/metrics` distinguishes causes.
//!
//! **Fairness.** Workers do not pop job ids directly: each submit puts
//! one *ticket* on the pool and the job id on its tenant's queue; a
//! ticket pops the next id **round-robin across tenants** with queued
//! work (anonymous jobs form one tenant-like bucket). One tenant
//! dumping 100 jobs cannot starve another's next submit behind them —
//! with queued work from k tenants, each gets every k-th worker slot.
//! Tickets and queue entries stay 1:1 by construction; a
//! cancelled-while-queued job's ticket pops it and no-ops on the status
//! check.
//!
//! **Observability.** The session's [`TrainEvent`] stream drains into a
//! per-job ring buffer of epoch progress ([`EVENT_RING_CAP`] entries;
//! older entries drop off, the `dropped` counter says how many). The
//! ring is in-memory only — progress history does not survive a
//! restart, results do. With a `--state-dir` each job additionally
//! writes a durable `dpquant-audit` v1 trail (`job-<id>.audit.jsonl`,
//! one flushed line per epoch, timing-off) recording the resolved DP
//! knobs, sampled mask, and composed (ε, α*) — served by
//! `GET /v1/jobs/{id}/audit` and replayable bit-exactly by
//! `dpquant audit replay`, including across `kill -9` recovery.
//!
//! **Durability.** With a `--state-dir`, every state transition writes
//! the job's *manifest* (`job-<id>.json`, atomic temp+rename) and every
//! completed epoch writes a full `dpquant-trainsession` checkpoint
//! (`job-<id>.ck.json`). A daemon killed at any instant — `kill -9`
//! mid-epoch included — restarts with the same `--state-dir` and
//! recovers every job: terminal jobs keep their recorded outcome;
//! queued and in-flight jobs are re-enqueued, resuming from their last
//! checkpoint (or from scratch if none was written yet). Because
//! checkpoints are bit-exact and training is deterministic, the
//! recovered job finishes with results byte-identical to an
//! uninterrupted run — `tests/serve.rs` proves this.
//!
//! **Locking.** One mutex guards the job table; workers take it only
//! for claim/transition/event pushes (all O(epoch), never O(step)), so
//! the HTTP threads' reads never wait on training compute.

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::backend;
use crate::cli;
use crate::config::{OptimizerKind, TrainConfig, KNOWN_TRAIN_KEYS};
use crate::coordinator::session::validate_config;
use crate::coordinator::{
    Checkpoint, EpochOutcome, EventSink, MultiSink, TrainEvent, TrainSession,
};
use crate::data;
use crate::metrics::RunRecord;
use crate::obs;
use crate::privacy::StepRecord;
use crate::sweep::pool::{panic_text, WorkerPool};
use crate::util::error::{ensure, err, Context, Error, Result};
use crate::util::json::{self, Json};

use super::ledger::{AdmitError, BudgetLedger};

/// On-disk job-manifest format tag (`job-<id>.json` in the state dir).
pub const MANIFEST_FORMAT: &str = "dpquant-serve-job";
/// Manifest version this build reads and writes.
pub const MANIFEST_VERSION: u64 = 1;
/// Epoch-progress entries kept per job before the oldest drop off.
pub const EVENT_RING_CAP: usize = 256;

// ---------------------------------------------------------------------
// Job state
// ---------------------------------------------------------------------

/// Lifecycle state of a job (wire names via [`JobStatus::name`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobStatus {
    /// Accepted, waiting for a worker.
    Queued,
    /// A worker is training it.
    Running,
    /// Finished successfully.
    Done,
    /// Stopped on an error (message in the status document).
    Failed,
    /// Cancelled before or during the run.
    Cancelled,
}

impl JobStatus {
    /// Lowercase wire name (what the JSON API emits).
    pub fn name(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
            JobStatus::Cancelled => "cancelled",
        }
    }

    fn parse(s: &str) -> Result<Self> {
        match s {
            "queued" => Ok(JobStatus::Queued),
            "running" => Ok(JobStatus::Running),
            "done" => Ok(JobStatus::Done),
            "failed" => Ok(JobStatus::Failed),
            "cancelled" => Ok(JobStatus::Cancelled),
            other => Err(err!("unknown job status '{other}'")),
        }
    }

    /// Done, failed or cancelled — no further transitions.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed | JobStatus::Cancelled)
    }
}

/// Final metrics of a finished job — what `job status`/`job wait`
/// render as the `final:` line. Plain JSON numbers round-trip f64
/// bit-exactly (shortest-round-trip formatting), so a line rebuilt from
/// the wire diffs byte-identical against `dpquant train`'s.
#[derive(Clone, Debug)]
pub struct JobSummary {
    /// Validation accuracy after the last epoch.
    pub final_accuracy: f64,
    /// Best validation accuracy over the run.
    pub best_accuracy: f64,
    /// Total ε consumed (training + analysis).
    pub final_epsilon: f64,
    /// ε attributable to analysis probes alone.
    pub analysis_epsilon: f64,
    /// Epochs actually completed.
    pub epochs_run: usize,
    /// Did the privacy budget stop the run early?
    pub truncated: bool,
}

impl JobSummary {
    fn from_record(record: &RunRecord, truncated: bool) -> Self {
        Self {
            final_accuracy: record.final_accuracy,
            best_accuracy: record.best_accuracy,
            final_epsilon: record.final_epsilon,
            analysis_epsilon: record.analysis_epsilon,
            epochs_run: record.epochs.len(),
            truncated,
        }
    }

    /// The summary as the `summary` object of the status document.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("final_accuracy", json::num(self.final_accuracy)),
            ("best_accuracy", json::num(self.best_accuracy)),
            ("final_epsilon", json::num(self.final_epsilon)),
            ("analysis_epsilon", json::num(self.analysis_epsilon)),
            ("epochs_run", json::num(self.epochs_run as f64)),
            ("truncated", Json::Bool(self.truncated)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            final_accuracy: jf64(j, "final_accuracy")?,
            best_accuracy: jf64(j, "best_accuracy")?,
            final_epsilon: jf64(j, "final_epsilon")?,
            analysis_epsilon: jf64(j, "analysis_epsilon")?,
            epochs_run: jusize(j, "epochs_run")?,
            truncated: jbool(j, "truncated")?,
        })
    }
}

/// One epoch-progress entry in a job's ring buffer.
#[derive(Clone, Debug)]
struct JobEvent {
    seq: u64,
    kind: &'static str,
    epoch: usize,
    train_loss: f64,
    val_loss: f64,
    val_accuracy: f64,
    epsilon: f64,
}

/// Fixed-capacity ring of the most recent [`JobEvent`]s.
struct EventRing {
    cap: usize,
    /// Sequence number of `items[0]` (== how many were dropped).
    start: u64,
    items: VecDeque<JobEvent>,
}

impl EventRing {
    fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            start: 0,
            items: VecDeque::new(),
        }
    }

    fn total(&self) -> u64 {
        self.start + self.items.len() as u64
    }

    fn push(&mut self, mut ev: JobEvent) {
        ev.seq = self.total();
        if self.items.len() == self.cap {
            self.items.pop_front();
            self.start += 1;
        }
        self.items.push_back(ev);
    }

    /// ε spent as of the most recent epoch event, if any has arrived.
    fn latest_epsilon(&self) -> Option<f64> {
        self.items.back().map(|e| e.epsilon)
    }

    fn to_json(&self) -> Json {
        json::obj(vec![
            ("total", json::num(self.total() as f64)),
            ("dropped", json::num(self.start as f64)),
            (
                "events",
                Json::Arr(
                    self.items
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("seq", json::num(e.seq as f64)),
                                ("kind", json::s(e.kind)),
                                ("epoch", json::num(e.epoch as f64)),
                                ("train_loss", json::num(e.train_loss)),
                                ("val_loss", json::num(e.val_loss)),
                                ("val_accuracy", json::num(e.val_accuracy)),
                                ("epsilon", json::num(e.epsilon)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

struct Job {
    id: u64,
    cfg: TrainConfig,
    /// Owning tenant, if the submit named one (`None` = anonymous:
    /// unmetered, admitted without a ledger check).
    tenant: Option<String>,
    status: JobStatus,
    epochs_completed: usize,
    error: Option<String>,
    summary: Option<JobSummary>,
    events: EventRing,
    cancel: Arc<AtomicBool>,
    /// True when this entry was rebuilt from a state-dir manifest.
    recovered: bool,
}

impl Job {
    fn new(id: u64, cfg: TrainConfig) -> Self {
        Self {
            id,
            cfg,
            tenant: None,
            status: JobStatus::Queued,
            epochs_completed: 0,
            error: None,
            summary: None,
            events: EventRing::new(EVENT_RING_CAP),
            cancel: Arc::new(AtomicBool::new(false)),
            recovered: false,
        }
    }

    /// Full status view (`GET /v1/jobs/{id}`).
    fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("status", json::s(self.status.name())),
            (
                "tenant",
                self.tenant.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            ("recovered", Json::Bool(self.recovered)),
            ("epochs_completed", json::num(self.epochs_completed as f64)),
            ("epochs_target", json::num(self.cfg.epochs as f64)),
            ("config", config_to_json(&self.cfg)),
            (
                "error",
                self.error.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            (
                "summary",
                self.summary.as_ref().map(JobSummary::to_json).unwrap_or(Json::Null),
            ),
        ])
    }

    /// Compact row (`GET /v1/jobs`).
    fn to_row_json(&self) -> Json {
        json::obj(vec![
            ("id", json::num(self.id as f64)),
            ("status", json::s(self.status.name())),
            (
                "tenant",
                self.tenant.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            ("model", json::s(&self.cfg.model)),
            ("dataset", json::s(&self.cfg.dataset)),
            ("scheduler", json::s(&self.cfg.scheduler)),
            ("seed", json::num(self.cfg.seed as f64)),
            ("epochs_completed", json::num(self.epochs_completed as f64)),
            ("epochs_target", json::num(self.cfg.epochs as f64)),
        ])
    }

    /// Durable manifest (`job-<id>.json`). Events are deliberately not
    /// persisted; outcomes, configs, and cancel intent are — an
    /// acknowledged cancel must survive a crash, or a restarted daemon
    /// would resurrect a job the user was told is stopping.
    fn to_manifest_json(&self) -> Json {
        json::obj(vec![
            ("format", json::s(MANIFEST_FORMAT)),
            ("version", json::num(MANIFEST_VERSION as f64)),
            ("id", json::num(self.id as f64)),
            ("status", json::s(self.status.name())),
            (
                "tenant",
                self.tenant.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            (
                "cancel_requested",
                Json::Bool(self.cancel.load(Ordering::SeqCst)),
            ),
            ("epochs_completed", json::num(self.epochs_completed as f64)),
            ("config", config_to_json(&self.cfg)),
            (
                "error",
                self.error.as_deref().map(json::s).unwrap_or(Json::Null),
            ),
            (
                "summary",
                self.summary.as_ref().map(JobSummary::to_json).unwrap_or(Json::Null),
            ),
        ])
    }

    fn from_manifest_text(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| err!("malformed JSON: {e}"))?;
        let format = j.get("format").and_then(Json::as_str).unwrap_or("<missing>");
        ensure!(
            format == MANIFEST_FORMAT,
            "not a serve job manifest (format '{format}', want '{MANIFEST_FORMAT}')"
        );
        let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        ensure!(
            version == MANIFEST_VERSION,
            "job manifest version {version} is not readable by this build (which reads \
             version {MANIFEST_VERSION})"
        );
        let cfg = config_from_json(
            j.get("config").ok_or_else(|| err!("missing field 'config'"))?,
        )?;
        let mut job = Job::new(jusize(&j, "id")? as u64, cfg);
        job.status = JobStatus::parse(
            j.get("status")
                .and_then(Json::as_str)
                .ok_or_else(|| err!("missing field 'status'"))?,
        )?;
        job.epochs_completed = jusize(&j, "epochs_completed")?;
        job.tenant = match j.get("tenant") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| err!("'tenant' must be null or a string"))?
                    .to_string(),
            ),
        };
        if j.get("cancel_requested").and_then(Json::as_bool) == Some(true) {
            job.cancel.store(true, Ordering::SeqCst);
        }
        job.error = match j.get("error") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| err!("'error' must be null or a string"))?
                    .to_string(),
            ),
        };
        job.summary = match j.get("summary") {
            None | Some(Json::Null) => None,
            Some(v) => Some(JobSummary::from_json(v)?),
        };
        job.recovered = true;
        Ok(job)
    }
}

/// Status counts for `GET /v1/healthz`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobCounts {
    /// Jobs accepted but not started.
    pub queued: usize,
    /// Jobs currently training.
    pub running: usize,
    /// Jobs finished successfully.
    pub done: usize,
    /// Jobs stopped on an error.
    pub failed: usize,
    /// Jobs cancelled.
    pub cancelled: usize,
}

/// Outcome of a cancel request, mapped by the API onto 200/404/409.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// No job with that id.
    NotFound,
    /// Job already reached `status` — nothing to cancel.
    AlreadyOver(&'static str),
    /// Cancelled while still queued: it will never run.
    CancelledQueued,
    /// Flagged while running: the job stops at the next epoch boundary.
    Cancelling,
}

/// Why a submit was refused, typed so the API can map causes onto
/// distinct status codes (400 / 404 / 403). Every variant has already
/// bumped its `serve.jobs_rejected.<reason>` counter when it reaches
/// the caller.
#[derive(Debug)]
pub enum SubmitError {
    /// Config or backend rejected (→ 400), with the same message the
    /// session builder / CLI would print.
    Invalid(Error),
    /// The submit named a tenant the ledger has never seen (→ 404).
    UnknownTenant(String),
    /// The tenant's remaining budget cannot cover the job (→ 403).
    Exhausted {
        /// The tenant that ran dry.
        tenant: String,
        /// Headroom at rejection — bit-identical to the tenant status
        /// document's `remaining_epsilon` (same ledger function).
        remaining_epsilon: f64,
        /// The rejected job's estimated composed ε at the tenant's δ.
        estimated_epsilon: f64,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Invalid(e) => write!(f, "{e:#}"),
            SubmitError::UnknownTenant(t) => write!(f, "no such tenant '{t}'"),
            SubmitError::Exhausted {
                tenant,
                remaining_epsilon,
                estimated_epsilon,
            } => write!(
                f,
                "tenant '{tenant}' budget exhausted: job needs an estimated \
                 ε = {estimated_epsilon} but only {remaining_epsilon} remains"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

// ---------------------------------------------------------------------
// Manager
// ---------------------------------------------------------------------

/// Per-tenant FIFO queues + a round-robin cursor. Pool workers consume
/// *tickets*, and each ticket pops the next job id from the first
/// non-empty tenant bucket after the cursor (BTreeMap order, wrapping) —
/// so tenants with queued work share worker slots evenly regardless of
/// how deep any one backlog is. Anonymous jobs queue under `""`.
#[derive(Default)]
struct Dispatch {
    queues: BTreeMap<String, VecDeque<u64>>,
    last: Option<String>,
}

impl Dispatch {
    fn push(&mut self, tenant: &str, id: u64) {
        self.queues.entry(tenant.to_string()).or_default().push_back(id);
    }

    /// Pop round-robin. Empty buckets are removed eagerly, so every key
    /// present has work and the first candidate always yields.
    fn pop(&mut self) -> Option<u64> {
        let key = match &self.last {
            Some(last) => self
                .queues
                .range::<String, _>((
                    std::ops::Bound::Excluded(last.clone()),
                    std::ops::Bound::Unbounded,
                ))
                .map(|(k, _)| k.clone())
                .next()
                .or_else(|| self.queues.keys().next().cloned()),
            None => self.queues.keys().next().cloned(),
        }?;
        let queue = self.queues.get_mut(&key).expect("key just observed");
        let id = queue.pop_front().expect("non-empty by invariant");
        if queue.is_empty() {
            self.queues.remove(&key);
        }
        self.last = Some(key);
        Some(id)
    }
}

struct Shared {
    state_dir: Option<String>,
    jobs: Mutex<BTreeMap<u64, Job>>,
    next_id: AtomicU64,
    workers: usize,
    ledger: Arc<BudgetLedger>,
    dispatch: Mutex<Dispatch>,
}

impl Shared {
    fn manifest_path(&self, id: u64) -> Option<String> {
        self.state_dir.as_ref().map(|d| format!("{d}/job-{id}.json"))
    }

    fn ck_path(&self, id: u64) -> Option<String> {
        self.state_dir.as_ref().map(|d| format!("{d}/job-{id}.ck.json"))
    }

    /// The job's `dpquant-audit` v1 log, next to its checkpoint.
    /// (`recover` skips any `job-*` stem containing a dot, so audit
    /// logs are never mistaken for manifests.)
    fn audit_path(&self, id: u64) -> Option<String> {
        self.state_dir
            .as_ref()
            .map(|d| format!("{d}/job-{id}.audit.jsonl"))
    }

    /// Write the job's manifest atomically (temp + rename). Persistence
    /// failures are reported on stderr, never panicked on — an
    /// unwritable state dir degrades durability, not service.
    fn persist(&self, job: &Job) {
        let Some(path) = self.manifest_path(job.id) else {
            return;
        };
        let tmp = format!("{path}.tmp");
        let result = std::fs::write(&tmp, job.to_manifest_json().to_string())
            .and_then(|()| std::fs::rename(&tmp, &path));
        if let Err(e) = result {
            eprintln!("serve: failed to persist manifest for job {}: {e}", job.id);
        }
    }
}

/// The daemon's job table + worker pool. All methods take `&self`; the
/// HTTP handler shares the manager behind an `Arc`.
pub struct JobManager {
    shared: Arc<Shared>,
    pool: WorkerPool,
}

impl JobManager {
    /// Start `workers` long-lived workers. With a state dir, recover
    /// every previously known job first: terminal jobs keep their
    /// outcome, queued/running jobs are re-enqueued (in id order) and
    /// resume from their checkpoints.
    pub fn new(workers: usize, state_dir: Option<&str>) -> Result<Self> {
        let state_dir = match state_dir {
            Some(d) => {
                std::fs::create_dir_all(d)
                    .with_context(|| format!("creating state dir {d}"))?;
                Some(d.to_string())
            }
            None => None,
        };
        let ledger = Arc::new(BudgetLedger::open(state_dir.as_deref())?);
        let shared = Arc::new(Shared {
            state_dir,
            jobs: Mutex::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            workers: workers.max(1),
            ledger,
            dispatch: Mutex::new(Dispatch::default()),
        });
        let manager = Self {
            shared,
            pool: WorkerPool::new(workers.max(1)),
        };
        manager.recover()?;
        Ok(manager)
    }

    /// Scan the state dir and rebuild the job table. Fails loudly on an
    /// unreadable manifest — silently dropping a job would violate the
    /// durability contract.
    fn recover(&self) -> Result<()> {
        let Some(dir) = self.shared.state_dir.clone() else {
            return Ok(());
        };
        let mut recovered: Vec<Job> = Vec::new();
        for entry in std::fs::read_dir(&dir).with_context(|| format!("reading state dir {dir}"))? {
            let entry = entry.with_context(|| format!("reading state dir {dir}"))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(stem) = name.strip_prefix("job-").and_then(|s| s.strip_suffix(".json"))
            else {
                continue;
            };
            // Checkpoints (`job-<id>.ck.json`) and torn temp files are
            // not manifests.
            if stem.ends_with(".ck") || stem.contains('.') {
                continue;
            }
            let id: u64 = stem
                .parse()
                .map_err(|_| err!("state dir entry '{name}' has a non-numeric job id"))?;
            let text = std::fs::read_to_string(entry.path())
                .with_context(|| format!("reading job manifest {name}"))?;
            let mut job = Job::from_manifest_text(&text)
                .with_context(|| format!("job manifest {name}"))?;
            ensure!(
                job.id == id,
                "job manifest {name} claims id {} (file name says {id})",
                job.id
            );
            // A job that was queued or mid-flight when the daemon died
            // goes back on the queue; its checkpoint (if any) carries
            // the progress. A cancel acknowledged before the crash is
            // honored here — the job becomes cancelled, not re-run.
            if !job.status.is_terminal() {
                job.status = if job.cancel.load(Ordering::SeqCst) {
                    JobStatus::Cancelled
                } else {
                    JobStatus::Queued
                };
            }
            recovered.push(job);
        }
        recovered.sort_by_key(|j| j.id);
        let mut max_id = 0;
        let mut to_enqueue: Vec<(String, u64)> = Vec::new();
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            for job in recovered {
                max_id = max_id.max(job.id);
                if job.status == JobStatus::Queued {
                    // A re-enqueued tenant job was admitted before the
                    // crash; rebuild its reservation (a pure function
                    // of the config, so remaining ε is identical before
                    // and after the kill) unless it was already debited
                    // — the ledger persists before the job manifest, so
                    // a crash between the two must not hold budget
                    // twice.
                    if let Some(t) = &job.tenant {
                        self.shared.ledger.restore_reservation(t, job.id, &job.cfg);
                    }
                    to_enqueue.push((job.tenant.clone().unwrap_or_default(), job.id));
                }
                self.shared.persist(&job);
                jobs.insert(job.id, job);
            }
        }
        self.shared.next_id.store(max_id + 1, Ordering::SeqCst);
        for (tenant, id) in to_enqueue {
            self.enqueue(&tenant, id);
        }
        Ok(())
    }

    /// Validate, admit (when a tenant is named), and enqueue a new job;
    /// returns its id. Rejects configs the session builder would reject
    /// (same messages), backends a self-contained worker cannot run,
    /// unknown tenants, and jobs the tenant's budget can't cover — each
    /// cause under its own `serve.jobs_rejected.<reason>` counter.
    pub fn submit(
        &self,
        cfg: TrainConfig,
        tenant: Option<&str>,
    ) -> std::result::Result<u64, SubmitError> {
        fn reject(reason: &str) {
            obs::global()
                .counter(&format!("serve.jobs_rejected.{reason}"))
                .inc();
        }
        if !matches!(cfg.backend.as_str(), "native" | "mock") {
            reject("backend");
            return Err(SubmitError::Invalid(err!(
                "backend '{}' is not servable: daemon workers are self-contained; \
                 use backend \"native\" or \"mock\"",
                cfg.backend
            )));
        }
        // |D_train| equals dataset_size by construction (data::train_val
        // draws dataset_size + val_size and splits val off the tail).
        if let Err(e) = validate_config(&cfg, cfg.dataset_size) {
            reject("validation");
            return Err(SubmitError::Invalid(e));
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::SeqCst);
        if let Some(t) = tenant {
            // Admission is atomic inside the ledger (check + reserve
            // under one lock), so racing submits never oversubscribe.
            // A rejected submit burns the id — ids only promise
            // monotonicity, not density.
            match self.shared.ledger.reserve(t, id, &cfg) {
                Ok(_estimated) => {}
                Err(AdmitError::UnknownTenant(t)) => {
                    reject("tenant");
                    return Err(SubmitError::UnknownTenant(t));
                }
                Err(AdmitError::Exhausted {
                    tenant,
                    remaining_epsilon,
                    estimated_epsilon,
                }) => {
                    reject("budget");
                    return Err(SubmitError::Exhausted {
                        tenant,
                        remaining_epsilon,
                        estimated_epsilon,
                    });
                }
            }
        }
        {
            let mut jobs = self.shared.jobs.lock().unwrap();
            let mut job = Job::new(id, cfg);
            job.tenant = tenant.map(str::to_string);
            self.shared.persist(&job);
            jobs.insert(id, job);
        }
        self.enqueue(tenant.unwrap_or(""), id);
        Ok(id)
    }

    /// Queue `id` under its tenant bucket and hand the pool one ticket.
    fn enqueue(&self, tenant: &str, id: u64) {
        self.shared.dispatch.lock().unwrap().push(tenant, id);
        let shared = Arc::clone(&self.shared);
        self.pool.submit(move || {
            // Tickets are 1:1 with queue entries, so the pop never
            // comes up empty; a racing shutdown drops leftovers whole.
            // (The guard must drop before the job runs — an `if let` on
            // the locked pop would hold the dispatch mutex for the
            // whole training run.)
            let next = shared.dispatch.lock().unwrap().pop();
            if let Some(next) = next {
                run_job(&shared, next);
            }
        });
    }

    /// The per-tenant budget ledger (tenant CRUD + status documents).
    pub fn ledger(&self) -> &BudgetLedger {
        &self.shared.ledger
    }

    /// Cancel a job: a queued job never runs, a running job stops at
    /// the next epoch boundary.
    pub fn cancel(&self, id: u64) -> CancelOutcome {
        let mut jobs = self.shared.jobs.lock().unwrap();
        let Some(job) = jobs.get_mut(&id) else {
            return CancelOutcome::NotFound;
        };
        match job.status {
            JobStatus::Queued => {
                job.cancel.store(true, Ordering::SeqCst);
                job.status = JobStatus::Cancelled;
                // A cancelled-while-queued job never spends: release
                // its reservation right here (its ticket will pop the
                // id and no-op on the status check).
                if let Some(t) = &job.tenant {
                    self.shared.ledger.refund(t, id);
                }
                self.shared.persist(job);
                CancelOutcome::CancelledQueued
            }
            JobStatus::Running => {
                job.cancel.store(true, Ordering::SeqCst);
                // Persist the intent: a daemon crash between this ack
                // and the next epoch boundary must not resurrect the
                // job on restart.
                self.shared.persist(job);
                CancelOutcome::Cancelling
            }
            s => CancelOutcome::AlreadyOver(s.name()),
        }
    }

    /// One job's full status document, if it exists.
    pub fn job_json(&self, id: u64) -> Option<Json> {
        self.shared.jobs.lock().unwrap().get(&id).map(Job::to_json)
    }

    /// Summary rows for every job, id order.
    pub fn jobs_json(&self) -> Json {
        Json::Arr(
            self.shared
                .jobs
                .lock()
                .unwrap()
                .values()
                .map(Job::to_row_json)
                .collect(),
        )
    }

    /// A job's buffered event ring as JSON, if the job exists.
    pub fn events_json(&self, id: u64) -> Option<Json> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|j| j.events.to_json())
    }

    /// A job's on-disk `dpquant-audit` log for `GET /v1/jobs/{id}/audit`.
    /// Outer `None`: no such job (404). Inner `None`: the job exists but
    /// has no audit log — the daemon runs without `--state-dir`, or the
    /// job hasn't started its first epoch yet.
    pub fn audit_text(&self, id: u64) -> Option<Option<String>> {
        if !self.shared.jobs.lock().unwrap().contains_key(&id) {
            return None;
        }
        let text = self
            .shared
            .audit_path(id)
            .and_then(|p| std::fs::read_to_string(p).ok());
        Some(text)
    }

    /// Per-status job counts (the healthz payload).
    pub fn counts(&self) -> JobCounts {
        let jobs = self.shared.jobs.lock().unwrap();
        let mut c = JobCounts::default();
        for job in jobs.values() {
            match job.status {
                JobStatus::Queued => c.queued += 1,
                JobStatus::Running => c.running += 1,
                JobStatus::Done => c.done += 1,
                JobStatus::Failed => c.failed += 1,
                JobStatus::Cancelled => c.cancelled += 1,
            }
        }
        c
    }

    /// Worker-thread count (`--jobs N`).
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Jobs waiting in the pool queue (excludes jobs already running).
    pub fn queue_depth(&self) -> usize {
        self.pool.pending()
    }

    /// Per-job privacy spend for `GET /v1/metrics`: `(id, ε)` for every
    /// job with a signal — a finished job's summary ε, else the ε of
    /// its most recent epoch event. Jobs that have not reported yet
    /// (queued, or recovered without a summary) are omitted.
    pub fn epsilons(&self) -> Vec<(u64, f64)> {
        let jobs = self.shared.jobs.lock().unwrap();
        jobs.values()
            .filter_map(|j| {
                let eps = j
                    .summary
                    .as_ref()
                    .map(|s| s.final_epsilon)
                    .or_else(|| j.events.latest_epsilon())?;
                Some((j.id, eps))
            })
            .collect()
    }

    /// Convenience for tests/embedders: the status name of one job.
    pub fn status_of(&self, id: u64) -> Option<&'static str> {
        self.shared
            .jobs
            .lock()
            .unwrap()
            .get(&id)
            .map(|j| j.status.name())
    }

    /// Drain the queue (cancelled jobs are skipped, not run) and join
    /// every worker. In-flight jobs finish first — cancel them before
    /// shutdown for a fast exit.
    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

// ---------------------------------------------------------------------
// Worker body
// ---------------------------------------------------------------------

enum JobEnd {
    /// Ran to completion: the summary plus the session accountant's
    /// actual RDP history — what a tenant's ledger debit records
    /// (reality, not the reservation's worst-case estimate).
    Finished(JobSummary, Vec<StepRecord>),
    Cancelled,
}

/// One job, start (or resume) to finish. Runs on a pool worker.
fn run_job(shared: &Arc<Shared>, id: u64) {
    // Claim: only a still-queued job runs (cancel-while-queued skips).
    let (cfg, cancel) = {
        let mut jobs = shared.jobs.lock().unwrap();
        let Some(job) = jobs.get_mut(&id) else { return };
        if job.status != JobStatus::Queued {
            return;
        }
        job.status = JobStatus::Running;
        shared.persist(job);
        (job.cfg.clone(), Arc::clone(&job.cancel))
    };

    // A panicking executor/session must fail THIS job, not the worker.
    let result = catch_unwind(AssertUnwindSafe(|| train_job(shared, id, &cfg, &cancel)));

    let mut jobs = shared.jobs.lock().unwrap();
    let Some(job) = jobs.get_mut(&id) else { return };
    // Ledger first, job manifest second: the debit is idempotent per
    // job id, so a crash between the two re-runs the job and the second
    // debit no-ops — the budget can never be spent twice, and a crash
    // *before* the debit leaves a non-terminal manifest whose recovery
    // restores the reservation. Cancel/failure/panic never spends.
    match result {
        Ok(Ok(JobEnd::Finished(summary, history))) => {
            if let Some(t) = &job.tenant {
                shared.ledger.debit(t, id, &history);
            }
            job.summary = Some(summary);
            job.status = JobStatus::Done;
        }
        Ok(Ok(JobEnd::Cancelled)) => {
            if let Some(t) = &job.tenant {
                shared.ledger.refund(t, id);
            }
            job.status = JobStatus::Cancelled;
        }
        Ok(Err(e)) => {
            if let Some(t) = &job.tenant {
                shared.ledger.refund(t, id);
            }
            job.error = Some(format!("{e:#}"));
            job.status = JobStatus::Failed;
        }
        Err(payload) => {
            if let Some(t) = &job.tenant {
                shared.ledger.refund(t, id);
            }
            job.error = Some(format!("job panicked: {}", panic_text(payload)));
            job.status = JobStatus::Failed;
        }
    }
    shared.persist(job);
}

fn train_job(
    shared: &Arc<Shared>,
    id: u64,
    cfg: &TrainConfig,
    cancel: &AtomicBool,
) -> Result<JobEnd> {
    let ck_path = shared.ck_path(id);
    let resume_ck = match ck_path.as_deref().filter(|p| std::path::Path::new(p).exists()) {
        Some(p) => Some(Checkpoint::load(p)?),
        None => None,
    };
    // On resume the checkpoint's config is authoritative (it equals the
    // manifest's by construction; trusting it keeps resume bit-exact).
    let cfg = match &resume_ck {
        Some(ck) => ck.config().clone(),
        None => cfg.clone(),
    };
    let (train_ds, val_ds) =
        data::train_val(&cfg.dataset, cfg.dataset_size, cfg.val_size, cfg.seed)?;
    let exec = backend::open_sweep_executor(&cfg, train_ds.example_numel, train_ds.n_classes)?;
    let mut session = match resume_ck {
        Some(ck) => TrainSession::resume_from(ck, exec.as_ref())?,
        None => TrainSession::builder(cfg.clone()).build(exec.as_ref(), &train_ds)?,
    };
    if session.epochs_completed() > 0 {
        let mut jobs = shared.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&id) {
            job.epochs_completed = session.epochs_completed();
        }
    }

    // DP audit trail, next to the checkpoint. Always timing-off: the
    // log must be byte-identical across kill -9 recovery, so it never
    // carries wall-clock payloads. Ordering is the durability story:
    // each epoch's audit line is written+flushed inside `step_epoch`
    // (before the checkpoint lands), so on recovery the checkpoint's
    // epoch count is ≤ the audit line count and `resume` truncates the
    // at-most-one in-flight line — the deterministically re-run epoch
    // appends it back verbatim. Audit failures degrade observability,
    // never the job.
    let audit = match shared.audit_path(id) {
        Some(p) => {
            let resumed = session.epochs_completed() > 0 && std::path::Path::new(&p).exists();
            let opened = if resumed {
                obs::AuditWriter::resume(&p, session.epochs_completed(), false)
            } else {
                obs::AuditWriter::create(&p, false).map(|w| {
                    w.begin_run(session.config(), train_ds.len(), session.accountant_history());
                    w
                })
            };
            match opened {
                Ok(w) => Some(w),
                Err(e) => {
                    eprintln!("serve: job {id}: audit log {p} unavailable: {e:#}");
                    None
                }
            }
        }
        None => None,
    };

    let mut ring = RingSink {
        shared: shared.as_ref(),
        id,
    };
    let mut audit_sink = audit.as_ref().map(obs::AuditSink::new);
    let mut sinks: Vec<&mut dyn EventSink> = vec![&mut ring];
    if let Some(s) = audit_sink.as_mut() {
        sinks.push(s);
    }
    let mut sink = MultiSink::new(sinks);
    loop {
        match session.step_epoch(exec.as_ref(), &train_ds, &val_ds, &mut sink)? {
            EpochOutcome::Finished => break,
            EpochOutcome::Completed { .. } | EpochOutcome::Truncated { .. } => {
                // Checkpoint cadence: every epoch. A kill at ANY point
                // loses at most the epoch in flight, which the resumed
                // session re-runs deterministically.
                if let Some(p) = &ck_path {
                    session.checkpoint(p)?;
                }
                if cancel.load(Ordering::SeqCst) {
                    return Ok(JobEnd::Cancelled);
                }
            }
        }
    }
    if let Some(w) = &audit {
        if let Err(e) = w.finish() {
            eprintln!("serve: job {id}: audit log incomplete: {e:#}");
        }
    }
    let truncated = session.is_truncated();
    let (record, _weights, accountant) = session.finish();
    Ok(JobEnd::Finished(
        JobSummary::from_record(&record, truncated),
        accountant.history().to_vec(),
    ))
}

/// Streams a session's epoch-level events into the job's ring buffer
/// (steps are too fine-grained for a remote observer; epochs are the
/// unit of progress the API reports).
struct RingSink<'a> {
    shared: &'a Shared,
    id: u64,
}

impl EventSink for RingSink<'_> {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        let ev = match event {
            TrainEvent::EpochCompleted { record } => JobEvent {
                seq: 0,
                kind: "epoch",
                epoch: record.epoch,
                train_loss: record.train_loss,
                val_loss: record.val_loss,
                val_accuracy: record.val_accuracy,
                epsilon: record.epsilon,
            },
            TrainEvent::Truncated { epoch, epsilon, .. } => JobEvent {
                seq: 0,
                kind: "truncated",
                epoch: *epoch,
                train_loss: 0.0,
                val_loss: 0.0,
                val_accuracy: 0.0,
                epsilon: *epsilon,
            },
            _ => return,
        };
        let mut jobs = self.shared.jobs.lock().unwrap();
        if let Some(job) = jobs.get_mut(&self.id) {
            if ev.kind == "epoch" {
                job.epochs_completed = ev.epoch + 1;
            }
            job.events.push(ev);
        }
    }
}

// ---------------------------------------------------------------------
// Config wire/manifest schema (shared by POST /v1/jobs and manifests)
// ---------------------------------------------------------------------

/// Serialize a config with the `[train]`-section key names and plain
/// JSON values — the schema `POST /v1/jobs` accepts and manifests
/// store. Plain numbers are lossless here: Rust prints floats in
/// shortest-round-trip form and our parser reads them back bit-exactly.
pub fn config_to_json(cfg: &TrainConfig) -> Json {
    json::obj(vec![
        ("model", json::s(&cfg.model)),
        ("dataset", json::s(&cfg.dataset)),
        ("quantizer", json::s(&cfg.quantizer)),
        ("epochs", json::num(cfg.epochs as f64)),
        ("batch_size", json::num(cfg.batch_size as f64)),
        ("noise_multiplier", json::num(cfg.noise_multiplier)),
        ("clip_norm", json::num(cfg.clip_norm)),
        ("lr", json::num(cfg.lr)),
        ("optimizer", json::s(cfg.optimizer.name())),
        (
            "target_epsilon",
            cfg.target_epsilon.map(json::num).unwrap_or(Json::Null),
        ),
        ("delta", json::num(cfg.delta)),
        ("quant_fraction", json::num(cfg.quant_fraction)),
        ("scheduler", json::s(&cfg.scheduler)),
        ("beta", json::num(cfg.beta)),
        ("analysis_interval", json::num(cfg.analysis_interval as f64)),
        ("analysis_reps", json::num(cfg.analysis_reps as f64)),
        ("analysis_samples", json::num(cfg.analysis_samples as f64)),
        ("sigma_measure", json::num(cfg.sigma_measure)),
        ("clip_measure", json::num(cfg.clip_measure)),
        ("ema_alpha", json::num(cfg.ema_alpha)),
        ("ema_enabled", Json::Bool(cfg.ema_enabled)),
        ("dataset_size", json::num(cfg.dataset_size as f64)),
        ("val_size", json::num(cfg.val_size as f64)),
        ("seed", json::num(cfg.seed as f64)),
        ("physical_batch", json::num(cfg.physical_batch as f64)),
        ("backend", json::s(&cfg.backend)),
    ])
}

/// Parse a config object: `[train]`-section keys, defaults for missing
/// ones, **hard errors** (with did-you-mean) for unknown keys — a typo
/// in a submitted job must not silently train the wrong experiment.
pub fn config_from_json(j: &Json) -> Result<TrainConfig> {
    let obj = j
        .as_obj()
        .ok_or_else(|| err!("'config' must be a JSON object of [train]-section keys"))?;
    for key in obj.keys() {
        if !KNOWN_TRAIN_KEYS.contains(&key.as_str()) {
            let mut msg = format!("unknown config key '{key}'");
            if let Some(near) = cli::nearest(key, KNOWN_TRAIN_KEYS.iter().copied()) {
                msg.push_str(&format!(" (did you mean '{near}'?)"));
            }
            return Err(err!("{msg}"));
        }
    }
    let d = TrainConfig::default();
    Ok(TrainConfig {
        model: jstr_or(j, "model", &d.model)?,
        dataset: jstr_or(j, "dataset", &d.dataset)?,
        quantizer: jstr_or(j, "quantizer", &d.quantizer)?,
        epochs: jusize_or(j, "epochs", d.epochs)?,
        batch_size: jusize_or(j, "batch_size", d.batch_size)?,
        noise_multiplier: jf64_or(j, "noise_multiplier", d.noise_multiplier)?,
        clip_norm: jf64_or(j, "clip_norm", d.clip_norm)?,
        lr: jf64_or(j, "lr", d.lr)?,
        optimizer: match j.get("optimizer") {
            None | Some(Json::Null) => d.optimizer,
            Some(v) => OptimizerKind::parse(
                v.as_str().ok_or_else(|| err!("'optimizer' must be a string"))?,
            )?,
        },
        target_epsilon: match j.get("target_epsilon") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_f64()
                    .ok_or_else(|| err!("'target_epsilon' must be a number or null"))?,
            ),
        },
        delta: jf64_or(j, "delta", d.delta)?,
        quant_fraction: jf64_or(j, "quant_fraction", d.quant_fraction)?,
        scheduler: jstr_or(j, "scheduler", &d.scheduler)?,
        beta: jf64_or(j, "beta", d.beta)?,
        analysis_interval: jusize_or(j, "analysis_interval", d.analysis_interval)?,
        analysis_reps: jusize_or(j, "analysis_reps", d.analysis_reps)?,
        analysis_samples: jusize_or(j, "analysis_samples", d.analysis_samples)?,
        sigma_measure: jf64_or(j, "sigma_measure", d.sigma_measure)?,
        clip_measure: jf64_or(j, "clip_measure", d.clip_measure)?,
        ema_alpha: jf64_or(j, "ema_alpha", d.ema_alpha)?,
        ema_enabled: match j.get("ema_enabled") {
            None | Some(Json::Null) => d.ema_enabled,
            Some(v) => v
                .as_bool()
                .ok_or_else(|| err!("'ema_enabled' must be a bool"))?,
        },
        dataset_size: jusize_or(j, "dataset_size", d.dataset_size)?,
        val_size: jusize_or(j, "val_size", d.val_size)?,
        // Seeds travel as JSON numbers: exact up to 2^53 (the CLI's u64
        // range narrows on this wire; real seeds are small).
        seed: jusize_or(j, "seed", d.seed as usize)? as u64,
        physical_batch: jusize_or(j, "physical_batch", d.physical_batch)?,
        backend: jstr_or(j, "backend", &d.backend)?,
    })
}

// -- tiny JSON field readers ------------------------------------------

fn jf64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| err!("'{key}' must be a number"))
}

fn jusize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as usize)
        .ok_or_else(|| err!("'{key}' must be a non-negative integer"))
}

fn jbool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| err!("'{key}' must be a bool"))
}

fn jstr_or(j: &Json, key: &str, default: &str) -> Result<String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default.to_string()),
        Some(v) => v
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| err!("'{key}' must be a string")),
    }
}

fn jf64_or(j: &Json, key: &str, default: f64) -> Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| err!("'{key}' must be a number")),
    }
}

fn jusize_or(j: &Json, key: &str, default: usize) -> Result<usize> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(v) => v
            .as_f64()
            .filter(|x| *x >= 0.0 && x.fract() == 0.0)
            .map(|x| x as usize)
            .ok_or_else(|| err!("'{key}' must be a non-negative integer")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mock_cfg(seed: u64, epochs: usize) -> TrainConfig {
        TrainConfig {
            backend: "mock".into(),
            dataset_size: 96,
            val_size: 32,
            batch_size: 16,
            physical_batch: 32,
            epochs,
            seed,
            ..TrainConfig::default()
        }
    }

    fn wait_terminal(m: &JobManager, id: u64) -> &'static str {
        for _ in 0..2000 {
            let s = m.status_of(id).unwrap();
            if matches!(s, "done" | "failed" | "cancelled") {
                return s;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        panic!("job {id} never reached a terminal status");
    }

    #[test]
    fn event_ring_drops_oldest_and_counts() {
        let mut ring = EventRing::new(3);
        for epoch in 0..5 {
            ring.push(JobEvent {
                seq: 0,
                kind: "epoch",
                epoch,
                train_loss: 0.0,
                val_loss: 0.0,
                val_accuracy: 0.0,
                epsilon: 0.0,
            });
        }
        assert_eq!(ring.total(), 5);
        assert_eq!(ring.start, 2);
        let j = ring.to_json();
        assert_eq!(j.get("total").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("dropped").unwrap().as_usize(), Some(2));
        let events = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].get("seq").unwrap().as_usize(), Some(2));
        assert_eq!(events[0].get("epoch").unwrap().as_usize(), Some(2));
        assert_eq!(events[2].get("seq").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn config_json_roundtrip_is_exact() {
        let cfg = TrainConfig {
            lr: 0.1 + 0.2, // a value with no short decimal form
            noise_multiplier: 1.0 / 3.0,
            target_epsilon: Some(7.77),
            quantizer: "fp8".into(),
            seed: 12345,
            ..TrainConfig::default()
        };
        let j = config_to_json(&cfg);
        let text = j.to_string();
        let back = config_from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.lr.to_bits(), cfg.lr.to_bits());
        assert_eq!(back.noise_multiplier.to_bits(), cfg.noise_multiplier.to_bits());
        assert_eq!(back.target_epsilon.unwrap().to_bits(), 7.77f64.to_bits());
        assert_eq!(back.quantizer, "fp8");
        assert_eq!(back.seed, 12345);
        assert_eq!(back.epochs, cfg.epochs);
    }

    #[test]
    fn config_from_json_rejects_unknown_keys_with_suggestion() {
        let j = crate::util::json::parse(r#"{"quant_fracton": 0.9}"#).unwrap();
        let e = config_from_json(&j).unwrap_err().to_string();
        assert!(e.contains("quant_fracton"), "{e}");
        assert!(e.contains("did you mean 'quant_fraction'?"), "{e}");
        // Wrong types are named too.
        let j = crate::util::json::parse(r#"{"epochs": "three"}"#).unwrap();
        let e = config_from_json(&j).unwrap_err().to_string();
        assert!(e.contains("epochs"), "{e}");
        // Not an object at all.
        assert!(config_from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn manifest_roundtrip_preserves_outcome() {
        let mut job = Job::new(7, tiny_mock_cfg(3, 2));
        job.status = JobStatus::Done;
        job.epochs_completed = 2;
        job.summary = Some(JobSummary {
            final_accuracy: 0.40625,
            best_accuracy: 0.46875,
            final_epsilon: 1.0 / 3.0,
            analysis_epsilon: 0.125,
            epochs_run: 2,
            truncated: false,
        });
        let text = job.to_manifest_json().to_string();
        let back = Job::from_manifest_text(&text).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.status, JobStatus::Done);
        assert_eq!(back.epochs_completed, 2);
        assert!(back.recovered);
        let s = back.summary.unwrap();
        assert_eq!(s.final_epsilon.to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(s.final_accuracy.to_bits(), 0.40625f64.to_bits());

        // Cancel intent survives the round-trip (crash-proof cancel).
        let cancelling = Job::new(8, tiny_mock_cfg(0, 3));
        cancelling.cancel.store(true, Ordering::SeqCst);
        let back =
            Job::from_manifest_text(&cancelling.to_manifest_json().to_string()).unwrap();
        assert!(back.cancel.load(Ordering::SeqCst));

        // Wrong format/version fail loudly.
        assert!(Job::from_manifest_text("{}").is_err());
        let wrong = text.replace("\"version\":1", "\"version\":99");
        assert!(Job::from_manifest_text(&wrong).is_err());
    }

    #[test]
    fn submit_validates_config_and_backend() {
        let m = JobManager::new(1, None).unwrap();
        // batch_size 0 is the session builder's canonical rejection.
        let mut bad = tiny_mock_cfg(0, 1);
        bad.batch_size = 0;
        let e = m.submit(bad, None).unwrap_err();
        assert!(matches!(e, SubmitError::Invalid(_)), "{e:?}");
        assert!(e.to_string().contains("batch_size"), "{e}");
        // pjrt cannot run in a self-contained worker.
        let mut pjrt = tiny_mock_cfg(0, 1);
        pjrt.backend = "pjrt".into();
        let e = m.submit(pjrt, None).unwrap_err().to_string();
        assert!(e.contains("not servable"), "{e}");
        // Naming a tenant nobody created is its own refusal.
        let e = m.submit(tiny_mock_cfg(0, 1), Some("nobody")).unwrap_err();
        assert!(matches!(e, SubmitError::UnknownTenant(_)), "{e:?}");
        assert_eq!(m.counts(), JobCounts::default());
        m.shutdown();
    }

    #[test]
    fn submit_runs_to_done_with_events() {
        let m = JobManager::new(2, None).unwrap();
        let id = m.submit(tiny_mock_cfg(5, 2), None).unwrap();
        assert_eq!(id, 1);
        assert_eq!(wait_terminal(&m, id), "done");
        let j = m.job_json(id).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("done"));
        assert_eq!(j.get("epochs_completed").unwrap().as_usize(), Some(2));
        let summary = j.get("summary").unwrap();
        assert_eq!(summary.get("epochs_run").unwrap().as_usize(), Some(2));
        let events = m.events_json(id).unwrap();
        assert_eq!(events.get("total").unwrap().as_usize(), Some(2));
        let c = m.counts();
        assert_eq!(c.done, 1);
        m.shutdown();
    }

    #[test]
    fn failing_job_is_marked_failed_not_fatal() {
        let m = JobManager::new(1, None).unwrap();
        // An unknown dataset passes config validation (datasets resolve
        // at run time) and then fails in the worker.
        let mut cfg = tiny_mock_cfg(0, 1);
        cfg.dataset = "imagenet".into();
        let id = m.submit(cfg, None).unwrap();
        assert_eq!(wait_terminal(&m, id), "failed");
        let j = m.job_json(id).unwrap();
        let error = j.get("error").unwrap().as_str().unwrap().to_string();
        assert!(error.contains("unknown dataset"), "{error}");
        // The worker survives: the next job still runs.
        let id2 = m.submit(tiny_mock_cfg(1, 1), None).unwrap();
        assert_eq!(wait_terminal(&m, id2), "done");
        m.shutdown();
    }

    #[test]
    fn cancel_queued_job_never_runs() {
        let m = JobManager::new(1, None).unwrap();
        // Head-of-line job long enough to keep the single worker busy.
        let head = m.submit(tiny_mock_cfg(0, 50), None).unwrap();
        let queued = m.submit(tiny_mock_cfg(1, 1), None).unwrap();
        // The cancel may land while the job is still queued (the usual
        // case: the lone worker is busy with `head`) or, in a slow-start
        // race, after it was claimed — both end in "cancelled".
        let outcome = m.cancel(queued);
        assert!(
            matches!(outcome, CancelOutcome::CancelledQueued | CancelOutcome::Cancelling),
            "{outcome:?}"
        );
        // Cancel the head too so the drain below is fast.
        m.cancel(head);
        assert_eq!(m.cancel(999), CancelOutcome::NotFound);
        assert_eq!(wait_terminal(&m, head), "cancelled");
        assert_eq!(wait_terminal(&m, queued), "cancelled");
        // Drained worker must NOT have run the queued-then-cancelled
        // job: a run would have flipped it to done or pushed events.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(m.status_of(queued), Some("cancelled"));
        let events = m.events_json(queued).unwrap();
        assert_eq!(events.get("total").unwrap().as_usize(), Some(0));
        m.shutdown();
    }

    #[test]
    fn dispatch_round_robins_across_tenants() {
        let mut d = Dispatch::default();
        // alice floods the queue; bob and an anonymous job arrive after.
        for id in 1..=4 {
            d.push("alice", id);
        }
        d.push("bob", 10);
        d.push("", 20);
        // BTreeMap order is "" < "alice" < "bob": each tenant with work
        // gets a slot per cycle, however deep alice's backlog is.
        let order: Vec<u64> = std::iter::from_fn(|| d.pop()).collect();
        assert_eq!(order, vec![20, 1, 10, 2, 3, 4]);
        assert!(d.pop().is_none());
    }

    #[test]
    fn tenant_job_reserves_then_debits_actual_spend() {
        let m = JobManager::new(1, None).unwrap();
        m.ledger().create_tenant("acme", 50.0, 1e-5).unwrap();
        let id = m.submit(tiny_mock_cfg(2, 2), Some("acme")).unwrap();
        let doc = m.ledger().status("acme").unwrap();
        assert!(doc.reserved_epsilon > 0.0 || doc.debited_jobs == 1);
        assert_eq!(wait_terminal(&m, id), "done");
        let doc = m.ledger().status("acme").unwrap();
        assert_eq!(doc.open_reservations, 0);
        assert_eq!(doc.debited_jobs, 1);
        assert!(doc.spent_epsilon > 0.0);
        // The status document carries the owner.
        let j = m.job_json(id).unwrap();
        assert_eq!(j.get("tenant").unwrap().as_str(), Some("acme"));
        m.shutdown();
    }

    #[test]
    fn served_job_audit_replays_bitwise_and_matches_the_ledger_debit() {
        let dir = std::env::temp_dir().join(format!("dpquant-jobs-audit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_str().unwrap().to_string();
        let m = JobManager::new(1, Some(&dir_s)).unwrap();
        let cfg = tiny_mock_cfg(4, 3);
        m.ledger().create_tenant("acme", 50.0, cfg.delta).unwrap();
        let id = m.submit(cfg, Some("acme")).unwrap();
        assert_eq!(wait_terminal(&m, id), "done");

        // The audit log is served, checks, and replays bitwise.
        let text = m.audit_text(id).unwrap().expect("audit log written");
        let path = format!("{dir_s}/job-{id}.audit.jsonl");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), text);
        let stats = obs::audit::check(&path).unwrap();
        assert_eq!(stats.epochs, 3);
        let replay = obs::audit::replay(&path).unwrap();

        // Replayed final ε == the job summary's ε == (single job, tenant
        // δ = job δ) the ledger's debited spend — ONE composition path.
        let j = m.job_json(id).unwrap();
        let final_epsilon = j
            .get("summary")
            .unwrap()
            .get("final_epsilon")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(replay.final_epsilon.to_bits(), final_epsilon.to_bits());
        let tenant = m.ledger().status("acme").unwrap();
        assert_eq!(tenant.spent_epsilon.to_bits(), replay.final_epsilon.to_bits());
        // And the debit timeline event carries the same ε.
        let debit = tenant
            .timeline
            .iter()
            .find(|e| e.kind == super::super::ledger::TimelineKind::Debit)
            .expect("debit event recorded");
        assert_eq!(debit.epsilon.to_bits(), replay.final_epsilon.to_bits());

        // Unknown jobs are a 404; known jobs without a log are an inner
        // None (no state dir).
        assert!(m.audit_text(999).is_none());
        m.shutdown();
        let m2 = JobManager::new(1, None).unwrap();
        let id2 = m2.submit(tiny_mock_cfg(0, 1), None).unwrap();
        assert_eq!(wait_terminal(&m2, id2), "done");
        assert_eq!(m2.audit_text(id2), Some(None));
        m2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cancelled_queued_tenant_job_refunds_in_full() {
        let m = JobManager::new(1, None).unwrap();
        m.ledger().create_tenant("acme", 50.0, 1e-5).unwrap();
        // Anonymous head keeps the lone worker busy; the tenant job
        // waits behind it.
        let head = m.submit(tiny_mock_cfg(0, 50), None).unwrap();
        let queued = m.submit(tiny_mock_cfg(1, 1), Some("acme")).unwrap();
        let reserved = m.ledger().status("acme").unwrap().reserved_epsilon;
        assert!(reserved > 0.0);
        m.cancel(queued);
        m.cancel(head);
        assert_eq!(wait_terminal(&m, queued), "cancelled");
        let doc = m.ledger().status("acme").unwrap();
        assert_eq!(doc.open_reservations, 0);
        assert_eq!(doc.spent_epsilon, 0.0);
        assert_eq!(doc.remaining_epsilon.to_bits(), 50.0f64.to_bits());
        m.shutdown();
    }
}
