//! Client half of the serving story: a typed [`Client`] over
//! [`http::http_call`](super::http::http_call) plus the `dpquant job`
//! CLI verbs (`submit | list | status | events | audit | cancel |
//! wait`) and the `dpquant tenant` verbs (`create | list | status`),
//! so CI and
//! operators drive the daemon with the same binary — no curl.
//!
//! `job status`/`job wait` rebuild the daemon's summary into the exact
//! `final:` line `dpquant train` prints (one shared formatter,
//! [`final_metrics_line`]); plain JSON numbers round-trip f64
//! bit-exactly, so the two lines diff byte-identical for the same
//! config + seed — the contract CI's `serve-smoke` job checks.

use std::time::{Duration, Instant};

use super::http::{http_call, http_call_raw};
use super::jobs::config_to_json;
use crate::cli::{self, Args};
use crate::config::{ServeConfig, TrainConfig, CONFIG_ARG_KEYS};
use crate::metrics::{final_metrics_line, Table};
use crate::util::error::{err, Result};
use crate::util::json::{self, Json};

/// Typed access to a running daemon.
pub struct Client {
    addr: String,
}

impl Client {
    /// A client for the daemon at `addr` (`host:port`).
    pub fn new(addr: &str) -> Self {
        Self {
            addr: addr.to_string(),
        }
    }

    fn get(&self, path: &str) -> Result<Json> {
        expect_2xx(http_call(&self.addr, "GET", path, None)?)
    }

    fn post(&self, path: &str, body: Option<&Json>) -> Result<Json> {
        expect_2xx(http_call(&self.addr, "POST", path, body)?)
    }

    /// Submit a config anonymously; returns the assigned job id.
    pub fn submit(&self, cfg: &TrainConfig) -> Result<u64> {
        self.submit_as(cfg, None)
    }

    /// Submit a config, optionally on a tenant's budget. A budget
    /// refusal surfaces as an error carrying the daemon's 403 message
    /// (use raw [`http_call`] to read the structured body).
    pub fn submit_as(&self, cfg: &TrainConfig, tenant: Option<&str>) -> Result<u64> {
        let mut fields = vec![("config", config_to_json(cfg))];
        if let Some(t) = tenant {
            fields.push(("tenant", json::s(t)));
        }
        let body = json::obj(fields);
        let resp = self.post("/v1/jobs", Some(&body))?;
        resp.get("id")
            .and_then(Json::as_usize)
            .map(|id| id as u64)
            .ok_or_else(|| err!("daemon accepted the job but sent no id: {resp}"))
    }

    /// `POST /v1/tenants` — create a tenant with a lifetime (ε, δ)
    /// budget; returns its status document.
    pub fn create_tenant(&self, id: &str, budget_epsilon: f64, delta: f64) -> Result<Json> {
        let body = json::obj(vec![
            ("id", json::s(id)),
            ("budget_epsilon", json::num(budget_epsilon)),
            ("delta", json::num(delta)),
        ]);
        self.post("/v1/tenants", Some(&body))
    }

    /// `GET /v1/tenants` — every tenant's status document.
    pub fn tenants(&self) -> Result<Json> {
        self.get("/v1/tenants")
    }

    /// `GET /v1/tenants/{id}` — one tenant's status document.
    pub fn tenant_status(&self, id: &str) -> Result<Json> {
        self.get(&format!("/v1/tenants/{id}"))
    }

    /// `GET /v1/jobs` — every job, one summary row each.
    pub fn jobs(&self) -> Result<Json> {
        self.get("/v1/jobs")
    }

    /// `GET /v1/jobs/{id}` — one job's full status document.
    pub fn job_status(&self, id: u64) -> Result<Json> {
        self.get(&format!("/v1/jobs/{id}"))
    }

    /// `GET /v1/jobs/{id}/events` — the job's buffered event ring.
    pub fn events(&self, id: u64) -> Result<Json> {
        self.get(&format!("/v1/jobs/{id}/events"))
    }

    /// `POST /v1/jobs/{id}/cancel` — request cancellation.
    pub fn cancel(&self, id: u64) -> Result<Json> {
        self.post(&format!("/v1/jobs/{id}/cancel"), None)
    }

    /// `GET /v1/jobs/{id}/audit` — the job's raw `dpquant-audit` v1
    /// JSONL stream, byte-for-byte as persisted under `--state-dir`
    /// (pipe into `dpquant audit check/replay`).
    pub fn audit(&self, id: u64) -> Result<String> {
        let (status, body) =
            http_call_raw(&self.addr, "GET", &format!("/v1/jobs/{id}/audit"), None)?;
        let text = String::from_utf8(body)
            .map_err(|_| err!("daemon sent a non-UTF-8 audit body"))?;
        if (200..300).contains(&status) {
            return Ok(text);
        }
        let msg = json::parse(&text)
            .ok()
            .and_then(|j| j.get("error").and_then(Json::as_str).map(str::to_string))
            .unwrap_or(text);
        Err(err!("daemon returned {status}: {msg}"))
    }

    /// `GET /v1/healthz` — daemon liveness + format versions + job counts.
    pub fn healthz(&self) -> Result<Json> {
        self.get("/v1/healthz")
    }

    /// `GET /v1/metrics` — the live `dpquant-metrics` v1 snapshot
    /// (job counts/throughput, queue depth, per-job ε, and the global
    /// pool/HTTP/kernel telemetry registry).
    pub fn metrics(&self) -> Result<Json> {
        self.get("/v1/metrics")
    }

    /// Poll until the job reaches a terminal status; returns its final
    /// status document.
    pub fn wait(&self, id: u64, timeout: Duration, poll: Duration) -> Result<Json> {
        let t0 = Instant::now();
        loop {
            let status = self.job_status(id)?;
            let s = status_str(&status);
            if matches!(s, "done" | "failed" | "cancelled") {
                return Ok(status);
            }
            if t0.elapsed() > timeout {
                return Err(err!(
                    "timed out after {:.0}s waiting for job {id} (status '{s}')",
                    timeout.as_secs_f64()
                ));
            }
            std::thread::sleep(poll);
        }
    }
}

fn expect_2xx((status, body): (u16, Json)) -> Result<Json> {
    if (200..300).contains(&status) {
        return Ok(body);
    }
    let msg = body
        .get("error")
        .and_then(Json::as_str)
        .map(str::to_string)
        .unwrap_or_else(|| body.to_string());
    Err(err!("daemon returned {status}: {msg}"))
}

fn status_str(j: &Json) -> &str {
    j.get("status").and_then(Json::as_str).unwrap_or("<unknown>")
}

/// The `final:` line for a finished job's status document — the SAME
/// bytes `dpquant train` prints for that config (shared formatter, f64
/// values bit-exact off the wire). None until the job is done.
pub fn final_line_from_status(status: &Json) -> Option<String> {
    let s = status.get("summary")?;
    Some(final_metrics_line(
        s.get("final_accuracy")?.as_f64()?,
        s.get("final_epsilon")?.as_f64()?,
        s.get("analysis_epsilon")?.as_f64()?,
        s.get("epochs_run")?.as_usize()?,
    ))
}

// ---------------------------------------------------------------------
// CLI verbs
// ---------------------------------------------------------------------

const JOB_SUBCOMMANDS: &[&str] = &["submit", "list", "status", "events", "audit", "cancel", "wait"];

const USAGE: &str = "\
usage: dpquant job <submit|list|status|events|audit|cancel|wait> [--addr HOST:PORT]
  submit [train flags / --config file] [--tenant ID]
                                         validate + enqueue a job, print its id
                                         (--tenant: charge the job to that
                                          tenant's budget; refused when it
                                          can't cover the estimated ε)
  list                                   all jobs, one row each
  status <id>                            full status (+ final metrics when done)
  events <id>                            the job's epoch-progress ring buffer
  audit <id>                             the job's dpquant-audit JSONL stream
                                         (verbatim; pipe into `dpquant audit`)
  cancel <id>                            cancel a queued/running job
  wait <id>... [--timeout-sec N] [--poll-ms N]   block until done, print final metrics";

/// `dpquant job <verb>` entry point (dispatched from `main.rs`).
pub fn run(args: &Args) -> Result<()> {
    let Some(sub) = args.subcommand() else {
        println!("{USAGE}");
        return Ok(());
    };
    let addr = args
        .get("addr")
        .map(str::to_string)
        .unwrap_or_else(|| ServeConfig::default().addr);
    let client = Client::new(&addr);
    match sub {
        "submit" => {
            let mut opts: Vec<&str> = CONFIG_ARG_KEYS.to_vec();
            opts.push("addr");
            opts.push("tenant");
            args.require_known("job submit", &opts, &["no-ema"])?;
            let cfg = TrainConfig::from_args(args)?;
            let tenant = args.get("tenant");
            let id = client.submit_as(&cfg, tenant)?;
            match tenant {
                Some(t) => println!("submitted job {id} for tenant {t} (status queued)"),
                None => println!("submitted job {id} (status queued)"),
            }
            println!("  follow with: dpquant job status {id} --addr {addr}");
            Ok(())
        }
        "list" => {
            args.require_known("job list", &["addr"], &[])?;
            let jobs = client.jobs()?;
            let rows = jobs
                .get("jobs")
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("daemon sent no job list: {jobs}"))?;
            let mut t = Table::new(&[
                "id", "status", "model", "dataset", "scheduler", "seed", "epochs",
            ]);
            for r in rows {
                t.row(vec![
                    fmt_num(r, "id"),
                    fmt_str(r, "status"),
                    fmt_str(r, "model"),
                    fmt_str(r, "dataset"),
                    fmt_str(r, "scheduler"),
                    fmt_num(r, "seed"),
                    format!("{}/{}", fmt_num(r, "epochs_completed"), fmt_num(r, "epochs_target")),
                ]);
            }
            t.print();
            Ok(())
        }
        "status" => {
            args.require_known("job status", &["addr"], &[])?;
            let id = positional_id(args, "job status")?;
            let status = client.job_status(id)?;
            print_status(id, &status);
            Ok(())
        }
        "events" => {
            args.require_known("job events", &["addr"], &[])?;
            let id = positional_id(args, "job events")?;
            let events = client.events(id)?;
            print_events(id, &events);
            Ok(())
        }
        "audit" => {
            args.require_known("job audit", &["addr"], &[])?;
            let id = positional_id(args, "job audit")?;
            // Verbatim bytes, no trailing println: the stream already
            // ends in a newline and `dpquant job audit N > f.jsonl`
            // must byte-match the daemon's on-disk file.
            print!("{}", client.audit(id)?);
            Ok(())
        }
        "cancel" => {
            args.require_known("job cancel", &["addr"], &[])?;
            let id = positional_id(args, "job cancel")?;
            let resp = client.cancel(id)?;
            println!("job {id}: {}", status_str(&resp));
            Ok(())
        }
        "wait" => {
            args.require_known("job wait", &["addr", "timeout-sec", "poll-ms"], &[])?;
            let timeout = Duration::from_secs(args.u64_or("timeout-sec", 600)?);
            let poll = Duration::from_millis(args.u64_or("poll-ms", 150)?.max(1));
            let ids = positional_ids(args, "job wait")?;
            for id in ids {
                let status = client.wait(id, timeout, poll)?;
                match status_str(&status) {
                    "done" => {
                        println!("job {id}: done");
                        if let Some(line) = final_line_from_status(&status) {
                            println!("{line}");
                        }
                    }
                    "cancelled" => println!("job {id}: cancelled"),
                    other => {
                        let error = status
                            .get("error")
                            .and_then(Json::as_str)
                            .unwrap_or("<no error recorded>");
                        return Err(err!("job {id} {other}: {error}"));
                    }
                }
            }
            Ok(())
        }
        other => Err(cli::unknown_command_error("job subcommand", other, JOB_SUBCOMMANDS).into()),
    }
}

const TENANT_SUBCOMMANDS: &[&str] = &["create", "list", "status"];

const TENANT_USAGE: &str = "\
usage: dpquant tenant <create|list|status> [--addr HOST:PORT]
  create <id> --budget-epsilon EPS [--delta D]   create a tenant with a lifetime
                                                 (ε, δ) budget (δ default 1e-5)
  list                                           every tenant: budget/spent/remaining
  status <id>                                    one tenant's full budget document
estimate a job's ε cost before spending: dpquant cost [train flags]";

/// `dpquant tenant <verb>` entry point (dispatched from `main.rs`).
pub fn run_tenant(args: &Args) -> Result<()> {
    let Some(sub) = args.subcommand() else {
        println!("{TENANT_USAGE}");
        return Ok(());
    };
    let addr = args
        .get("addr")
        .map(str::to_string)
        .unwrap_or_else(|| ServeConfig::default().addr);
    let client = Client::new(&addr);
    match sub {
        "create" => {
            args.require_known("tenant create", &["addr", "budget-epsilon", "delta"], &[])?;
            let id = positional_tenant(args, "tenant create")?;
            let budget: f64 = args
                .get("budget-epsilon")
                .ok_or_else(|| err!("'tenant create' needs --budget-epsilon EPS"))?
                .parse()
                .map_err(|_| err!("--budget-epsilon must be a number"))?;
            let delta = args.f64_or("delta", TrainConfig::default().delta)?;
            let doc = client.create_tenant(id, budget, delta)?;
            println!("created tenant {id} (budget ε = {budget}, δ = {delta})");
            print_tenant(&doc);
            Ok(())
        }
        "list" => {
            args.require_known("tenant list", &["addr"], &[])?;
            let resp = client.tenants()?;
            let rows = resp
                .get("tenants")
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("daemon sent no tenant list: {resp}"))?;
            let mut t = Table::new(&[
                "tenant", "budget_eps", "spent_eps", "reserved_eps", "remaining_eps", "jobs",
            ]);
            for r in rows {
                t.row(vec![
                    fmt_str(r, "id"),
                    fmt_eps(r, "budget_epsilon"),
                    fmt_eps(r, "spent_epsilon"),
                    fmt_eps(r, "reserved_epsilon"),
                    fmt_eps(r, "remaining_epsilon"),
                    fmt_num(r, "debited_jobs"),
                ]);
            }
            t.print();
            Ok(())
        }
        "status" => {
            args.require_known("tenant status", &["addr"], &[])?;
            let id = positional_tenant(args, "tenant status")?;
            let doc = client.tenant_status(id)?;
            print_tenant(&doc);
            Ok(())
        }
        other => {
            Err(cli::unknown_command_error("tenant subcommand", other, TENANT_SUBCOMMANDS).into())
        }
    }
}

fn positional_tenant<'a>(args: &'a Args, what: &str) -> Result<&'a str> {
    let ids: Vec<&String> = args.positional.iter().skip(2).collect();
    match ids.as_slice() {
        [one] => Ok(one.as_str()),
        [] => Err(err!("'{what}' needs a tenant id (see `dpquant tenant`)")),
        _ => Err(err!("'{what}' takes exactly one tenant id")),
    }
}

/// Render a tenant status document. The ε lines use Rust's default
/// (shortest-round-trip) float formatting on purpose: scripts diffing
/// remaining budget across a daemon restart need every bit.
fn print_tenant(doc: &Json) {
    let f = |key: &str| {
        doc.get(key)
            .and_then(Json::as_f64)
            .map(|v| v.to_string())
            .unwrap_or_else(|| "?".into())
    };
    println!(
        "tenant {}: budget ε = {} at δ = {}",
        doc.get("id").and_then(Json::as_str).unwrap_or("?"),
        f("budget_epsilon"),
        f("delta"),
    );
    println!(
        "  spent ε     = {}  ({} jobs debited)",
        f("spent_epsilon"),
        fmt_num(doc, "debited_jobs")
    );
    println!(
        "  reserved ε  = {}  ({} open reservations)",
        f("reserved_epsilon"),
        fmt_num(doc, "open_reservations")
    );
    println!("  remaining ε = {}", f("remaining_epsilon"));
    let timeline = doc.get("timeline").and_then(Json::as_arr).unwrap_or(&[]);
    if !timeline.is_empty() {
        println!("  timeline ({} events):", timeline.len());
        for e in timeline {
            let g = |key: &str| {
                e.get(key)
                    .and_then(Json::as_f64)
                    .map(|v| v.to_string())
                    .unwrap_or_else(|| "?".into())
            };
            println!(
                "    {:<7} job {:<4} ε = {}  remaining ε = {}",
                e.get("kind").and_then(Json::as_str).unwrap_or("?"),
                fmt_num(e, "job"),
                g("epsilon"),
                g("remaining"),
            );
        }
    }
}

/// Short fixed-precision ε for table cells (full precision lives in
/// `tenant status`).
fn fmt_eps(j: &Json, key: &str) -> String {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|v| format!("{v:.4}"))
        .unwrap_or_else(|| "?".into())
}

fn positional_ids(args: &Args, what: &str) -> Result<Vec<u64>> {
    let ids: Vec<&String> = args.positional.iter().skip(2).collect();
    if ids.is_empty() {
        return Err(err!("'{what}' needs at least one job id (see `dpquant job`)"));
    }
    ids.iter()
        .map(|s| {
            s.parse::<u64>()
                .map_err(|_| err!("'{what}': '{s}' is not a job id"))
        })
        .collect()
}

fn positional_id(args: &Args, what: &str) -> Result<u64> {
    let ids = positional_ids(args, what)?;
    if ids.len() > 1 {
        return Err(err!("'{what}' takes exactly one job id"));
    }
    Ok(ids[0])
}

fn print_status(id: u64, status: &Json) {
    let s = status_str(status);
    let cfg = status.get("config");
    let describe = |key: &str| -> String {
        cfg.and_then(|c| c.get(key))
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string()
    };
    let seed = cfg
        .and_then(|c| c.get("seed"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    println!(
        "job {id}: {s} (model={} dataset={} scheduler={} seed={seed}, epochs {}/{}{})",
        describe("model"),
        describe("dataset"),
        describe("scheduler"),
        status.get("epochs_completed").and_then(Json::as_usize).unwrap_or(0),
        status.get("epochs_target").and_then(Json::as_usize).unwrap_or(0),
        if status.get("recovered").and_then(Json::as_bool) == Some(true) {
            ", recovered"
        } else {
            ""
        }
    );
    if let Some(error) = status.get("error").and_then(Json::as_str) {
        println!("error: {error}");
    }
    if let Some(line) = final_line_from_status(status) {
        println!("{line}");
    }
}

fn print_events(id: u64, events: &Json) {
    let total = events.get("total").and_then(Json::as_usize).unwrap_or(0);
    let dropped = events.get("dropped").and_then(Json::as_usize).unwrap_or(0);
    let list = events.get("events").and_then(Json::as_arr).unwrap_or(&[]);
    println!(
        "job {id}: {total} events ({} shown, {dropped} dropped off the ring)",
        list.len()
    );
    for e in list {
        let epoch = e.get("epoch").and_then(Json::as_usize).unwrap_or(0);
        match e.get("kind").and_then(Json::as_str) {
            Some("truncated") => println!(
                "  epoch {epoch:>3}  TRUNCATED at eps {:.3}",
                e.get("epsilon").and_then(Json::as_f64).unwrap_or(0.0)
            ),
            _ => println!(
                "  epoch {epoch:>3}  loss {:.4}  val_acc {:.3}  eps {:.3}",
                e.get("train_loss").and_then(Json::as_f64).unwrap_or(0.0),
                e.get("val_accuracy").and_then(Json::as_f64).unwrap_or(0.0),
                e.get("epsilon").and_then(Json::as_f64).unwrap_or(0.0)
            ),
        }
    }
}

fn fmt_str(j: &Json, key: &str) -> String {
    j.get(key).and_then(Json::as_str).unwrap_or("?").to_string()
}

fn fmt_num(j: &Json, key: &str) -> String {
    j.get(key)
        .and_then(Json::as_usize)
        .map(|v| v.to_string())
        .unwrap_or_else(|| "?".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_line_roundtrips_through_wire_json() {
        // A summary as the daemon would serialize it, through text and
        // back: the rebuilt line must match the direct formatting.
        let summary = json::obj(vec![
            ("final_accuracy", json::num(0.40625)),
            ("final_epsilon", json::num(1.0 / 3.0)),
            ("analysis_epsilon", json::num(0.1 + 0.2)),
            ("epochs_run", json::num(4.0)),
        ]);
        let status = json::obj(vec![("summary", summary)]);
        let wire = status.to_string();
        let parsed = json::parse(&wire).unwrap();
        assert_eq!(
            final_line_from_status(&parsed).unwrap(),
            final_metrics_line(0.40625, 1.0 / 3.0, 0.1 + 0.2, 4)
        );
        // No summary (job not done yet) -> no line.
        assert!(final_line_from_status(&json::obj(vec![])).is_none());
    }

    #[test]
    fn positional_ids_parse_and_reject() {
        let args = Args::parse(
            "job wait 3 7 --timeout-sec 5".split_whitespace().map(String::from),
        )
        .unwrap();
        assert_eq!(positional_ids(&args, "job wait").unwrap(), vec![3, 7]);
        let args = Args::parse("job status".split_whitespace().map(String::from)).unwrap();
        assert!(positional_id(&args, "job status").is_err());
        let args = Args::parse("job status x".split_whitespace().map(String::from)).unwrap();
        assert!(positional_id(&args, "job status").is_err());
        let args = Args::parse("job status 1 2".split_whitespace().map(String::from)).unwrap();
        assert!(positional_id(&args, "job status").is_err());
    }

    #[test]
    fn positional_tenant_parses_and_rejects() {
        let args =
            Args::parse("tenant status acme --addr x".split_whitespace().map(String::from))
                .unwrap();
        assert_eq!(positional_tenant(&args, "tenant status").unwrap(), "acme");
        let args = Args::parse("tenant list".split_whitespace().map(String::from)).unwrap();
        assert!(positional_tenant(&args, "tenant status").is_err());
        let args =
            Args::parse("tenant status a b".split_whitespace().map(String::from)).unwrap();
        assert!(positional_tenant(&args, "tenant status").is_err());
    }
}
