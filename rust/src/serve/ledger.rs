//! The per-tenant privacy-budget ledger: durable (ε, δ) accounting for
//! the job daemon (DESIGN.md §15).
//!
//! A **tenant** owns a lifetime `(budget_epsilon, delta)` budget. The
//! ledger moves every tenant-owned job through a three-state machine:
//!
//! ```text
//!   submit ──reserve──▶ open reservation ──debit───▶ spent (durable)
//!                               │
//!                               └─refund──▶ gone (cancel / failure)
//! ```
//!
//! * **reserve** — admission control. The job's worst-case schedule is
//!   derived from its config ([`schedule_cost`]: all training steps
//!   plus every analysis-eligible epoch, truncation ignored) and
//!   composed — at the RDP level, through the same
//!   [`RdpAccountant`] math a live run uses — with the tenant's spent
//!   history and every open reservation. If the composed ε exceeds the
//!   budget the submit is rejected; the `remaining_epsilon` in the
//!   rejection is computed by the **same function** that feeds
//!   `GET /v1/tenants/{id}`, so the two agree bit-for-bit.
//! * **debit** — on successful completion the job's *actual* accountant
//!   history (from `TrainSession::finish`) is appended to the tenant's
//!   spent records and the reservation is released. Debits are
//!   idempotent per job id (`debited_jobs`), so a crash between the
//!   ledger write and the job-manifest write can never double-spend.
//! * **refund** — cancel, failure, or panic releases the reservation
//!   without spending.
//!
//! **Why records, not a running ε.** ε does not add: composing two runs
//! through one accountant is tighter than summing their individual ε's.
//! The ledger therefore stores per-tenant RDP *step records* and
//! re-derives ε by replay, which makes a tenant's spend after two
//! sequential jobs bit-equal to one accountant composing both runs
//! (`tests/privacy_golden.rs` pins this).
//!
//! **Durability.** With a state dir, tenants + spent histories +
//! debited-job ids + spend timelines persist to `ledger.json`
//! (`dpquant-serve-ledger` v1, atomic temp+rename, floats as IEEE-754
//! hex — the checkpoint idiom), rewritten on every mutation (reserve,
//! debit, refund). Reservations are deliberately **not** persisted:
//! they are reconstructed during restart recovery for every re-enqueued
//! tenant-owned job (a pure function of the job's config, so the
//! remaining ε is identical before and after a `kill -9`), and a
//! reservation whose job died terminally can therefore never leak.
//!
//! **The timeline.** Each tenant additionally carries an append-only
//! [`TimelineEvent`] log — every reserve/debit/refund with the
//! post-event remaining ε — served by `GET /v1/tenants/{id}`. Because
//! events are appended exactly where they become durable (and recovery
//! appends nothing), the timeline a client reads after a `kill -9` is
//! byte-identical to the uninterrupted one; CI's `audit-smoke` job
//! diffs exactly that.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

use crate::config::TrainConfig;
use crate::coordinator::adaptive::{self, AdaptivePolicy, EpochKnobs};
use crate::obs;
use crate::privacy::{Mechanism, RdpAccountant, StepRecord};
use crate::util::error::{ensure, err, Result};
use crate::util::json::{self, Json};

/// On-disk ledger format tag (`ledger.json` in the state dir).
pub const LEDGER_FORMAT: &str = "dpquant-serve-ledger";
/// Ledger version this build reads and writes.
pub const LEDGER_VERSION: u64 = 1;

// ---------------------------------------------------------------------
// Schedule cost estimation (shared by admission control and `dpquant cost`)
// ---------------------------------------------------------------------

/// The worst-case privacy schedule a config can spend, and its composed
/// cost — what a reservation holds. Training steps count every epoch
/// (`target_epsilon` truncation is ignored: the estimate must be an
/// upper bound on actual spend); analysis steps count every
/// analysis-eligible epoch of the `dpquant` scheduler (the live path
/// additionally skips empty Poisson probes, so this too is an upper
/// bound). Under an adaptive policy the training portion is a
/// heterogeneous `(σ_t, q_t)` sequence — one block per distinct
/// per-epoch knob setting ([`adaptive::training_schedule`]) — so
/// dynamic-noise and rate-schedule jobs are admitted at their true
/// composed cost, not a single-triple approximation.
#[derive(Clone, Debug)]
pub struct ScheduleCost {
    /// Training Poisson rate `q = B/|D|` at the schedule start (epoch 0).
    pub sample_rate: f64,
    /// Training noise multiplier σ at the schedule start (epoch 0).
    pub noise_multiplier: f64,
    /// DP-SGD steps: `epochs × max(|D|/B, 1)`, summed over all blocks.
    pub train_steps: u64,
    /// Analysis probe rate `min(analysis_samples/|D|, 1)`.
    pub analysis_rate: f64,
    /// Analysis noise σ_measure.
    pub analysis_sigma: f64,
    /// Analysis invocations: `ceil(epochs/analysis_interval)` for the
    /// `dpquant` scheduler, 0 otherwise.
    pub analysis_steps: u64,
    /// δ the ε below is converted at.
    pub delta: f64,
    /// Composed (training + analysis) ε at `delta`.
    pub epsilon: f64,
    /// The Rényi order that realized `epsilon`.
    pub alpha: f64,
    /// ε of the training schedule alone (the analysis overhead is
    /// `epsilon - train_epsilon`).
    pub train_epsilon: f64,
    /// The full block schedule (training blocks in epoch order, then
    /// the analysis block) — what a reservation composes against the
    /// tenant's history.
    records: Vec<StepRecord>,
}

/// Estimate a config's full-schedule privacy cost via
/// [`RdpAccountant::predict_schedule`]. Pure function of the config —
/// recovery relies on this to rebuild byte-identical reservations. The
/// config's adaptive policy (`cfg.policy`) shapes the training blocks;
/// an invalid policy spec falls back to the static single-block
/// schedule (admission happens after config validation on every serve
/// path, so the fallback only guards direct library callers).
pub fn schedule_cost(cfg: &TrainConfig) -> ScheduleCost {
    let steps_per_epoch = (cfg.dataset_size / cfg.batch_size.max(1)).max(1);
    let train_steps = (cfg.epochs * steps_per_epoch) as u64;
    let sample_rate = cfg.sample_rate();
    let analysis_steps = if cfg.scheduler == "dpquant" {
        cfg.epochs.div_ceil(cfg.analysis_interval.max(1)) as u64
    } else {
        0
    };
    let analysis_rate = (cfg.analysis_samples as f64 / cfg.dataset_size.max(1) as f64).min(1.0);
    let policy = AdaptivePolicy::from_config(cfg).unwrap_or(AdaptivePolicy::Static);
    let base = EpochKnobs {
        noise_multiplier: cfg.noise_multiplier,
        clip_norm: cfg.clip_norm,
        sample_rate,
    };
    let train_records =
        adaptive::training_schedule(&policy, &base, cfg.epochs, steps_per_epoch as u64);
    let (train_epsilon, _) = RdpAccountant::predict_schedule(&train_records, cfg.delta);
    let mut records = train_records;
    records.push(StepRecord {
        mechanism: Mechanism::Analysis,
        sample_rate: analysis_rate,
        noise_multiplier: cfg.sigma_measure,
        steps: analysis_steps,
    });
    let (epsilon, alpha) = RdpAccountant::predict_schedule(&records, cfg.delta);
    ScheduleCost {
        sample_rate,
        noise_multiplier: cfg.noise_multiplier,
        train_steps,
        analysis_rate,
        analysis_sigma: cfg.sigma_measure,
        analysis_steps,
        delta: cfg.delta,
        epsilon,
        alpha,
        train_epsilon,
        records,
    }
}

impl ScheduleCost {
    /// The estimated schedule as [`StepRecord`] blocks: training blocks
    /// in epoch order (one per distinct `(q, σ)` setting of the
    /// config's adaptive policy), then the analysis block.
    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }
}

// ---------------------------------------------------------------------
// Tenant state
// ---------------------------------------------------------------------

struct TenantState {
    budget_epsilon: f64,
    delta: f64,
    /// Coalesced RDP history of every debited job, oldest first —
    /// appended by replaying each job's actual accountant history, so
    /// this is exactly what one accountant composing all the runs would
    /// hold.
    spent: Vec<StepRecord>,
    /// Job ids already debited (debit idempotence across crashes).
    debited_jobs: BTreeSet<u64>,
    /// Open reservations, job id → estimated schedule. In-memory only;
    /// rebuilt during recovery.
    reservations: BTreeMap<u64, Vec<StepRecord>>,
    /// The spend timeline: every reserve/debit/refund this tenant ever
    /// saw, in event order, each with the post-event remaining ε.
    /// Persisted with the ledger (hex floats), so it rebuilds
    /// bit-identically across a `kill -9`. Recovery's
    /// [`BudgetLedger::restore_reservation`] appends **nothing** — the
    /// original reserve event is already durable, so a crash never
    /// duplicates timeline entries.
    timeline: Vec<TimelineEvent>,
}

/// One ledger mutation of a tenant's budget, as served in the
/// `GET /v1/tenants/{id}` spend timeline.
#[derive(Clone, Debug)]
pub struct TimelineEvent {
    /// What happened.
    pub kind: TimelineKind,
    /// The job the event belongs to.
    pub job: u64,
    /// The ε the event moved: the reservation's estimated composed ε at
    /// the tenant's δ (reserve/refund), or the tenant's total spent ε
    /// after the debit landed (debit — the number `audit replay`
    /// cross-checks against a served job's recorded ε timeline).
    pub epsilon: f64,
    /// `remaining_epsilon` immediately after the event — the same
    /// function that feeds admission control and the status document.
    pub remaining: f64,
}

/// Timeline event kinds, mirroring the ledger's three-state machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimelineKind {
    /// Admission placed a reservation.
    Reserve,
    /// A completed job's actual spend landed durably.
    Debit,
    /// A reservation was released without spending.
    Refund,
}

impl TimelineKind {
    /// Wire name (`reserve` / `debit` / `refund`).
    pub fn name(self) -> &'static str {
        match self {
            TimelineKind::Reserve => "reserve",
            TimelineKind::Debit => "debit",
            TimelineKind::Refund => "refund",
        }
    }
}

fn parse_timeline_kind(s: &str) -> Result<TimelineKind> {
    match s {
        "reserve" => Ok(TimelineKind::Reserve),
        "debit" => Ok(TimelineKind::Debit),
        "refund" => Ok(TimelineKind::Refund),
        other => Err(err!("unknown timeline event kind '{other}'")),
    }
}

/// ε of a record sequence by replay through a fresh accountant — the
/// ONE composition path every ledger number flows through. An empty
/// history is defined as ε = 0 (a fresh tenant has spent nothing; the
/// RDP→(ε, δ) conversion of an all-zero curve would still pay its
/// log(1/δ)/(α−1) term).
fn epsilon_of_records<'a, I>(records: I, delta: f64) -> f64
where
    I: IntoIterator<Item = &'a StepRecord>,
{
    let mut acc = RdpAccountant::new();
    for r in records {
        acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
    }
    if acc.history().is_empty() {
        return 0.0;
    }
    acc.epsilon(delta).0
}

impl TenantState {
    fn spent_epsilon(&self) -> f64 {
        epsilon_of_records(&self.spent, self.delta)
    }

    /// ε of spent ∪ reserved, composed (reservations in job-id order).
    fn committed_epsilon(&self) -> f64 {
        epsilon_of_records(
            self.spent.iter().chain(self.reservations.values().flatten()),
            self.delta,
        )
    }

    /// Budget headroom: `max(budget − ε(spent ∪ reserved), 0)`. The one
    /// function behind both the 403 body and the tenant status document.
    fn remaining_epsilon(&self) -> f64 {
        (self.budget_epsilon - self.committed_epsilon()).max(0.0)
    }

    /// Append a timeline event for `job`, stamping the *post-event*
    /// remaining ε. Call after the mutation it records.
    fn push_event(&mut self, kind: TimelineKind, job: u64, epsilon: f64) {
        let remaining = self.remaining_epsilon();
        self.timeline.push(TimelineEvent {
            kind,
            job,
            epsilon,
            remaining,
        });
    }

    fn doc(&self, id: &str) -> TenantDoc {
        let spent = self.spent_epsilon();
        let committed = self.committed_epsilon();
        TenantDoc {
            id: id.to_string(),
            budget_epsilon: self.budget_epsilon,
            delta: self.delta,
            spent_epsilon: spent,
            reserved_epsilon: committed - spent,
            remaining_epsilon: self.remaining_epsilon(),
            debited_jobs: self.debited_jobs.len(),
            open_reservations: self.reservations.len(),
            timeline: self.timeline.clone(),
        }
    }

    fn update_gauges(&self, id: &str) {
        let reg = obs::global();
        let doc = self.doc(id);
        reg.gauge(&format!("ledger.tenant.{id}.spent_epsilon")).set(doc.spent_epsilon);
        reg.gauge(&format!("ledger.tenant.{id}.reserved_epsilon"))
            .set(doc.reserved_epsilon);
        reg.gauge(&format!("ledger.tenant.{id}.remaining_epsilon"))
            .set(doc.remaining_epsilon);
    }
}

/// A tenant's public status: what `GET /v1/tenants/{id}` serves and the
/// `dpquant tenant` CLI renders.
#[derive(Clone, Debug)]
pub struct TenantDoc {
    /// Tenant id (`[A-Za-z0-9_-]`, ≤ 64 chars).
    pub id: String,
    /// Lifetime ε budget.
    pub budget_epsilon: f64,
    /// δ every ledger ε for this tenant is converted at.
    pub delta: f64,
    /// ε of the debited (actually spent) history.
    pub spent_epsilon: f64,
    /// Marginal ε held by open reservations on top of the spend
    /// (`ε(spent ∪ reserved) − ε(spent)`).
    pub reserved_epsilon: f64,
    /// `max(budget − ε(spent ∪ reserved), 0)` — admission headroom.
    pub remaining_epsilon: f64,
    /// Jobs debited so far.
    pub debited_jobs: usize,
    /// Open (undecided) reservations.
    pub open_reservations: usize,
    /// The full spend timeline, event order (see [`TimelineEvent`]).
    pub timeline: Vec<TimelineEvent>,
}

impl TenantDoc {
    /// The status document as JSON (plain numbers: Rust's shortest
    /// round-trip float formatting keeps them f64-bit-exact on the wire).
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("id", json::s(&self.id)),
            ("budget_epsilon", json::num(self.budget_epsilon)),
            ("delta", json::num(self.delta)),
            ("spent_epsilon", json::num(self.spent_epsilon)),
            ("reserved_epsilon", json::num(self.reserved_epsilon)),
            ("remaining_epsilon", json::num(self.remaining_epsilon)),
            ("debited_jobs", json::num(self.debited_jobs as f64)),
            ("open_reservations", json::num(self.open_reservations as f64)),
            (
                "timeline",
                Json::Arr(
                    self.timeline
                        .iter()
                        .map(|e| {
                            json::obj(vec![
                                ("epsilon", json::num(e.epsilon)),
                                ("job", json::num(e.job as f64)),
                                ("kind", json::s(e.kind.name())),
                                ("remaining", json::num(e.remaining)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Why a tenant could not be created (maps to 400 / 409 in the API).
#[derive(Clone, Debug)]
pub enum CreateError {
    /// Bad id / budget / delta.
    Invalid(String),
    /// A tenant with that id already exists.
    Exists(String),
}

impl std::fmt::Display for CreateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CreateError::Invalid(m) => f.write_str(m),
            CreateError::Exists(id) => write!(f, "tenant '{id}' already exists"),
        }
    }
}

impl std::error::Error for CreateError {}

/// Why a tenant-owned submit was refused (maps to 404 / 403 in the API).
#[derive(Clone, Debug)]
pub enum AdmitError {
    /// No tenant with that id.
    UnknownTenant(String),
    /// The remaining budget cannot cover the job's estimated cost.
    Exhausted {
        /// The tenant that ran dry.
        tenant: String,
        /// Headroom at rejection time — bit-identical to the
        /// `remaining_epsilon` of `GET /v1/tenants/{id}`.
        remaining_epsilon: f64,
        /// The rejected job's estimated composed ε.
        estimated_epsilon: f64,
    },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::UnknownTenant(id) => write!(f, "no such tenant '{id}'"),
            AdmitError::Exhausted {
                tenant,
                remaining_epsilon,
                estimated_epsilon,
            } => write!(
                f,
                "tenant '{tenant}' budget exhausted: job needs an estimated \
                 ε = {estimated_epsilon} but only {remaining_epsilon} remains"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

// ---------------------------------------------------------------------
// The ledger
// ---------------------------------------------------------------------

/// Durable per-tenant budget ledger. All methods take `&self`; one
/// mutex guards the tenant table (admission check + reservation insert
/// are a single critical section, so concurrent submits can never
/// oversubscribe a budget).
pub struct BudgetLedger {
    /// `ledger.json` path, when running with a state dir.
    path: Option<String>,
    tenants: Mutex<BTreeMap<String, TenantState>>,
}

impl BudgetLedger {
    /// Open the ledger: load `ledger.json` from the state dir if
    /// present (failing loudly on a malformed one — silently dropping
    /// budgets would violate the durability contract), else start
    /// empty. `None` runs fully in-memory.
    pub fn open(state_dir: Option<&str>) -> Result<Self> {
        let path = state_dir.map(|d| format!("{d}/ledger.json"));
        let tenants = match path.as_deref().filter(|p| std::path::Path::new(p).exists()) {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| err!("reading ledger manifest {p}: {e}"))?;
                parse_manifest(&text).map_err(|e| err!("ledger manifest {p}: {e:#}"))?
            }
            None => BTreeMap::new(),
        };
        let ledger = Self {
            path,
            tenants: Mutex::new(tenants),
        };
        {
            let tenants = ledger.tenants.lock().unwrap();
            for (id, t) in tenants.iter() {
                t.update_gauges(id);
            }
        }
        Ok(ledger)
    }

    /// Create a tenant with a lifetime (ε, δ) budget.
    pub fn create_tenant(
        &self,
        id: &str,
        budget_epsilon: f64,
        delta: f64,
    ) -> std::result::Result<TenantDoc, CreateError> {
        if id.is_empty()
            || id.len() > 64
            || !id.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(CreateError::Invalid(format!(
                "tenant id '{id}' must be 1–64 chars of [A-Za-z0-9_-]"
            )));
        }
        if !(budget_epsilon.is_finite() && budget_epsilon > 0.0) {
            return Err(CreateError::Invalid(format!(
                "budget_epsilon must be a finite value > 0 (got {budget_epsilon})"
            )));
        }
        if !(delta.is_finite() && delta > 0.0 && delta < 1.0) {
            return Err(CreateError::Invalid(format!(
                "delta must be in (0, 1) (got {delta})"
            )));
        }
        let mut tenants = self.tenants.lock().unwrap();
        if tenants.contains_key(id) {
            return Err(CreateError::Exists(id.to_string()));
        }
        let state = TenantState {
            budget_epsilon,
            delta,
            spent: Vec::new(),
            debited_jobs: BTreeSet::new(),
            reservations: BTreeMap::new(),
            timeline: Vec::new(),
        };
        state.update_gauges(id);
        let doc = state.doc(id);
        tenants.insert(id.to_string(), state);
        self.persist(&tenants);
        Ok(doc)
    }

    /// Every tenant's status, id order.
    pub fn tenants(&self) -> Vec<TenantDoc> {
        let tenants = self.tenants.lock().unwrap();
        tenants.iter().map(|(id, t)| t.doc(id)).collect()
    }

    /// One tenant's status, if it exists.
    pub fn status(&self, id: &str) -> Option<TenantDoc> {
        let tenants = self.tenants.lock().unwrap();
        tenants.get(id).map(|t| t.doc(id))
    }

    /// Admission control: atomically check the tenant's headroom
    /// against `cfg`'s worst-case schedule and place a reservation for
    /// `job_id`. Returns the estimated ε on success.
    pub fn reserve(
        &self,
        tenant: &str,
        job_id: u64,
        cfg: &TrainConfig,
    ) -> std::result::Result<f64, AdmitError> {
        let cost = schedule_cost(cfg);
        let mut tenants = self.tenants.lock().unwrap();
        let Some(t) = tenants.get_mut(tenant) else {
            return Err(AdmitError::UnknownTenant(tenant.to_string()));
        };
        // The candidate composes at the tenant's δ, not the job's.
        let records = cost.records().to_vec();
        let would_be = epsilon_of_records(
            t.spent
                .iter()
                .chain(t.reservations.values().flatten())
                .chain(records.iter()),
            t.delta,
        );
        let (estimated_epsilon, _) = RdpAccountant::predict_schedule(&records, t.delta);
        if would_be > t.budget_epsilon {
            return Err(AdmitError::Exhausted {
                tenant: tenant.to_string(),
                remaining_epsilon: t.remaining_epsilon(),
                estimated_epsilon,
            });
        }
        t.reservations.insert(job_id, records);
        t.push_event(TimelineKind::Reserve, job_id, estimated_epsilon);
        t.update_gauges(tenant);
        // Persist so the reserve event is durable: recovery rebuilds the
        // reservation itself from the job's config, but must NOT append
        // a second timeline entry — the one written here is the record.
        self.persist(&tenants);
        Ok(cost.epsilon)
    }

    /// Recovery: rebuild the reservation for a re-enqueued job without
    /// re-running admission (it was admitted before the crash; refusing
    /// it now would strand a durable job). No-ops if the job was
    /// already debited — its cost already lives in `spent`.
    pub fn restore_reservation(&self, tenant: &str, job_id: u64, cfg: &TrainConfig) {
        let mut tenants = self.tenants.lock().unwrap();
        let Some(t) = tenants.get_mut(tenant) else {
            eprintln!(
                "serve: recovered job {job_id} names unknown tenant '{tenant}'; \
                 running it unmetered"
            );
            return;
        };
        if t.debited_jobs.contains(&job_id) {
            return;
        }
        t.reservations.insert(job_id, schedule_cost(cfg).records().to_vec());
        t.update_gauges(tenant);
    }

    /// Debit the job's **actual** spend (its session accountant
    /// history) and release its reservation. Idempotent per job id: a
    /// second debit (crash-recovered job re-finishing) only releases
    /// the reservation. Persists the ledger — callers write the job's
    /// terminal manifest *after* this returns, so a crash between the
    /// two re-runs the job deterministically and the second debit
    /// no-ops.
    pub fn debit(&self, tenant: &str, job_id: u64, history: &[StepRecord]) {
        let mut tenants = self.tenants.lock().unwrap();
        let Some(t) = tenants.get_mut(tenant) else {
            return;
        };
        t.reservations.remove(&job_id);
        if t.debited_jobs.insert(job_id) {
            // Append by replay so `spent` stays exactly the coalesced
            // history one accountant composing every run would hold.
            let mut acc = RdpAccountant::new();
            for r in t.spent.iter().chain(history.iter()) {
                acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
            }
            t.spent = acc.history().to_vec();
            // The debit event records the tenant's total spent ε after
            // this job landed — the number a served job's audit replay
            // cross-checks. Idempotence extends to the timeline: a
            // crash-recovered second debit appends nothing.
            let spent_epsilon = t.spent_epsilon();
            t.push_event(TimelineKind::Debit, job_id, spent_epsilon);
            self.persist(&tenants);
            // Re-borrow after persist (persist only reads).
            let t = tenants.get(tenant).expect("tenant just updated");
            t.update_gauges(tenant);
        } else {
            t.update_gauges(tenant);
        }
    }

    /// Release a reservation without spending (cancel / failure /
    /// panic). Idempotent; unknown tenants or jobs no-op — and only an
    /// actually-open reservation produces a timeline event, so repeated
    /// refunds can never pad the history.
    pub fn refund(&self, tenant: &str, job_id: u64) {
        let mut tenants = self.tenants.lock().unwrap();
        if let Some(t) = tenants.get_mut(tenant) {
            if let Some(records) = t.reservations.remove(&job_id) {
                let (estimated_epsilon, _) = RdpAccountant::predict_schedule(&records, t.delta);
                t.push_event(TimelineKind::Refund, job_id, estimated_epsilon);
                t.update_gauges(tenant);
                self.persist(&tenants);
            } else {
                t.update_gauges(tenant);
            }
        }
    }

    /// Per-tenant spend/reservation snapshot for `GET /v1/metrics`:
    /// `{tenant: {budget_epsilon, spent_epsilon, reserved_epsilon,
    /// remaining_epsilon}}`.
    pub fn metrics_json(&self) -> Json {
        let tenants = self.tenants.lock().unwrap();
        Json::Obj(
            tenants
                .iter()
                .map(|(id, t)| {
                    let doc = t.doc(id);
                    (
                        id.clone(),
                        json::obj(vec![
                            ("budget_epsilon", json::num(doc.budget_epsilon)),
                            ("spent_epsilon", json::num(doc.spent_epsilon)),
                            ("reserved_epsilon", json::num(doc.reserved_epsilon)),
                            ("remaining_epsilon", json::num(doc.remaining_epsilon)),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Write `ledger.json` atomically (temp + rename). Failures are
    /// reported on stderr, never panicked on — an unwritable state dir
    /// degrades durability, not service (same policy as job manifests).
    fn persist(&self, tenants: &BTreeMap<String, TenantState>) {
        let Some(path) = &self.path else {
            return;
        };
        let tmp = format!("{path}.tmp");
        let text = manifest_json(tenants).to_string();
        let result =
            std::fs::write(&tmp, text).and_then(|()| std::fs::rename(&tmp, path.as_str()));
        if let Err(e) = result {
            eprintln!("serve: failed to persist ledger: {e}");
        }
    }
}

// ---------------------------------------------------------------------
// Manifest (dpquant-serve-ledger v1)
// ---------------------------------------------------------------------

// Floats persist as IEEE-754 bit patterns in hex (the
// dpquant-trainsession idiom): budgets and rates round-trip bit-exactly
// by construction, so recovered admission decisions can never drift.
fn hex_f64(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn parse_hex_f64(j: &Json, what: &str) -> Result<f64> {
    let s = j
        .as_str()
        .ok_or_else(|| err!("{what} must be a 16-hex-digit string"))?;
    ensure!(s.len() == 16, "{what} must be exactly 16 hex digits (got '{s}')");
    let bits = u64::from_str_radix(s, 16).map_err(|_| err!("{what}: bad hex '{s}'"))?;
    Ok(f64::from_bits(bits))
}

fn mechanism_name(m: Mechanism) -> &'static str {
    match m {
        Mechanism::Training => "training",
        Mechanism::Analysis => "analysis",
    }
}

fn parse_mechanism(s: &str) -> Result<Mechanism> {
    match s {
        "training" => Ok(Mechanism::Training),
        "analysis" => Ok(Mechanism::Analysis),
        other => Err(err!("unknown mechanism '{other}'")),
    }
}

fn manifest_json(tenants: &BTreeMap<String, TenantState>) -> Json {
    let body: BTreeMap<String, Json> = tenants
        .iter()
        .map(|(id, t)| {
            (
                id.clone(),
                json::obj(vec![
                    ("budget_epsilon", hex_f64(t.budget_epsilon)),
                    ("delta", hex_f64(t.delta)),
                    (
                        "debited_jobs",
                        Json::Arr(
                            t.debited_jobs.iter().map(|id| json::num(*id as f64)).collect(),
                        ),
                    ),
                    (
                        "spent",
                        Json::Arr(
                            t.spent
                                .iter()
                                .map(|r| {
                                    json::obj(vec![
                                        ("mechanism", json::s(mechanism_name(r.mechanism))),
                                        ("sample_rate", hex_f64(r.sample_rate)),
                                        ("noise_multiplier", hex_f64(r.noise_multiplier)),
                                        ("steps", json::num(r.steps as f64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "timeline",
                        Json::Arr(
                            t.timeline
                                .iter()
                                .map(|e| {
                                    json::obj(vec![
                                        ("epsilon", hex_f64(e.epsilon)),
                                        ("job", json::num(e.job as f64)),
                                        ("kind", json::s(e.kind.name())),
                                        ("remaining", hex_f64(e.remaining)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ]),
            )
        })
        .collect();
    json::obj(vec![
        ("format", json::s(LEDGER_FORMAT)),
        ("version", json::num(LEDGER_VERSION as f64)),
        ("tenants", Json::Obj(body)),
    ])
}

fn parse_manifest(text: &str) -> Result<BTreeMap<String, TenantState>> {
    let j = json::parse(text).map_err(|e| err!("malformed JSON: {e}"))?;
    let format = j.get("format").and_then(Json::as_str).unwrap_or("<missing>");
    ensure!(
        format == LEDGER_FORMAT,
        "not a ledger manifest (format '{format}', want '{LEDGER_FORMAT}')"
    );
    let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    ensure!(
        version == LEDGER_VERSION,
        "ledger manifest version {version} is not readable by this build (which reads \
         version {LEDGER_VERSION})"
    );
    let tenants_json = j
        .get("tenants")
        .and_then(Json::as_obj)
        .ok_or_else(|| err!("missing 'tenants' object"))?;
    let mut tenants = BTreeMap::new();
    for (id, tj) in tenants_json {
        let budget_epsilon = parse_hex_f64(
            tj.get("budget_epsilon").ok_or_else(|| err!("tenant '{id}': missing budget_epsilon"))?,
            "budget_epsilon",
        )?;
        let delta = parse_hex_f64(
            tj.get("delta").ok_or_else(|| err!("tenant '{id}': missing delta"))?,
            "delta",
        )?;
        let debited_jobs: BTreeSet<u64> = tj
            .get("debited_jobs")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("tenant '{id}': missing debited_jobs array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .map(|n| n as u64)
                    .ok_or_else(|| err!("tenant '{id}': debited_jobs entries must be job ids"))
            })
            .collect::<Result<_>>()?;
        let spent: Vec<StepRecord> = tj
            .get("spent")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("tenant '{id}': missing spent array"))?
            .iter()
            .map(|rj| {
                Ok(StepRecord {
                    mechanism: parse_mechanism(
                        rj.get("mechanism")
                            .and_then(Json::as_str)
                            .ok_or_else(|| err!("tenant '{id}': spent entry missing mechanism"))?,
                    )?,
                    sample_rate: parse_hex_f64(
                        rj.get("sample_rate")
                            .ok_or_else(|| err!("tenant '{id}': spent entry missing sample_rate"))?,
                        "sample_rate",
                    )?,
                    noise_multiplier: parse_hex_f64(
                        rj.get("noise_multiplier").ok_or_else(|| {
                            err!("tenant '{id}': spent entry missing noise_multiplier")
                        })?,
                        "noise_multiplier",
                    )?,
                    steps: rj
                        .get("steps")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| err!("tenant '{id}': spent entry missing steps"))?
                        as u64,
                })
            })
            .collect::<Result<_>>()?;
        // Absent in pre-timeline manifests: an empty timeline, same
        // LEDGER_VERSION (the field is additive).
        let timeline: Vec<TimelineEvent> = match tj.get("timeline").and_then(Json::as_arr) {
            None => Vec::new(),
            Some(events) => events
                .iter()
                .map(|ej| {
                    Ok(TimelineEvent {
                        kind: parse_timeline_kind(
                            ej.get("kind")
                                .and_then(Json::as_str)
                                .ok_or_else(|| err!("tenant '{id}': timeline entry missing kind"))?,
                        )?,
                        job: ej
                            .get("job")
                            .and_then(Json::as_usize)
                            .ok_or_else(|| err!("tenant '{id}': timeline entry missing job"))?
                            as u64,
                        epsilon: parse_hex_f64(
                            ej.get("epsilon")
                                .ok_or_else(|| err!("tenant '{id}': timeline entry missing epsilon"))?,
                            "timeline epsilon",
                        )?,
                        remaining: parse_hex_f64(
                            ej.get("remaining").ok_or_else(|| {
                                err!("tenant '{id}': timeline entry missing remaining")
                            })?,
                            "timeline remaining",
                        )?,
                    })
                })
                .collect::<Result<_>>()?,
        };
        tenants.insert(
            id.clone(),
            TenantState {
                budget_epsilon,
                delta,
                spent,
                debited_jobs,
                reservations: BTreeMap::new(),
                timeline,
            },
        );
    }
    Ok(tenants)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TrainConfig {
        TrainConfig {
            backend: "mock".into(),
            dataset_size: 96,
            val_size: 32,
            batch_size: 16,
            physical_batch: 32,
            epochs: 2,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn schedule_cost_counts_the_full_schedule() {
        let cfg = tiny_cfg();
        let cost = schedule_cost(&cfg);
        // 2 epochs × (96/16 = 6 steps) = 12 training steps; dpquant
        // scheduler analyzes epochs 0 (and every analysis_interval-th):
        // ceil(2/2) = 1 invocation.
        assert_eq!(cost.train_steps, 12);
        assert_eq!(cost.analysis_steps, 1);
        assert_eq!(cost.sample_rate.to_bits(), (16.0f64 / 96.0).to_bits());
        assert!(cost.epsilon.is_finite() && cost.epsilon > 0.0);
        assert!(cost.train_epsilon <= cost.epsilon);
        // Non-dpquant schedulers never run Algorithm 1.
        let mut none = tiny_cfg();
        none.scheduler = "none".into();
        assert_eq!(schedule_cost(&none).analysis_steps, 0);
    }

    #[test]
    fn schedule_cost_expands_adaptive_policies_block_by_block() {
        // A noise-decay config must be admitted at its heterogeneous
        // composed cost: one training block per distinct per-epoch σ.
        let mut cfg = tiny_cfg();
        cfg.policy = "noise_decay".into();
        cfg.noise_final = cfg.noise_multiplier * 2.0;
        let cost = schedule_cost(&cfg);
        let train_blocks = cost
            .records()
            .iter()
            .filter(|r| r.mechanism == Mechanism::Training)
            .count();
        assert_eq!(train_blocks, cfg.epochs, "one block per distinct sigma");
        let block_steps: u64 = cost
            .records()
            .iter()
            .filter(|r| r.mechanism == Mechanism::Training)
            .map(|r| r.steps)
            .sum();
        assert_eq!(block_steps, cost.train_steps);
        // Decaying *up* to 2σ must cost less than running every epoch at
        // the starting σ, and more than running every epoch at 2σ.
        let static_lo = schedule_cost(&tiny_cfg());
        let mut hi_cfg = tiny_cfg();
        hi_cfg.noise_multiplier *= 2.0;
        hi_cfg.sigma_measure = cfg.sigma_measure;
        let static_hi = schedule_cost(&hi_cfg);
        assert!(cost.epsilon < static_lo.epsilon, "decay toward more noise is cheaper");
        assert!(cost.epsilon > static_hi.epsilon, "but not as cheap as all-high-noise");
        // The quoted ε is exactly the block-by-block replay.
        let (replay, _) = RdpAccountant::predict_schedule(cost.records(), cfg.delta);
        assert_eq!(cost.epsilon.to_bits(), replay.to_bits());
        // And the static path still produces the legacy two-block shape
        // with an ε bit-equal to the legacy 7-arg predict.
        let s = static_lo;
        assert_eq!(s.records().len(), 2);
        let (legacy, _) = RdpAccountant::predict(
            s.sample_rate,
            s.noise_multiplier,
            s.train_steps,
            s.analysis_rate,
            s.analysis_sigma,
            s.analysis_steps,
            tiny_cfg().delta,
        );
        assert_eq!(s.epsilon.to_bits(), legacy.to_bits());
    }

    #[test]
    fn adaptive_jobs_admit_and_exhaust_through_the_ledger() {
        let ledger = BudgetLedger::open(None).unwrap();
        let mut cfg = tiny_cfg();
        cfg.policy = "rate_schedule".into();
        cfg.rate_final = cfg.sample_rate() / 2.0;
        let one_job = schedule_cost(&cfg).epsilon;
        // Strict `>` admission: a budget of exactly one composed job
        // admits job 1 and rejects job 2 (two jobs always compose to
        // strictly more than one).
        ledger.create_tenant("t", one_job, 1e-5).unwrap();
        ledger.reserve("t", 1, &cfg).unwrap();
        // The second identical job must be rejected with the schedule's
        // composed ε quoted at the tenant's δ (here equal to the job's).
        let err = ledger.reserve("t", 2, &cfg).unwrap_err();
        let AdmitError::Exhausted {
            estimated_epsilon, ..
        } = err
        else {
            panic!("expected Exhausted, got {err:?}");
        };
        assert_eq!(estimated_epsilon.to_bits(), one_job.to_bits());
    }

    #[test]
    fn create_validates_and_rejects_duplicates() {
        let ledger = BudgetLedger::open(None).unwrap();
        assert!(matches!(
            ledger.create_tenant("", 1.0, 1e-5),
            Err(CreateError::Invalid(_))
        ));
        assert!(matches!(
            ledger.create_tenant("bad/slash", 1.0, 1e-5),
            Err(CreateError::Invalid(_))
        ));
        assert!(matches!(
            ledger.create_tenant("a", 0.0, 1e-5),
            Err(CreateError::Invalid(_))
        ));
        assert!(matches!(
            ledger.create_tenant("a", 1.0, 1.5),
            Err(CreateError::Invalid(_))
        ));
        let doc = ledger.create_tenant("alice", 4.0, 1e-5).unwrap();
        assert_eq!(doc.remaining_epsilon.to_bits(), 4.0f64.to_bits());
        assert_eq!(doc.spent_epsilon, 0.0);
        assert!(matches!(
            ledger.create_tenant("alice", 2.0, 1e-5),
            Err(CreateError::Exists(_))
        ));
        assert_eq!(ledger.tenants().len(), 1);
    }

    #[test]
    fn reserve_debit_refund_lifecycle() {
        let ledger = BudgetLedger::open(None).unwrap();
        ledger.create_tenant("t", 8.0, 1e-5).unwrap();
        let cfg = tiny_cfg();
        let est = ledger.reserve("t", 1, &cfg).unwrap();
        assert!(est > 0.0);
        let doc = ledger.status("t").unwrap();
        assert_eq!(doc.open_reservations, 1);
        assert!(doc.reserved_epsilon > 0.0);
        assert!(doc.remaining_epsilon < 8.0);
        // Refund restores the headroom bit-exactly.
        ledger.refund("t", 1);
        let doc = ledger.status("t").unwrap();
        assert_eq!(doc.open_reservations, 0);
        assert_eq!(doc.remaining_epsilon.to_bits(), 8.0f64.to_bits());
        // Reserve again and debit an actual (smaller) history.
        ledger.reserve("t", 2, &cfg).unwrap();
        let history = vec![StepRecord {
            mechanism: Mechanism::Training,
            sample_rate: 16.0 / 96.0,
            noise_multiplier: 1.0,
            steps: 12,
        }];
        ledger.debit("t", 2, &history);
        let doc = ledger.status("t").unwrap();
        assert_eq!(doc.open_reservations, 0);
        assert_eq!(doc.debited_jobs, 1);
        assert!(doc.spent_epsilon > 0.0);
        // Idempotent: a second debit of the same job changes nothing.
        let before = doc.spent_epsilon.to_bits();
        ledger.debit("t", 2, &history);
        assert_eq!(ledger.status("t").unwrap().spent_epsilon.to_bits(), before);
        assert_eq!(ledger.status("t").unwrap().debited_jobs, 1);
    }

    #[test]
    fn exhaustion_rejects_with_the_status_documents_remaining() {
        let ledger = BudgetLedger::open(None).unwrap();
        let cfg = tiny_cfg();
        let one_job = schedule_cost(&cfg).epsilon;
        // Budget fits one job but not two.
        ledger.create_tenant("t", one_job * 1.5, 1e-5).unwrap();
        ledger.reserve("t", 1, &cfg).unwrap();
        let err = ledger.reserve("t", 2, &cfg).unwrap_err();
        let AdmitError::Exhausted {
            remaining_epsilon, ..
        } = err
        else {
            panic!("expected Exhausted, got {err:?}");
        };
        let doc = ledger.status("t").unwrap();
        assert_eq!(remaining_epsilon.to_bits(), doc.remaining_epsilon.to_bits());
        // The rejected reservation left no trace.
        assert_eq!(doc.open_reservations, 1);
        // Unknown tenants are their own error.
        assert!(matches!(
            ledger.reserve("ghost", 3, &cfg),
            Err(AdmitError::UnknownTenant(_))
        ));
    }

    #[test]
    fn manifest_roundtrip_is_bit_exact() {
        let dir = std::env::temp_dir().join(format!("dpquant-ledger-rt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dir_s = dir.to_str().unwrap().to_string();
        let cfg = tiny_cfg();
        {
            let ledger = BudgetLedger::open(Some(&dir_s)).unwrap();
            ledger.create_tenant("alice", 1.0 / 3.0, 1e-5).unwrap();
            ledger.create_tenant("bob", 7.0, 1e-6).unwrap();
            ledger.reserve("alice", 1, &cfg).unwrap();
            ledger.debit(
                "alice",
                1,
                &[StepRecord {
                    mechanism: Mechanism::Training,
                    sample_rate: 0.1 + 0.2, // no short decimal form
                    noise_multiplier: 1.0 / 7.0,
                    steps: 5,
                }],
            );
        }
        let reopened = BudgetLedger::open(Some(&dir_s)).unwrap();
        let alice = reopened.status("alice").unwrap();
        assert_eq!(alice.budget_epsilon.to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(alice.debited_jobs, 1);
        // Reservations do NOT survive: they are rebuilt by job recovery.
        assert_eq!(alice.open_reservations, 0);
        let bob = reopened.status("bob").unwrap();
        assert_eq!(bob.budget_epsilon.to_bits(), 7.0f64.to_bits());
        assert_eq!(bob.spent_epsilon, 0.0);
        // The spent history replays to the same ε.
        {
            let mut acc = RdpAccountant::new();
            acc.step_training(0.1 + 0.2, 1.0 / 7.0, 5);
            assert_eq!(alice.spent_epsilon.to_bits(), acc.epsilon(1e-5).0.to_bits());
        }
        // Malformed manifests fail loudly.
        std::fs::write(dir.join("ledger.json"), "{}").unwrap();
        assert!(BudgetLedger::open(Some(&dir_s)).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timeline_records_the_lifecycle_and_reopens_bit_identically() {
        let dir = std::env::temp_dir().join(format!("dpquant-ledger-tl-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let dir_s = dir.to_str().unwrap().to_string();
        let cfg = tiny_cfg();
        {
            let ledger = BudgetLedger::open(Some(&dir_s)).unwrap();
            ledger.create_tenant("t", 8.0, 1e-5).unwrap();
            ledger.reserve("t", 1, &cfg).unwrap();
            ledger.debit("t", 1, schedule_cost(&cfg).records());
            ledger.reserve("t", 2, &cfg).unwrap();
            ledger.refund("t", 2);
            // Idempotent paths append nothing.
            ledger.debit("t", 1, schedule_cost(&cfg).records());
            ledger.refund("t", 2);
            ledger.restore_reservation("t", 3, &cfg);
            let doc = ledger.status("t").unwrap();
            let kinds: Vec<&str> = doc.timeline.iter().map(|e| e.kind.name()).collect();
            assert_eq!(kinds, ["reserve", "debit", "reserve", "refund"]);
            // Post-event remaining: the refund restored the debit-time
            // headroom minus the restored (unrecorded) reservation.
            assert_eq!(doc.timeline[1].epsilon.to_bits(), doc.spent_epsilon.to_bits());
            assert!(doc.timeline[0].remaining > doc.timeline[2].remaining);
            assert!(doc.timeline[3].remaining > doc.timeline[2].remaining);
        }
        // Reopen: the timeline (and every ε in it) round-trips bit-exactly.
        let reopened = BudgetLedger::open(Some(&dir_s)).unwrap();
        let doc = reopened.status("t").unwrap();
        assert_eq!(doc.timeline.len(), 4);
        {
            let fresh = BudgetLedger::open(Some(&dir_s)).unwrap();
            let a = doc.to_json().to_string();
            // restore_reservation never touches the timeline, so a
            // recovered daemon serves the same bytes.
            fresh.restore_reservation("t", 3, &cfg);
            let mut b = fresh.status("t").unwrap();
            b.open_reservations = doc.open_reservations; // recovery state differs by design
            b.reserved_epsilon = doc.reserved_epsilon;
            b.remaining_epsilon = doc.remaining_epsilon;
            assert_eq!(a, b.to_json().to_string());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn restore_reservation_skips_debited_jobs() {
        let ledger = BudgetLedger::open(None).unwrap();
        let cfg = tiny_cfg();
        ledger.create_tenant("t", 100.0, 1e-5).unwrap();
        ledger.reserve("t", 1, &cfg).unwrap();
        ledger.debit("t", 1, schedule_cost(&cfg).records());
        // A crash-recovered, already-debited job must not re-reserve.
        ledger.restore_reservation("t", 1, &cfg);
        assert_eq!(ledger.status("t").unwrap().open_reservations, 0);
        // A genuinely open job does.
        ledger.restore_reservation("t", 2, &cfg);
        assert_eq!(ledger.status("t").unwrap().open_reservations, 1);
    }
}
