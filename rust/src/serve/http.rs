//! Minimal threaded HTTP/1.1 on `std::net` — both halves of the wire.
//!
//! Server: [`serve`] binds a `TcpListener`, accepts on a dedicated
//! thread, and runs one thread per connection (bounded by
//! [`MAX_CONCURRENT_CONNS`]; excess connections get an immediate 503).
//! Requests are parsed with **hard size caps** at every layer — request
//! line, header section, and `Content-Length` body — and every parse
//! failure becomes a 4xx JSON error response on a connection that then
//! closes; nothing a client sends can panic the daemon (handler panics
//! are caught and answered with a 500). Keep-alive is honored for
//! well-formed HTTP/1.1 exchanges, up to [`MAX_REQUESTS_PER_CONN`] per
//! connection; HTTP/1.0 and `Connection: close` close after one
//! response. Chunked request bodies are not supported (501) — the API's
//! bodies are small JSON documents with explicit lengths.
//!
//! Client: [`http_call`] speaks just enough HTTP/1.1 over one
//! `TcpStream` (one connection per call, `Connection: close`) for the
//! `dpquant job` verbs and CI — no `curl` dependency.
//!
//! Bodies are JSON in both directions (`util/json`), which the parser
//! hardening in that module makes safe against hostile payloads
//! (bounded nesting, no overflow-to-inf, positioned errors) — except
//! for the two explicit text responses ([`Response::text`]): the
//! Prometheus metrics exposition and the raw `dpquant-audit` stream,
//! which ship verbatim under their own content types.

use std::collections::BTreeMap;
use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::obs;
use crate::util::error::{err, Context, Result};
use crate::util::json::{self, Json};

/// Cap on the request line and on any single header line, in bytes.
pub const MAX_LINE_BYTES: usize = 8 * 1024;
/// Cap on the whole header section, in bytes.
pub const MAX_HEADER_BYTES: usize = 16 * 1024;
/// Cap on a request body (`Content-Length`), in bytes. API bodies are
/// sub-kilobyte config documents; 1 MiB is already generous.
pub const MAX_BODY_BYTES: usize = 1 << 20;
/// Keep-alive budget: requests served on one connection before closing.
pub const MAX_REQUESTS_PER_CONN: usize = 1000;
/// Connection-thread cap; excess connections are answered 503 inline.
pub const MAX_CONCURRENT_CONNS: usize = 64;

const READ_TIMEOUT: Duration = Duration::from_secs(10);
const CLIENT_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------
// Request / Response
// ---------------------------------------------------------------------

/// A parsed request. Header names are lowercased; the target is split
/// into `path` and the (unparsed) `query` at the first `?`.
#[derive(Debug)]
pub struct Request {
    /// Uppercase HTTP method.
    pub method: String,
    /// Request path (before any `?`).
    pub path: String,
    /// Raw query string after `?`, if any.
    pub query: Option<String>,
    /// Lowercased name -> trimmed value.
    pub headers: BTreeMap<String, String>,
    /// Raw request body bytes.
    pub body: Vec<u8>,
    /// False for HTTP/1.0 (which never keeps alive).
    pub http11: bool,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }

    /// Should the server close the connection after responding?
    pub fn wants_close(&self) -> bool {
        !self.http11
            || matches!(self.header("connection"), Some(c) if c.eq_ignore_ascii_case("close"))
    }

    /// Parse the body as JSON (the only body type the API accepts).
    pub fn body_json(&self) -> std::result::Result<Json, String> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|e| format!("body is not UTF-8: {e}"))?;
        json::parse(text)
    }
}

/// An outgoing response: a status code plus a body — JSON by default,
/// or raw text (with an explicit content type) for the two text
/// endpoints (`/v1/metrics?format=prometheus` and the audit stream).
#[derive(Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// JSON body (ignored when `text` is set).
    pub body: Json,
    /// `(content_type, body)` for a raw text response. Built only by
    /// [`Response::text`]; `None` means `body` is serialized as JSON.
    text: Option<(String, String)>,
}

impl Response {
    /// A 200 response with the given body.
    pub fn ok(body: Json) -> Self {
        Self::json(200, body)
    }

    /// A response with an explicit status and a JSON body.
    pub fn json(status: u16, body: Json) -> Self {
        Self {
            status,
            body,
            text: None,
        }
    }

    /// A 200 response with a raw text body served under `content_type`
    /// (bytes pass through verbatim — no JSON escaping).
    pub fn text<C: fmt::Display>(content_type: C, text: String) -> Self {
        Self {
            status: 200,
            body: Json::Null,
            text: Some((content_type.to_string(), text)),
        }
    }

    /// The `(content_type, body)` of a text response, `None` for JSON.
    pub fn as_text(&self) -> Option<(&str, &str)> {
        self.text.as_ref().map(|(c, t)| (c.as_str(), t.as_str()))
    }

    /// An error response with the daemon's uniform `{"error": ...}`
    /// body.
    pub fn error<M: fmt::Display>(status: u16, message: M) -> Self {
        Self::json(
            status,
            json::obj(vec![("error", json::s(&message.to_string()))]),
        )
    }
}

/// A request-parsing failure, carrying the status the server answers
/// with (always 4xx/5xx; never a panic).
#[derive(Debug)]
pub struct HttpError {
    /// Status code to answer with (4xx/5xx).
    pub status: u16,
    /// Human-readable error detail.
    pub message: String,
}

impl HttpError {
    fn new<M: fmt::Display>(status: u16, message: M) -> Self {
        Self {
            status,
            message: message.to_string(),
        }
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        201 => "Created",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

// ---------------------------------------------------------------------
// Request parsing (pure over any BufRead, so tests need no sockets)
// ---------------------------------------------------------------------

/// Read one `\n`-terminated line of at most `cap` bytes, trimming the
/// `\r\n`. `Ok(None)` is clean EOF before any byte.
fn read_line_capped<R: BufRead>(
    r: &mut R,
    cap: usize,
) -> std::result::Result<Option<Vec<u8>>, HttpError> {
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(cap as u64)
        .read_until(b'\n', &mut buf)
        .map_err(|e| HttpError::new(408, format!("read failed: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    if buf.last() != Some(&b'\n') {
        if buf.len() >= cap {
            return Err(HttpError::new(400, format!("line exceeds {cap} bytes")));
        }
        return Err(HttpError::new(400, "truncated request"));
    }
    buf.pop();
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    Ok(Some(buf))
}

/// Parse one request off the stream. `Ok(None)` means the peer closed
/// cleanly between requests (the keep-alive exit).
pub fn read_request<R: BufRead>(r: &mut R) -> std::result::Result<Option<Request>, HttpError> {
    // Tolerate a stray blank line between pipelined requests (RFC 9112
    // §2.2 says servers SHOULD ignore at least one).
    let mut line = Vec::new();
    for _ in 0..3 {
        match read_line_capped(r, MAX_LINE_BYTES)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => {
                line = l;
                break;
            }
        }
    }
    if line.is_empty() {
        return Err(HttpError::new(400, "expected a request line"));
    }
    let text = String::from_utf8(line)
        .map_err(|_| HttpError::new(400, "request line is not UTF-8"))?;
    let mut parts = text.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(HttpError::new(
                400,
                format!("malformed request line '{text}'"),
            ))
        }
    };
    let http11 = match version {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        other => {
            return Err(HttpError::new(
                505,
                format!("unsupported protocol version '{other}'"),
            ))
        }
    };

    let mut headers = BTreeMap::new();
    let mut header_bytes = 0usize;
    loop {
        let line = read_line_capped(r, MAX_LINE_BYTES)?
            .ok_or_else(|| HttpError::new(400, "connection closed inside headers"))?;
        if line.is_empty() {
            break;
        }
        header_bytes += line.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Err(HttpError::new(
                400,
                format!("header section exceeds {MAX_HEADER_BYTES} bytes"),
            ));
        }
        let text = String::from_utf8(line)
            .map_err(|_| HttpError::new(400, "header line is not UTF-8"))?;
        let (name, value) = text
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header '{text}'")))?;
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }

    if let Some(te) = headers.get("transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(HttpError::new(
                501,
                "chunked request bodies are not supported; send Content-Length",
            ));
        }
    }
    let len = match headers.get("content-length") {
        None => 0,
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| HttpError::new(400, format!("bad Content-Length '{v}'")))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(HttpError::new(
            413,
            format!("body of {len} bytes exceeds the {MAX_BODY_BYTES}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .map_err(|_| HttpError::new(400, "body shorter than Content-Length"))?;

    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok(Some(Request {
        method: method.to_string(),
        path,
        query,
        headers,
        body,
        http11,
    }))
}

/// Serialize a response (status line, headers, body) onto `w`.
pub fn write_response<W: Write>(w: &mut W, resp: &Response, close: bool) -> std::io::Result<()> {
    let json_body;
    let (content_type, body): (&str, &str) = match &resp.text {
        Some((ct, text)) => (ct, text),
        None => {
            json_body = resp.body.to_string();
            ("application/json", &json_body)
        }
    };
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        reason(resp.status),
        content_type,
        body.len(),
        if close { "close" } else { "keep-alive" }
    );
    w.write_all(head.as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// The routing callback: pure request -> response (the API layer).
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server: an accept thread plus per-connection threads.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

/// Bind `addr` (`host:port`; port 0 picks an ephemeral port) and serve
/// `handler` until [`Server::stop`] — or forever under
/// [`Server::join`].
pub fn serve(addr: &str, handler: Handler) -> Result<Server> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    let addr = listener.local_addr().context("reading the bound address")?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let accept = {
        let shutdown = Arc::clone(&shutdown);
        std::thread::spawn(move || accept_loop(&listener, &handler, &shutdown))
    };
    Ok(Server {
        addr,
        shutdown,
        accept: Some(accept),
    })
}

impl Server {
    /// The actually-bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block on the accept thread forever — the CLI daemon path.
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting and join the accept thread. In-flight connection
    /// threads finish their current request and exit on their own.
    pub fn stop(mut self) {
        self.request_stop();
    }

    fn request_stop(&mut self) {
        if let Some(h) = self.accept.take() {
            self.shutdown.store(true, Ordering::SeqCst);
            // Unblock the accept() call with a throwaway connection.
            let _ = TcpStream::connect(self.addr);
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.request_stop();
    }
}

fn accept_loop(listener: &TcpListener, handler: &Handler, shutdown: &Arc<AtomicBool>) {
    let live = Arc::new(AtomicUsize::new(0));
    for conn in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let mut stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        if live.load(Ordering::SeqCst) >= MAX_CONCURRENT_CONNS {
            let _ = write_response(
                &mut stream,
                &Response::error(503, "too many concurrent connections"),
                true,
            );
            continue;
        }
        live.fetch_add(1, Ordering::SeqCst);
        let handler = Arc::clone(handler);
        let live = Arc::clone(&live);
        std::thread::spawn(move || {
            // The connection loop already catches handler panics; this
            // outer catch keeps the live-connection count honest even
            // if the loop machinery itself panics.
            let r = catch_unwind(AssertUnwindSafe(|| handle_connection(stream, &handler)));
            live.fetch_sub(1, Ordering::SeqCst);
            if r.is_err() {
                eprintln!("serve: connection thread panicked (connection dropped)");
            }
        });
    }
}

fn handle_connection(stream: TcpStream, handler: &Handler) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(READ_TIMEOUT));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Request-level metrics (one registry touch per request, always on):
    // the latency histogram times the handler only — parse and socket
    // I/O are the client's pace, not the daemon's.
    let reg = obs::global();
    let latency = reg.histogram_ns("http.request_ns");
    let requests = reg.counter("http.requests");
    let errors = reg.counter("http.errors");
    let mut reader = BufReader::new(stream);
    for _ in 0..MAX_REQUESTS_PER_CONN {
        match read_request(&mut reader) {
            Ok(None) => return, // peer closed between requests
            Ok(Some(req)) => {
                requests.inc();
                let close = req.wants_close();
                let resp = {
                    let _timer = latency.start_timer();
                    catch_unwind(AssertUnwindSafe(|| handler(&req))).unwrap_or_else(|_| {
                        Response::error(500, "internal error: request handler panicked")
                    })
                };
                if resp.status >= 400 {
                    errors.inc();
                }
                if write_response(&mut writer, &resp, close).is_err() || close {
                    return;
                }
            }
            Err(e) => {
                // Malformed input: answer with its 4xx/5xx and close.
                requests.inc();
                errors.inc();
                let _ = write_response(&mut writer, &Response::error(e.status, &e.message), true);
                return;
            }
        }
    }
    // Keep-alive budget spent; the last response already said
    // keep-alive, but closing here is legal and bounds resource use.
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// One HTTP exchange with the daemon: connect, send `method path` with
/// an optional JSON body, return `(status, parsed JSON body)`. Uses
/// `Connection: close` — one TCP connection per call keeps the client
/// trivially correct, and the CLI's call rate is human-scale.
pub fn http_call(addr: &str, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
    let (status, body) = http_call_raw(addr, method, path, body)?;
    let text = std::str::from_utf8(&body).map_err(|_| err!("daemon body is not UTF-8"))?;
    let parsed = if text.trim().is_empty() {
        Json::Null
    } else {
        json::parse(text).map_err(|e| err!("daemon sent malformed JSON: {e}"))?
    };
    Ok((status, parsed))
}

/// [`http_call`] without the JSON parse: returns the raw body bytes.
/// The `dpquant job audit` verb and the Prometheus scrape path use
/// this — their bodies are text streams, not JSON documents.
pub fn http_call_raw(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&Json>,
) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr).with_context(|| {
        format!("connecting to the dpquant daemon at {addr} (is `dpquant serve` running?)")
    })?;
    stream
        .set_read_timeout(Some(CLIENT_TIMEOUT))
        .context("setting read timeout")?;
    stream
        .set_write_timeout(Some(CLIENT_TIMEOUT))
        .context("setting write timeout")?;

    let body_text = body.map(Json::to_string).unwrap_or_default();
    let request = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body_text}",
        body_text.len()
    );
    stream
        .write_all(request.as_bytes())
        .context("sending request")?;

    let mut reader = BufReader::new(stream);
    let status_line = read_line_capped(&mut reader, MAX_LINE_BYTES)
        .map_err(|e| err!("malformed response: {}", e.message))?
        .ok_or_else(|| err!("daemon closed the connection without responding"))?;
    let status_line = String::from_utf8(status_line)
        .map_err(|_| err!("daemon status line is not UTF-8"))?;
    let mut parts = status_line.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    ensure_http(version, &status_line)?;
    let status: u16 = parts
        .next()
        .ok_or_else(|| err!("daemon status line '{status_line}' has no code"))?
        .parse()
        .map_err(|_| err!("daemon status line '{status_line}' has a bad code"))?;

    let mut content_length: Option<usize> = None;
    loop {
        let line = read_line_capped(&mut reader, MAX_LINE_BYTES)
            .map_err(|e| err!("malformed response header: {}", e.message))?
            .ok_or_else(|| err!("daemon closed the connection inside response headers"))?;
        if line.is_empty() {
            break;
        }
        let text = String::from_utf8(line).map_err(|_| err!("response header is not UTF-8"))?;
        if let Some((name, value)) = text.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(
                    value
                        .trim()
                        .parse()
                        .map_err(|_| err!("daemon sent a bad Content-Length"))?,
                );
            }
        }
    }
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            if n > MAX_BODY_BYTES {
                return Err(err!("daemon response of {n} bytes exceeds the client cap"));
            }
            body.resize(n, 0);
            reader
                .read_exact(&mut body)
                .context("reading response body")?;
        }
        None => {
            // Connection: close, so EOF delimits the body.
            reader
                .take(MAX_BODY_BYTES as u64)
                .read_to_end(&mut body)
                .context("reading response body")?;
        }
    }
    Ok((status, body))
}

fn ensure_http(version: &str, line: &str) -> Result<()> {
    if version.starts_with("HTTP/1.") {
        Ok(())
    } else {
        Err(err!("'{line}' is not an HTTP response (wrong port?)"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse_bytes(input: &[u8]) -> std::result::Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(input.to_vec()))
    }

    #[test]
    fn parses_get_with_headers_and_query() {
        let req = parse_bytes(
            b"GET /v1/jobs/3/events?since=5 HTTP/1.1\r\nHost: x\r\nAccept: application/json\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/v1/jobs/3/events");
        assert_eq!(req.query.as_deref(), Some("since=5"));
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("ACCEPT"), Some("application/json"));
        assert!(req.http11);
        assert!(!req.wants_close());
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse_bytes(
            b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 15\r\n\r\n{\"config\": {}}\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body.len(), 15);
        assert!(req.body_json().is_ok());
    }

    #[test]
    fn connection_close_and_http10_want_close() {
        let req = parse_bytes(b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(req.wants_close());
        let req = parse_bytes(b"GET / HTTP/1.0\r\n\r\n").unwrap().unwrap();
        assert!(!req.http11);
        assert!(req.wants_close());
    }

    #[test]
    fn clean_eof_is_none() {
        assert!(parse_bytes(b"").unwrap().is_none());
        // A single stray CRLF then EOF is also a clean close.
        assert!(parse_bytes(b"\r\n").unwrap().is_none());
    }

    #[test]
    fn malformed_inputs_get_4xx_not_panics() {
        for (input, want) in [
            (b"NONSENSE\r\n\r\n" as &[u8], 400u16),
            (b"GET /\r\n\r\n", 400),
            (b"GET / HTTP/2\r\n\r\n", 505),
            (b"GET / SPAM HTTP/1.1\r\n\r\n", 400),
            (b"GET / HTTP/1.1\r\nbroken header\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\nContent-Length: oops\r\n\r\n", 400),
            (b"POST / HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort", 400),
            (b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n", 501),
            (b"GET / HTTP/1.1\r\nAbrupt", 400),
        ] {
            let e = parse_bytes(input).unwrap_err();
            assert_eq!(e.status, want, "input {:?} -> {}", input, e.message);
        }
    }

    #[test]
    fn size_caps_enforced() {
        // Request line over the cap.
        let mut line = b"GET /".to_vec();
        line.extend(vec![b'x'; MAX_LINE_BYTES]);
        line.extend(b" HTTP/1.1\r\n\r\n");
        assert_eq!(parse_bytes(&line).unwrap_err().status, 400);

        // Header section over the cap (each line legal on its own).
        let mut req = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..8 {
            req.extend(format!("X-Pad-{i}: {}\r\n", "y".repeat(4000)).into_bytes());
        }
        req.extend(b"\r\n");
        let e = parse_bytes(&req).unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.message.contains("header section"), "{}", e.message);

        // Declared body over the cap: rejected before allocation.
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert_eq!(parse_bytes(huge.as_bytes()).unwrap_err().status, 413);
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::ok(json::obj(vec![("a", json::num(1.0))])), false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"), "{text}");
        assert!(text.contains("Content-Length: 7\r\n"), "{text}");
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{\"a\":1}"), "{text}");

        let mut out = Vec::new();
        write_response(&mut out, &Response::error(404, "no such job"), true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("{\"error\":\"no such job\"}"), "{text}");
    }

    #[test]
    fn text_responses_ship_verbatim_with_their_content_type() {
        let body = "line one\nline two {\"not\": \"escaped\"}\n".to_string();
        let resp = Response::text("application/jsonl", body.clone());
        assert_eq!(resp.status, 200);
        let mut out = Vec::new();
        write_response(&mut out, &resp, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/jsonl\r\n"), "{text}");
        assert!(
            text.contains(&format!("Content-Length: {}\r\n", body.len())),
            "{text}"
        );
        assert!(text.ends_with(&format!("\r\n\r\n{body}")), "{text}");
    }

    #[test]
    fn loopback_server_roundtrip_and_keepalive() {
        let handler: Handler = Arc::new(|req: &Request| {
            Response::ok(json::obj(vec![
                ("path", json::s(&req.path)),
                ("method", json::s(&req.method)),
            ]))
        });
        let server = serve("127.0.0.1:0", handler).unwrap();
        let addr = server.local_addr().to_string();

        // Client helper sees a well-formed exchange.
        let (status, body) = http_call(&addr, "GET", "/v1/ping", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.get("path").unwrap().as_str(), Some("/v1/ping"));

        // Two requests on ONE raw connection: keep-alive works.
        let mut stream = TcpStream::connect(&addr).unwrap();
        stream
            .write_all(b"GET /first HTTP/1.1\r\nHost: t\r\n\r\nGET /second HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .unwrap();
        let mut text = String::new();
        stream.read_to_string(&mut text).unwrap();
        assert!(text.contains("\"path\":\"/first\""), "{text}");
        assert!(text.contains("\"path\":\"/second\""), "{text}");

        server.stop();
    }
}
