//! The versioned JSON API surface (`dpquant-serve-api` v1) routed over
//! [`http`](super::http).
//!
//! | Method | Path                    | Body                | Reply |
//! |--------|-------------------------|---------------------|-------|
//! | POST   | `/v1/jobs`              | `{"config": {...}, "tenant": ...?}` | 201 `{"id", "status"}` |
//! | GET    | `/v1/jobs`              | —                   | 200 `{"jobs": [...]}` |
//! | GET    | `/v1/jobs/{id}`         | —                   | 200 full status |
//! | GET    | `/v1/jobs/{id}/events`  | —                   | 200 epoch-event ring |
//! | GET    | `/v1/jobs/{id}/audit`   | —                   | 200 `dpquant-audit` JSONL (text) |
//! | POST   | `/v1/jobs/{id}/cancel`  | —                   | 200 `{"id", "status"}` |
//! | POST   | `/v1/tenants`           | `{"id", "budget_epsilon", "delta"?}` | 201 tenant status |
//! | GET    | `/v1/tenants`           | —                   | 200 `{"tenants": [...]}` |
//! | GET    | `/v1/tenants/{id}`      | —                   | 200 tenant status |
//! | GET    | `/v1/healthz`           | —                   | 200 counts + formats |
//! | GET    | `/v1/metrics`           | —                   | 200 live metrics snapshot |
//!
//! `GET /v1/metrics?format=prometheus` returns the registry's text
//! exposition (`text/plain; version=0.0.4`) instead of JSON; the audit
//! endpoint returns the job's raw `dpquant-audit` v1 stream
//! (`application/jsonl`) exactly as persisted under `--state-dir`.
//!
//! Every response body is JSON; every error is `{"error": "..."}` with
//! a 4xx status (404 unknown path/job/tenant, 405 wrong method, 400 bad
//! id or body, 409 cancel on a finished job or duplicate tenant). The
//! `config` object uses the `[train]`-section keys (see
//! [`config_from_json`]); unknown keys are 400s with a did-you-mean,
//! mirroring the CLI.
//!
//! A submit naming a `tenant` goes through budget admission (DESIGN.md
//! §15); refusal is a **403** `{"error": "budget_exhausted",
//! "remaining_epsilon", "estimated_epsilon", "tenant"}` whose
//! `remaining_epsilon` is bit-identical to `GET /v1/tenants/{id}`'s
//! (same ledger function, shortest-round-trip float formatting).
//!
//! `/v1/healthz` doubles as the compatibility probe: it reports the API
//! format/version plus the on-disk format versions this daemon speaks,
//! so `dpquant version` output can be checked against a live daemon.

use std::fmt::Display;
use std::sync::Arc;
use std::time::Instant;

use super::http::{Handler, Request, Response};
use super::jobs::{config_from_json, CancelOutcome, JobManager, SubmitError};
use super::ledger::{CreateError, LEDGER_FORMAT, LEDGER_VERSION};
use crate::coordinator::session::{CHECKPOINT_FORMAT, CHECKPOINT_VERSION};
use crate::exp::perf::{BENCH_FORMAT, BENCH_VERSION};
use crate::obs;
use crate::sweep::report::{REPORT_FORMAT, REPORT_VERSION};
use crate::util::json::{self, Json};

/// Wire-format tag of this API.
pub const API_FORMAT: &str = "dpquant-serve-api";
/// API version (the `/v1/` path prefix).
pub const API_VERSION: u64 = 1;

/// The daemon's request router. Shares the [`JobManager`] with whoever
/// started it (the CLI keeps a handle for shutdown).
pub struct Api {
    manager: Arc<JobManager>,
    /// Construction instant — the daemon's uptime epoch for
    /// `/v1/healthz` and the `/v1/metrics` jobs-per-second rate.
    start: Instant,
}

impl Api {
    /// An API over the given job manager.
    pub fn new(manager: Arc<JobManager>) -> Self {
        Self {
            manager,
            start: Instant::now(),
        }
    }

    /// Wrap into the boxed callback `http::serve` wants.
    pub fn into_handler(self) -> Handler {
        Arc::new(move |req: &Request| self.handle(req))
    }

    /// Route one request. Total: every (method, path) pair gets a
    /// response, and nothing a client sends reaches a panic.
    pub fn handle(&self, req: &Request) -> Response {
        let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
        let method = req.method.as_str();
        match segments.as_slice() {
            ["v1", "healthz"] => match method {
                "GET" => self.healthz(),
                _ => method_not_allowed(method, "GET /v1/healthz"),
            },
            ["v1", "metrics"] => match method {
                "GET" => self.metrics(req),
                _ => method_not_allowed(method, "GET /v1/metrics"),
            },
            ["v1", "jobs"] => match method {
                "GET" => Response::ok(json::obj(vec![("jobs", self.manager.jobs_json())])),
                "POST" => self.submit(req),
                _ => method_not_allowed(method, "GET or POST /v1/jobs"),
            },
            ["v1", "jobs", id] => {
                let Some(id) = parse_id(id) else {
                    return bad_id(id);
                };
                match method {
                    "GET" => match self.manager.job_json(id) {
                        Some(j) => Response::ok(j),
                        None => no_such_job(id),
                    },
                    _ => method_not_allowed(method, "GET /v1/jobs/{id}"),
                }
            }
            ["v1", "jobs", id, "audit"] => {
                let Some(id) = parse_id(id) else {
                    return bad_id(id);
                };
                match method {
                    "GET" => match self.manager.audit_text(id) {
                        None => no_such_job(id),
                        Some(None) => Response::error(
                            404,
                            format!(
                                "job {id} has no audit log (daemon running without \
                                 --state-dir, or the job predates audit logging)"
                            ),
                        ),
                        Some(Some(text)) => Response::text("application/jsonl", text),
                    },
                    _ => method_not_allowed(method, "GET /v1/jobs/{id}/audit"),
                }
            }
            ["v1", "jobs", id, "events"] => {
                let Some(id) = parse_id(id) else {
                    return bad_id(id);
                };
                match method {
                    "GET" => match self.manager.events_json(id) {
                        Some(mut j) => {
                            if let Json::Obj(o) = &mut j {
                                o.insert("id".into(), json::num(id as f64));
                            }
                            Response::ok(j)
                        }
                        None => no_such_job(id),
                    },
                    _ => method_not_allowed(method, "GET /v1/jobs/{id}/events"),
                }
            }
            ["v1", "tenants"] => match method {
                "GET" => Response::ok(json::obj(vec![(
                    "tenants",
                    Json::Arr(
                        self.manager
                            .ledger()
                            .tenants()
                            .iter()
                            .map(|d| d.to_json())
                            .collect(),
                    ),
                )])),
                "POST" => self.create_tenant(req),
                _ => method_not_allowed(method, "GET or POST /v1/tenants"),
            },
            ["v1", "tenants", id] => match method {
                "GET" => match self.manager.ledger().status(id) {
                    Some(doc) => Response::ok(doc.to_json()),
                    None => Response::error(404, format!("no such tenant '{id}'")),
                },
                _ => method_not_allowed(method, "GET /v1/tenants/{id}"),
            },
            ["v1", "jobs", id, "cancel"] => {
                let Some(id) = parse_id(id) else {
                    return bad_id(id);
                };
                match method {
                    "POST" => match self.manager.cancel(id) {
                        CancelOutcome::NotFound => no_such_job(id),
                        CancelOutcome::AlreadyOver(status) => Response::error(
                            409,
                            format!("job {id} already finished (status '{status}')"),
                        ),
                        CancelOutcome::CancelledQueued => id_status(id, "cancelled"),
                        CancelOutcome::Cancelling => id_status(id, "cancelling"),
                    },
                    _ => method_not_allowed(method, "POST /v1/jobs/{id}/cancel"),
                }
            }
            _ => Response::error(
                404,
                format!(
                    "no such endpoint '{} {}' (API {API_FORMAT} v{API_VERSION}; \
                     see GET /v1/healthz)",
                    req.method, req.path
                ),
            ),
        }
    }

    fn submit(&self, req: &Request) -> Response {
        let body = match req.body_json() {
            Ok(j) => j,
            Err(e) => return Response::error(400, format!("malformed JSON body: {e}")),
        };
        let Some(cfg_json) = body.get("config") else {
            return Response::error(
                400,
                "body must be {\"config\": {...}} with [train]-section keys",
            );
        };
        let cfg = match config_from_json(cfg_json) {
            Ok(c) => c,
            Err(e) => return Response::error(400, format!("bad config: {e:#}")),
        };
        let tenant = match body.get("tenant") {
            None | Some(Json::Null) => None,
            Some(Json::Str(t)) => Some(t.as_str()),
            Some(_) => {
                return Response::error(400, "'tenant' must be a string (a tenant id) or null")
            }
        };
        match self.manager.submit(cfg, tenant) {
            Ok(id) => Response::json(
                201,
                json::obj(vec![
                    ("id", json::num(id as f64)),
                    ("status", json::s("queued")),
                ]),
            ),
            Err(SubmitError::Invalid(e)) => Response::error(400, format!("rejected: {e:#}")),
            Err(SubmitError::UnknownTenant(t)) => {
                Response::error(404, format!("no such tenant '{t}'"))
            }
            // The 403 body is structured, not a plain message: clients
            // (and the loadgen) read `remaining_epsilon` off it, and it
            // must match the tenant status document bit-for-bit.
            Err(SubmitError::Exhausted {
                tenant,
                remaining_epsilon,
                estimated_epsilon,
            }) => Response::json(
                403,
                json::obj(vec![
                    ("error", json::s("budget_exhausted")),
                    ("tenant", json::s(&tenant)),
                    ("remaining_epsilon", json::num(remaining_epsilon)),
                    ("estimated_epsilon", json::num(estimated_epsilon)),
                ]),
            ),
        }
    }

    /// `POST /v1/tenants` `{"id": ..., "budget_epsilon": ..., "delta":
    /// ...?}` (δ defaults to the training default 1e-5).
    fn create_tenant(&self, req: &Request) -> Response {
        let body = match req.body_json() {
            Ok(j) => j,
            Err(e) => return Response::error(400, format!("malformed JSON body: {e}")),
        };
        let Some(id) = body.get("id").and_then(Json::as_str) else {
            return Response::error(
                400,
                "body must be {\"id\": \"...\", \"budget_epsilon\": N, \"delta\": N?}",
            );
        };
        let Some(budget) = body.get("budget_epsilon").and_then(Json::as_f64) else {
            return Response::error(400, "'budget_epsilon' must be a number");
        };
        let delta = match body.get("delta") {
            None | Some(Json::Null) => crate::config::TrainConfig::default().delta,
            Some(v) => match v.as_f64() {
                Some(d) => d,
                None => return Response::error(400, "'delta' must be a number"),
            },
        };
        match self.manager.ledger().create_tenant(id, budget, delta) {
            Ok(doc) => Response::json(201, doc.to_json()),
            Err(e @ CreateError::Invalid(_)) => Response::error(400, e.to_string()),
            Err(e @ CreateError::Exists(_)) => Response::error(409, e.to_string()),
        }
    }

    fn healthz(&self) -> Response {
        let c = self.manager.counts();
        Response::ok(json::obj(vec![
            ("status", json::s("ok")),
            ("format", json::s(API_FORMAT)),
            ("version", json::num(API_VERSION as f64)),
            ("uptime_seconds", json::num(self.start.elapsed().as_secs_f64())),
            ("workers", json::num(self.manager.workers() as f64)),
            ("queue_depth", json::num(c.queued as f64)),
            (
                "jobs",
                json::obj(vec![
                    ("queued", json::num(c.queued as f64)),
                    ("running", json::num(c.running as f64)),
                    ("done", json::num(c.done as f64)),
                    ("failed", json::num(c.failed as f64)),
                    ("cancelled", json::num(c.cancelled as f64)),
                ]),
            ),
            (
                "formats",
                Json::Arr(vec![
                    format_entry(CHECKPOINT_FORMAT, CHECKPOINT_VERSION),
                    format_entry(REPORT_FORMAT, REPORT_VERSION),
                    format_entry(API_FORMAT, API_VERSION),
                    format_entry(BENCH_FORMAT, u64::from(BENCH_VERSION)),
                    format_entry(obs::TRACE_FORMAT, obs::TRACE_VERSION),
                    format_entry(obs::METRICS_FORMAT, obs::METRICS_VERSION),
                    format_entry(obs::AUDIT_FORMAT, obs::AUDIT_VERSION),
                    format_entry(LEDGER_FORMAT, LEDGER_VERSION),
                ]),
            ),
        ]))
    }

    /// `GET /v1/metrics`: the `dpquant-metrics` v1 document extended
    /// with daemon-level job fields — per-status counts, throughput
    /// since start, live queue depth, and per-job ε spend — on top of
    /// the global registry snapshot (pool utilization, HTTP latency,
    /// kernel timings). `?format=prometheus` swaps the JSON document
    /// for the registry's Prometheus text exposition (scrape target);
    /// the daemon-level job fields live only in the JSON form.
    fn metrics(&self, req: &Request) -> Response {
        match query_param(req, "format") {
            None | Some("json") => {}
            Some("prometheus") => {
                return Response::text(
                    "text/plain; version=0.0.4",
                    obs::global().to_prometheus(),
                )
            }
            Some(other) => {
                return Response::error(
                    400,
                    format!("unknown metrics format '{other}' (want json or prometheus)"),
                )
            }
        }
        let c = self.manager.counts();
        let uptime = self.start.elapsed().as_secs_f64();
        let jobs_per_sec = if uptime > 0.0 { c.done as f64 / uptime } else { 0.0 };
        let per_job: std::collections::BTreeMap<String, Json> = self
            .manager
            .epsilons()
            .into_iter()
            .map(|(id, eps)| (id.to_string(), json::num(eps)))
            .collect();
        Response::ok(json::obj(vec![
            ("format", json::s(obs::METRICS_FORMAT)),
            ("version", json::num(obs::METRICS_VERSION as f64)),
            ("uptime_seconds", json::num(uptime)),
            ("workers", json::num(self.manager.workers() as f64)),
            ("queue_depth", json::num(self.manager.queue_depth() as f64)),
            (
                "jobs",
                json::obj(vec![
                    ("queued", json::num(c.queued as f64)),
                    ("running", json::num(c.running as f64)),
                    ("done", json::num(c.done as f64)),
                    ("failed", json::num(c.failed as f64)),
                    ("cancelled", json::num(c.cancelled as f64)),
                ]),
            ),
            ("jobs_per_sec", json::num(jobs_per_sec)),
            ("per_job_epsilon", Json::Obj(per_job)),
            ("per_tenant", self.manager.ledger().metrics_json()),
            ("metrics", obs::global().to_json()),
        ]))
    }
}

fn format_entry(name: &str, version: u64) -> Json {
    json::obj(vec![
        ("name", json::s(name)),
        ("version", json::num(version as f64)),
    ])
}

fn parse_id(s: &str) -> Option<u64> {
    s.parse().ok()
}

/// First value of `name` in the raw query string (`a=b&c=d`). No
/// percent-decoding — the API's parameter values are plain tokens.
fn query_param<'a>(req: &'a Request, name: &str) -> Option<&'a str> {
    req.query.as_deref()?.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=')?;
        (k == name).then_some(v)
    })
}

fn bad_id<M: Display>(id: M) -> Response {
    Response::error(400, format!("'{id}' is not a job id (want a non-negative integer)"))
}

fn no_such_job(id: u64) -> Response {
    Response::error(404, format!("no such job {id}"))
}

fn id_status(id: u64, status: &str) -> Response {
    Response::ok(json::obj(vec![
        ("id", json::num(id as f64)),
        ("status", json::s(status)),
    ]))
}

fn method_not_allowed(method: &str, allowed: &str) -> Response {
    Response::error(405, format!("method {method} not allowed here (use {allowed})"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn api() -> Api {
        Api::new(Arc::new(JobManager::new(1, None).unwrap()))
    }

    fn req(method: &str, path: &str, body: &str) -> Request {
        Request {
            method: method.into(),
            path: path.into(),
            query: None,
            headers: BTreeMap::new(),
            body: body.as_bytes().to_vec(),
            http11: true,
        }
    }

    #[test]
    fn routes_cover_errors_without_panics() {
        let api = api();
        // Unknown path.
        assert_eq!(api.handle(&req("GET", "/nope", "")).status, 404);
        assert_eq!(api.handle(&req("GET", "/v1", "")).status, 404);
        assert_eq!(api.handle(&req("GET", "/v1/jobs/1/extra/deep", "")).status, 404);
        // Wrong method.
        assert_eq!(api.handle(&req("DELETE", "/v1/jobs", "")).status, 405);
        assert_eq!(api.handle(&req("POST", "/v1/healthz", "")).status, 405);
        assert_eq!(api.handle(&req("POST", "/v1/jobs/1", "")).status, 405);
        assert_eq!(api.handle(&req("GET", "/v1/jobs/1/cancel", "")).status, 405);
        // Bad ids.
        assert_eq!(api.handle(&req("GET", "/v1/jobs/banana", "")).status, 400);
        assert_eq!(api.handle(&req("GET", "/v1/jobs/-3", "")).status, 400);
        // Unknown job ids.
        assert_eq!(api.handle(&req("GET", "/v1/jobs/42", "")).status, 404);
        assert_eq!(api.handle(&req("GET", "/v1/jobs/42/events", "")).status, 404);
        assert_eq!(api.handle(&req("POST", "/v1/jobs/42/cancel", "")).status, 404);
        // Bad submit bodies.
        assert_eq!(api.handle(&req("POST", "/v1/jobs", "not json")).status, 400);
        assert_eq!(api.handle(&req("POST", "/v1/jobs", "{}")).status, 400);
        let e = api.handle(&req("POST", "/v1/jobs", r#"{"config": {"epochs": -1}}"#));
        assert_eq!(e.status, 400);
        let e = api.handle(&req("POST", "/v1/jobs", r#"{"config": {"epcohs": 2}}"#));
        assert_eq!(e.status, 400);
        assert!(e.body.get("error").unwrap().as_str().unwrap().contains("did you mean"));
    }

    #[test]
    fn healthz_reports_formats_and_counts() {
        let api = api();
        let resp = api.handle(&req("GET", "/v1/healthz", ""));
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body.get("format").unwrap().as_str(), Some(API_FORMAT));
        assert_eq!(resp.body.get("workers").unwrap().as_usize(), Some(1));
        let formats = resp.body.get("formats").unwrap().as_arr().unwrap();
        let names: Vec<&str> = formats
            .iter()
            .map(|f| f.get("name").unwrap().as_str().unwrap())
            .collect();
        assert!(names.contains(&"dpquant-trainsession"), "{names:?}");
        assert!(names.contains(&"dpquant-sweep-report"), "{names:?}");
        assert!(names.contains(&"dpquant-serve-api"), "{names:?}");
        assert!(names.contains(&"dpquant-bench"), "{names:?}");
        assert!(names.contains(&"dpquant-trace"), "{names:?}");
        assert!(names.contains(&"dpquant-metrics"), "{names:?}");
        assert!(names.contains(&"dpquant-audit"), "{names:?}");
        assert!(names.contains(&"dpquant-serve-ledger"), "{names:?}");
        let uptime = resp.body.get("uptime_seconds").unwrap().as_f64().unwrap();
        assert!(uptime >= 0.0, "{uptime}");
        let jobs = resp.body.get("jobs").unwrap();
        assert_eq!(jobs.get("queued").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn metrics_endpoint_serves_the_registry_snapshot() {
        let api = api();
        let resp = api.handle(&req("GET", "/v1/metrics", ""));
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.body.get("format").unwrap().as_str(),
            Some("dpquant-metrics")
        );
        assert_eq!(resp.body.get("version").unwrap().as_f64(), Some(1.0));
        assert!(resp.body.get("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(resp.body.get("jobs").unwrap().get("done").unwrap().as_usize(), Some(0));
        assert!(resp.body.get("per_job_epsilon").unwrap().as_obj().is_some());
        assert!(resp.body.get("per_tenant").unwrap().as_obj().is_some());
        let m = resp.body.get("metrics").unwrap();
        assert!(m.get("counters").is_some());
        assert!(m.get("gauges").is_some());
        assert!(m.get("histograms").is_some());
        assert_eq!(api.handle(&req("POST", "/v1/metrics", "")).status, 405);
    }

    #[test]
    fn metrics_format_prometheus_serves_the_text_exposition() {
        let api = api();
        let mut r = req("GET", "/v1/metrics", "");
        r.query = Some("format=prometheus".into());
        let resp = api.handle(&r);
        assert_eq!(resp.status, 200);
        let (ct, body) = resp.as_text().expect("prometheus reply must be text");
        assert_eq!(ct, "text/plain; version=0.0.4");
        assert!(body.contains("# TYPE"), "{body}");
        // Explicit json and the default agree on shape.
        let mut r = req("GET", "/v1/metrics", "");
        r.query = Some("format=json".into());
        let resp = api.handle(&r);
        assert_eq!(resp.status, 200);
        assert!(resp.as_text().is_none());
        assert!(resp.body.get("metrics").is_some());
        // Unknown formats are a 400, not a guess.
        let mut r = req("GET", "/v1/metrics", "");
        r.query = Some("format=xml".into());
        assert_eq!(api.handle(&r).status, 400);
    }

    #[test]
    fn audit_route_covers_the_error_space() {
        let api = api();
        assert_eq!(api.handle(&req("GET", "/v1/jobs/42/audit", "")).status, 404);
        assert_eq!(api.handle(&req("GET", "/v1/jobs/nan/audit", "")).status, 400);
        assert_eq!(api.handle(&req("POST", "/v1/jobs/42/audit", "")).status, 405);
    }

    #[test]
    fn submit_status_events_cancel_through_the_router() {
        let api = api();
        let submit_body = r#"{"config": {"backend": "mock", "dataset_size": 96,
            "val_size": 32, "batch_size": 16, "physical_batch": 32, "epochs": 2}}"#;
        let resp = api.handle(&req("POST", "/v1/jobs", submit_body));
        assert_eq!(resp.status, 201, "{:?}", resp.body.to_string());
        let id = resp.body.get("id").unwrap().as_usize().unwrap();
        assert_eq!(id, 1);

        // Poll through the router until done.
        let mut status = String::new();
        for _ in 0..2000 {
            let s = api.handle(&req("GET", "/v1/jobs/1", ""));
            assert_eq!(s.status, 200);
            status = s.body.get("status").unwrap().as_str().unwrap().to_string();
            if status == "done" || status == "failed" {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(status, "done");

        let list = api.handle(&req("GET", "/v1/jobs", ""));
        assert_eq!(list.body.get("jobs").unwrap().as_arr().unwrap().len(), 1);
        let events = api.handle(&req("GET", "/v1/jobs/1/events", ""));
        assert_eq!(events.status, 200);
        assert_eq!(events.body.get("id").unwrap().as_usize(), Some(1));
        assert_eq!(events.body.get("total").unwrap().as_usize(), Some(2));

        // Cancelling a finished job is a 409, not a crash.
        let c = api.handle(&req("POST", "/v1/jobs/1/cancel", ""));
        assert_eq!(c.status, 409);

        // No --state-dir means a finished job has no audit log: a 404
        // that says so, distinct from the unknown-job 404.
        let a = api.handle(&req("GET", "/v1/jobs/1/audit", ""));
        assert_eq!(a.status, 404);
        let msg = a.body.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("no audit log"), "{msg}");
    }

    #[test]
    fn tenant_endpoints_create_list_status_and_reject() {
        let api = api();
        // Bad bodies.
        assert_eq!(api.handle(&req("POST", "/v1/tenants", "nope")).status, 400);
        assert_eq!(api.handle(&req("POST", "/v1/tenants", "{}")).status, 400);
        let e = api.handle(&req(
            "POST",
            "/v1/tenants",
            r#"{"id": "bad/slash", "budget_epsilon": 2}"#,
        ));
        assert_eq!(e.status, 400);
        // Create, duplicate, list, status.
        let c = api.handle(&req(
            "POST",
            "/v1/tenants",
            r#"{"id": "acme", "budget_epsilon": 2.5}"#,
        ));
        assert_eq!(c.status, 201, "{}", c.body.to_string());
        assert_eq!(c.body.get("remaining_epsilon").unwrap().as_f64(), Some(2.5));
        let dup = api.handle(&req(
            "POST",
            "/v1/tenants",
            r#"{"id": "acme", "budget_epsilon": 1}"#,
        ));
        assert_eq!(dup.status, 409);
        let list = api.handle(&req("GET", "/v1/tenants", ""));
        assert_eq!(list.body.get("tenants").unwrap().as_arr().unwrap().len(), 1);
        let s = api.handle(&req("GET", "/v1/tenants/acme", ""));
        assert_eq!(s.status, 200);
        assert_eq!(s.body.get("delta").unwrap().as_f64(), Some(1e-5));
        assert_eq!(api.handle(&req("GET", "/v1/tenants/ghost", "")).status, 404);
        assert_eq!(api.handle(&req("DELETE", "/v1/tenants/acme", "")).status, 405);
    }

    #[test]
    fn exhausted_submit_403_matches_tenant_status_bitwise() {
        let api = api();
        // A budget far below one tiny job's estimate: first tenant
        // submit must be refused.
        let c = api.handle(&req(
            "POST",
            "/v1/tenants",
            r#"{"id": "tiny", "budget_epsilon": 1e-6}"#,
        ));
        assert_eq!(c.status, 201);
        let submit_body = r#"{"tenant": "tiny", "config": {"backend": "mock",
            "dataset_size": 96, "val_size": 32, "batch_size": 16,
            "physical_batch": 32, "epochs": 2}}"#;
        let resp = api.handle(&req("POST", "/v1/jobs", submit_body));
        assert_eq!(resp.status, 403, "{}", resp.body.to_string());
        assert_eq!(
            resp.body.get("error").unwrap().as_str(),
            Some("budget_exhausted")
        );
        let rejected_remaining = resp.body.get("remaining_epsilon").unwrap().as_f64().unwrap();
        let status = api.handle(&req("GET", "/v1/tenants/tiny", ""));
        let status_remaining = status.body.get("remaining_epsilon").unwrap().as_f64().unwrap();
        assert_eq!(rejected_remaining.to_bits(), status_remaining.to_bits());
        // Unknown tenants are 404, not 403.
        let ghost = submit_body.replace("tiny", "ghost");
        assert_eq!(api.handle(&req("POST", "/v1/jobs", &ghost)).status, 404);
        // And a non-string tenant field is a 400.
        let bad = submit_body.replace("\"tiny\"", "7");
        assert_eq!(api.handle(&req("POST", "/v1/jobs", &bad)).status, 400);
    }
}
