//! The DP-training job server: submit, queue, observe, cancel, and
//! recover training work as a **service** instead of babysitting
//! one-shot CLI processes.
//!
//! * [`http`]   — zero-dependency threaded HTTP/1.1 on `std::net`
//!   (hard request-size caps, malformed input → 4xx, never a panic),
//!   plus the `TcpStream` client the CLI verbs use;
//! * [`jobs`]   — the job manager: `session::validate_config`-gated
//!   submission, monotonically increasing ids, a long-lived
//!   [`WorkerPool`](crate::sweep::pool::WorkerPool) of `--jobs N`
//!   concurrent `TrainSession`s, per-job epoch-event ring buffers, and
//!   checkpoint-backed durability (`--state-dir`: manifest + a
//!   `dpquant-trainsession` checkpoint per epoch — a `kill -9`'d daemon
//!   restarts and finishes every job bit-exactly);
//! * [`api`]    — the versioned JSON endpoints (`dpquant-serve-api`
//!   v1: `POST /v1/jobs`, `GET /v1/jobs[/{id}[/events]]`,
//!   `POST /v1/jobs/{id}/cancel`, `GET /v1/healthz`,
//!   `GET /v1/metrics` — the live `dpquant-metrics` v1 snapshot:
//!   job counts and throughput, queue depth, per-job ε spend, and the
//!   global registry of pool/HTTP/kernel telemetry);
//! * [`ledger`] — the per-tenant privacy-budget ledger
//!   (`dpquant-serve-ledger` v1, DESIGN.md §15): lifetime (ε, δ)
//!   budgets, reservation-based admission control on submit, debit of
//!   the actual spend on completion, refunds on cancel/failure, and
//!   crash-safe durability (reservations rebuilt during recovery);
//! * [`client`] — the typed client + the `dpquant job
//!   submit|list|status|events|cancel|wait` and `dpquant tenant
//!   create|list|status` CLI verbs;
//! * [`loadgen`] — the zero-dep loopback load generator
//!   (`dpquant loadgen`): hammers the HTTP API from N tenants, drives
//!   budgets into exhaustion on purpose, and writes submit/wait latency
//!   percentiles plus accept/reject counts into `BENCH_serve.json`.
//!
//! **Thread ownership** (DESIGN.md §12): the accept thread owns the
//! listener; each connection gets a short-lived handler thread that
//! only ever touches the job table through the manager's mutex; each
//! pool worker owns its executor/session/datasets outright. Training
//! state is never shared across threads — only observed through the
//! table.
//!
//! **Determinism contract**: workers open backends through
//! `backend::open_sweep_executor` (native pinned to one internal
//! thread), so a job's final metrics are a pure function of its config —
//! byte-identical to `DPQUANT_THREADS=1 dpquant train` with the same
//! config, regardless of how many jobs run concurrently. `tests/serve.rs`
//! and CI's `serve-smoke` enforce this end to end.

pub mod api;
pub mod client;
pub mod http;
pub mod jobs;
pub mod ledger;
pub mod loadgen;

use std::sync::Arc;

use crate::cli::Args;
use crate::config::ServeConfig;
use crate::util::error::Result;
use self::api::{Api, API_FORMAT, API_VERSION};
use self::jobs::JobManager;

/// A running daemon: HTTP server + job manager. Embeddable (tests start
/// one on `127.0.0.1:0`); the CLI wraps it in [`run_serve`].
pub struct Daemon {
    /// Shared with the HTTP handler; kept public so embedders can
    /// observe jobs without going over the wire.
    pub manager: Arc<JobManager>,
    server: http::Server,
}

impl Daemon {
    /// Bind `addr`, recover state from `state_dir` (if any), start
    /// `workers` job workers, and begin serving.
    pub fn start(addr: &str, workers: usize, state_dir: Option<&str>) -> Result<Daemon> {
        let manager = Arc::new(JobManager::new(workers, state_dir)?);
        let server = http::serve(addr, Api::new(Arc::clone(&manager)).into_handler())?;
        Ok(Daemon { manager, server })
    }

    /// The actually-bound `host:port` (resolves port 0).
    pub fn addr(&self) -> String {
        self.server.local_addr().to_string()
    }

    /// Stop accepting connections and drop the daemon. Worker threads
    /// drain outstanding jobs when the last manager handle drops
    /// (cancel jobs first for a fast exit).
    pub fn stop(self) {
        self.server.stop();
    }
}

/// `dpquant serve --addr H:P --jobs N --state-dir DIR` — run the daemon
/// until killed.
pub fn run_serve(args: &Args) -> Result<()> {
    let sc = ServeConfig::from_args(args)?;
    // The daemon always feeds `GET /v1/metrics`; recording never
    // touches job outputs (the determinism contract above).
    crate::obs::set_kernel_timing(true);
    let daemon = Daemon::start(&sc.addr, sc.jobs, sc.state_dir.as_deref())?;
    let counts = daemon.manager.counts();
    let recovered = counts.queued + counts.running + counts.done + counts.failed + counts.cancelled;
    println!(
        "dpquant serve: listening on http://{} ({} workers, state dir: {})",
        daemon.addr(),
        sc.jobs,
        sc.state_dir.as_deref().unwrap_or("<none — jobs die with the process>")
    );
    if recovered > 0 {
        println!(
            "recovered {recovered} jobs from the state dir ({} re-queued)",
            counts.queued
        );
    }
    println!(
        "API {API_FORMAT} v{API_VERSION}: POST /v1/jobs  GET /v1/jobs[/ID[/events]]  \
         POST /v1/jobs/ID/cancel  POST/GET /v1/tenants[/ID]  GET /v1/healthz  GET /v1/metrics"
    );
    println!("submit with: dpquant job submit --addr {} [train flags]", daemon.addr());
    daemon.server.join();
    Ok(())
}
