//! `dpquant loadgen` — a zero-dependency loopback load generator for
//! the multi-tenant daemon.
//!
//! Spins up an embedded [`Daemon`] on `127.0.0.1:0` (or targets an
//! external one via `--addr`), creates `--tenants N` tenants whose
//! budgets are sized to fit only about **half** of their
//! `--jobs-per-tenant M` jobs — driving the ledger into exhaustion on
//! purpose — and hammers the HTTP API from `--concurrency C` client
//! threads using the same [`http_call`] the CLI verbs use. Each thread
//! submits a job, records the submit round-trip, then polls the job to
//! a terminal status and records the wait; 403 budget refusals are
//! counted, not retried (the point is to measure the refusal path).
//!
//! The run reports accept/reject counts and submit/wait latency
//! percentiles to stdout and writes them as a `dpquant-bench` v1 blob
//! of the `"serve"` family to `--out` (default `BENCH_serve.json`) —
//! validatable with `dpquant bench --check`, exactly like
//! `BENCH_native.json`.
//!
//! Jobs are tiny mock-backend configs: the generator measures the
//! *serving* stack (admission, queueing, fairness, recovery machinery),
//! not kernel throughput — that's `dpquant bench`'s job.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::http::http_call;
use super::jobs::config_to_json;
use super::ledger::schedule_cost;
use super::Daemon;
use crate::cli::Args;
use crate::config::TrainConfig;
use crate::exp::perf::{BENCH_FORMAT, BENCH_VERSION};
use crate::privacy::{Mechanism, RdpAccountant};
use crate::util::error::{err, Result};
use crate::util::json::{self, Json};

/// The tiny mock job every loadgen submit carries (seed varies per
/// job). Mock backend: admission math is identical to native's, the
/// training loop is just cheap.
fn loadgen_cfg(seed: u64, epochs: usize) -> TrainConfig {
    TrainConfig {
        backend: "mock".into(),
        dataset_size: 96,
        val_size: 32,
        batch_size: 16,
        physical_batch: 32,
        epochs,
        seed,
        ..TrainConfig::default()
    }
}

/// A tenant budget that admits about half of `per_tenant` copies of
/// `cfg`: the composed ε of `ceil(per_tenant/2)` worst-case schedules.
/// Composition is done the ledger's way (one accountant, records in
/// sequence), so "fits k jobs" means exactly what admission will
/// compute.
fn half_fleet_budget(cfg: &TrainConfig, per_tenant: usize) -> f64 {
    let cost = schedule_cost(cfg);
    let mut acc = RdpAccountant::new();
    for _ in 0..per_tenant.div_ceil(2) {
        for r in cost.records() {
            acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
        }
    }
    acc.epsilon(cfg.delta).0
}

fn ms_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Nearest-rank percentile of an already-sorted sample: the value at
/// rank `⌈p/100 · n⌉` (1-based, clamped to `[1, n]`, so p = 0 reads the
/// minimum and p = 100 the maximum); 0.0 for an empty sample
/// (all-rejected runs still emit finite, checkable numbers).
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

fn percentile_obj(samples: &mut Vec<f64>) -> Json {
    samples.sort_by(|a, b| a.total_cmp(b));
    json::obj(vec![
        ("p50", json::num(percentile(samples, 50.0))),
        ("p90", json::num(percentile(samples, 90.0))),
        ("p99", json::num(percentile(samples, 99.0))),
        ("max", json::num(samples.last().copied().unwrap_or(0.0))),
        ("count", json::num(samples.len() as f64)),
    ])
}

/// `dpquant loadgen --tenants N --jobs-per-tenant M --concurrency C
/// [--epochs E] [--addr HOST:PORT] [--out PATH]` — see the module doc.
pub fn run_loadgen(args: &Args) -> Result<()> {
    args.require_known(
        "loadgen",
        &["tenants", "jobs-per-tenant", "concurrency", "epochs", "jobs", "addr", "out"],
        &[],
    )?;
    let n_tenants = args.usize_or("tenants", 3)?.max(1);
    let per_tenant = args.usize_or("jobs-per-tenant", 4)?.max(1);
    // Well under the server's per-connection cap; loadgen opens one
    // short-lived connection per call.
    let concurrency = args.usize_or("concurrency", 4)?.clamp(1, 16);
    let epochs = args.usize_or("epochs", 2)?.max(1);
    let workers = args.usize_or("jobs", 2)?.max(1);
    let out = args.str_or("out", "BENCH_serve.json");

    // Embedded daemon by default — the "loopback" in loopback loadgen.
    // `--addr` redirects the hammering at an already-running daemon
    // (tenant names are pid-suffixed so reruns don't collide).
    let embedded = match args.get("addr") {
        Some(_) => None,
        None => Some(Daemon::start("127.0.0.1:0", workers, None)?),
    };
    let addr = match (&embedded, args.get("addr")) {
        (Some(d), _) => d.addr(),
        (None, Some(a)) => a.to_string(),
        (None, None) => unreachable!("no addr and no embedded daemon"),
    };

    let base = loadgen_cfg(0, epochs);
    let budget = half_fleet_budget(&base, per_tenant);
    let tenant_names: Vec<String> = (0..n_tenants)
        .map(|i| format!("load-{}-t{i}", std::process::id()))
        .collect();
    for name in &tenant_names {
        let body = json::obj(vec![
            ("id", json::s(name)),
            ("budget_epsilon", json::num(budget)),
            ("delta", json::num(base.delta)),
        ]);
        let (status, resp) = http_call(&addr, "POST", "/v1/tenants", Some(&body))?;
        if status != 201 {
            return Err(err!("loadgen: creating tenant {name} failed ({status}): {resp}"));
        }
    }
    println!(
        "loadgen: {n_tenants} tenants x {per_tenant} jobs (concurrency {concurrency}) \
         against http://{addr}"
    );
    println!(
        "  per-tenant budget ε = {budget} (≈ {} of {per_tenant} jobs — exhaustion is the point)",
        per_tenant.div_ceil(2)
    );

    // Interleave tenants round-by-round so every tenant is still
    // submitting when budgets start running dry.
    let mut items: VecDeque<(String, Json)> = VecDeque::new();
    for round in 0..per_tenant {
        for (t, name) in tenant_names.iter().enumerate() {
            let cfg = loadgen_cfg((round * n_tenants + t) as u64, epochs);
            items.push_back((
                name.clone(),
                json::obj(vec![
                    ("config", config_to_json(&cfg)),
                    ("tenant", json::s(name)),
                ]),
            ));
        }
    }
    let queue = Mutex::new(items);
    let submit_ms = Mutex::new(Vec::<f64>::new());
    let wait_ms = Mutex::new(Vec::<f64>::new());
    let accepted = AtomicU64::new(0);
    let rejected_budget = AtomicU64::new(0);
    let completed = AtomicU64::new(0);
    let errors = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for _ in 0..concurrency {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop_front();
                let Some((_tenant, body)) = item else { break };
                let t0 = Instant::now();
                let reply = http_call(&addr, "POST", "/v1/jobs", Some(&body));
                let elapsed = ms_since(t0);
                match reply {
                    Ok((201, resp)) => {
                        submit_ms.lock().unwrap().push(elapsed);
                        accepted.fetch_add(1, Ordering::Relaxed);
                        let Some(id) = resp.get("id").and_then(Json::as_usize) else {
                            errors.fetch_add(1, Ordering::Relaxed);
                            continue;
                        };
                        // Poll to terminal; ~10 minutes of patience is
                        // a hang, not a slow mock job.
                        let t1 = Instant::now();
                        let mut outcome = None;
                        for _ in 0..120_000 {
                            match http_call(&addr, "GET", &format!("/v1/jobs/{id}"), None) {
                                Ok((200, s)) => {
                                    let st = s
                                        .get("status")
                                        .and_then(Json::as_str)
                                        .unwrap_or("")
                                        .to_string();
                                    if matches!(st.as_str(), "done" | "failed" | "cancelled") {
                                        outcome = Some(st);
                                        break;
                                    }
                                }
                                _ => break,
                            }
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        wait_ms.lock().unwrap().push(ms_since(t1));
                        match outcome.as_deref() {
                            Some("done") => {
                                completed.fetch_add(1, Ordering::Relaxed);
                            }
                            _ => {
                                errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    Ok((403, resp))
                        if resp.get("error").and_then(Json::as_str)
                            == Some("budget_exhausted") =>
                    {
                        submit_ms.lock().unwrap().push(elapsed);
                        rejected_budget.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok((status, resp)) => {
                        eprintln!("loadgen: unexpected submit reply {status}: {resp}");
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        eprintln!("loadgen: submit failed: {e:#}");
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });

    let submitted = (n_tenants * per_tenant) as u64;
    let accepted = accepted.into_inner();
    let rejected_budget = rejected_budget.into_inner();
    let completed = completed.into_inner();
    let errors = errors.into_inner();
    let mut submit_ms = submit_ms.into_inner().unwrap();
    let mut wait_ms = wait_ms.into_inner().unwrap();
    let submit_obj = percentile_obj(&mut submit_ms);
    let wait_obj = percentile_obj(&mut wait_ms);

    println!(
        "  submitted {submitted}: accepted {accepted}, rejected(budget) {rejected_budget}, \
         errors {errors}; completed {completed}"
    );
    for (label, o) in [("submit", &submit_obj), ("wait", &wait_obj)] {
        let g = |k: &str| o.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        println!(
            "  {label} latency ms: p50 {:.2}  p90 {:.2}  p99 {:.2}  max {:.2}",
            g("p50"),
            g("p90"),
            g("p99"),
            g("max")
        );
    }

    // Mirror into the global registry (same idiom as `dpquant bench`).
    let reg = crate::obs::global();
    reg.gauge("bench.serve.accepted").set(accepted as f64);
    reg.gauge("bench.serve.rejected_budget").set(rejected_budget as f64);
    for (label, o) in [("submit_ms", &submit_obj), ("wait_ms", &wait_obj)] {
        for p in ["p50", "p90", "p99"] {
            let v = o.get(p).and_then(Json::as_f64).unwrap_or(0.0);
            reg.gauge(&format!("bench.serve.{label}.{p}")).set(v);
        }
    }

    let doc = json::obj(vec![
        ("format", json::s(BENCH_FORMAT)),
        ("version", json::num(BENCH_VERSION as f64)),
        ("family", json::s("serve")),
        ("quick", Json::Bool(std::env::var_os("DPQUANT_BENCH_QUICK").is_some())),
        ("provisional", Json::Bool(false)),
        (
            "load",
            json::obj(vec![
                ("tenants", json::num(n_tenants as f64)),
                ("jobs_per_tenant", json::num(per_tenant as f64)),
                ("concurrency", json::num(concurrency as f64)),
                ("workers", json::num(workers as f64)),
                ("budget_epsilon", json::num(budget)),
            ]),
        ),
        (
            "counts",
            json::obj(vec![
                ("submitted", json::num(submitted as f64)),
                ("accepted", json::num(accepted as f64)),
                ("rejected_budget", json::num(rejected_budget as f64)),
                ("completed", json::num(completed as f64)),
                ("errors", json::num(errors as f64)),
            ]),
        ),
        ("submit_ms", submit_obj),
        ("wait_ms", wait_obj),
    ]);
    std::fs::write(&out, format!("{doc}\n"))?;
    println!("[loadgen json -> {out}]  (validate: dpquant bench --check {out})");

    if let Some(daemon) = embedded {
        daemon.stop();
    }
    if errors > 0 {
        return Err(err!("loadgen finished with {errors} errors (see stderr above)"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_nearest_rank_and_total() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
        // True nearest-rank (⌈p/100·n⌉, 1-based) — these two
        // distinguish it from the old round(p/100·(n−1)) interpolation,
        // which returned 3.0 and 2.0 respectively.
        assert_eq!(percentile(&[1.0, 2.0, 3.0, 4.0], 50.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 25.0), 1.0);
        // p90 of 10 samples is the 9th order statistic, not the 10th.
        assert_eq!(percentile(&v, 90.0), 9.0);
        // NaN-free sorting path.
        let mut v = vec![3.0, 1.0, 2.0];
        let o = percentile_obj(&mut v);
        assert_eq!(o.get("p50").unwrap().as_f64(), Some(2.0));
        assert_eq!(o.get("max").unwrap().as_f64(), Some(3.0));
        assert_eq!(o.get("count").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn half_fleet_budget_sits_between_half_and_full_fleet() {
        let cfg = loadgen_cfg(0, 2);
        let one = schedule_cost(&cfg).epsilon;
        let budget = half_fleet_budget(&cfg, 4); // fits 2 of 4 jobs
        assert!(budget > one, "budget {budget} must fit more than one job ({one})");
        let mut acc = RdpAccountant::new();
        let cost = schedule_cost(&cfg);
        for _ in 0..4 {
            acc.record(
                Mechanism::Training,
                cost.sample_rate,
                cost.noise_multiplier,
                cost.train_steps,
            );
            acc.record(
                Mechanism::Analysis,
                cost.analysis_rate,
                cost.analysis_sigma,
                cost.analysis_steps,
            );
        }
        let full = acc.epsilon(cfg.delta).0;
        assert!(budget < full, "budget {budget} must NOT fit the whole fleet ({full})");
    }

    #[test]
    fn loadgen_end_to_end_exhausts_and_emits_checkable_json() {
        let dir = std::env::temp_dir().join(format!("dpquant-loadgen-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_serve.json");
        let argv = format!(
            "loadgen --tenants 2 --jobs-per-tenant 2 --concurrency 2 --epochs 1 --out {}",
            out.display()
        );
        let args = Args::parse(argv.split_whitespace().map(String::from)).unwrap();
        run_loadgen(&args).unwrap();
        let doc = json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(doc.get("format").unwrap().as_str(), Some(BENCH_FORMAT));
        assert_eq!(doc.get("family").unwrap().as_str(), Some("serve"));
        let counts = doc.get("counts").unwrap();
        // Budget fits ceil(2/2) = 1 job per tenant: the second submit
        // of each tenant must be a 403.
        assert_eq!(counts.get("submitted").unwrap().as_usize(), Some(4));
        assert_eq!(counts.get("accepted").unwrap().as_usize(), Some(2));
        assert_eq!(counts.get("rejected_budget").unwrap().as_usize(), Some(2));
        assert_eq!(counts.get("errors").unwrap().as_usize(), Some(0));
        assert!(doc.get("submit_ms").unwrap().get("p99").unwrap().as_f64().is_some());
        assert!(doc.get("wait_ms").unwrap().get("p50").unwrap().as_f64().is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
