//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime (shapes, parameter layout, file names).

use crate::util::json::{self, Json};
use std::collections::BTreeMap;

/// One parameter tensor's metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamInfo {
    /// Parameter name as the compiler emitted it.
    pub name: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

impl ParamInfo {
    /// Scalar element count (min 1, so scalars count too).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
    /// Weight tensors (quantizable compute operands) end in `_w`; scales,
    /// biases and norm parameters are "overhead" tensors.
    pub fn is_weight(&self) -> bool {
        self.name.ends_with("_w")
    }
}

/// Everything the runtime needs to drive one (model, dataset, quantizer)
/// graph pair.
#[derive(Clone, Debug)]
pub struct GraphInfo {
    /// Model family the graph was compiled for.
    pub model: String,
    /// Dataset the graph was compiled for.
    pub dataset: String,
    /// Quantizer baked into the train graph.
    pub quantizer: String,
    /// Physical batch size baked into the executables.
    pub batch: usize,
    /// Per-sample clipping norm C baked into the train graph.
    pub clip_norm: f64,
    /// Number of output classes.
    pub n_classes: usize,
    /// How many layers accept a quant-mask entry.
    pub n_quant_layers: usize,
    /// Names of the quantizable layers, mask order.
    pub quant_layer_names: Vec<String>,
    /// Shape of one input example.
    pub example_shape: Vec<usize>,
    /// Input dtype (`f32` or a token-id integer type).
    pub example_dtype: String,
    /// Parameter tensors, graph argument order.
    pub params: Vec<ParamInfo>,
    /// Relative path of the train graph's HLO text.
    pub train_hlo: String,
    /// Relative path of the eval graph's HLO text.
    pub eval_hlo: String,
    /// Relative path of the initial-weights blob.
    pub weights: String,
}

impl GraphInfo {
    /// Elements per example.
    pub fn example_numel(&self) -> usize {
        self.example_shape.iter().product()
    }
    /// Total scalar parameter count.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(ParamInfo::numel).sum()
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Graphs by tag (`model_dataset_quantizer`).
    pub graphs: BTreeMap<String, GraphInfo>,
}

fn get_str(o: &Json, key: &str) -> Result<String, String> {
    o.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string '{key}'"))
}

fn get_usize(o: &Json, key: &str) -> Result<usize, String> {
    o.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("missing number '{key}'"))
}

impl Manifest {
    /// Parse manifest JSON, validating every graph entry.
    pub fn parse(text: &str) -> Result<Self, String> {
        let root = json::parse(text)?;
        let graphs_json = root
            .get("graphs")
            .and_then(Json::as_obj)
            .ok_or("manifest missing 'graphs'")?;
        let mut graphs = BTreeMap::new();
        for (tag, g) in graphs_json {
            let params = g
                .get("params")
                .and_then(Json::as_arr)
                .ok_or("graph missing 'params'")?
                .iter()
                .map(|p| {
                    Ok(ParamInfo {
                        name: get_str(p, "name")?,
                        shape: p
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or("param missing shape")?
                            .iter()
                            .map(|d| d.as_usize().ok_or_else(|| "bad dim".to_string()))
                            .collect::<Result<_, String>>()?,
                    })
                })
                .collect::<Result<Vec<_>, String>>()?;
            let quant_layer_names = g
                .get("quant_layer_names")
                .and_then(Json::as_arr)
                .ok_or("missing quant_layer_names")?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| "bad layer name".to_string())
                })
                .collect::<Result<Vec<_>, String>>()?;
            let example_shape = g
                .get("example_shape")
                .and_then(Json::as_arr)
                .ok_or("missing example_shape")?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| "bad dim".to_string()))
                .collect::<Result<Vec<_>, String>>()?;
            graphs.insert(
                tag.clone(),
                GraphInfo {
                    model: get_str(g, "model")?,
                    dataset: get_str(g, "dataset")?,
                    quantizer: get_str(g, "quantizer")?,
                    batch: get_usize(g, "batch")?,
                    clip_norm: g
                        .get("clip_norm")
                        .and_then(Json::as_f64)
                        .ok_or("missing clip_norm")?,
                    n_classes: get_usize(g, "n_classes")?,
                    n_quant_layers: get_usize(g, "n_quant_layers")?,
                    quant_layer_names,
                    example_shape,
                    example_dtype: get_str(g, "example_dtype")?,
                    params,
                    train_hlo: get_str(g, "train_hlo")?,
                    eval_hlo: get_str(g, "eval_hlo")?,
                    weights: get_str(g, "weights")?,
                },
            );
        }
        Ok(Self { graphs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "graphs": {
        "m_d_q": {
          "model": "m", "dataset": "d", "quantizer": "q",
          "batch": 8, "clip_norm": 1.0, "n_classes": 10,
          "n_quant_layers": 2,
          "quant_layer_names": ["conv1", "fc"],
          "example_shape": [4, 4, 3], "example_dtype": "float32",
          "params": [
            {"name": "conv1_w", "shape": [3, 3, 3, 8]},
            {"name": "fc_b", "shape": [10]}
          ],
          "train_hlo": "train_m_d_q.hlo.txt",
          "eval_hlo": "eval_m_d.hlo.txt",
          "weights": "weights_m_d.bin"
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let g = &m.graphs["m_d_q"];
        assert_eq!(g.batch, 8);
        assert_eq!(g.params.len(), 2);
        assert_eq!(g.params[0].numel(), 216);
        assert!(g.params[0].is_weight());
        assert!(!g.params[1].is_weight());
        assert_eq!(g.example_numel(), 48);
        assert_eq!(g.total_params(), 226);
        assert_eq!(g.quant_layer_names, vec!["conv1", "fc"]);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"graphs": {"x": {"model": "m"}}}"#).is_err());
        assert!(Manifest::parse(r#"{}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        // Integration hook: when artifacts exist, the real manifest must
        // parse and be internally consistent.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Manifest::parse(&text).unwrap();
            assert!(!m.graphs.is_empty());
            for (tag, g) in &m.graphs {
                assert_eq!(g.quant_layer_names.len(), g.n_quant_layers, "{tag}");
                assert!(g.total_params() > 0, "{tag}");
            }
        }
    }
}
