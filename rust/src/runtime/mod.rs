//! PJRT runtime: load AOT artifacts (HLO text + manifest) and execute
//! them from the coordinator's hot path.
//!
//! `python/compile/aot.py` runs **once** at build time; afterwards the
//! Rust binary is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`, following
//! /opt/xla-example/load_hlo (HLO *text* is the interchange format — see
//! aot.py's docstring for why not serialized protos).

pub mod manifest;

use crate::util::error::{err, Context, Result};
use crate::xla;
use manifest::{GraphInfo, Manifest};
use std::path::{Path, PathBuf};

/// Shared PJRT CPU client; create once per process.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    /// The parsed artifact manifest.
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifacts directory (reads `manifest.json`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — run `make artifacts`? (`--backend native` \
                 trains without any artifacts)"
            )
        })?;
        let manifest = Manifest::parse(&text).map_err(|e| err!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self {
            client,
            artifacts_dir: dir,
            manifest,
        })
    }

    /// Load + compile one graph (train + eval executables + init weights).
    pub fn load(&self, tag: &str) -> Result<LoadedGraph> {
        let info = self
            .manifest
            .graphs
            .get(tag)
            .ok_or_else(|| err!("graph '{tag}' not in manifest"))?
            .clone();

        let train_exe = self.compile_hlo(&info.train_hlo)?;
        let eval_exe = self.compile_hlo(&info.eval_hlo)?;
        let init_weights = self.read_weights(&info)?;
        Ok(LoadedGraph {
            info,
            train_exe,
            eval_exe,
            init_weights,
        })
    }

    fn compile_hlo(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("bad path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    fn read_weights(&self, info: &GraphInfo) -> Result<Vec<Vec<f32>>> {
        let path = self.artifacts_dir.join(&info.weights);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let total: usize = info.params.iter().map(|p| p.numel()).sum();
        if bytes.len() != total * 4 {
            return Err(err!(
                "{path:?}: {} bytes, expected {} ({} f32 params)",
                bytes.len(),
                total * 4,
                total
            ));
        }
        let flat: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut out = Vec::with_capacity(info.params.len());
        let mut off = 0;
        for p in &info.params {
            let n = p.numel();
            out.push(flat[off..off + n].to_vec());
            off += n;
        }
        Ok(out)
    }
}

/// A compiled (train, eval) pair plus its metadata and initial weights.
pub struct LoadedGraph {
    /// Manifest metadata for this graph pair.
    pub info: GraphInfo,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    /// Initial weights shipped with the artifact.
    pub init_weights: Vec<Vec<f32>>,
}

/// Output of one DP-SGD train step (before noise/update, which are the
/// coordinator's job).
pub struct TrainOutput {
    /// Σ over the batch of clipped per-sample grads, one per parameter.
    pub grad_sums: Vec<Vec<f32>>,
    /// Σ of per-sample losses over the batch.
    pub loss_sum: f32,
    /// Count of correct predictions in the batch.
    pub correct_sum: f32,
    /// Σ over the batch of pre-clip per-sample gradient L2 norms
    /// (Fig. 1c / Table 2 tap).
    pub raw_norm_sum: f32,
    /// Max over the batch of pre-clip per-sample gradient L2 norms.
    pub raw_norm_max: f32,
}

/// Output of one eval call.
pub struct EvalOutput {
    /// Σ of per-sample losses over the batch.
    pub loss_sum: f32,
    /// Count of correct predictions in the batch.
    pub correct_sum: f32,
}

impl LoadedGraph {
    /// Physical batch size baked into the executables.
    pub fn batch(&self) -> usize {
        self.info.batch
    }

    /// Number of parameter tensors.
    pub fn n_params(&self) -> usize {
        self.info.params.len()
    }

    fn example_literal(&self, x: &[f32], b: usize) -> Result<xla::Literal> {
        let ex: usize = self.info.example_shape.iter().product();
        assert_eq!(x.len(), b * ex, "batch data size");
        let mut dims: Vec<i64> = vec![b as i64];
        dims.extend(self.info.example_shape.iter().map(|&d| d as i64));
        if self.info.example_dtype == "int32" {
            // Token inputs arrive as f32 storage from the dataset layer;
            // convert.
            let ints: Vec<i32> = x.iter().map(|&v| v as i32).collect();
            Ok(xla::Literal::vec1(&ints).reshape(&dims)?)
        } else {
            Ok(xla::Literal::vec1(x).reshape(&dims)?)
        }
    }

    fn param_literals(&self, weights: &[Vec<f32>]) -> Result<Vec<xla::Literal>> {
        assert_eq!(weights.len(), self.info.params.len(), "param count");
        weights
            .iter()
            .zip(&self.info.params)
            .map(|(w, p)| {
                let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
                assert_eq!(w.len(), p.numel(), "param {} size", p.name);
                Ok(xla::Literal::vec1(w).reshape(&dims)?)
            })
            .collect()
    }

    /// Run one DP-SGD step. `x` is row-major batch data (padded to the
    /// physical batch), `y` labels, `mask` 1.0 for real examples.
    pub fn train_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        quant_mask: &[f32],
        seed: f32,
    ) -> Result<TrainOutput> {
        let b = self.batch();
        assert_eq!(y.len(), b);
        assert_eq!(mask.len(), b);
        assert_eq!(quant_mask.len(), self.info.n_quant_layers, "quant mask len");

        let mut args = self.param_literals(weights)?;
        args.push(self.example_literal(x, b)?);
        args.push(xla::Literal::vec1(y).reshape(&[b as i64])?);
        args.push(xla::Literal::vec1(mask).reshape(&[b as i64])?);
        args.push(
            xla::Literal::vec1(quant_mask).reshape(&[self.info.n_quant_layers as i64])?,
        );
        args.push(xla::Literal::from(seed));

        let result = self.train_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        let n = self.n_params();
        if outs.len() != n + 4 {
            return Err(err!("train outputs: got {}, want {}", outs.len(), n + 4));
        }
        let mut grad_sums = Vec::with_capacity(n);
        for lit in outs.iter().take(n) {
            grad_sums.push(lit.to_vec::<f32>()?);
        }
        let loss_sum = outs[n].to_vec::<f32>()?[0];
        let correct_sum = outs[n + 1].to_vec::<f32>()?[0];
        let raw_norm_sum = outs[n + 2].to_vec::<f32>()?[0];
        let raw_norm_max = outs[n + 3].to_vec::<f32>()?[0];
        Ok(TrainOutput {
            grad_sums,
            loss_sum,
            correct_sum,
            raw_norm_sum,
            raw_norm_max,
        })
    }

    /// Full-precision evaluation of a (masked) batch. The compiled graph
    /// also takes a quant_mask + seed (kept as runtime inputs so XLA's
    /// constant folder cannot recurse into the pallas loops); standard
    /// evaluation passes all-zeros.
    pub fn eval_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOutput> {
        let b = self.batch();
        let mut args = self.param_literals(weights)?;
        args.push(self.example_literal(x, b)?);
        args.push(xla::Literal::vec1(y).reshape(&[b as i64])?);
        args.push(xla::Literal::vec1(mask).reshape(&[b as i64])?);
        let zeros = vec![0f32; self.info.n_quant_layers];
        args.push(xla::Literal::vec1(&zeros).reshape(&[self.info.n_quant_layers as i64])?);
        args.push(xla::Literal::from(0f32));

        let result = self.eval_exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 2 {
            return Err(err!("eval outputs: got {}, want 2", outs.len()));
        }
        Ok(EvalOutput {
            loss_sum: outs[0].to_vec::<f32>()?[0],
            correct_sum: outs[1].to_vec::<f32>()?[0],
        })
    }
}
