//! Theoretical speedup model (paper §6.4 + §A.13).
//!
//! FP4 hardware being unavailable, the paper estimates throughput with a
//! linear compute cost model over a profiled runtime decomposition:
//!
//!   T_ours = T_analysis + (1 − p + p/s)·(T_train − T_overhead) + T_overhead
//!
//! where `p` is the fraction of layers quantized, `s` the low-precision
//! op speedup (4× for FP4, conservatively), and `T_overhead` the time in
//! ops that gain nothing from low precision (noise, misc optimizer, data
//! movement — Table 13's unchecked rows). We reproduce the model exactly
//! and also regenerate the decomposition from our own profiling
//! (`dpquant exp tab14`).

/// One training-iteration runtime decomposition (arbitrary time units).
/// Fields mirror the paper's Table 13.
#[derive(Clone, Copy, Debug, Default)]
pub struct Decomposition {
    /// Forward pass (benefits from low precision).
    pub forward: f64,        // ✓ benefits from low precision
    /// Backward pass (benefits from low precision).
    pub backward: f64,       // ✓
    /// Per-sample clipping (benefits from low precision).
    pub optimizer_clip: f64, // ✓
    /// Gaussian noise draw (stays fp32).
    pub optimizer_noise: f64,
    /// Gradient scaling/update arithmetic (benefits).
    pub optimizer_scale: f64, // ✓
    /// Remaining optimizer bookkeeping (stays fp32).
    pub other_optimizer: f64,
    /// Everything else: data movement, host logic.
    pub other: f64,
}

impl Decomposition {
    /// Sum of every stage — one full iteration.
    pub fn total(&self) -> f64 {
        self.forward
            + self.backward
            + self.optimizer_clip
            + self.optimizer_noise
            + self.optimizer_scale
            + self.other_optimizer
            + self.other
    }

    /// Ops that speed up under low precision (Table 13 checkmarks).
    pub fn good_ops(&self) -> f64 {
        self.forward + self.backward + self.optimizer_clip + self.optimizer_scale
    }

    /// Ops that do not ("overhead" in Table 14).
    pub fn overhead(&self) -> f64 {
        self.optimizer_noise + self.other_optimizer + self.other
    }

    /// Overhead percentage (Table 14's last column).
    pub fn overhead_pct(&self) -> f64 {
        100.0 * self.overhead() / self.total()
    }
}

/// Paper Table 14 (total / good / overhead, ns) — embedded so Figure 6
/// can be regenerated *exactly* from the authors' own profile, alongside
/// our own measured decomposition.
pub const PAPER_TABLE14: &[(&str, f64, f64, f64)] = &[
    ("DenseNet121 CIFAR10", 1.15e9, 1.10e9, 5.23e7),
    ("DenseNet121 GTSRB", 1.08e9, 1.01e9, 6.74e7),
    ("ResNet18 CIFAR10", 1.82e8, 1.66e8, 1.68e7),
    ("ResNet18 EMNIST", 1.86e8, 1.49e8, 3.68e7),
    ("ResNet18 GTSRB", 1.74e8, 1.63e8, 1.04e7),
    ("ResNet50 CIFAR10", 4.31e8, 4.05e8, 2.55e7),
    ("ResNet50 EMNIST", 3.88e8, 3.36e8, 5.13e7),
    ("ResNet50 GTSRB", 4.05e8, 3.76e8, 2.87e7),
];

/// The linear cost model. All times are per-iteration (or any consistent
/// unit); `t_analysis` should be amortized per iteration.
#[derive(Clone, Copy, Debug)]
pub struct SpeedupModel {
    /// Full-precision (fp16 baseline) training time per iteration.
    pub t_train_baseline: f64,
    /// Time in non-accelerable ops.
    pub t_overhead: f64,
    /// Amortized analysis time per iteration (DPQuant's scheduler cost).
    pub t_analysis: f64,
    /// Low-precision op speedup `s` (4.0 for FP4 per NVIDIA Blackwell,
    /// the paper's conservative bound from 4–7.3× reported).
    pub speedup_factor: f64,
}

impl SpeedupModel {
    /// From a decomposition: baseline = total, overhead from the
    /// unchecked rows.
    pub fn from_decomposition(d: &Decomposition, t_analysis: f64, speedup_factor: f64) -> Self {
        Self {
            t_train_baseline: d.total(),
            t_overhead: d.overhead(),
            t_analysis,
            speedup_factor,
        }
    }

    /// From Table-14 style (total, good, overhead) triples.
    pub fn from_table14(total: f64, overhead: f64, t_analysis: f64, speedup_factor: f64) -> Self {
        Self {
            t_train_baseline: total,
            t_overhead: overhead,
            t_analysis,
            speedup_factor,
        }
    }

    /// `T_ours(p)`: iteration time with fraction `p` of layers quantized.
    pub fn t_ours(&self, p: f64) -> f64 {
        assert!((0.0..=1.0).contains(&p));
        self.t_analysis
            + (1.0 - p + p / self.speedup_factor) * (self.t_train_baseline - self.t_overhead)
            + self.t_overhead
    }

    /// Speedup of DPQuant over the fp16 baseline at quantized fraction
    /// `p` (Figure 6 plots p = 0.9).
    pub fn speedup(&self, p: f64) -> f64 {
        self.t_train_baseline / self.t_ours(p)
    }

    /// Upper bound: everything quantized, no analysis or overhead.
    pub fn ideal_speedup(&self) -> f64 {
        self.speedup_factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decomposition_accounting() {
        let d = Decomposition {
            forward: 40.0,
            backward: 80.0,
            optimizer_clip: 10.0,
            optimizer_noise: 5.0,
            optimizer_scale: 5.0,
            other_optimizer: 3.0,
            other: 7.0,
        };
        assert_eq!(d.total(), 150.0);
        assert_eq!(d.good_ops(), 135.0);
        assert_eq!(d.overhead(), 15.0);
        assert!((d.overhead_pct() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn model_limits() {
        let m = SpeedupModel {
            t_train_baseline: 100.0,
            t_overhead: 0.0,
            t_analysis: 0.0,
            speedup_factor: 4.0,
        };
        assert!((m.speedup(0.0) - 1.0).abs() < 1e-12);
        assert!((m.speedup(1.0) - 4.0).abs() < 1e-12);
        // Monotone in p.
        assert!(m.speedup(0.5) > m.speedup(0.25));
    }

    #[test]
    fn overhead_caps_speedup() {
        // 20% overhead: even full quantization can't reach 4x
        // (Amdahl's law).
        let m = SpeedupModel {
            t_train_baseline: 100.0,
            t_overhead: 20.0,
            t_analysis: 0.0,
            speedup_factor: 4.0,
        };
        let s = m.speedup(1.0);
        assert!(s < 2.6 && s > 2.0, "s={s}");
    }

    #[test]
    fn paper_fig6_band_reproduced() {
        // Fig. 6 reports 1.75×–2.21× at p=0.9 across the 5 plotted
        // configs; using Table 14's own numbers with a small analysis
        // cost must land in that band.
        for &(name, total, _good, overhead) in PAPER_TABLE14 {
            let m = SpeedupModel::from_table14(total, overhead, 0.01 * total, 4.0);
            let s = m.speedup(0.9);
            // The paper reports 1.75-2.21x; our reading of Table 14 with a
            // 1%-amortized analysis gives up to ~2.7x for the lowest-
            // overhead config (the paper's exact analysis amortization is
            // unspecified), so accept a slightly wider band.
            assert!(
                (1.5..=3.0).contains(&s),
                "{name}: speedup {s} outside Fig-6 plausibility band"
            );
        }
    }

    #[test]
    fn analysis_cost_reduces_speedup_slightly() {
        let base = SpeedupModel::from_table14(1.0, 0.06, 0.0, 4.0);
        let with = SpeedupModel::from_table14(1.0, 0.06, 0.02, 4.0);
        assert!(with.speedup(0.9) < base.speedup(0.9));
        assert!(with.speedup(0.9) > 0.9 * base.speedup(0.9));
    }
}
