//! Exponential moving average of per-layer loss-impact scores
//! (Algorithm 1 step 4: `L[p] <- (1-α)·L[p] + α·R̂[p]`).
//!
//! The EMA smooths the privatized, noisy sensitivity estimates so a
//! single measurement cannot flip the layer ranking (§A.8 shows the
//! ablation: EMA consistently improves accuracy).

/// Per-layer EMA state.
#[derive(Clone, Debug)]
pub struct EmaScores {
    scores: Vec<f64>,
    alpha: f64,
    /// When disabled (Table 10 ablation) updates overwrite instead of
    /// averaging.
    enabled: bool,
    initialized: bool,
}

impl EmaScores {
    /// Scores for `n` layers, EMA coefficient `alpha` (Algorithm 1's β).
    pub fn new(n: usize, alpha: f64, enabled: bool) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self {
            scores: vec![0.0; n],
            alpha,
            enabled,
            initialized: false,
        }
    }

    /// Rebuild from checkpointed state (scores + the seeded flag);
    /// `alpha`/`enabled` come back from the config as in [`EmaScores::new`].
    pub fn from_parts(scores: Vec<f64>, alpha: f64, enabled: bool, initialized: bool) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self {
            scores,
            alpha,
            enabled,
            initialized,
        }
    }

    /// Fold one privatized measurement vector in.
    pub fn update(&mut self, measured: &[f64]) {
        assert_eq!(measured.len(), self.scores.len());
        if !self.enabled || !self.initialized {
            // First measurement seeds the EMA directly (no stale zero
            // pull); with EMA disabled every update overwrites.
            self.scores.copy_from_slice(measured);
            self.initialized = true;
            return;
        }
        for (s, &m) in self.scores.iter_mut().zip(measured) {
            *s = (1.0 - self.alpha) * *s + self.alpha * m;
        }
    }

    /// Current per-layer scores.
    pub fn scores(&self) -> &[f64] {
        &self.scores
    }

    /// Has the first measurement been folded in yet?
    pub fn is_initialized(&self) -> bool {
        self.initialized
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_update_seeds() {
        let mut e = EmaScores::new(3, 0.3, true);
        e.update(&[1.0, 2.0, 3.0]);
        assert_eq!(e.scores(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn ema_blends() {
        let mut e = EmaScores::new(2, 0.25, true);
        e.update(&[0.0, 4.0]);
        e.update(&[4.0, 0.0]);
        assert_eq!(e.scores(), &[1.0, 3.0]);
    }

    #[test]
    fn disabled_overwrites() {
        let mut e = EmaScores::new(2, 0.25, false);
        e.update(&[0.0, 4.0]);
        e.update(&[4.0, 0.0]);
        assert_eq!(e.scores(), &[4.0, 0.0]);
    }

    #[test]
    fn converges_to_constant_input() {
        let mut e = EmaScores::new(1, 0.5, true);
        e.update(&[0.0]);
        for _ in 0..40 {
            e.update(&[2.0]);
        }
        assert!((e.scores()[0] - 2.0).abs() < 1e-6);
    }
}
