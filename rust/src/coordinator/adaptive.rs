//! Adaptive-DP policies: per-epoch schedules for the DP-SGD knobs —
//! noise multiplier, clipping norm, Poisson sampling rate, per-layer
//! learning rates — pluggable behind the existing scheduler config
//! (DESIGN.md §16).
//!
//! Three levers from the adaptive-DP literature adjacent to the paper,
//! each a contained policy selected by the `policy` config key:
//!
//! * [`AdaptivePolicy::NoiseDecay`] — Dynamic DP-SGD (arXiv
//!   2111.00173): σ(t) and C(t) follow a linear or exponential
//!   schedule across epochs. ε-consuming: every epoch's (q, σ_t) pair
//!   becomes its own RDP composition block.
//! * [`AdaptivePolicy::RateSchedule`] — the DPIS lever (arXiv
//!   2210.09634): the Poisson sampling rate q(t) follows a linear
//!   schedule, with per-step (q_t, σ) accounting through the same
//!   subsampled-Gaussian math.
//! * [`AdaptivePolicy::LayerLr`] — adaptive per-layer learning rates
//!   (arXiv 1912.09150) driven by the **already-privatized** EMA
//!   loss-impact scores: pure post-processing of DP outputs, zero
//!   extra ε.
//!
//! The contract that keeps the budget ledger honest: a policy's
//! worst-case training schedule is a pure function of the config
//! ([`training_schedule`]), and replaying those records through
//! `RdpAccountant::predict_schedule` composes **bit-identically** to
//! the live run's block-by-block accounting (the per-epoch knobs here
//! are the very values the session feeds `step_training`; pinned by
//! `tests/privacy_golden.rs`).
//!
//! Clipping decays without touching the executor: executors clip every
//! per-sample gradient at the immutable build-time norm C₀, and the
//! optimizer rescales the summed clipped gradients by `C(t)/C₀` — a
//! valid sensitivity-C(t) mechanism (clip-then-rescale), so the
//! accountant's (q, σ_t) pairs are exactly right (DESIGN.md §16.2).

use crate::config::TrainConfig;
use crate::privacy::{Mechanism, StepRecord};
use crate::util::error::{ensure, err, Result};

/// Interpolation shape for [`AdaptivePolicy::NoiseDecay`] schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecayShape {
    /// `a + t·(b − a)` — exact at both endpoints.
    Linear,
    /// `a·(b/a)^t` — geometric decay; needs positive endpoints.
    Exp,
}

impl DecayShape {
    /// Parse a shape name as it appears in configs/flags.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "linear" => Ok(Self::Linear),
            "exp" => Ok(Self::Exp),
            other => Err(err!("unknown decay_shape '{other}' (expected linear | exp)")),
        }
    }
}

/// The per-epoch values of every scheduling-relevant DP knob. The
/// session computes one of these at the top of each epoch and feeds it
/// to the optimizer (σ·C, C(t)/C₀ rescale) and the accountant
/// ((q_t, σ_t) per step); [`training_schedule`] replays the identical
/// sequence for admission control.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpochKnobs {
    /// DP-SGD noise multiplier σ_t.
    pub noise_multiplier: f64,
    /// Clipping norm C_t (applied as a C_t/C₀ rescale of C₀-clipped
    /// sums — executors clip at the immutable C₀).
    pub clip_norm: f64,
    /// Poisson sampling rate q_t.
    pub sample_rate: f64,
}

/// An adaptive-DP policy: how the DP knobs evolve across epochs.
///
/// `Static` (the default) and `LayerLr` return the base knobs with
/// **no arithmetic at all**, so their training runs and privacy
/// accounting are bit-identical to the pre-policy code path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AdaptivePolicy {
    /// The paper's fixed-(σ, C, q) schedule — today's behavior.
    Static,
    /// Dynamic DP-SGD: σ and C interpolate from the config's base
    /// values to `noise_final` / `clip_final` over the epochs.
    NoiseDecay {
        /// Linear or exponential interpolation.
        shape: DecayShape,
        /// σ at the last epoch (resolved: a `noise_final` of 0 in the
        /// config holds σ at its base value).
        noise_final: f64,
        /// C at the last epoch (resolved likewise).
        clip_final: f64,
    },
    /// DPIS-style sampling-rate schedule: q interpolates linearly from
    /// the config's `batch_size/dataset_size` to `rate_final`.
    RateSchedule {
        /// q at the last epoch (resolved: 0 holds q at its base value).
        rate_final: f64,
    },
    /// Per-layer learning rates from the privatized EMA scores
    /// (post-processing — the DP knobs stay at their base values).
    LayerLr {
        /// Scale spread: per-layer lr factors span
        /// `[1 − strength/2, 1 + strength/2]`. Must be in `[0, 2)`.
        strength: f64,
    },
}

impl AdaptivePolicy {
    /// Resolve and validate the policy a config selects. Finals of 0.0
    /// mean "hold the base value"; every endpoint is range-checked here
    /// so `validate_config` rejects hostile configs before a session
    /// (or a ledger reservation) is built.
    pub fn from_config(cfg: &TrainConfig) -> Result<Self> {
        match cfg.policy.as_str() {
            "static" => Ok(Self::Static),
            "noise_decay" => {
                let shape = DecayShape::parse(&cfg.decay_shape)?;
                let noise_final = if cfg.noise_final == 0.0 {
                    cfg.noise_multiplier
                } else {
                    cfg.noise_final
                };
                let clip_final = if cfg.clip_final == 0.0 {
                    cfg.clip_norm
                } else {
                    cfg.clip_final
                };
                ensure!(
                    noise_final.is_finite() && noise_final >= 0.0,
                    "noise_final must be a finite value >= 0 (got {noise_final})"
                );
                ensure!(
                    clip_final.is_finite() && clip_final > 0.0,
                    "clip_final must be a finite value > 0 (got {clip_final})"
                );
                if shape == DecayShape::Exp {
                    ensure!(
                        cfg.noise_multiplier > 0.0 && noise_final > 0.0,
                        "decay_shape=exp needs positive noise endpoints \
                         (sigma {} -> {noise_final})",
                        cfg.noise_multiplier
                    );
                }
                Ok(Self::NoiseDecay {
                    shape,
                    noise_final,
                    clip_final,
                })
            }
            "rate_schedule" => {
                let rate_final = if cfg.rate_final == 0.0 {
                    cfg.sample_rate()
                } else {
                    cfg.rate_final
                };
                ensure!(
                    rate_final.is_finite() && rate_final > 0.0 && rate_final <= 1.0,
                    "rate_final must be in (0, 1] (got {rate_final})"
                );
                Ok(Self::RateSchedule { rate_final })
            }
            "layer_lr" => {
                ensure!(
                    cfg.scheduler == "dpquant",
                    "policy 'layer_lr' needs the privatized EMA scores only the 'dpquant' \
                     scheduler maintains (got scheduler '{}')",
                    cfg.scheduler
                );
                let strength = cfg.layer_lr_strength;
                ensure!(
                    strength.is_finite() && (0.0..2.0).contains(&strength),
                    "layer_lr_strength must be in [0, 2) so lr scales stay positive \
                     (got {strength})"
                );
                Ok(Self::LayerLr { strength })
            }
            other => Err(err!(
                "unknown policy '{other}' (expected static | noise_decay | rate_schedule \
                 | layer_lr)"
            )),
        }
    }

    /// The knob values for `epoch` of an `epochs`-epoch run. The
    /// schedule position is `t = epoch/(epochs−1)` (0 for single-epoch
    /// runs), so the base values apply exactly at epoch 0 and the
    /// finals exactly at the last epoch. `Static` and `LayerLr` return
    /// `base` untouched — no float op, so their bits cannot drift.
    pub fn knobs(&self, epoch: usize, epochs: usize, base: &EpochKnobs) -> EpochKnobs {
        let t = if epochs <= 1 {
            0.0
        } else {
            epoch as f64 / (epochs - 1) as f64
        };
        match *self {
            Self::Static | Self::LayerLr { .. } => *base,
            Self::NoiseDecay {
                shape,
                noise_final,
                clip_final,
            } => EpochKnobs {
                noise_multiplier: interp(shape, base.noise_multiplier, noise_final, t),
                clip_norm: interp(shape, base.clip_norm, clip_final, t),
                sample_rate: base.sample_rate,
            },
            Self::RateSchedule { rate_final } => EpochKnobs {
                noise_multiplier: base.noise_multiplier,
                clip_norm: base.clip_norm,
                sample_rate: interp(DecayShape::Linear, base.sample_rate, rate_final, t),
            },
        }
    }
}

/// Interpolate between `a` (t = 0) and `b` (t = 1). Both shapes are
/// exact at t = 0 and fixed-point when `a == b` (so a resolved-to-base
/// final reproduces the static schedule bit for bit).
fn interp(shape: DecayShape, a: f64, b: f64, t: f64) -> f64 {
    match shape {
        DecayShape::Linear => a + t * (b - a),
        DecayShape::Exp => a * (b / a).powf(t),
    }
}

/// The worst-case training-side privacy schedule of a policy: one
/// `(q_t, σ_t)` block per epoch, adjacent identical blocks coalesced —
/// exactly the history a live run's per-step `step_training` calls
/// coalesce into. Pure function of `(policy, base, epochs,
/// steps_per_epoch)`, which is what lets the budget ledger rebuild
/// byte-identical reservations after a crash.
pub fn training_schedule(
    policy: &AdaptivePolicy,
    base: &EpochKnobs,
    epochs: usize,
    steps_per_epoch: u64,
) -> Vec<StepRecord> {
    let mut out: Vec<StepRecord> = Vec::new();
    for epoch in 0..epochs {
        let k = policy.knobs(epoch, epochs, base);
        match out.last_mut() {
            Some(r)
                if r.sample_rate == k.sample_rate
                    && r.noise_multiplier == k.noise_multiplier =>
            {
                r.steps += steps_per_epoch;
            }
            _ => out.push(StepRecord {
                mechanism: Mechanism::Training,
                sample_rate: k.sample_rate,
                noise_multiplier: k.noise_multiplier,
                steps: steps_per_epoch,
            }),
        }
    }
    out
}

/// Per-layer learning-rate factors from the privatized EMA scores:
/// min-max normalize, then spread around 1.0 so the highest-impact
/// layer trains at `1 + strength/2` and the lowest at `1 − strength/2`.
/// Degenerate score vectors (empty, constant, non-finite spread — in
/// particular an uninitialized EMA) yield all-ones: the policy is a
/// no-op until the first privatized measurement lands.
pub fn layer_lr_scales(scores: &[f64], strength: f64) -> Vec<f64> {
    let n = scores.len();
    if n == 0 {
        return Vec::new();
    }
    let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let spread = max - min;
    if !spread.is_finite() || spread <= 0.0 {
        return vec![1.0; n];
    }
    scores
        .iter()
        .map(|&s| 1.0 + strength * ((s - min) / spread - 0.5))
        .collect()
}

/// Map per-*layer* lr factors onto per-*tensor* factors: a tensor's
/// factor is the mean over the quantizable layers whose weights live in
/// it (`StepExecutor::quant_weight_params`), 1.0 for tensors no layer
/// maps to (biases, unmapped params). Layers are not 1:1 with tensors —
/// `MockExecutor` has one tensor for all its layers.
pub fn tensor_lr_scales(
    layer_scales: &[f64],
    layer_tensors: &[usize],
    n_tensors: usize,
) -> Vec<f64> {
    let mut sums = vec![0.0f64; n_tensors];
    let mut counts = vec![0usize; n_tensors];
    for (l, &ti) in layer_tensors.iter().enumerate() {
        if ti < n_tensors && l < layer_scales.len() {
            sums[ti] += layer_scales[l];
            counts[ti] += 1;
        }
    }
    (0..n_tensors)
        .map(|i| {
            if counts[i] > 0 {
                sums[i] / counts[i] as f64
            } else {
                1.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> EpochKnobs {
        EpochKnobs {
            noise_multiplier: 0.6,
            clip_norm: 1.0,
            sample_rate: 0.0625,
        }
    }

    fn cfg() -> TrainConfig {
        TrainConfig {
            batch_size: 16,
            dataset_size: 256,
            noise_multiplier: 0.6,
            clip_norm: 1.0,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn static_and_layer_lr_knobs_are_bit_identical_to_base() {
        let b = base();
        for policy in [AdaptivePolicy::Static, AdaptivePolicy::LayerLr { strength: 0.5 }] {
            for epoch in 0..7 {
                let k = policy.knobs(epoch, 7, &b);
                assert_eq!(k.noise_multiplier.to_bits(), b.noise_multiplier.to_bits());
                assert_eq!(k.clip_norm.to_bits(), b.clip_norm.to_bits());
                assert_eq!(k.sample_rate.to_bits(), b.sample_rate.to_bits());
            }
        }
    }

    #[test]
    fn noise_decay_hits_both_endpoints_exactly() {
        let b = base();
        for shape in [DecayShape::Linear, DecayShape::Exp] {
            let p = AdaptivePolicy::NoiseDecay {
                shape,
                noise_final: 1.2,
                clip_final: 0.5,
            };
            let first = p.knobs(0, 5, &b);
            assert_eq!(first.noise_multiplier.to_bits(), 0.6f64.to_bits());
            assert_eq!(first.clip_norm.to_bits(), 1.0f64.to_bits());
            let last = p.knobs(4, 5, &b);
            assert_eq!(last.noise_multiplier.to_bits(), 1.2f64.to_bits());
            assert_eq!(last.clip_norm.to_bits(), 0.5f64.to_bits());
            // q never moves under noise decay.
            for e in 0..5 {
                assert_eq!(p.knobs(e, 5, &b).sample_rate.to_bits(), b.sample_rate.to_bits());
            }
        }
    }

    #[test]
    fn equal_endpoints_are_a_fixed_point() {
        // A final resolved to the base value must reproduce the base
        // bits at EVERY epoch — this is what keeps noise_final=0 (hold)
        // schedules coalescing into one accounting block.
        let b = base();
        for shape in [DecayShape::Linear, DecayShape::Exp] {
            let p = AdaptivePolicy::NoiseDecay {
                shape,
                noise_final: b.noise_multiplier,
                clip_final: b.clip_norm,
            };
            for e in 0..9 {
                let k = p.knobs(e, 9, &b);
                assert_eq!(k.noise_multiplier.to_bits(), b.noise_multiplier.to_bits());
                assert_eq!(k.clip_norm.to_bits(), b.clip_norm.to_bits());
            }
        }
    }

    #[test]
    fn single_epoch_runs_pin_t_to_zero() {
        let b = base();
        let p = AdaptivePolicy::NoiseDecay {
            shape: DecayShape::Linear,
            noise_final: 9.0,
            clip_final: 9.0,
        };
        let k = p.knobs(0, 1, &b);
        assert_eq!(k.noise_multiplier.to_bits(), b.noise_multiplier.to_bits());
        assert_eq!(k.clip_norm.to_bits(), b.clip_norm.to_bits());
    }

    #[test]
    fn rate_schedule_moves_only_q_and_monotonically() {
        let b = base();
        let p = AdaptivePolicy::RateSchedule { rate_final: 0.03125 };
        let mut prev = f64::INFINITY;
        for e in 0..6 {
            let k = p.knobs(e, 6, &b);
            assert_eq!(k.noise_multiplier.to_bits(), b.noise_multiplier.to_bits());
            assert_eq!(k.clip_norm.to_bits(), b.clip_norm.to_bits());
            assert!(k.sample_rate < prev);
            prev = k.sample_rate;
        }
        assert_eq!(p.knobs(0, 6, &b).sample_rate.to_bits(), 0.0625f64.to_bits());
        assert_eq!(p.knobs(5, 6, &b).sample_rate.to_bits(), 0.03125f64.to_bits());
    }

    #[test]
    fn static_schedule_coalesces_to_one_block() {
        let recs = training_schedule(&AdaptivePolicy::Static, &base(), 8, 16);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].steps, 8 * 16);
        assert_eq!(recs[0].sample_rate.to_bits(), 0.0625f64.to_bits());
        assert_eq!(recs[0].noise_multiplier.to_bits(), 0.6f64.to_bits());
    }

    #[test]
    fn decay_schedule_has_one_block_per_distinct_epoch() {
        let p = AdaptivePolicy::NoiseDecay {
            shape: DecayShape::Linear,
            noise_final: 1.2,
            clip_final: 1.0,
        };
        let recs = training_schedule(&p, &base(), 4, 16);
        assert_eq!(recs.len(), 4, "4 distinct sigmas, 4 blocks");
        assert_eq!(recs.iter().map(|r| r.steps).sum::<u64>(), 64);
        // Each block carries the exact per-epoch knob value.
        let b = base();
        for (e, r) in recs.iter().enumerate() {
            let k = p.knobs(e, 4, &b);
            assert_eq!(r.noise_multiplier.to_bits(), k.noise_multiplier.to_bits());
            assert_eq!(r.sample_rate.to_bits(), k.sample_rate.to_bits());
        }
    }

    #[test]
    fn from_config_resolves_and_rejects() {
        // Defaults: static.
        assert_eq!(AdaptivePolicy::from_config(&cfg()).unwrap(), AdaptivePolicy::Static);
        // noise_decay resolves 0.0 finals to the base values.
        let mut c = cfg();
        c.policy = "noise_decay".into();
        assert_eq!(
            AdaptivePolicy::from_config(&c).unwrap(),
            AdaptivePolicy::NoiseDecay {
                shape: DecayShape::Linear,
                noise_final: 0.6,
                clip_final: 1.0,
            }
        );
        c.noise_final = 1.5;
        c.clip_final = 0.25;
        c.decay_shape = "exp".into();
        assert_eq!(
            AdaptivePolicy::from_config(&c).unwrap(),
            AdaptivePolicy::NoiseDecay {
                shape: DecayShape::Exp,
                noise_final: 1.5,
                clip_final: 0.25,
            }
        );
        // rate_schedule resolves 0.0 to the base sample rate.
        let mut c = cfg();
        c.policy = "rate_schedule".into();
        assert_eq!(
            AdaptivePolicy::from_config(&c).unwrap(),
            AdaptivePolicy::RateSchedule { rate_final: 16.0 / 256.0 }
        );
        // Rejections.
        let reject = |mutate: &dyn Fn(&mut TrainConfig), needle: &str| {
            let mut c = cfg();
            mutate(&mut c);
            let e = AdaptivePolicy::from_config(&c).unwrap_err().to_string();
            assert!(e.contains(needle), "want '{needle}' in '{e}'");
        };
        reject(&|c| c.policy = "frobnicate".into(), "unknown policy");
        reject(
            &|c| {
                c.policy = "noise_decay".into();
                c.decay_shape = "cubic".into();
            },
            "decay_shape",
        );
        reject(
            &|c| {
                c.policy = "noise_decay".into();
                c.noise_final = f64::NAN;
            },
            "noise_final",
        );
        reject(
            &|c| {
                c.policy = "noise_decay".into();
                c.clip_final = -1.0;
            },
            "clip_final",
        );
        reject(
            &|c| {
                c.policy = "noise_decay".into();
                c.decay_shape = "exp".into();
                c.noise_multiplier = 0.0;
            },
            "positive noise endpoints",
        );
        reject(
            &|c| {
                c.policy = "rate_schedule".into();
                c.rate_final = 1.5;
            },
            "rate_final",
        );
        reject(
            &|c| {
                c.policy = "layer_lr".into();
                c.scheduler = "static_random".into();
            },
            "layer_lr",
        );
        reject(
            &|c| {
                c.policy = "layer_lr".into();
                c.layer_lr_strength = 2.0;
            },
            "layer_lr_strength",
        );
    }

    #[test]
    fn layer_lr_scales_spread_and_degenerate_cases() {
        // Empty and constant scores are no-ops.
        assert!(layer_lr_scales(&[], 0.5).is_empty());
        assert_eq!(layer_lr_scales(&[3.0, 3.0, 3.0], 0.5), vec![1.0, 1.0, 1.0]);
        // Min-max spread: lowest at 1 - s/2, highest at 1 + s/2.
        let s = layer_lr_scales(&[0.0, 1.0, 2.0], 1.0);
        assert_eq!(s, vec![0.5, 1.0, 1.5]);
        // Strength 0 is the identity.
        assert_eq!(layer_lr_scales(&[0.0, 7.0], 0.0), vec![1.0, 1.0]);
        // All factors stay positive for strength < 2.
        let s = layer_lr_scales(&[-5.0, 0.0, 11.0], 1.99);
        assert!(s.iter().all(|&x| x > 0.0), "{s:?}");
    }

    #[test]
    fn tensor_scales_average_mapped_layers() {
        // Layers 0,1 -> tensor 0; layer 2 -> tensor 2; tensor 1 unmapped.
        let got = tensor_lr_scales(&[0.5, 1.5, 2.0], &[0, 0, 2], 3);
        assert_eq!(got, vec![1.0, 1.0, 2.0]);
        // No mapping at all: all ones.
        assert_eq!(tensor_lr_scales(&[2.0], &[], 2), vec![1.0, 1.0]);
    }
}
