//! Batch-mode compatibility wrapper around [`super::session`].
//!
//! The epoch loop itself lives in [`TrainSession`](super::session::TrainSession)
//! — a resumable, observable state machine. This module keeps the
//! original run-to-completion API (`train()` + `TrainerOptions` +
//! `TrainResult`) as a thin adapter so existing callers and tests work
//! unchanged, hosts the pieces both APIs share (the [`Scheduler`] enum,
//! [`StepTrace`], [`train_with_sink`]), and re-exports
//! [`evaluate`](super::session::evaluate) from its new home beside the
//! session.

use super::executor::StepExecutor;
use super::optimizer::NoiseStats;
use super::session::{EventSink, MultiSink, TraceSink, TrainSession, VerboseSink};
use crate::config::TrainConfig;
use crate::data::Dataset;
use crate::metrics::RunRecord;
use crate::privacy::RdpAccountant;
use crate::util::error::{err, Result};

pub use super::session::evaluate;

/// Scheduling strategy (paper §6.3 ablation + baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduler {
    /// Full DPQuant: probabilistic layer sampling + loss-aware
    /// prioritization (PLS + LLP).
    DpQuant,
    /// Probabilistic layer sampling only (uniform rotation, no analysis).
    Pls,
    /// A random subset chosen once and frozen (the paper's baseline).
    StaticRandom,
    /// First k layers, frozen.
    StaticFirst,
    /// Last k layers, frozen.
    StaticLast,
    /// No quantization at all.
    None,
    /// Everything quantized every epoch.
    All,
}

impl Scheduler {
    /// Parse a scheduler name as it appears in configs/flags.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dpquant" => Self::DpQuant,
            "pls" => Self::Pls,
            "static_random" => Self::StaticRandom,
            "static_first" => Self::StaticFirst,
            "static_last" => Self::StaticLast,
            "none" | "fp" => Self::None,
            "all" => Self::All,
            other => return Err(err!("unknown scheduler '{other}'")),
        })
    }
}

/// Per-step gradient/noise statistics (drives Fig. 1b/1c, Table 2).
#[derive(Clone, Debug, Default)]
pub struct StepTrace {
    /// Per-step noise statistics, one entry per optimizer step.
    pub stats: Vec<NoiseStats>,
    /// Mean pre-clip per-sample grad norm, one entry per step.
    pub raw_norm_mean: Vec<f64>,
    /// Max pre-clip per-sample grad norm, one entry per step.
    pub raw_norm_max: Vec<f64>,
}

/// Options beyond `TrainConfig` (experiment taps).
///
/// Kept for the batch API only: each flag maps onto a provided
/// [`EventSink`] (`collect_step_stats` → [`TraceSink`], `verbose` →
/// [`VerboseSink`]). New code should attach sinks to a
/// [`TrainSession`](super::session::TrainSession) directly.
#[derive(Clone, Debug, Default)]
pub struct TrainerOptions {
    /// Record per-step grad/noise norms (costs nothing extra — they fall
    /// out of the optimizer pass).
    pub collect_step_stats: bool,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

/// Result of `train`.
pub struct TrainResult {
    /// Per-epoch metrics and final/best aggregates.
    pub record: RunRecord,
    /// Per-step stats (empty unless `collect_step_stats`).
    pub trace: StepTrace,
    /// Model weights after the last epoch.
    pub final_weights: Vec<Vec<f32>>,
    /// The privacy accountant in its final state.
    pub accountant: RdpAccountant,
}

/// Build a fresh session from `cfg`, run it to completion against the
/// given `sink`, and return the pieces every batch-mode caller wants:
/// `(record, final weights, accountant)`.
///
/// This is the one shared run-to-completion engine behind
/// [`train`] (which attaches the legacy flag-mapped sinks), the
/// experiment harness's `ExpCtx::run_cfg`, and the sweep orchestrator's
/// workers (which attach a per-grid-point progress sink).
pub fn train_with_sink<E: StepExecutor + ?Sized>(
    exec: &E,
    cfg: &TrainConfig,
    train_ds: &Dataset,
    val_ds: &Dataset,
    sink: &mut dyn EventSink,
) -> Result<(RunRecord, Vec<Vec<f32>>, RdpAccountant)> {
    let mut session = TrainSession::builder(cfg.clone()).build(exec, train_ds)?;
    session.run(exec, train_ds, val_ds, sink)?;
    Ok(session.finish())
}

/// Train with the configured scheduler, start to finish. This is the
/// paper's Figure 2 pipeline, now implemented by
/// [`TrainSession`](super::session::TrainSession); this wrapper builds a
/// session, attaches the sinks the legacy flags asked for
/// ([`VerboseSink`] / [`TraceSink`]), runs it to completion, and packs
/// the pieces into a [`TrainResult`]. Bit-for-bit identical to the
/// historical monolithic loop.
pub fn train<E: StepExecutor + ?Sized>(
    exec: &E,
    cfg: &TrainConfig,
    train_ds: &Dataset,
    val_ds: &Dataset,
    opts: &TrainerOptions,
) -> Result<TrainResult> {
    let mut trace_sink = TraceSink::default();
    let mut verbose_sink = VerboseSink;
    let mut sinks: Vec<&mut dyn EventSink> = Vec::new();
    if opts.collect_step_stats {
        sinks.push(&mut trace_sink);
    }
    if opts.verbose {
        sinks.push(&mut verbose_sink);
    }
    let mut sink = MultiSink::new(sinks);
    let (record, final_weights, accountant) =
        train_with_sink(exec, cfg, train_ds, val_ds, &mut sink)?;
    Ok(TrainResult {
        record,
        trace: trace_sink.into_trace(),
        final_weights,
        accountant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::privacy::Mechanism;
    use crate::util::rng::Xoshiro256;

    fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let c = rng.next_below(classes as u64) as i32;
            for f in 0..feats {
                xs.push(0.5 * rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 });
            }
            ys.push(c);
        }
        Dataset {
            xs,
            ys,
            example_numel: feats,
            n_classes: classes,
        }
    }

    fn base_cfg() -> TrainConfig {
        TrainConfig {
            epochs: 6,
            batch_size: 16,
            dataset_size: 256,
            noise_multiplier: 0.6,
            clip_norm: 1.0,
            lr: 0.8,
            quant_fraction: 0.5,
            scheduler: "dpquant".into(),
            analysis_interval: 2,
            seed: 3,
            physical_batch: 32,
            ..TrainConfig::default()
        }
    }

    fn run(cfg: &TrainConfig) -> TrainResult {
        let exec = MockExecutor::new(8, 4, 6, 32);
        let ds = toy_dataset(256 + 64, 8, 4, cfg.seed);
        let (tr, va) = ds.split(64);
        train(&exec, cfg, &tr, &va, &TrainerOptions::default()).unwrap()
    }

    #[test]
    fn dpquant_learns_and_accounts() {
        let res = run(&base_cfg());
        assert_eq!(res.record.epochs.len(), 6);
        assert!(res.record.final_accuracy > 0.5, "acc={}", res.record.final_accuracy);
        assert!(res.record.final_epsilon > 0.0);
        // Analysis ran ⌈6/2⌉ = 3 times.
        assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 3);
        assert_eq!(
            res.accountant.steps_of(Mechanism::Training),
            6 * (256 / 16) as u64
        );
        // Each epoch quantized exactly k = 3 of 6 layers.
        for e in &res.record.epochs {
            assert_eq!(e.quantized_layers.len(), 3);
        }
    }

    #[test]
    fn schedulers_produce_expected_layer_patterns() {
        for (name, rotates) in [
            ("static_random", false),
            ("static_first", false),
            ("pls", true),
            ("dpquant", true),
        ] {
            let cfg = TrainConfig {
                scheduler: name.into(),
                ..base_cfg()
            };
            let res = run(&cfg);
            let first = &res.record.epochs[0].quantized_layers;
            let all_same = res
                .record
                .epochs
                .iter()
                .all(|e| &e.quantized_layers == first);
            if rotates {
                assert!(!all_same, "{name} should rotate layers");
            } else {
                assert!(all_same, "{name} should freeze layers");
            }
        }
        // static_first quantizes layers 0..k.
        let cfg = TrainConfig {
            scheduler: "static_first".into(),
            ..base_cfg()
        };
        let res = run(&cfg);
        assert_eq!(res.record.epochs[0].quantized_layers, vec![0, 1, 2]);
    }

    #[test]
    fn none_scheduler_never_quantizes_and_skips_analysis() {
        let cfg = TrainConfig {
            scheduler: "none".into(),
            ..base_cfg()
        };
        let res = run(&cfg);
        assert!(res.record.epochs.iter().all(|e| e.quantized_layers.is_empty()));
        assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 0);
        assert_eq!(res.record.analysis_epsilon, 0.0);
    }

    #[test]
    fn target_epsilon_truncates() {
        // Use a scheduler without analysis so ε grows smoothly per step
        // and truncation lands near the target.
        let mut cfg = base_cfg();
        cfg.scheduler = "static_random".into();
        // One SGM step at q=16/256, σ=1 already costs ε≈1.76 at δ=1e-5,
        // so pick a target a few steps out and verify the run stops just
        // past it.
        cfg.target_epsilon = Some(2.5);
        cfg.epochs = 50;
        cfg.noise_multiplier = 1.0;
        let res = run(&cfg);
        assert!(res.record.epochs.len() < 50, "should truncate early");
        // Final ε is at (just past) the target, not way beyond.
        assert!(res.record.final_epsilon >= 2.5);
        assert!(res.record.final_epsilon < 2.8, "eps={}", res.record.final_epsilon);
    }

    #[test]
    fn budget_checked_before_analysis() {
        // A tiny budget must stop the run before (further) analysis
        // spends more: final ε may exceed the target once but not grow
        // across later epochs.
        let mut cfg = base_cfg();
        cfg.target_epsilon = Some(0.5);
        cfg.epochs = 30;
        let res = run(&cfg);
        assert!(res.record.epochs.len() <= 2, "len={}", res.record.epochs.len());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&base_cfg());
        let b = run(&base_cfg());
        assert_eq!(a.record.final_accuracy, b.record.final_accuracy);
        assert_eq!(
            a.record.epochs.last().unwrap().quantized_layers,
            b.record.epochs.last().unwrap().quantized_layers
        );
        let mut cfg2 = base_cfg();
        cfg2.seed = 4;
        let c = run(&cfg2);
        let layers_a: Vec<_> = a.record.epochs.iter().map(|e| e.quantized_layers.clone()).collect();
        let layers_c: Vec<_> = c.record.epochs.iter().map(|e| e.quantized_layers.clone()).collect();
        assert_ne!(layers_a, layers_c, "different seeds, different schedules");
    }

    #[test]
    fn step_stats_collected_when_requested() {
        let exec = MockExecutor::new(8, 4, 6, 32);
        let cfg = base_cfg();
        let ds = toy_dataset(320, 8, 4, 1);
        let (tr, va) = ds.split(64);
        let opts = TrainerOptions {
            collect_step_stats: true,
            verbose: false,
        };
        let res = train(&exec, &cfg, &tr, &va, &opts).unwrap();
        assert!(!res.trace.stats.is_empty());
        let s = &res.trace.stats[0];
        assert!(s.noise_l2 > 0.0 && s.grad_l2 > 0.0);
        // (The paper's Eq.-2 dominance claim needs high-dimensional
        // models; it is asserted in the optimizer's own tests and
        // reproduced at scale by `dpquant exp fig1b`.)
    }
}
