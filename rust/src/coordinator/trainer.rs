//! The DPQuant training coordinator: epoch loop tying together Poisson
//! sampling, the compiled DP-SGD step, the fp32 noise mechanism, the
//! privacy accountant, and the dynamic quantization scheduler
//! (Algorithms 1 + 2).

use super::analysis::compute_loss_impact;
use super::ema::EmaScores;
use super::executor::StepExecutor;
use super::optimizer::{DpOptimizer, NoiseStats};
use super::policy::{budget_to_k, Policy};
use super::sampler::select_targets;
use crate::config::TrainConfig;
use crate::data::{eval_batches, make_batches, poisson_sample, Dataset};
use crate::metrics::{EpochRecord, RunRecord};
use crate::privacy::{Mechanism, RdpAccountant};
use crate::util::error::{err, Result};
use crate::util::gaussian::GaussianSampler;
use crate::util::rng::Xoshiro256;

/// Scheduling strategy (paper §6.3 ablation + baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduler {
    /// Full DPQuant: probabilistic layer sampling + loss-aware
    /// prioritization (PLS + LLP).
    DpQuant,
    /// Probabilistic layer sampling only (uniform rotation, no analysis).
    Pls,
    /// A random subset chosen once and frozen (the paper's baseline).
    StaticRandom,
    /// First k layers, frozen.
    StaticFirst,
    /// Last k layers, frozen.
    StaticLast,
    /// No quantization at all.
    None,
    /// Everything quantized every epoch.
    All,
}

impl Scheduler {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dpquant" => Self::DpQuant,
            "pls" => Self::Pls,
            "static_random" => Self::StaticRandom,
            "static_first" => Self::StaticFirst,
            "static_last" => Self::StaticLast,
            "none" | "fp" => Self::None,
            "all" => Self::All,
            other => return Err(err!("unknown scheduler '{other}'")),
        })
    }
}

/// Per-step gradient/noise statistics (drives Fig. 1b/1c, Table 2).
#[derive(Clone, Debug, Default)]
pub struct StepTrace {
    pub stats: Vec<NoiseStats>,
    /// Mean pre-clip per-sample grad norm, one entry per step.
    pub raw_norm_mean: Vec<f64>,
    /// Max pre-clip per-sample grad norm, one entry per step.
    pub raw_norm_max: Vec<f64>,
}

/// Options beyond `TrainConfig` (experiment taps).
#[derive(Clone, Debug, Default)]
pub struct TrainerOptions {
    /// Record per-step grad/noise norms (costs nothing extra — they fall
    /// out of the optimizer pass).
    pub collect_step_stats: bool,
    /// Print per-epoch progress lines.
    pub verbose: bool,
}

/// Result of `train`.
pub struct TrainResult {
    pub record: RunRecord,
    pub trace: StepTrace,
    pub final_weights: Vec<Vec<f32>>,
    pub accountant: RdpAccountant,
}

/// Evaluate `weights` over a full dataset; returns (mean loss, accuracy).
pub fn evaluate<E: StepExecutor + ?Sized>(
    exec: &E,
    weights: &[Vec<f32>],
    ds: &Dataset,
) -> Result<(f64, f64)> {
    let mut loss = 0f64;
    let mut correct = 0f64;
    for b in eval_batches(ds, exec.physical_batch()) {
        let out = exec.eval_step(weights, &b.x, &b.y, &b.mask)?;
        loss += out.loss_sum as f64;
        correct += out.correct_sum as f64;
    }
    let n = ds.len() as f64;
    Ok((loss / n, correct / n))
}

/// Train with the configured scheduler. This is the paper's Figure 2
/// pipeline: every `analysis_interval` epochs run COMPUTELOSSIMPACT
/// (DPQuant only), then SELECTTARGETS a policy for the epoch, then run
/// the epoch's Poisson-sampled DP-SGD steps with the policy's
/// `quant_mask`; truncate when the privacy budget is exhausted.
pub fn train<E: StepExecutor + ?Sized>(
    exec: &E,
    cfg: &TrainConfig,
    train_ds: &Dataset,
    val_ds: &Dataset,
    opts: &TrainerOptions,
) -> Result<TrainResult> {
    let scheduler = Scheduler::parse(&cfg.scheduler)?;
    let n_layers = exec.n_quant_layers();
    let k = budget_to_k(n_layers, cfg.quant_fraction);
    let q = cfg.batch_size as f64 / train_ds.len() as f64;
    let steps_per_epoch = (train_ds.len() / cfg.batch_size).max(1);

    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let mut data_rng = rng.split(0xDA7A);
    let mut sched_rng = rng.split(0x5C4E);
    let noise = GaussianSampler::new(rng.split(0x0153));
    let mut analysis_noise = GaussianSampler::new(rng.split(0xA2A1));

    let mut weights = exec.initial_weights();
    let mut opt = DpOptimizer::new(
        cfg.optimizer,
        cfg.lr,
        cfg.noise_multiplier,
        cfg.clip_norm,
        cfg.batch_size as f64,
        &exec.param_sizes(),
        noise.clone(),
    );
    let mut accountant = RdpAccountant::new();
    let mut ema = EmaScores::new(n_layers, cfg.ema_alpha, cfg.ema_enabled);
    let mut record = RunRecord {
        name: format!(
            "{}_{}_{}_{}_k{}_s{}",
            cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler, k, cfg.seed
        ),
        config_summary: format!(
            "opt={} lr={} sigma={} C={} B={} |D|={} eps_target={:?} beta={}",
            cfg.optimizer.name(),
            cfg.lr,
            cfg.noise_multiplier,
            cfg.clip_norm,
            cfg.batch_size,
            train_ds.len(),
            cfg.target_epsilon,
            cfg.beta
        ),
        ..Default::default()
    };
    let mut trace = StepTrace::default();

    // Frozen subsets for the static baselines.
    let static_policy = match scheduler {
        Scheduler::StaticRandom => Some(Policy::from_layers(
            n_layers,
            sched_rng.sample_indices(n_layers, k),
        )),
        Scheduler::StaticFirst => Some(Policy::from_layers(n_layers, (0..k).collect())),
        Scheduler::StaticLast => Some(Policy::from_layers(
            n_layers,
            (n_layers - k..n_layers).collect(),
        )),
        Scheduler::None => Some(Policy::baseline(n_layers)),
        Scheduler::All => Some(Policy::all(n_layers)),
        _ => None,
    };

    let mut truncated = false;
    'epochs: for epoch in 0..cfg.epochs {
        // ---- Budget check before spending on analysis.
        if let Some(target) = cfg.target_epsilon {
            if accountant.epsilon(cfg.delta).0 >= target {
                break 'epochs;
            }
        }

        // ---- Algorithm 1 (DPQuant only, every analysis_interval epochs)
        let mut analysis_seconds = 0.0;
        if scheduler == Scheduler::DpQuant && epoch % cfg.analysis_interval.max(1) == 0 {
            // The probe subsample is n_sample examples in expectation
            // (paper Table 3), NOT a full training batch — this keeps
            // the analysis SGM's privacy cost negligible (Fig. 3).
            let q_meas =
                (cfg.analysis_samples as f64 / train_ds.len() as f64).min(1.0);
            let probe_idx = poisson_sample(&mut data_rng, train_ds.len(), q_meas);
            if !probe_idx.is_empty() {
                let probes = make_batches(train_ds, &probe_idx, exec.physical_batch());
                let report = compute_loss_impact(
                    exec,
                    cfg,
                    &weights,
                    &probes,
                    &mut ema,
                    &mut accountant,
                    &mut analysis_noise,
                    (epoch * 7919) as f32,
                )?;
                analysis_seconds = report.seconds;
            }
        }

        // ---- Algorithm 2: pick this epoch's policy
        let policy = match scheduler {
            Scheduler::DpQuant => {
                let scores = ema.scores().to_vec();
                Policy::from_layers(n_layers, select_targets(&mut sched_rng, &scores, cfg.beta, k))
            }
            Scheduler::Pls => {
                Policy::from_layers(n_layers, sched_rng.sample_indices(n_layers, k))
            }
            _ => static_policy.clone().unwrap(),
        };
        let quant_mask = policy.mask();

        // ---- The epoch's DP-SGD steps
        let t0 = std::time::Instant::now();
        let mut train_loss_sum = 0f64;
        let mut train_count = 0f64;
        for step in 0..steps_per_epoch {
            let idx = poisson_sample(&mut data_rng, train_ds.len(), q);
            accountant.step_training(q, cfg.noise_multiplier, 1);
            if idx.is_empty() {
                continue;
            }
            // Poisson batches can exceed the physical batch: chunk and
            // accumulate the clipped-grad sums (exact — the sum is linear).
            let mut agg: Option<Vec<Vec<f32>>> = None;
            let step_base = (cfg.seed as usize)
                .wrapping_mul(1_000_003)
                .wrapping_add(epoch * 10_007 + step);
            let mut step_rawsum = 0f64;
            let mut step_rawmax = 0f64;
            // Each physical chunk gets a distinct seed so per-sample
            // stochastic-rounding streams never collide across chunks of
            // one logical step (executors key their RNG on (seed, row)
            // with row < physical_batch ≤ the 4096 stride). Seeds travel
            // as f32 (the compiled graphs take a scalar f32 input), so
            // reduce mod 2^24 *after* the chunk offset — every value
            // stays in f32's exact-integer range and never rounds.
            for (ci, b) in make_batches(train_ds, &idx, exec.physical_batch())
                .into_iter()
                .enumerate()
            {
                let chunk_seed = (step_base.wrapping_add(ci * 4096) % (1 << 24)) as f32;
                let out = exec.train_step(&weights, &b.x, &b.y, &b.mask, &quant_mask, chunk_seed)?;
                train_loss_sum += out.loss_sum as f64;
                train_count += b.real as f64;
                step_rawsum += out.raw_norm_sum as f64;
                step_rawmax = step_rawmax.max(out.raw_norm_max as f64);
                match agg.as_mut() {
                    None => agg = Some(out.grad_sums),
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(&out.grad_sums) {
                            for (ai, gi) in a.iter_mut().zip(g) {
                                *ai += gi;
                            }
                        }
                    }
                }
            }
            let mut grads = agg.unwrap();
            let stats = opt.update(&mut weights, &mut grads);
            if opts.collect_step_stats {
                trace.stats.push(stats);
                trace.raw_norm_mean.push(step_rawsum / idx.len() as f64);
                trace.raw_norm_max.push(step_rawmax);
            }

            // Budget check: truncate training at the target ε (paper §6.2
            // "truncating the training at the respective privacy
            // budgets").
            if let Some(target) = cfg.target_epsilon {
                if accountant.epsilon(cfg.delta).0 >= target {
                    truncated = true;
                }
            }
            if truncated {
                break;
            }
        }
        let train_seconds = t0.elapsed().as_secs_f64();

        // ---- Eval + record
        let (val_loss, val_acc) = evaluate(exec, &weights, val_ds)?;
        let (eps, _) = accountant.epsilon(cfg.delta);
        record.analysis_epsilon = accountant.epsilon_of(Mechanism::Analysis, cfg.delta).0;
        record.push(EpochRecord {
            epoch,
            train_loss: train_loss_sum / train_count.max(1.0),
            val_loss,
            val_accuracy: val_acc,
            epsilon: eps,
            quantized_layers: policy.layers.clone(),
            train_seconds,
            analysis_seconds,
        });
        if opts.verbose {
            println!(
                "epoch {epoch:>3}  loss {:.4}  val_acc {:.3}  eps {:.3}  layers {:?}",
                record.epochs.last().unwrap().train_loss,
                val_acc,
                eps,
                policy.layers
            );
        }
        if truncated {
            break 'epochs;
        }
    }

    Ok(TrainResult {
        record,
        trace,
        final_weights: weights,
        accountant,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;

    fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let c = rng.next_below(classes as u64) as i32;
            for f in 0..feats {
                xs.push(0.5 * rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 });
            }
            ys.push(c);
        }
        Dataset {
            xs,
            ys,
            example_numel: feats,
            n_classes: classes,
        }
    }

    fn base_cfg() -> TrainConfig {
        TrainConfig {
            epochs: 6,
            batch_size: 16,
            dataset_size: 256,
            noise_multiplier: 0.6,
            clip_norm: 1.0,
            lr: 0.8,
            quant_fraction: 0.5,
            scheduler: "dpquant".into(),
            analysis_interval: 2,
            seed: 3,
            physical_batch: 32,
            ..TrainConfig::default()
        }
    }

    fn run(cfg: &TrainConfig) -> TrainResult {
        let exec = MockExecutor::new(8, 4, 6, 32);
        let ds = toy_dataset(256 + 64, 8, 4, cfg.seed);
        let (tr, va) = ds.split(64);
        train(&exec, cfg, &tr, &va, &TrainerOptions::default()).unwrap()
    }

    #[test]
    fn dpquant_learns_and_accounts() {
        let res = run(&base_cfg());
        assert_eq!(res.record.epochs.len(), 6);
        assert!(res.record.final_accuracy > 0.5, "acc={}", res.record.final_accuracy);
        assert!(res.record.final_epsilon > 0.0);
        // Analysis ran ⌈6/2⌉ = 3 times.
        assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 3);
        assert_eq!(
            res.accountant.steps_of(Mechanism::Training),
            6 * (256 / 16) as u64
        );
        // Each epoch quantized exactly k = 3 of 6 layers.
        for e in &res.record.epochs {
            assert_eq!(e.quantized_layers.len(), 3);
        }
    }

    #[test]
    fn schedulers_produce_expected_layer_patterns() {
        for (name, rotates) in [
            ("static_random", false),
            ("static_first", false),
            ("pls", true),
            ("dpquant", true),
        ] {
            let cfg = TrainConfig {
                scheduler: name.into(),
                ..base_cfg()
            };
            let res = run(&cfg);
            let first = &res.record.epochs[0].quantized_layers;
            let all_same = res
                .record
                .epochs
                .iter()
                .all(|e| &e.quantized_layers == first);
            if rotates {
                assert!(!all_same, "{name} should rotate layers");
            } else {
                assert!(all_same, "{name} should freeze layers");
            }
        }
        // static_first quantizes layers 0..k.
        let cfg = TrainConfig {
            scheduler: "static_first".into(),
            ..base_cfg()
        };
        let res = run(&cfg);
        assert_eq!(res.record.epochs[0].quantized_layers, vec![0, 1, 2]);
    }

    #[test]
    fn none_scheduler_never_quantizes_and_skips_analysis() {
        let cfg = TrainConfig {
            scheduler: "none".into(),
            ..base_cfg()
        };
        let res = run(&cfg);
        assert!(res.record.epochs.iter().all(|e| e.quantized_layers.is_empty()));
        assert_eq!(res.accountant.steps_of(Mechanism::Analysis), 0);
        assert_eq!(res.record.analysis_epsilon, 0.0);
    }

    #[test]
    fn target_epsilon_truncates() {
        // Use a scheduler without analysis so ε grows smoothly per step
        // and truncation lands near the target.
        let mut cfg = base_cfg();
        cfg.scheduler = "static_random".into();
        // One SGM step at q=16/256, σ=1 already costs ε≈1.76 at δ=1e-5,
        // so pick a target a few steps out and verify the run stops just
        // past it.
        cfg.target_epsilon = Some(2.5);
        cfg.epochs = 50;
        cfg.noise_multiplier = 1.0;
        let res = run(&cfg);
        assert!(res.record.epochs.len() < 50, "should truncate early");
        // Final ε is at (just past) the target, not way beyond.
        assert!(res.record.final_epsilon >= 2.5);
        assert!(res.record.final_epsilon < 2.8, "eps={}", res.record.final_epsilon);
    }

    #[test]
    fn budget_checked_before_analysis() {
        // A tiny budget must stop the run before (further) analysis
        // spends more: final ε may exceed the target once but not grow
        // across later epochs.
        let mut cfg = base_cfg();
        cfg.target_epsilon = Some(0.5);
        cfg.epochs = 30;
        let res = run(&cfg);
        assert!(res.record.epochs.len() <= 2, "len={}", res.record.epochs.len());
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&base_cfg());
        let b = run(&base_cfg());
        assert_eq!(a.record.final_accuracy, b.record.final_accuracy);
        assert_eq!(
            a.record.epochs.last().unwrap().quantized_layers,
            b.record.epochs.last().unwrap().quantized_layers
        );
        let mut cfg2 = base_cfg();
        cfg2.seed = 4;
        let c = run(&cfg2);
        let layers_a: Vec<_> = a.record.epochs.iter().map(|e| e.quantized_layers.clone()).collect();
        let layers_c: Vec<_> = c.record.epochs.iter().map(|e| e.quantized_layers.clone()).collect();
        assert_ne!(layers_a, layers_c, "different seeds, different schedules");
    }

    #[test]
    fn step_stats_collected_when_requested() {
        let exec = MockExecutor::new(8, 4, 6, 32);
        let cfg = base_cfg();
        let ds = toy_dataset(320, 8, 4, 1);
        let (tr, va) = ds.split(64);
        let opts = TrainerOptions {
            collect_step_stats: true,
            verbose: false,
        };
        let res = train(&exec, &cfg, &tr, &va, &opts).unwrap();
        assert!(!res.trace.stats.is_empty());
        let s = &res.trace.stats[0];
        assert!(s.noise_l2 > 0.0 && s.grad_l2 > 0.0);
        // (The paper's Eq.-2 dominance claim needs high-dimensional
        // models; it is asserted in the optimizer's own tests and
        // reproduced at scale by `dpquant exp fig1b`.)
    }
}
