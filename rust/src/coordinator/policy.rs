//! Quantization policies: which layers run low-precision this epoch.
//!
//! Following §5.3 the scheduler reasons per-layer: the policy space P in
//! Algorithm 1/2 is instantiated as the single-layer policies
//! `p_i = {layer i}` (so `L[p_i]` is layer i's loss impact), and a
//! concrete epoch policy is a union of k sampled layers, carried to the
//! compiled graph as the `quant_mask` runtime input.

/// A set of quantized layers out of `n_layers`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Policy {
    /// Total quantizable layers in the model.
    pub n_layers: usize,
    /// Sorted, distinct layer indices that run quantized.
    pub layers: Vec<usize>,
}

impl Policy {
    /// The empty (full-precision) policy — Algorithm 1's baseline p0.
    pub fn baseline(n_layers: usize) -> Self {
        Self {
            n_layers,
            layers: Vec::new(),
        }
    }

    /// Quantize everything.
    pub fn all(n_layers: usize) -> Self {
        Self {
            n_layers,
            layers: (0..n_layers).collect(),
        }
    }

    /// A single-layer probe policy.
    pub fn single(n_layers: usize, layer: usize) -> Self {
        assert!(layer < n_layers);
        Self {
            n_layers,
            layers: vec![layer],
        }
    }

    /// From an arbitrary set of indices.
    pub fn from_layers(n_layers: usize, mut layers: Vec<usize>) -> Self {
        layers.sort_unstable();
        layers.dedup();
        assert!(layers.iter().all(|&l| l < n_layers));
        Self { n_layers, layers }
    }

    /// Number of quantized layers.
    pub fn k(&self) -> usize {
        self.layers.len()
    }

    /// The runtime `quant_mask` input for the compiled graph.
    pub fn mask(&self) -> Vec<f32> {
        let mut m = vec![0f32; self.n_layers];
        for &l in &self.layers {
            m[l] = 1.0;
        }
        m
    }

    /// Is `layer` quantized under this policy?
    pub fn contains(&self, layer: usize) -> bool {
        self.layers.binary_search(&layer).is_ok()
    }
}

/// How many layers a "percent quantized" budget means (paper Table 1 uses
/// fractions of the quantizable layers, rounding to nearest).
pub fn budget_to_k(n_layers: usize, fraction: f64) -> usize {
    ((n_layers as f64 * fraction).round() as usize).clamp(0, n_layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks() {
        let p = Policy::from_layers(5, vec![3, 1, 3]);
        assert_eq!(p.layers, vec![1, 3]);
        assert_eq!(p.mask(), vec![0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!(p.contains(1) && !p.contains(0));
        assert_eq!(p.k(), 2);
    }

    #[test]
    fn baseline_and_all() {
        assert_eq!(Policy::baseline(4).mask(), vec![0.0; 4]);
        assert_eq!(Policy::all(3).mask(), vec![1.0; 3]);
    }

    #[test]
    fn budget_rounding() {
        assert_eq!(budget_to_k(10, 0.5), 5);
        assert_eq!(budget_to_k(10, 0.75), 8);
        assert_eq!(budget_to_k(10, 0.9), 9);
        assert_eq!(budget_to_k(8, 0.9), 7);
        assert_eq!(budget_to_k(7, 1.0), 7);
        assert_eq!(budget_to_k(7, 0.0), 0);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range() {
        Policy::from_layers(3, vec![5]);
    }
}
