//! Abstraction over the compiled step executables, so the scheduler,
//! analysis and trainer are testable against a pure-Rust mock model as
//! well as the real PJRT-backed graphs.

use crate::runtime::{EvalOutput, LoadedGraph, TrainOutput};
use crate::util::error::Result;

/// Everything the coordinator needs from a (train, eval) executable pair.
pub trait StepExecutor {
    /// Number of quantizable layers (length of `quant_mask`).
    fn n_quant_layers(&self) -> usize;
    /// Physical batch size of the compiled graphs.
    fn physical_batch(&self) -> usize;
    /// Sizes (numel) of each parameter tensor.
    fn param_sizes(&self) -> Vec<usize>;
    /// Initial parameter values.
    fn initial_weights(&self) -> Vec<Vec<f32>>;
    /// DP-SGD step: Σ clipped per-sample grads + loss/correct sums.
    fn train_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        quant_mask: &[f32],
        seed: f32,
    ) -> Result<TrainOutput>;
    /// Full-precision eval of a masked batch.
    fn eval_step(&self, weights: &[Vec<f32>], x: &[f32], y: &[i32], mask: &[f32])
        -> Result<EvalOutput>;
    /// For each quantizable layer, the index of the parameter tensor
    /// holding its weights (quantizable layers are NOT 1:1 with
    /// parameter tensors — biases have their own tensors, and the mock
    /// folds every layer into one). `None` when the executor cannot
    /// provide the mapping; policy = "layer_lr" then degrades to
    /// uniform learning rates instead of guessing.
    fn quant_weight_params(&self) -> Option<Vec<usize>> {
        None
    }
}

impl StepExecutor for LoadedGraph {
    fn n_quant_layers(&self) -> usize {
        self.info.n_quant_layers
    }
    fn physical_batch(&self) -> usize {
        self.batch()
    }
    fn param_sizes(&self) -> Vec<usize> {
        self.info.params.iter().map(|p| p.numel()).collect()
    }
    fn initial_weights(&self) -> Vec<Vec<f32>> {
        self.init_weights.clone()
    }
    fn train_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        quant_mask: &[f32],
        seed: f32,
    ) -> Result<TrainOutput> {
        LoadedGraph::train_step(self, weights, x, y, mask, quant_mask, seed)
    }
    fn eval_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOutput> {
        LoadedGraph::eval_step(self, weights, x, y, mask)
    }
}

/// A pure-Rust mock: multinomial logistic regression over raw features
/// with simulated per-layer quantization noise. Exact per-sample
/// clipping, differentiable by hand — used by unit/integration tests and
/// by benches that must not depend on artifacts.
pub struct MockExecutor {
    /// Input feature count per example.
    pub n_features: usize,
    /// Number of output classes.
    pub n_classes: usize,
    /// How many (simulated) quantizable layers to expose.
    pub n_layers: usize,
    /// Physical batch size the mock accepts.
    pub batch: usize,
    /// Per-sample clipping norm C.
    pub clip_norm: f32,
    /// Per-layer quantization damage: scales the synthetic gradient noise
    /// injected when a layer is quantized (higher = more sensitive).
    pub layer_sensitivity: Vec<f32>,
}

impl MockExecutor {
    /// A mock with unit clip norm and mildly increasing layer sensitivity.
    pub fn new(n_features: usize, n_classes: usize, n_layers: usize, batch: usize) -> Self {
        Self {
            n_features,
            n_classes,
            n_layers,
            batch,
            clip_norm: 1.0,
            layer_sensitivity: (0..n_layers).map(|i| 1.0 + i as f32 * 0.25).collect(),
        }
    }

    fn logits(&self, w: &[f32], x: &[f32]) -> Vec<f32> {
        (0..self.n_classes)
            .map(|c| {
                (0..self.n_features)
                    .map(|f| w[c * self.n_features + f] * x[f])
                    .sum()
            })
            .collect()
    }

    /// Deterministic pseudo-quantization noise with Prop-1 semantics:
    /// per-element error magnitude scales with the tensor's ∞-norm (a
    /// scale-invariant grid quantizer's variance is Θ(‖g‖∞²)). Under DP,
    /// noisy weights inflate gradient magnitudes, so the same fraction of
    /// quantized layers injects more absolute error — exactly the
    /// amplification the paper analyzes in §4.
    fn quant_perturb(&self, g: &mut [f32], quant_mask: &[f32], seed: f32) {
        let strength: f32 = quant_mask
            .iter()
            .zip(&self.layer_sensitivity)
            .map(|(&m, &s)| m * s)
            .sum::<f32>()
            / self.n_layers as f32;
        if strength == 0.0 {
            return;
        }
        let gmax = g.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let mut h = seed.to_bits() ^ 0x5bd1e995;
        for v in g.iter_mut() {
            h = h.wrapping_mul(1664525).wrapping_add(1013904223);
            let r = (h >> 9) as f32 / (1u32 << 23) as f32 - 1.0; // [-1,1)
            *v += 0.06 * strength * r * gmax;
        }
    }
}

impl StepExecutor for MockExecutor {
    fn n_quant_layers(&self) -> usize {
        self.n_layers
    }
    fn physical_batch(&self) -> usize {
        self.batch
    }
    fn param_sizes(&self) -> Vec<usize> {
        vec![self.n_classes * self.n_features]
    }
    fn initial_weights(&self) -> Vec<Vec<f32>> {
        vec![vec![0f32; self.n_classes * self.n_features]]
    }
    fn quant_weight_params(&self) -> Option<Vec<usize>> {
        // Every simulated layer perturbs the single logistic-regression
        // weight tensor.
        Some(vec![0; self.n_layers])
    }

    fn train_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
        quant_mask: &[f32],
        seed: f32,
    ) -> Result<TrainOutput> {
        let w = &weights[0];
        let mut grad_sum = vec![0f32; w.len()];
        let mut loss_sum = 0f32;
        let mut correct = 0f32;
        let mut raw_norm_sum = 0f32;
        let mut raw_norm_max = 0f32;
        for i in 0..self.batch {
            if mask[i] == 0.0 {
                continue;
            }
            let xi = &x[i * self.n_features..(i + 1) * self.n_features];
            let logits = self.logits(w, xi);
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|&l| (l - maxl).exp()).collect();
            let z: f32 = exps.iter().sum();
            let yi = y[i] as usize;
            loss_sum += z.ln() + maxl - logits[yi];
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == yi {
                correct += 1.0;
            }
            // Per-sample grad of CE wrt w, then simulated quantization
            // perturbation, then clip, then accumulate.
            let mut gi = vec![0f32; w.len()];
            for c in 0..self.n_classes {
                let p = exps[c] / z - if c == yi { 1.0 } else { 0.0 };
                for f in 0..self.n_features {
                    gi[c * self.n_features + f] = p * xi[f];
                }
            }
            self.quant_perturb(&mut gi, quant_mask, seed + i as f32);
            let norm: f32 = gi.iter().map(|&g| g * g).sum::<f32>().sqrt();
            raw_norm_sum += norm;
            raw_norm_max = raw_norm_max.max(norm);
            let scale = (self.clip_norm / norm.max(1e-12)).min(1.0);
            for (gs, g) in grad_sum.iter_mut().zip(&gi) {
                *gs += g * scale;
            }
        }
        Ok(TrainOutput {
            grad_sums: vec![grad_sum],
            loss_sum,
            correct_sum: correct,
            raw_norm_sum,
            raw_norm_max,
        })
    }

    fn eval_step(
        &self,
        weights: &[Vec<f32>],
        x: &[f32],
        y: &[i32],
        mask: &[f32],
    ) -> Result<EvalOutput> {
        let w = &weights[0];
        let mut loss_sum = 0f32;
        let mut correct = 0f32;
        for i in 0..self.batch {
            if mask[i] == 0.0 {
                continue;
            }
            let xi = &x[i * self.n_features..(i + 1) * self.n_features];
            let logits = self.logits(w, xi);
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = logits.iter().map(|&l| (l - maxl).exp()).sum();
            loss_sum += z.ln() + maxl - logits[y[i] as usize];
            let argmax = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if argmax == y[i] as usize {
                correct += 1.0;
            }
        }
        Ok(EvalOutput {
            loss_sum,
            correct_sum: correct,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch(exec: &MockExecutor, seed: u64) -> (Vec<f32>, Vec<i32>, Vec<f32>) {
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(seed);
        let mut x = vec![0f32; exec.batch * exec.n_features];
        let mut y = vec![0i32; exec.batch];
        for i in 0..exec.batch {
            let class = rng.next_below(exec.n_classes as u64) as i32;
            y[i] = class;
            for f in 0..exec.n_features {
                x[i * exec.n_features + f] =
                    rng.next_f32() + if f == class as usize { 1.5 } else { 0.0 };
            }
        }
        (x, y, vec![1.0; exec.batch])
    }

    #[test]
    fn mock_learns_separable_task() {
        let exec = MockExecutor::new(6, 3, 4, 16);
        let mut w = exec.initial_weights();
        let zero_mask = vec![0f32; 4];
        for step in 0..60 {
            let (x, y, m) = toy_batch(&exec, step);
            let out = exec.train_step(&w, &x, &y, &m, &zero_mask, 0.0).unwrap();
            for (wi, gi) in w[0].iter_mut().zip(&out.grad_sums[0]) {
                *wi -= 0.1 * gi / 16.0;
            }
        }
        let (x, y, m) = toy_batch(&exec, 999);
        let ev = exec.eval_step(&w, &x, &y, &m).unwrap();
        assert!(
            ev.correct_sum >= 12.0,
            "accuracy too low: {}/16",
            ev.correct_sum
        );
    }

    #[test]
    fn clip_bound_holds() {
        let exec = MockExecutor::new(4, 2, 3, 8);
        let w = vec![vec![0.5f32; 8]];
        let (x, y, m) = toy_batch(&exec, 1);
        let out = exec.train_step(&w, &x, &y, &m, &[0.0, 0.0, 0.0], 0.0).unwrap();
        let norm: f32 = out.grad_sums[0].iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(norm <= 8.0 * exec.clip_norm + 1e-4);
    }

    #[test]
    fn quantization_perturbs_and_scales_with_sensitivity() {
        let exec = MockExecutor::new(4, 2, 3, 8);
        let w = vec![vec![0.3f32; 8]];
        let (x, y, m) = toy_batch(&exec, 2);
        let base = exec.train_step(&w, &x, &y, &m, &[0.0; 3], 7.0).unwrap();
        let q = exec.train_step(&w, &x, &y, &m, &[1.0, 1.0, 1.0], 7.0).unwrap();
        let diff: f32 = base.grad_sums[0]
            .iter()
            .zip(&q.grad_sums[0])
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 0.0, "quantized mask must perturb grads");
    }
}
