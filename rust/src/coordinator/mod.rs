//! The DPQuant coordinator — the paper's system contribution, in Rust.
//!
//! * [`adaptive`]  — adaptive-DP policies: noise/clip decay, sampling-
//!   rate schedules, per-layer learning rates (DESIGN.md §16);
//! * [`policy`]    — quantization policies and masks;
//! * [`ema`]       — EMA of loss-impact scores (Alg. 1 step 4);
//! * [`sampler`]   — Algorithm 2 (SELECTTARGETS);
//! * [`analysis`]  — Algorithm 1 (COMPUTELOSSIMPACT, the DP estimator);
//! * [`optimizer`] — DP-SGD/Adam/AdamW with fp32 noise (§A.17);
//! * [`executor`]  — abstraction over the compiled PJRT step + mock;
//! * [`session`]   — the public API: `TrainSession`, a resumable,
//!   observable, checkpointable state machine over the epoch loop;
//! * [`trainer`]   — the batch-mode `train()` compatibility wrapper.

pub mod adaptive;
pub mod analysis;
pub mod ema;
pub mod executor;
pub mod optimizer;
pub mod policy;
pub mod sampler;
pub mod session;
pub mod trainer;

pub use adaptive::{AdaptivePolicy, DecayShape, EpochKnobs};
pub use executor::{MockExecutor, StepExecutor};
pub use policy::{budget_to_k, Policy};
pub use session::{
    AuditEpoch, Checkpoint, EpochOutcome, EventSink, MultiSink, NullSink, SessionBuilder,
    TraceSink, TrainEvent, TrainSession, VerboseSink,
};
pub use session::evaluate;
pub use trainer::{train, train_with_sink, Scheduler, TrainResult, TrainerOptions};
