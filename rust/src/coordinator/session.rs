//! `TrainSession` — the coordinator's public API: a resumable,
//! observable, checkpointable training state machine.
//!
//! The paper's epoch loop (Fig. 2: COMPUTELOSSIMPACT → SELECTTARGETS →
//! DP-SGD steps → truncate-at-budget) is inherently stateful across
//! epochs: EMA'd loss-impact scores, the composed RDP curve, optimizer
//! moments, and four independent RNG streams all carry over. This module
//! makes that state a first-class value instead of locals trapped in a
//! 300-line `train()` body:
//!
//! * [`TrainSession::builder`] validates a [`TrainConfig`] **once** at
//!   build time (scheduler parsed into an enum, hostile values like
//!   `batch_size == 0` or `quant_fraction ∉ [0, 1]` rejected with
//!   actionable messages);
//! * [`TrainSession::step_epoch`] advances one epoch and reports an
//!   [`EpochOutcome`]; [`TrainSession::run`] drives it to completion,
//!   reproducing the legacy `train()` semantics bit-for-bit;
//! * progress is observed through a typed [`TrainEvent`] stream into an
//!   [`EventSink`] — the provided [`VerboseSink`] and [`TraceSink`]
//!   replace the old `TrainerOptions { verbose, collect_step_stats }`
//!   flags;
//! * [`TrainSession::checkpoint`] / [`TrainSession::resume`] serialize
//!   the **full** state (weights, optimizer moments, RDP history, EMA
//!   scores, RNG streams, counters) in a versioned zero-dependency JSON
//!   format; resuming continues the run **bit-exactly** — floats travel
//!   as IEEE-754 bit patterns in hex, never as decimal text.

use super::adaptive::{self, AdaptivePolicy, EpochKnobs};
use super::analysis::compute_loss_impact;
use super::ema::EmaScores;
use super::executor::StepExecutor;
use super::optimizer::{DpOptimizer, NoiseStats};
use super::policy::{budget_to_k, Policy};
use super::sampler::{normalize, select_targets, softmax_neg};
use super::trainer::{Scheduler, StepTrace};
use crate::config::TrainConfig;
use crate::data::{eval_batches, make_batches, poisson_sample, Dataset};
use crate::metrics::{EpochRecord, RunRecord};
use crate::privacy::{Mechanism, RdpAccountant, StepRecord};
use crate::util::error::{ensure, err, Context, Result};
use crate::util::gaussian::GaussianSampler;
use crate::util::json::{self, Json};
use crate::util::rng::Xoshiro256;
use std::fmt::Write as _;

// ---------------------------------------------------------------------
// Event stream
// ---------------------------------------------------------------------

/// A typed progress event emitted by [`TrainSession`]. Borrowed payloads
/// point into the session; sinks that need to keep them clone.
#[derive(Debug)]
pub enum TrainEvent<'a> {
    /// An epoch is about to run.
    EpochStarted { epoch: usize },
    /// Algorithm 1 ran (DPQuant scheduler only): privatized per-layer
    /// loss impacts, already folded into the EMA.
    AnalysisCompleted {
        epoch: usize,
        impacts: &'a [f64],
        seconds: f64,
    },
    /// Algorithm 2 picked this epoch's quantization policy.
    PolicySelected { epoch: usize, policy: &'a Policy },
    /// One DP-SGD step finished (emitted for non-empty Poisson batches).
    StepCompleted {
        epoch: usize,
        step: usize,
        /// Examples in the logical (Poisson) batch.
        examples: usize,
        stats: NoiseStats,
        /// Mean pre-clip per-sample grad norm over the batch.
        raw_norm_mean: f64,
        /// Max pre-clip per-sample grad norm over the batch.
        raw_norm_max: f64,
    },
    /// The privacy budget was reached mid-epoch; no further steps run.
    Truncated { epoch: usize, step: usize, epsilon: f64 },
    /// The epoch's record (eval + ε) was appended to the run record.
    EpochCompleted { record: &'a EpochRecord },
    /// The epoch's DP audit record: resolved knobs, sampled mask with
    /// draw probabilities, the accountant's step-record delta, and the
    /// composed (ε, α*). Emitted once per epoch, after
    /// [`EpochCompleted`](TrainEvent::EpochCompleted). Collecting it is
    /// pure observation — no RNG stream or accountant state is touched —
    /// so audited and unaudited runs are byte-identical.
    EpochAudited { audit: &'a AuditEpoch },
}

impl TrainEvent<'_> {
    /// Stable short name, for logs and golden tests.
    pub fn kind(&self) -> &'static str {
        match self {
            TrainEvent::EpochStarted { .. } => "epoch_started",
            TrainEvent::AnalysisCompleted { .. } => "analysis_completed",
            TrainEvent::PolicySelected { .. } => "policy_selected",
            TrainEvent::StepCompleted { .. } => "step_completed",
            TrainEvent::Truncated { .. } => "truncated",
            TrainEvent::EpochCompleted { .. } => "epoch_completed",
            TrainEvent::EpochAudited { .. } => "epoch_audited",
        }
    }
}

/// Everything ε-relevant that one epoch resolved, for the
/// `dpquant-audit` stream (DESIGN.md §17): the adaptive-policy knobs
/// actually applied, the Algorithm 2 mask with its draw probabilities,
/// the accountant's step-record *delta* for the epoch (training blocks
/// plus any analysis-probe event, in live order), and the composed
/// (ε, α*) afterwards. Built from clones of already-computed state plus
/// the pure Algorithm 2 probability function — never from fresh RNG
/// draws — so emitting it cannot perturb training.
#[derive(Clone, Debug)]
pub struct AuditEpoch {
    /// Epoch index.
    pub epoch: usize,
    /// Resolved σ_t after the adaptive policy.
    pub noise_multiplier: f64,
    /// Resolved Poisson rate q_t after the adaptive policy.
    pub sample_rate: f64,
    /// Resolved clip norm C_t.
    pub clip_norm: f64,
    /// C_t / C₀ — the clip-then-rescale factor applied to updates.
    pub clip_scale: f64,
    /// Per-layer lr scales when the `layer_lr` policy is active.
    pub lr_scales: Option<Vec<f64>>,
    /// The quantized-layer mask Algorithm 2 sampled (sorted indices).
    pub mask: Vec<usize>,
    /// Algorithm 2 draw probabilities π = softmax(-β · normalize(EMA))
    /// over all layers (DPQuant scheduler only; empty otherwise).
    pub draw_probs: Vec<f64>,
    /// The accountant's step-record delta for this epoch, in the order
    /// the live accountant recorded it (analysis probe first, then
    /// training steps). Replaying these blocks through a fresh
    /// accountant reproduces the composed ε bit-for-bit.
    pub accounting: Vec<StepRecord>,
    /// Training SGM steps accounted this epoch (= the training-step sum
    /// of `accounting`).
    pub steps: u64,
    /// Composed ε after this epoch, at the config δ.
    pub epsilon: f64,
    /// The α* minimizing the RDP→(ε, δ) conversion.
    pub alpha: f64,
    /// Wall-clock seconds the Algorithm 1 probe took (0 when it did not
    /// run; zeroed on the wire in `--no-timing` mode).
    pub analysis_seconds: f64,
    /// Did this epoch end by privacy-budget truncation?
    pub truncated: bool,
}

/// The accountant history appended since a bookmark taken at epoch
/// start (`mark` = history length, `boundary_steps` = step count of the
/// then-last block). Because [`RdpAccountant::record`] coalesces
/// identical adjacent blocks, the first block of the delta may be the
/// *growth* of the pre-existing boundary block; replaying the deltas of
/// every epoch in order through a fresh accountant rebuilds the exact
/// coalesced history — and therefore the exact ε float-sum order — of
/// the live run.
fn history_delta(history: &[StepRecord], mark: usize, boundary_steps: u64) -> Vec<StepRecord> {
    let mut delta = Vec::new();
    if mark > 0 && mark <= history.len() {
        let boundary = &history[mark - 1];
        if boundary.steps > boundary_steps {
            delta.push(StepRecord {
                steps: boundary.steps - boundary_steps,
                ..boundary.clone()
            });
        }
    }
    delta.extend(history[mark.min(history.len())..].iter().cloned());
    delta
}

/// Receives [`TrainEvent`]s as the session advances.
pub trait EventSink {
    fn on_event(&mut self, event: &TrainEvent<'_>);
}

/// Discards every event.
pub struct NullSink;

impl EventSink for NullSink {
    fn on_event(&mut self, _event: &TrainEvent<'_>) {}
}

/// Prints the per-epoch progress line the legacy `verbose` flag printed.
pub struct VerboseSink;

impl EventSink for VerboseSink {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        if let TrainEvent::EpochCompleted { record } = event {
            println!(
                "epoch {:>3}  loss {:.4}  val_acc {:.3}  eps {:.3}  layers {:?}",
                record.epoch,
                record.train_loss,
                record.val_accuracy,
                record.epsilon,
                record.quantized_layers
            );
        }
    }
}

/// Accumulates a [`StepTrace`] — the typed replacement for the legacy
/// `collect_step_stats` flag.
#[derive(Default)]
pub struct TraceSink {
    trace: StepTrace,
}

impl TraceSink {
    /// The trace collected so far.
    pub fn trace(&self) -> &StepTrace {
        &self.trace
    }
    /// Consume the sink, yielding the collected trace.
    pub fn into_trace(self) -> StepTrace {
        self.trace
    }
}

impl EventSink for TraceSink {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        if let TrainEvent::StepCompleted {
            stats,
            raw_norm_mean,
            raw_norm_max,
            ..
        } = event
        {
            self.trace.stats.push(*stats);
            self.trace.raw_norm_mean.push(*raw_norm_mean);
            self.trace.raw_norm_max.push(*raw_norm_max);
        }
    }
}

/// Fans one event stream out to several sinks.
pub struct MultiSink<'a> {
    sinks: Vec<&'a mut dyn EventSink>,
}

impl<'a> MultiSink<'a> {
    /// Fan out to the given sinks, in order.
    pub fn new(sinks: Vec<&'a mut dyn EventSink>) -> Self {
        Self { sinks }
    }
}

impl EventSink for MultiSink<'_> {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        for sink in self.sinks.iter_mut() {
            sink.on_event(event);
        }
    }
}

/// Evaluate `weights` over a full dataset; returns (mean loss, accuracy).
///
/// This is the single shared implementation behind the session's
/// per-epoch eval, the `trainer::train` wrapper, and the CLI's
/// `eval-only` — it lives beside the session (the core API) and is
/// re-exported from `trainer` for the legacy call sites.
pub fn evaluate<E: StepExecutor + ?Sized>(
    exec: &E,
    weights: &[Vec<f32>],
    ds: &Dataset,
) -> Result<(f64, f64)> {
    let mut loss = 0f64;
    let mut correct = 0f64;
    for b in eval_batches(ds, exec.physical_batch()) {
        let out = exec.eval_step(weights, &b.x, &b.y, &b.mask)?;
        loss += out.loss_sum as f64;
        correct += out.correct_sum as f64;
    }
    let n = ds.len() as f64;
    Ok((loss / n, correct / n))
}

// ---------------------------------------------------------------------
// Builder + validation
// ---------------------------------------------------------------------

/// Validates a config and produces a fresh [`TrainSession`].
pub struct SessionBuilder {
    cfg: TrainConfig,
}

impl SessionBuilder {
    /// Stage `cfg` for validation.
    pub fn new(cfg: TrainConfig) -> Self {
        Self { cfg }
    }

    /// Validate the config against the executor and training set, then
    /// build a session positioned before epoch 0.
    pub fn build<E: StepExecutor + ?Sized>(
        self,
        exec: &E,
        train_ds: &Dataset,
    ) -> Result<TrainSession> {
        let scheduler = validate_config(&self.cfg, train_ds.len())?;
        Ok(TrainSession::fresh(self.cfg, scheduler, exec, train_ds.len()))
    }
}

/// Reject configurations that would divide by zero, drive the Poisson
/// rate out of \[0, 1\], or otherwise corrupt a run midway. Returns the
/// parsed scheduler so the loop never re-parses strings.
pub fn validate_config(cfg: &TrainConfig, train_len: usize) -> Result<Scheduler> {
    ensure!(
        cfg.batch_size > 0,
        "batch_size must be positive: steps_per_epoch = |D|/B and q = B/|D| are undefined at 0"
    );
    ensure!(
        train_len > 0,
        "training set is empty: the Poisson rate q = B/|D| is undefined"
    );
    ensure!(
        cfg.batch_size <= train_len,
        "batch_size {} exceeds the training-set size {}: the Poisson rate q = B/|D| would \
         exceed 1, which the RDP accountant cannot compose",
        cfg.batch_size,
        train_len
    );
    ensure!(
        cfg.physical_batch > 0,
        "physical_batch must be positive (it is the executor's chunk size)"
    );
    ensure!(
        cfg.dataset_size > 0,
        "dataset_size must be positive (the analysis SGM rate divides by it)"
    );
    ensure!(
        cfg.quant_fraction.is_finite() && (0.0..=1.0).contains(&cfg.quant_fraction),
        "quant_fraction {} is outside [0, 1]: it is the fraction of layers quantized per epoch",
        cfg.quant_fraction
    );
    ensure!(
        cfg.noise_multiplier.is_finite() && cfg.noise_multiplier >= 0.0,
        "noise_multiplier {} must be a finite value >= 0",
        cfg.noise_multiplier
    );
    ensure!(
        cfg.clip_norm.is_finite() && cfg.clip_norm > 0.0,
        "clip_norm {} must be a finite value > 0",
        cfg.clip_norm
    );
    ensure!(cfg.lr.is_finite(), "lr {} must be finite", cfg.lr);
    ensure!(
        cfg.delta > 0.0 && cfg.delta < 1.0,
        "delta {} must lie strictly inside (0, 1) for the RDP-to-(eps, delta) conversion",
        cfg.delta
    );
    ensure!(
        cfg.beta.is_finite() && cfg.beta >= 0.0,
        "beta {} (Algorithm 2 softmax temperature) must be a finite value >= 0",
        cfg.beta
    );
    ensure!(
        (0.0..=1.0).contains(&cfg.ema_alpha),
        "ema_alpha {} is outside [0, 1]",
        cfg.ema_alpha
    );
    if let Some(target) = cfg.target_epsilon {
        ensure!(
            target.is_finite() && target > 0.0,
            "target_epsilon {target} must be a finite value > 0"
        );
    }
    // The adaptive-DP policy resolves (and range-checks its endpoints)
    // from the same config; reject hostile schedules here, before a
    // session or a ledger reservation is built on them.
    AdaptivePolicy::from_config(cfg)?;
    Scheduler::parse(&cfg.scheduler)
}

// ---------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------

/// Result of one [`TrainSession::step_epoch`] call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EpochOutcome {
    /// An epoch ran to completion.
    Completed { epoch: usize, epsilon: f64, val_accuracy: f64 },
    /// An epoch ran but hit the privacy budget mid-way; the session is
    /// finished.
    Truncated { epoch: usize, epsilon: f64, val_accuracy: f64 },
    /// Nothing ran: all epochs are done, the budget was already
    /// exhausted, or a previous epoch truncated.
    Finished,
}

/// The training state machine. Owns every piece of cross-epoch state;
/// the executor and datasets stay outside and are passed to each call
/// (they are immutable throughout a run).
pub struct TrainSession {
    cfg: TrainConfig,
    scheduler: Scheduler,
    /// Adaptive-DP policy (DESIGN.md §16). A pure function of `cfg`,
    /// so it is re-derived on resume rather than checkpointed.
    adaptive: AdaptivePolicy,
    n_layers: usize,
    k: usize,
    /// Poisson rate q = B / |D_train|.
    q: f64,
    steps_per_epoch: usize,
    /// |D_train| the session was built against (guards mismatched data).
    train_len: usize,
    /// |D_val| observed on the first epoch (None until then); later
    /// epochs — including resumed ones — must present the same set.
    val_len: Option<usize>,
    weights: Vec<Vec<f32>>,
    opt: DpOptimizer,
    accountant: RdpAccountant,
    ema: EmaScores,
    data_rng: Xoshiro256,
    sched_rng: Xoshiro256,
    analysis_noise: GaussianSampler,
    /// Frozen subset for the static baselines (None for rotating
    /// schedulers).
    static_policy: Option<Policy>,
    record: RunRecord,
    /// Next epoch index to run == number of completed epochs.
    epoch: usize,
    truncated: bool,
    finished: bool,
}

impl TrainSession {
    /// Entry point: `TrainSession::builder(cfg).build(exec, train_ds)`.
    pub fn builder(cfg: TrainConfig) -> SessionBuilder {
        SessionBuilder::new(cfg)
    }

    fn fresh<E: StepExecutor + ?Sized>(
        cfg: TrainConfig,
        scheduler: Scheduler,
        exec: &E,
        train_len: usize,
    ) -> Self {
        let n_layers = exec.n_quant_layers();
        let k = budget_to_k(n_layers, cfg.quant_fraction);
        let q = cfg.batch_size as f64 / train_len as f64;
        let steps_per_epoch = (train_len / cfg.batch_size).max(1);
        let adaptive =
            AdaptivePolicy::from_config(&cfg).expect("config validated by SessionBuilder");

        // Stream order is part of the reproducibility contract: the
        // legacy trainer split data/sched/noise/analysis in exactly this
        // order from the root seed.
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
        let mut data_rng = rng.split(0xDA7A);
        let mut sched_rng = rng.split(0x5C4E);
        let noise = GaussianSampler::new(rng.split(0x0153));
        let analysis_noise = GaussianSampler::new(rng.split(0xA2A1));

        let weights = exec.initial_weights();
        let opt = DpOptimizer::new(
            cfg.optimizer,
            cfg.lr,
            cfg.noise_multiplier,
            cfg.clip_norm,
            cfg.batch_size as f64,
            &exec.param_sizes(),
            noise,
        );
        let accountant = RdpAccountant::new();
        let ema = EmaScores::new(n_layers, cfg.ema_alpha, cfg.ema_enabled);

        // Frozen subsets for the static baselines (drawn once, before
        // any epoch, from the scheduler stream — as the legacy loop did).
        let static_policy = match scheduler {
            Scheduler::StaticRandom => Some(Policy::from_layers(
                n_layers,
                sched_rng.sample_indices(n_layers, k),
            )),
            Scheduler::StaticFirst => Some(Policy::from_layers(n_layers, (0..k).collect())),
            Scheduler::StaticLast => Some(Policy::from_layers(
                n_layers,
                (n_layers - k..n_layers).collect(),
            )),
            Scheduler::None => Some(Policy::baseline(n_layers)),
            Scheduler::All => Some(Policy::all(n_layers)),
            _ => None,
        };

        let record = RunRecord {
            name: format!(
                "{}_{}_{}_{}_k{}_s{}",
                cfg.model, cfg.dataset, cfg.quantizer, cfg.scheduler, k, cfg.seed
            ),
            config_summary: format!(
                "opt={} lr={} sigma={} C={} B={} |D|={} eps_target={:?} beta={}",
                cfg.optimizer.name(),
                cfg.lr,
                cfg.noise_multiplier,
                cfg.clip_norm,
                cfg.batch_size,
                train_len,
                cfg.target_epsilon,
                cfg.beta
            ),
            ..Default::default()
        };

        Self {
            cfg,
            scheduler,
            adaptive,
            n_layers,
            k,
            q,
            steps_per_epoch,
            train_len,
            val_len: None,
            weights,
            opt,
            accountant,
            ema,
            data_rng,
            sched_rng,
            analysis_noise,
            static_policy,
            record,
            epoch: 0,
            truncated: false,
            finished: false,
        }
    }

    /// Advance one epoch (Fig. 2 pipeline: budget check → Algorithm 1 →
    /// Algorithm 2 → Poisson-sampled DP-SGD steps → eval + record).
    pub fn step_epoch<E: StepExecutor + ?Sized>(
        &mut self,
        exec: &E,
        train_ds: &Dataset,
        val_ds: &Dataset,
        sink: &mut dyn EventSink,
    ) -> Result<EpochOutcome> {
        ensure!(
            train_ds.len() == self.train_len,
            "training set has {} examples but the session was built against {}; \
             resume must regenerate the identical dataset",
            train_ds.len(),
            self.train_len
        );
        match self.val_len {
            None => self.val_len = Some(val_ds.len()),
            Some(n) => ensure!(
                val_ds.len() == n,
                "validation set has {} examples but earlier epochs evaluated {}; \
                 resume must regenerate the identical dataset",
                val_ds.len(),
                n
            ),
        }
        if self.finished || self.truncated || self.epoch >= self.cfg.epochs {
            self.finished = true;
            return Ok(EpochOutcome::Finished);
        }
        // Budget check before spending on analysis.
        if let Some(target) = self.cfg.target_epsilon {
            if self.accountant.epsilon(self.cfg.delta).0 >= target {
                self.finished = true;
                return Ok(EpochOutcome::Finished);
            }
        }

        let epoch = self.epoch;
        sink.on_event(&TrainEvent::EpochStarted { epoch });

        // Audit bookmark: where the accountant history stands before
        // this epoch spends anything, so the epoch's delta can be
        // extracted afterwards (pure reads — see `AuditEpoch`).
        let audit_mark = self.accountant.history().len();
        let audit_boundary_steps = self.accountant.history().last().map_or(0, |r| r.steps);

        // ---- Algorithm 1 (DPQuant only, every analysis_interval epochs)
        let mut analysis_seconds = 0.0;
        if self.scheduler == Scheduler::DpQuant && epoch % self.cfg.analysis_interval.max(1) == 0 {
            // The probe subsample is n_sample examples in expectation
            // (paper Table 3), NOT a full training batch — this keeps
            // the analysis SGM's privacy cost negligible (Fig. 3).
            let q_meas = (self.cfg.analysis_samples as f64 / train_ds.len() as f64).min(1.0);
            let probe_idx = poisson_sample(&mut self.data_rng, train_ds.len(), q_meas);
            if !probe_idx.is_empty() {
                let probes = make_batches(train_ds, &probe_idx, exec.physical_batch());
                let report = compute_loss_impact(
                    exec,
                    &self.cfg,
                    &self.weights,
                    &probes,
                    &mut self.ema,
                    &mut self.accountant,
                    &mut self.analysis_noise,
                    (epoch * 7919) as f32,
                )?;
                analysis_seconds = report.seconds;
                if crate::obs::kernel_timing() {
                    static H: std::sync::OnceLock<crate::obs::Histogram> =
                        std::sync::OnceLock::new();
                    H.get_or_init(|| crate::obs::global().histogram_ns("session.analysis_ns"))
                        .record(report.seconds * 1e9);
                }
                sink.on_event(&TrainEvent::AnalysisCompleted {
                    epoch,
                    impacts: &report.privatized_impacts,
                    seconds: report.seconds,
                });
            }
        }

        // ---- Algorithm 2: pick this epoch's policy
        let mut audit_draw_probs: Vec<f64> = Vec::new();
        let policy = match self.scheduler {
            Scheduler::DpQuant => {
                let scores = self.ema.scores().to_vec();
                // The same π the sampler draws from, recomputed through
                // the pure pipeline (no RNG) for the audit record.
                audit_draw_probs = softmax_neg(&normalize(&scores), self.cfg.beta);
                Policy::from_layers(
                    self.n_layers,
                    select_targets(&mut self.sched_rng, &scores, self.cfg.beta, self.k),
                )
            }
            Scheduler::Pls => Policy::from_layers(
                self.n_layers,
                self.sched_rng.sample_indices(self.n_layers, self.k),
            ),
            _ => self.static_policy.clone().unwrap(),
        };
        sink.on_event(&TrainEvent::PolicySelected { epoch, policy: &policy });
        let quant_mask = policy.mask();

        // ---- Adaptive-DP policy: this epoch's DP knobs (DESIGN.md §16).
        // `Static` returns the base values without arithmetic, and the
        // re-derived σ·C / C(t)/C₀ = 1.0 are bit-exact, so the default
        // path cannot drift from pre-policy builds.
        let base = EpochKnobs {
            noise_multiplier: self.cfg.noise_multiplier,
            clip_norm: self.cfg.clip_norm,
            sample_rate: self.q,
        };
        let knobs = self.adaptive.knobs(epoch, self.cfg.epochs, &base);
        self.opt.set_dp_params(
            knobs.noise_multiplier,
            knobs.clip_norm,
            knobs.clip_norm / self.cfg.clip_norm,
        );
        if let AdaptivePolicy::RateSchedule { .. } = self.adaptive {
            // Poisson lot size follows q_t; only touched on this policy
            // (q·|D| need not reproduce B's bits exactly).
            self.opt.set_expected_batch(knobs.sample_rate * self.train_len as f64);
        }
        let mut audit_lr_scales: Option<Vec<f64>> = None;
        if let AdaptivePolicy::LayerLr { strength } = self.adaptive {
            // Post-processing of the privatized EMA scores: zero extra ε.
            // Recomputed every epoch so it tracks the EMA (and survives
            // resume — the EMA is checkpointed, the scales are not).
            let layer_scales = adaptive::layer_lr_scales(self.ema.scores(), strength);
            audit_lr_scales = Some(layer_scales.clone());
            let scales = exec.quant_weight_params().map(|map| {
                adaptive::tensor_lr_scales(&layer_scales, &map, exec.param_sizes().len())
            });
            self.opt.set_lr_scales(scales);
        }

        // ---- The epoch's DP-SGD steps
        let t0 = std::time::Instant::now();
        let mut train_loss_sum = 0f64;
        let mut train_count = 0f64;
        for step in 0..self.steps_per_epoch {
            let idx = poisson_sample(&mut self.data_rng, train_ds.len(), knobs.sample_rate);
            self.accountant
                .step_training(knobs.sample_rate, knobs.noise_multiplier, 1);
            if idx.is_empty() {
                continue;
            }
            // Poisson batches can exceed the physical batch: chunk and
            // accumulate the clipped-grad sums (exact — the sum is linear).
            let mut agg: Option<Vec<Vec<f32>>> = None;
            let step_base = (self.cfg.seed as usize)
                .wrapping_mul(1_000_003)
                .wrapping_add(epoch * 10_007 + step);
            let mut step_rawsum = 0f64;
            let mut step_rawmax = 0f64;
            // Each physical chunk gets a distinct seed so per-sample
            // stochastic-rounding streams never collide across chunks of
            // one logical step (executors key their RNG on (seed, row)
            // with row < physical_batch ≤ the 4096 stride). Seeds travel
            // as f32 (the compiled graphs take a scalar f32 input), so
            // reduce mod 2^24 *after* the chunk offset — every value
            // stays in f32's exact-integer range and never rounds.
            for (ci, b) in make_batches(train_ds, &idx, exec.physical_batch())
                .into_iter()
                .enumerate()
            {
                let chunk_seed = (step_base.wrapping_add(ci * 4096) % (1 << 24)) as f32;
                let out =
                    exec.train_step(&self.weights, &b.x, &b.y, &b.mask, &quant_mask, chunk_seed)?;
                train_loss_sum += out.loss_sum as f64;
                train_count += b.real as f64;
                step_rawsum += out.raw_norm_sum as f64;
                step_rawmax = step_rawmax.max(out.raw_norm_max as f64);
                match agg.as_mut() {
                    None => agg = Some(out.grad_sums),
                    Some(acc) => {
                        for (a, g) in acc.iter_mut().zip(&out.grad_sums) {
                            for (ai, gi) in a.iter_mut().zip(g) {
                                *ai += gi;
                            }
                        }
                    }
                }
            }
            let mut grads = agg.unwrap();
            let stats = self.opt.update(&mut self.weights, &mut grads);
            sink.on_event(&TrainEvent::StepCompleted {
                epoch,
                step,
                examples: idx.len(),
                stats,
                raw_norm_mean: step_rawsum / idx.len() as f64,
                raw_norm_max: step_rawmax,
            });

            // Budget check: truncate training at the target ε (paper §6.2
            // "truncating the training at the respective privacy
            // budgets").
            if let Some(target) = self.cfg.target_epsilon {
                let (eps_now, _) = self.accountant.epsilon(self.cfg.delta);
                if eps_now >= target {
                    self.truncated = true;
                    sink.on_event(&TrainEvent::Truncated {
                        epoch,
                        step,
                        epsilon: eps_now,
                    });
                }
            }
            if self.truncated {
                break;
            }
        }
        let train_seconds = t0.elapsed().as_secs_f64();

        // ---- Eval + record
        let (val_loss, val_acc) = evaluate(exec, &self.weights, val_ds)?;
        let (eps, alpha) = self.accountant.epsilon(self.cfg.delta);
        self.record.analysis_epsilon =
            self.accountant.epsilon_of(Mechanism::Analysis, self.cfg.delta).0;
        self.record.push(EpochRecord {
            epoch,
            train_loss: train_loss_sum / train_count.max(1.0),
            val_loss,
            val_accuracy: val_acc,
            epsilon: eps,
            quantized_layers: policy.layers.clone(),
            train_seconds,
            analysis_seconds,
        });
        sink.on_event(&TrainEvent::EpochCompleted {
            record: self.record.epochs.last().unwrap(),
        });

        // ---- Audit record (pure observation of what just happened)
        let accounting =
            history_delta(self.accountant.history(), audit_mark, audit_boundary_steps);
        let accounted_steps: u64 = accounting
            .iter()
            .filter(|r| r.mechanism == Mechanism::Training)
            .map(|r| r.steps)
            .sum();
        let audit = AuditEpoch {
            epoch,
            noise_multiplier: knobs.noise_multiplier,
            sample_rate: knobs.sample_rate,
            clip_norm: knobs.clip_norm,
            clip_scale: knobs.clip_norm / self.cfg.clip_norm,
            lr_scales: audit_lr_scales,
            mask: policy.layers.clone(),
            draw_probs: audit_draw_probs,
            accounting,
            steps: accounted_steps,
            epsilon: eps,
            alpha,
            analysis_seconds,
            truncated: self.truncated,
        };
        sink.on_event(&TrainEvent::EpochAudited { audit: &audit });
        self.epoch += 1;

        if self.truncated {
            self.finished = true;
            Ok(EpochOutcome::Truncated {
                epoch,
                epsilon: eps,
                val_accuracy: val_acc,
            })
        } else {
            Ok(EpochOutcome::Completed {
                epoch,
                epsilon: eps,
                val_accuracy: val_acc,
            })
        }
    }

    /// Drive [`TrainSession::step_epoch`] until the session finishes —
    /// the convenience reproducing the legacy `train()` loop.
    pub fn run<E: StepExecutor + ?Sized>(
        &mut self,
        exec: &E,
        train_ds: &Dataset,
        val_ds: &Dataset,
        sink: &mut dyn EventSink,
    ) -> Result<()> {
        while self.step_epoch(exec, train_ds, val_ds, sink)? != EpochOutcome::Finished {}
        Ok(())
    }

    // -- observers ----------------------------------------------------

    /// The config this session runs under.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
    /// The run record accumulated so far.
    pub fn record(&self) -> &RunRecord {
        &self.record
    }
    /// The current model weights.
    pub fn weights(&self) -> &[Vec<f32>] {
        &self.weights
    }
    /// Number of completed epochs (== next epoch index).
    pub fn epochs_completed(&self) -> usize {
        self.epoch
    }
    /// Has the session run to completion (or truncation)?
    pub fn is_finished(&self) -> bool {
        self.finished
    }
    /// Did the privacy budget stop the session before its epoch target?
    pub fn is_truncated(&self) -> bool {
        self.truncated
    }
    /// The accountant's coalesced step history so far. An audit writer
    /// opened mid-run (`--resume` + `--audit-out`) records this as the
    /// run's `prior` blocks so `audit replay` can seed its fresh
    /// accountant before the first audited epoch.
    pub fn accountant_history(&self) -> &[StepRecord] {
        self.accountant.history()
    }

    /// Raise (or lower) the epoch target — the supported override when
    /// resuming a checkpoint with `--epochs`. A session that finished
    /// only because its epochs ran out becomes runnable again.
    pub fn set_epochs(&mut self, epochs: usize) {
        self.cfg.epochs = epochs;
        if !self.truncated {
            self.finished = false;
        }
    }

    /// Consume the session: `(record, final_weights, accountant)`.
    pub fn finish(self) -> (RunRecord, Vec<Vec<f32>>, RdpAccountant) {
        (self.record, self.weights, self.accountant)
    }

    // -- checkpointing ------------------------------------------------

    /// Serialize the full session state to `path` (versioned JSON; see
    /// the module docs). Safe at any epoch boundary. The write is
    /// atomic (temp file + rename), so a crash mid-write — the exact
    /// scenario checkpointing defends against — can never destroy the
    /// previous good snapshot at the same path.
    pub fn checkpoint(&self, path: &str) -> Result<()> {
        let t = crate::obs::maybe_start();
        let parent = std::path::Path::new(path).parent();
        if let Some(dir) = parent.filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint directory {}", dir.display()))?;
        }
        let tmp = format!("{path}.tmp");
        std::fs::write(&tmp, self.checkpoint_text())
            .with_context(|| format!("writing checkpoint {tmp}"))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("moving checkpoint {tmp} into place"))?;
        if let Some(t0) = t {
            static H: std::sync::OnceLock<crate::obs::Histogram> = std::sync::OnceLock::new();
            H.get_or_init(|| crate::obs::global().histogram_ns("session.checkpoint_write_ns"))
                .record_duration(t0.elapsed());
        }
        Ok(())
    }

    /// The checkpoint document as a string — the exact bytes
    /// [`TrainSession::checkpoint`] writes. Callers that own their
    /// durability story (the serving daemon's state dir, tests, an
    /// object store) route the same versioned document through any
    /// writer; [`Checkpoint::from_json_text`] reads it back.
    pub fn checkpoint_text(&self) -> String {
        self.to_json().to_string()
    }

    /// Stream the checkpoint document into `w` — same bytes as
    /// [`TrainSession::checkpoint`], but the caller owns atomicity
    /// (temp-file + rename, a socket, a pipe, ...).
    pub fn write_checkpoint<W: std::io::Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(self.checkpoint_text().as_bytes())
            .context("writing checkpoint stream")?;
        Ok(())
    }

    /// Load a checkpoint and rebuild the session against `exec`. The
    /// caller must supply the same executor configuration and regenerate
    /// the identical datasets (the checkpoint stores the config needed
    /// to do both — see [`Checkpoint::config`]).
    pub fn resume<E: StepExecutor + ?Sized>(path: &str, exec: &E) -> Result<Self> {
        Self::resume_from(Checkpoint::load(path)?, exec)
    }

    /// Rebuild a session from an already-loaded [`Checkpoint`].
    pub fn resume_from<E: StepExecutor + ?Sized>(ckpt: Checkpoint, exec: &E) -> Result<Self> {
        let scheduler = validate_config(&ckpt.cfg, ckpt.train_len)?;
        ensure!(
            exec.n_quant_layers() == ckpt.n_layers,
            "checkpoint was written for a model with {} quantizable layers; executor has {}",
            ckpt.n_layers,
            exec.n_quant_layers()
        );
        let sizes = exec.param_sizes();
        ensure!(
            sizes.len() == ckpt.weights.len(),
            "checkpoint has {} weight tensors; executor expects {}",
            ckpt.weights.len(),
            sizes.len()
        );
        for (i, (w, &n)) in ckpt.weights.iter().zip(&sizes).enumerate() {
            ensure!(
                w.len() == n,
                "checkpoint weight tensor {i} has {} values; executor expects {n}",
                w.len()
            );
        }
        ensure!(
            ckpt.ema_scores.len() == ckpt.n_layers,
            "checkpoint EMA has {} scores for {} layers",
            ckpt.ema_scores.len(),
            ckpt.n_layers
        );
        let moments_ok = match ckpt.cfg.optimizer {
            crate::config::OptimizerKind::Sgd => ckpt.opt_m.is_empty() && ckpt.opt_v.is_empty(),
            _ => {
                ckpt.opt_m.len() == sizes.len()
                    && ckpt.opt_v.len() == sizes.len()
                    && ckpt.opt_m.iter().zip(&sizes).all(|(m, &n)| m.len() == n)
                    && ckpt.opt_v.iter().zip(&sizes).all(|(v, &n)| v.len() == n)
            }
        };
        ensure!(
            moments_ok,
            "checkpoint optimizer moments do not match the '{}' optimizer and model shapes",
            ckpt.cfg.optimizer.name()
        );
        if let Some(layers) = &ckpt.static_policy {
            ensure!(
                layers.iter().all(|&l| l < ckpt.n_layers),
                "checkpoint static policy references a layer >= {}",
                ckpt.n_layers
            );
        }
        // Static schedulers dereference the frozen policy every epoch; a
        // checkpoint missing it must fail here, not panic mid-training.
        let needs_static = !matches!(scheduler, Scheduler::DpQuant | Scheduler::Pls);
        ensure!(
            !needs_static || ckpt.static_policy.is_some(),
            "checkpoint uses the static '{}' scheduler but stores no static policy",
            ckpt.cfg.scheduler
        );

        let k = budget_to_k(ckpt.n_layers, ckpt.cfg.quant_fraction);
        let q = ckpt.cfg.batch_size as f64 / ckpt.train_len as f64;
        let steps_per_epoch = (ckpt.train_len / ckpt.cfg.batch_size).max(1);
        let adaptive = AdaptivePolicy::from_config(&ckpt.cfg)?;

        let mut opt = DpOptimizer::new(
            ckpt.cfg.optimizer,
            ckpt.cfg.lr,
            ckpt.cfg.noise_multiplier,
            ckpt.cfg.clip_norm,
            ckpt.cfg.batch_size as f64,
            &sizes,
            ckpt.opt_sampler,
        );
        opt.restore(ckpt.opt_step, ckpt.opt_m, ckpt.opt_v);

        let mut accountant = RdpAccountant::new();
        for rec in &ckpt.history {
            accountant.record(rec.mechanism, rec.sample_rate, rec.noise_multiplier, rec.steps);
        }

        let ema = EmaScores::from_parts(
            ckpt.ema_scores,
            ckpt.cfg.ema_alpha,
            ckpt.cfg.ema_enabled,
            ckpt.ema_initialized,
        );
        let static_policy = ckpt
            .static_policy
            .map(|layers| Policy::from_layers(ckpt.n_layers, layers));

        Ok(Self {
            cfg: ckpt.cfg,
            scheduler,
            adaptive,
            n_layers: ckpt.n_layers,
            k,
            q,
            steps_per_epoch,
            train_len: ckpt.train_len,
            val_len: ckpt.val_len,
            weights: ckpt.weights,
            opt,
            accountant,
            ema,
            data_rng: ckpt.data_rng,
            sched_rng: ckpt.sched_rng,
            analysis_noise: ckpt.analysis_noise,
            static_policy,
            record: ckpt.record,
            epoch: ckpt.epoch,
            truncated: ckpt.truncated,
            finished: ckpt.finished,
        })
    }

    fn to_json(&self) -> Json {
        let (m, v) = self.opt.moments();
        let history: Vec<Json> = self
            .accountant
            .history()
            .iter()
            .map(|r| {
                json::obj(vec![
                    ("mechanism", json::s(mechanism_name(r.mechanism))),
                    ("sample_rate", hex_f64(r.sample_rate)),
                    ("noise_multiplier", hex_f64(r.noise_multiplier)),
                    ("steps", hex_u64(r.steps)),
                ])
            })
            .collect();
        json::obj(vec![
            ("format", json::s(CHECKPOINT_FORMAT)),
            ("version", json::num(CHECKPOINT_VERSION as f64)),
            ("config", config_to_json(&self.cfg)),
            ("train_len", json::num(self.train_len as f64)),
            (
                "val_len",
                self.val_len.map(|n| json::num(n as f64)).unwrap_or(Json::Null),
            ),
            ("n_layers", json::num(self.n_layers as f64)),
            ("epoch", json::num(self.epoch as f64)),
            ("truncated", Json::Bool(self.truncated)),
            ("finished", Json::Bool(self.finished)),
            (
                "weights",
                Json::Arr(self.weights.iter().map(|w| hex_f32s(w)).collect()),
            ),
            (
                "optimizer",
                json::obj(vec![
                    ("step", hex_u64(self.opt.step_count())),
                    ("m", Json::Arr(m.iter().map(|t| hex_f32s(t)).collect())),
                    ("v", Json::Arr(v.iter().map(|t| hex_f32s(t)).collect())),
                    ("sampler", sampler_json(self.opt.sampler())),
                ]),
            ),
            ("accountant", Json::Arr(history)),
            (
                "ema",
                json::obj(vec![
                    (
                        "scores",
                        Json::Arr(self.ema.scores().iter().map(|&x| hex_f64(x)).collect()),
                    ),
                    ("initialized", Json::Bool(self.ema.is_initialized())),
                ]),
            ),
            ("data_rng", rng_json(&self.data_rng)),
            ("sched_rng", rng_json(&self.sched_rng)),
            ("analysis_noise", sampler_json(&self.analysis_noise)),
            (
                "static_policy",
                match &self.static_policy {
                    Some(p) => Json::Arr(
                        p.layers.iter().map(|&l| json::num(l as f64)).collect(),
                    ),
                    None => Json::Null,
                },
            ),
            ("record", record_to_json(&self.record)),
        ])
    }
}

// ---------------------------------------------------------------------
// Checkpoint format
// ---------------------------------------------------------------------

/// `format` tag every checkpoint JSON carries.
pub const CHECKPOINT_FORMAT: &str = "dpquant-trainsession";
/// Checkpoint schema version this build reads and writes.
pub const CHECKPOINT_VERSION: u64 = 1;

/// A parsed, structurally-validated checkpoint. Loading is split from
/// resuming so callers can read the stored [`TrainConfig`] first (the
/// CLI needs it to regenerate the dataset and open the right backend).
pub struct Checkpoint {
    cfg: TrainConfig,
    train_len: usize,
    val_len: Option<usize>,
    n_layers: usize,
    epoch: usize,
    truncated: bool,
    finished: bool,
    weights: Vec<Vec<f32>>,
    opt_step: u64,
    opt_m: Vec<Vec<f32>>,
    opt_v: Vec<Vec<f32>>,
    opt_sampler: GaussianSampler,
    history: Vec<StepRecord>,
    ema_scores: Vec<f64>,
    ema_initialized: bool,
    data_rng: Xoshiro256,
    sched_rng: Xoshiro256,
    analysis_noise: GaussianSampler,
    static_policy: Option<Vec<usize>>,
    record: RunRecord,
}

impl Checkpoint {
    /// Read and validate a checkpoint file.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading checkpoint {path}"))?;
        Self::from_json_text(&text).with_context(|| format!("checkpoint {path}"))
    }

    /// The training config the checkpointed session ran under.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Completed epochs at checkpoint time.
    pub fn epochs_completed(&self) -> usize {
        self.epoch
    }

    /// Parse and structurally validate checkpoint JSON (format/version
    /// pins, required fields, shape checks).
    pub fn from_json_text(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| err!("malformed JSON: {e}"))?;
        let format = j.get("format").and_then(Json::as_str).unwrap_or("<missing>");
        ensure!(
            format == CHECKPOINT_FORMAT,
            "not a TrainSession checkpoint (format '{format}', want '{CHECKPOINT_FORMAT}')"
        );
        let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        ensure!(
            version == CHECKPOINT_VERSION,
            "checkpoint version {version} is not readable by this build (which reads version \
             {CHECKPOINT_VERSION}); re-create the checkpoint with a matching build"
        );
        let cfg = config_from_json(field(&j, "config")?)?;
        let weights = field(&j, "weights")?
            .as_arr()
            .ok_or_else(|| err!("'weights' must be an array"))?
            .iter()
            .map(|w| parse_f32s(w, "weights"))
            .collect::<Result<Vec<_>>>()?;
        let opt = field(&j, "optimizer")?;
        let opt_m = field(opt, "m")?
            .as_arr()
            .ok_or_else(|| err!("'optimizer.m' must be an array"))?
            .iter()
            .map(|t| parse_f32s(t, "optimizer.m"))
            .collect::<Result<Vec<_>>>()?;
        let opt_v = field(opt, "v")?
            .as_arr()
            .ok_or_else(|| err!("'optimizer.v' must be an array"))?
            .iter()
            .map(|t| parse_f32s(t, "optimizer.v"))
            .collect::<Result<Vec<_>>>()?;
        let history = field(&j, "accountant")?
            .as_arr()
            .ok_or_else(|| err!("'accountant' must be an array"))?
            .iter()
            .map(parse_step_record)
            .collect::<Result<Vec<_>>>()?;
        let ema = field(&j, "ema")?;
        let ema_scores = field(ema, "scores")?
            .as_arr()
            .ok_or_else(|| err!("'ema.scores' must be an array"))?
            .iter()
            .map(|x| parse_hex_f64(x, "ema.scores"))
            .collect::<Result<Vec<_>>>()?;
        let static_policy = match field(&j, "static_policy")? {
            Json::Null => None,
            Json::Arr(layers) => Some(
                layers
                    .iter()
                    .map(|l| parse_usize(l, "static_policy"))
                    .collect::<Result<Vec<_>>>()?,
            ),
            _ => return Err(err!("'static_policy' must be null or an array")),
        };
        Ok(Self {
            cfg,
            train_len: parse_usize(field(&j, "train_len")?, "train_len")?,
            val_len: match field(&j, "val_len")? {
                Json::Null => None,
                v => Some(parse_usize(v, "val_len")?),
            },
            n_layers: parse_usize(field(&j, "n_layers")?, "n_layers")?,
            epoch: parse_usize(field(&j, "epoch")?, "epoch")?,
            truncated: parse_bool(field(&j, "truncated")?, "truncated")?,
            finished: parse_bool(field(&j, "finished")?, "finished")?,
            weights,
            opt_step: parse_hex_u64(field(opt, "step")?, "optimizer.step")?,
            opt_m,
            opt_v,
            opt_sampler: parse_sampler(field(opt, "sampler")?, "optimizer.sampler")?,
            history,
            ema_scores,
            ema_initialized: parse_bool(field(ema, "initialized")?, "ema.initialized")?,
            data_rng: parse_rng(field(&j, "data_rng")?, "data_rng")?,
            sched_rng: parse_rng(field(&j, "sched_rng")?, "sched_rng")?,
            analysis_noise: parse_sampler(field(&j, "analysis_noise")?, "analysis_noise")?,
            static_policy,
            record: record_from_json(field(&j, "record")?)?,
        })
    }
}

fn mechanism_name(m: Mechanism) -> &'static str {
    match m {
        Mechanism::Training => "training",
        Mechanism::Analysis => "analysis",
    }
}

fn parse_step_record(j: &Json) -> Result<StepRecord> {
    let mechanism = match field(j, "mechanism")?.as_str() {
        Some("training") => Mechanism::Training,
        Some("analysis") => Mechanism::Analysis,
        other => return Err(err!("unknown accountant mechanism {other:?}")),
    };
    Ok(StepRecord {
        mechanism,
        sample_rate: parse_hex_f64(field(j, "sample_rate")?, "accountant.sample_rate")?,
        noise_multiplier: parse_hex_f64(
            field(j, "noise_multiplier")?,
            "accountant.noise_multiplier",
        )?,
        steps: parse_hex_u64(field(j, "steps")?, "accountant.steps")?,
    })
}

// ---------------------------------------------------------------------
// Serialization helpers: floats travel as IEEE-754 bit patterns in hex
// so a checkpoint round-trip is bit-exact by construction (decimal
// formatting would lose -0.0 and invite rounding subtleties).
// ---------------------------------------------------------------------

fn hex_u64(x: u64) -> Json {
    Json::Str(format!("{x:016x}"))
}

fn hex_f64(x: f64) -> Json {
    hex_u64(x.to_bits())
}

fn hex_f32s(xs: &[f32]) -> Json {
    let mut s = String::with_capacity(xs.len() * 8);
    for x in xs {
        let _ = write!(s, "{:08x}", x.to_bits());
    }
    Json::Str(s)
}

fn rng_json(rng: &Xoshiro256) -> Json {
    Json::Arr(rng.state().iter().map(|&x| hex_u64(x)).collect())
}

fn sampler_json(g: &GaussianSampler) -> Json {
    let (rng, cached) = g.state();
    json::obj(vec![
        ("rng", Json::Arr(rng.iter().map(|&x| hex_u64(x)).collect())),
        ("cached", cached.map(hex_f64).unwrap_or(Json::Null)),
    ])
}

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json> {
    obj.get(key).ok_or_else(|| err!("missing field '{key}'"))
}

fn parse_hex_u64(j: &Json, what: &str) -> Result<u64> {
    let s = j
        .as_str()
        .ok_or_else(|| err!("{what}: expected a hex string"))?;
    u64::from_str_radix(s, 16).map_err(|e| err!("{what}: bad hex '{s}': {e}"))
}

fn parse_hex_f64(j: &Json, what: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_hex_u64(j, what)?))
}

fn parse_f32s(j: &Json, what: &str) -> Result<Vec<f32>> {
    let s = j
        .as_str()
        .ok_or_else(|| err!("{what}: expected a hex blob"))?;
    ensure!(
        s.len() % 8 == 0 && s.is_ascii(),
        "{what}: hex blob length {} is not a multiple of 8",
        s.len()
    );
    (0..s.len() / 8)
        .map(|i| {
            u32::from_str_radix(&s[i * 8..i * 8 + 8], 16)
                .map(f32::from_bits)
                .map_err(|e| err!("{what}: bad hex at value {i}: {e}"))
        })
        .collect()
}

fn parse_usize(j: &Json, what: &str) -> Result<usize> {
    j.as_f64()
        .filter(|v| *v >= 0.0 && v.fract() == 0.0)
        .map(|v| v as usize)
        .ok_or_else(|| err!("{what}: expected a non-negative integer"))
}

fn parse_bool(j: &Json, what: &str) -> Result<bool> {
    j.as_bool().ok_or_else(|| err!("{what}: expected a bool"))
}

fn parse_str(j: &Json, what: &str) -> Result<String> {
    j.as_str()
        .map(str::to_string)
        .ok_or_else(|| err!("{what}: expected a string"))
}

fn parse_rng(j: &Json, what: &str) -> Result<Xoshiro256> {
    let arr = j.as_arr().ok_or_else(|| err!("{what}: expected an array"))?;
    ensure!(arr.len() == 4, "{what}: RNG state must have 4 words");
    let mut s = [0u64; 4];
    for (out, word) in s.iter_mut().zip(arr) {
        *out = parse_hex_u64(word, what)?;
    }
    Ok(Xoshiro256::from_state(s))
}

fn parse_sampler(j: &Json, what: &str) -> Result<GaussianSampler> {
    let rng = parse_rng(field(j, "rng")?, what)?;
    let cached = match field(j, "cached")? {
        Json::Null => None,
        v => Some(parse_hex_f64(v, what)?),
    };
    Ok(GaussianSampler::from_state(rng.state(), cached))
}

fn config_to_json(cfg: &TrainConfig) -> Json {
    json::obj(vec![
        ("model", json::s(&cfg.model)),
        ("dataset", json::s(&cfg.dataset)),
        ("quantizer", json::s(&cfg.quantizer)),
        ("epochs", json::num(cfg.epochs as f64)),
        ("batch_size", json::num(cfg.batch_size as f64)),
        ("noise_multiplier", hex_f64(cfg.noise_multiplier)),
        ("clip_norm", hex_f64(cfg.clip_norm)),
        ("lr", hex_f64(cfg.lr)),
        ("optimizer", json::s(cfg.optimizer.name())),
        (
            "target_epsilon",
            cfg.target_epsilon.map(hex_f64).unwrap_or(Json::Null),
        ),
        ("delta", hex_f64(cfg.delta)),
        ("quant_fraction", hex_f64(cfg.quant_fraction)),
        ("scheduler", json::s(&cfg.scheduler)),
        ("beta", hex_f64(cfg.beta)),
        ("analysis_interval", json::num(cfg.analysis_interval as f64)),
        ("analysis_reps", json::num(cfg.analysis_reps as f64)),
        ("analysis_samples", json::num(cfg.analysis_samples as f64)),
        ("sigma_measure", hex_f64(cfg.sigma_measure)),
        ("clip_measure", hex_f64(cfg.clip_measure)),
        ("ema_alpha", hex_f64(cfg.ema_alpha)),
        ("ema_enabled", Json::Bool(cfg.ema_enabled)),
        ("dataset_size", json::num(cfg.dataset_size as f64)),
        ("val_size", json::num(cfg.val_size as f64)),
        ("seed", hex_u64(cfg.seed)),
        ("physical_batch", json::num(cfg.physical_batch as f64)),
        ("backend", json::s(&cfg.backend)),
        ("policy", json::s(&cfg.policy)),
        ("noise_final", hex_f64(cfg.noise_final)),
        ("clip_final", hex_f64(cfg.clip_final)),
        ("rate_final", hex_f64(cfg.rate_final)),
        ("decay_shape", json::s(&cfg.decay_shape)),
        ("layer_lr_strength", hex_f64(cfg.layer_lr_strength)),
    ])
}

fn config_from_json(j: &Json) -> Result<TrainConfig> {
    // Adaptive-policy keys are optional (absent -> defaults) so version-1
    // checkpoints written before the policy suite stay readable; their
    // defaults reproduce the pre-policy behavior bit for bit.
    let d = TrainConfig::default();
    Ok(TrainConfig {
        model: parse_str(field(j, "model")?, "config.model")?,
        dataset: parse_str(field(j, "dataset")?, "config.dataset")?,
        quantizer: parse_str(field(j, "quantizer")?, "config.quantizer")?,
        epochs: parse_usize(field(j, "epochs")?, "config.epochs")?,
        batch_size: parse_usize(field(j, "batch_size")?, "config.batch_size")?,
        noise_multiplier: parse_hex_f64(field(j, "noise_multiplier")?, "config.noise_multiplier")?,
        clip_norm: parse_hex_f64(field(j, "clip_norm")?, "config.clip_norm")?,
        lr: parse_hex_f64(field(j, "lr")?, "config.lr")?,
        optimizer: crate::config::OptimizerKind::parse(&parse_str(
            field(j, "optimizer")?,
            "config.optimizer",
        )?)?,
        target_epsilon: match field(j, "target_epsilon")? {
            Json::Null => None,
            v => Some(parse_hex_f64(v, "config.target_epsilon")?),
        },
        delta: parse_hex_f64(field(j, "delta")?, "config.delta")?,
        quant_fraction: parse_hex_f64(field(j, "quant_fraction")?, "config.quant_fraction")?,
        scheduler: parse_str(field(j, "scheduler")?, "config.scheduler")?,
        beta: parse_hex_f64(field(j, "beta")?, "config.beta")?,
        analysis_interval: parse_usize(field(j, "analysis_interval")?, "config.analysis_interval")?,
        analysis_reps: parse_usize(field(j, "analysis_reps")?, "config.analysis_reps")?,
        analysis_samples: parse_usize(field(j, "analysis_samples")?, "config.analysis_samples")?,
        sigma_measure: parse_hex_f64(field(j, "sigma_measure")?, "config.sigma_measure")?,
        clip_measure: parse_hex_f64(field(j, "clip_measure")?, "config.clip_measure")?,
        ema_alpha: parse_hex_f64(field(j, "ema_alpha")?, "config.ema_alpha")?,
        ema_enabled: parse_bool(field(j, "ema_enabled")?, "config.ema_enabled")?,
        dataset_size: parse_usize(field(j, "dataset_size")?, "config.dataset_size")?,
        val_size: parse_usize(field(j, "val_size")?, "config.val_size")?,
        seed: parse_hex_u64(field(j, "seed")?, "config.seed")?,
        physical_batch: parse_usize(field(j, "physical_batch")?, "config.physical_batch")?,
        backend: parse_str(field(j, "backend")?, "config.backend")?,
        policy: match j.get("policy") {
            None => d.policy,
            Some(v) => parse_str(v, "config.policy")?,
        },
        noise_final: match j.get("noise_final") {
            None => d.noise_final,
            Some(v) => parse_hex_f64(v, "config.noise_final")?,
        },
        clip_final: match j.get("clip_final") {
            None => d.clip_final,
            Some(v) => parse_hex_f64(v, "config.clip_final")?,
        },
        rate_final: match j.get("rate_final") {
            None => d.rate_final,
            Some(v) => parse_hex_f64(v, "config.rate_final")?,
        },
        decay_shape: match j.get("decay_shape") {
            None => d.decay_shape,
            Some(v) => parse_str(v, "config.decay_shape")?,
        },
        layer_lr_strength: match j.get("layer_lr_strength") {
            None => d.layer_lr_strength,
            Some(v) => parse_hex_f64(v, "config.layer_lr_strength")?,
        },
    })
}

fn record_to_json(r: &RunRecord) -> Json {
    json::obj(vec![
        ("name", json::s(&r.name)),
        ("config_summary", json::s(&r.config_summary)),
        ("final_epsilon", hex_f64(r.final_epsilon)),
        ("analysis_epsilon", hex_f64(r.analysis_epsilon)),
        ("final_accuracy", hex_f64(r.final_accuracy)),
        ("best_accuracy", hex_f64(r.best_accuracy)),
        (
            "epochs",
            Json::Arr(
                r.epochs
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("epoch", json::num(e.epoch as f64)),
                            ("train_loss", hex_f64(e.train_loss)),
                            ("val_loss", hex_f64(e.val_loss)),
                            ("val_accuracy", hex_f64(e.val_accuracy)),
                            ("epsilon", hex_f64(e.epsilon)),
                            (
                                "quantized_layers",
                                Json::Arr(
                                    e.quantized_layers
                                        .iter()
                                        .map(|&l| json::num(l as f64))
                                        .collect(),
                                ),
                            ),
                            ("train_seconds", hex_f64(e.train_seconds)),
                            ("analysis_seconds", hex_f64(e.analysis_seconds)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn record_from_json(j: &Json) -> Result<RunRecord> {
    let epochs = field(j, "epochs")?
        .as_arr()
        .ok_or_else(|| err!("'record.epochs' must be an array"))?
        .iter()
        .map(|e| {
            Ok(EpochRecord {
                epoch: parse_usize(field(e, "epoch")?, "record.epoch")?,
                train_loss: parse_hex_f64(field(e, "train_loss")?, "record.train_loss")?,
                val_loss: parse_hex_f64(field(e, "val_loss")?, "record.val_loss")?,
                val_accuracy: parse_hex_f64(field(e, "val_accuracy")?, "record.val_accuracy")?,
                epsilon: parse_hex_f64(field(e, "epsilon")?, "record.epsilon")?,
                quantized_layers: field(e, "quantized_layers")?
                    .as_arr()
                    .ok_or_else(|| err!("'record.quantized_layers' must be an array"))?
                    .iter()
                    .map(|l| parse_usize(l, "record.quantized_layers"))
                    .collect::<Result<Vec<_>>>()?,
                train_seconds: parse_hex_f64(field(e, "train_seconds")?, "record.train_seconds")?,
                analysis_seconds: parse_hex_f64(
                    field(e, "analysis_seconds")?,
                    "record.analysis_seconds",
                )?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(RunRecord {
        name: parse_str(field(j, "name")?, "record.name")?,
        config_summary: parse_str(field(j, "config_summary")?, "record.config_summary")?,
        epochs,
        final_epsilon: parse_hex_f64(field(j, "final_epsilon")?, "record.final_epsilon")?,
        analysis_epsilon: parse_hex_f64(field(j, "analysis_epsilon")?, "record.analysis_epsilon")?,
        final_accuracy: parse_hex_f64(field(j, "final_accuracy")?, "record.final_accuracy")?,
        best_accuracy: parse_hex_f64(field(j, "best_accuracy")?, "record.best_accuracy")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;

    fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let c = rng.next_below(classes as u64) as i32;
            for f in 0..feats {
                xs.push(0.5 * rng.next_f32() + if f == c as usize { 1.0 } else { 0.0 });
            }
            ys.push(c);
        }
        Dataset {
            xs,
            ys,
            example_numel: feats,
            n_classes: classes,
        }
    }

    fn base_cfg() -> TrainConfig {
        TrainConfig {
            epochs: 4,
            batch_size: 16,
            dataset_size: 256,
            noise_multiplier: 0.6,
            clip_norm: 1.0,
            lr: 0.8,
            quant_fraction: 0.5,
            scheduler: "dpquant".into(),
            analysis_interval: 2,
            seed: 3,
            physical_batch: 32,
            ..TrainConfig::default()
        }
    }

    fn fixtures(cfg: &TrainConfig) -> (MockExecutor, Dataset, Dataset) {
        let exec = MockExecutor::new(8, 4, 6, 32);
        let ds = toy_dataset(256 + 64, 8, 4, cfg.seed);
        let (tr, va) = ds.split(64);
        (exec, tr, va)
    }

    fn reject(mutate: impl FnOnce(&mut TrainConfig), needle: &str) {
        let mut cfg = base_cfg();
        mutate(&mut cfg);
        let err = validate_config(&cfg, 256).unwrap_err().to_string();
        assert!(err.contains(needle), "expected '{needle}' in: {err}");
    }

    #[test]
    fn validation_rejects_hostile_configs() {
        reject(|c| c.batch_size = 0, "batch_size");
        reject(|c| c.batch_size = 10_000, "exceeds the training-set size");
        reject(|c| c.physical_batch = 0, "physical_batch");
        reject(|c| c.dataset_size = 0, "dataset_size");
        reject(|c| c.quant_fraction = 1.5, "quant_fraction");
        reject(|c| c.quant_fraction = -0.1, "quant_fraction");
        reject(|c| c.quant_fraction = f64::NAN, "quant_fraction");
        reject(|c| c.noise_multiplier = -1.0, "noise_multiplier");
        reject(|c| c.clip_norm = 0.0, "clip_norm");
        reject(|c| c.lr = f64::INFINITY, "lr");
        reject(|c| c.delta = 0.0, "delta");
        reject(|c| c.delta = 1.0, "delta");
        reject(|c| c.beta = -2.0, "beta");
        reject(|c| c.ema_alpha = 1.5, "ema_alpha");
        reject(|c| c.target_epsilon = Some(0.0), "target_epsilon");
        reject(|c| c.scheduler = "dpqaunt".into(), "scheduler");
        // Adaptive-policy configs are validated through the same gate.
        reject(|c| c.policy = "frobnicate".into(), "policy");
        reject(
            |c| {
                c.policy = "noise_decay".into();
                c.noise_final = f64::NAN;
            },
            "noise_final",
        );
        reject(
            |c| {
                c.policy = "rate_schedule".into();
                c.rate_final = -0.5;
            },
            "rate_final",
        );
        reject(
            |c| {
                c.policy = "layer_lr".into();
                c.scheduler = "pls".into();
            },
            "layer_lr",
        );
        // An empty training set is rejected regardless of config.
        assert!(validate_config(&base_cfg(), 0).is_err());
        // The default config is valid.
        assert!(validate_config(&base_cfg(), 256).is_ok());
    }

    #[test]
    fn session_matches_legacy_train_wrapper() {
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);
        let legacy = super::super::trainer::train(
            &exec,
            &cfg,
            &tr,
            &va,
            &super::super::trainer::TrainerOptions::default(),
        )
        .unwrap();

        let mut session = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        let mut outcomes = 0;
        loop {
            match session.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap() {
                EpochOutcome::Finished => break,
                _ => outcomes += 1,
            }
        }
        assert_eq!(outcomes, cfg.epochs);
        let (record, weights, _) = session.finish();
        assert_eq!(record.final_accuracy, legacy.record.final_accuracy);
        assert_eq!(record.final_epsilon, legacy.record.final_epsilon);
        assert_eq!(weights, legacy.final_weights);
        let layers: Vec<_> = record.epochs.iter().map(|e| &e.quantized_layers).collect();
        let legacy_layers: Vec<_> =
            legacy.record.epochs.iter().map(|e| &e.quantized_layers).collect();
        assert_eq!(layers, legacy_layers);
    }

    #[test]
    fn checkpoint_text_and_writer_match_file_bytes() {
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);
        let mut s = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        s.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap();

        let path = std::env::temp_dir()
            .join(format!("dpquant_ckpt_text_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        s.checkpoint(&path).unwrap();
        let file_bytes = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();

        // The writer hooks emit the exact bytes checkpoint() persists.
        assert_eq!(s.checkpoint_text(), file_bytes);
        let mut streamed = Vec::new();
        s.write_checkpoint(&mut streamed).unwrap();
        assert_eq!(streamed, file_bytes.as_bytes());

        // And the streamed document resumes like the file-backed one.
        let ckpt = Checkpoint::from_json_text(std::str::from_utf8(&streamed).unwrap()).unwrap();
        let resumed = TrainSession::resume_from(ckpt, &exec).unwrap();
        assert_eq!(resumed.epochs_completed(), 1);
        assert_eq!(resumed.weights(), s.weights());
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_exact() {
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);

        // Uninterrupted reference run.
        let mut full = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        full.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (full_record, full_weights, mut full_acc) = full.finish();

        // Checkpoint after epoch 2, resume through JSON, run to the end.
        let mut first = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        for _ in 0..2 {
            assert!(matches!(
                first.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap(),
                EpochOutcome::Completed { .. }
            ));
        }
        let path = std::env::temp_dir()
            .join(format!("dpquant_ckpt_roundtrip_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        first.checkpoint(&path).unwrap();

        let mut resumed = TrainSession::resume(&path, &exec).unwrap();
        assert_eq!(resumed.epochs_completed(), 2);
        resumed.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (record, weights, mut acc) = resumed.finish();
        std::fs::remove_file(&path).ok();

        assert_eq!(record.final_accuracy.to_bits(), full_record.final_accuracy.to_bits());
        assert_eq!(record.final_epsilon.to_bits(), full_record.final_epsilon.to_bits());
        assert_eq!(record.best_accuracy.to_bits(), full_record.best_accuracy.to_bits());
        assert_eq!(weights, full_weights);
        assert_eq!(record.epochs.len(), full_record.epochs.len());
        for (a, b) in record.epochs.iter().zip(&full_record.epochs) {
            assert_eq!(a.quantized_layers, b.quantized_layers);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.epsilon.to_bits(), b.epsilon.to_bits());
        }
        assert_eq!(acc.epsilon(1e-5), full_acc.epsilon(1e-5));
    }

    #[test]
    fn corrupted_and_mismatched_checkpoints_rejected() {
        let err = Checkpoint::from_json_text("{not json").unwrap_err().to_string();
        assert!(err.contains("malformed JSON"), "{err}");

        let err = Checkpoint::from_json_text("{\"hello\": 1}").unwrap_err().to_string();
        assert!(err.contains("not a TrainSession checkpoint"), "{err}");

        let future = format!(
            "{{\"format\": \"{CHECKPOINT_FORMAT}\", \"version\": {}}}",
            CHECKPOINT_VERSION + 7
        );
        let err = Checkpoint::from_json_text(&future).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");

        // A truncated (torn-write) checkpoint fails loudly too.
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);
        let mut s = TrainSession::builder(cfg).build(&exec, &tr).unwrap();
        s.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap();
        let text = s.to_json().to_string();
        assert!(Checkpoint::from_json_text(&text[..text.len() / 2]).is_err());
        // And the intact text parses.
        assert!(Checkpoint::from_json_text(&text).is_ok());
    }

    #[test]
    fn resume_rejects_mismatched_executor() {
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);
        let mut s = TrainSession::builder(cfg).build(&exec, &tr).unwrap();
        s.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap();
        let text = s.to_json().to_string();
        let ckpt = Checkpoint::from_json_text(&text).unwrap();
        // 5 quantizable layers instead of 6.
        let other = MockExecutor::new(8, 4, 5, 32);
        let err = TrainSession::resume_from(ckpt, &other).unwrap_err().to_string();
        assert!(err.contains("quantizable layers"), "{err}");
    }

    #[test]
    fn event_stream_golden_sequence() {
        // batch_size == |D_train| makes every Poisson step non-empty
        // (q = 1) with exactly one step per epoch, and analysis_samples
        // == |D_train| makes the probe deterministic — so the exact event
        // sequence is provable, not just observed.
        struct Recorder(Vec<String>);
        impl EventSink for Recorder {
            fn on_event(&mut self, event: &TrainEvent<'_>) {
                self.0.push(event.kind().to_string());
            }
        }
        let cfg = TrainConfig {
            epochs: 2,
            batch_size: 64,
            dataset_size: 64,
            analysis_interval: 1,
            analysis_samples: 64,
            quant_fraction: 0.5,
            scheduler: "dpquant".into(),
            seed: 11,
            physical_batch: 64,
            ..TrainConfig::default()
        };
        let exec = MockExecutor::new(8, 4, 6, 64);
        let ds = toy_dataset(64 + 16, 8, 4, 1);
        let (tr, va) = ds.split(16);
        let mut session = TrainSession::builder(cfg).build(&exec, &tr).unwrap();
        let mut rec = Recorder(Vec::new());
        session.run(&exec, &tr, &va, &mut rec).unwrap();
        let per_epoch = [
            "epoch_started",
            "analysis_completed",
            "policy_selected",
            "step_completed",
            "epoch_completed",
            "epoch_audited",
        ];
        let expected: Vec<String> = per_epoch
            .iter()
            .cycle()
            .take(2 * per_epoch.len())
            .map(|s| s.to_string())
            .collect();
        assert_eq!(rec.0, expected);
    }

    #[test]
    fn audit_events_replay_bitwise_and_never_perturb_training() {
        struct AuditRec(Vec<AuditEpoch>);
        impl EventSink for AuditRec {
            fn on_event(&mut self, event: &TrainEvent<'_>) {
                if let TrainEvent::EpochAudited { audit } = event {
                    self.0.push((*audit).clone());
                }
            }
        }
        let cfg = base_cfg();
        let (exec, tr, va) = fixtures(&cfg);
        let mut audited = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        let mut rec = AuditRec(Vec::new());
        audited.run(&exec, &tr, &va, &mut rec).unwrap();
        assert_eq!(rec.0.len(), cfg.epochs);

        // Replaying every epoch's accounting delta through a fresh
        // accountant reproduces the recorded ε timeline bit-for-bit —
        // the `dpquant audit replay` contract, at the session level.
        let mut fresh = RdpAccountant::new();
        for a in &rec.0 {
            for r in &a.accounting {
                fresh.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
            }
            let (eps, alpha) = fresh.epsilon(cfg.delta);
            assert_eq!(eps.to_bits(), a.epsilon.to_bits(), "epoch {}", a.epoch);
            assert_eq!(alpha.to_bits(), a.alpha.to_bits(), "epoch {}", a.epoch);
        }
        // Masks mirror the run record; DPQuant epochs carry a full
        // probability vector over the executor's 6 quantizable layers.
        for (a, r) in rec.0.iter().zip(&audited.record().epochs) {
            assert_eq!(a.mask, r.quantized_layers);
            assert_eq!(a.draw_probs.len(), 6);
            let sum: f64 = a.draw_probs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-9, "draw probs sum to {sum}");
            assert_eq!(a.clip_scale.to_bits(), 1.0f64.to_bits());
        }

        // Observation never perturbs training: a run that discards the
        // event stream entirely ends with bit-identical weights.
        let (exec2, tr2, va2) = fixtures(&cfg);
        let mut plain = TrainSession::builder(cfg).build(&exec2, &tr2).unwrap();
        plain.run(&exec2, &tr2, &va2, &mut NullSink).unwrap();
        for (a, b) in audited.weights().iter().zip(plain.weights()) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn evaluate_mean_semantics_and_batch_invariance() {
        // A linearly separable set with a huge margin: under the identity
        // weight matrix every example is classified correctly and the
        // per-example loss is ~0, so (mean loss, accuracy) are provable.
        let feats = 3;
        let classes = 3;
        let n = 10;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let c = i % classes;
            for f in 0..feats {
                xs.push(if f == c { 20.0 } else { 0.0 });
            }
            ys.push(c as i32);
        }
        let ds = Dataset {
            xs,
            ys,
            example_numel: feats,
            n_classes: classes,
        };
        // Identity weights: logit_c = 20 for the true class, 0 elsewhere.
        let mut w = vec![0f32; classes * feats];
        for c in 0..classes {
            w[c * feats + c] = 1.0;
        }
        let weights = vec![w];

        let exec = MockExecutor::new(feats, classes, 2, 4);
        let (loss, acc) = evaluate(&exec, &weights, &ds).unwrap();
        assert_eq!(acc, 1.0, "separated set must be fully correct");
        assert!(loss >= 0.0 && loss < 1e-6, "loss={loss}");

        // The physical batch size (and thus the padded final chunk) must
        // not change the result: n=10 over batches of 4 vs 7 vs 16.
        for batch in [7usize, 16] {
            let other = MockExecutor::new(feats, classes, 2, batch);
            let (l2, a2) = evaluate(&other, &weights, &ds).unwrap();
            assert_eq!(a2, acc);
            assert!((l2 - loss).abs() < 1e-9, "{l2} vs {loss}");
        }

        // Mean semantics: duplicating the dataset leaves (loss, acc)
        // unchanged.
        let mut xs2 = ds.xs.clone();
        xs2.extend_from_slice(&ds.xs);
        let mut ys2 = ds.ys.clone();
        ys2.extend_from_slice(&ds.ys);
        let doubled = Dataset {
            xs: xs2,
            ys: ys2,
            example_numel: feats,
            n_classes: classes,
        };
        let (l3, a3) = evaluate(&exec, &weights, &doubled).unwrap();
        assert_eq!(a3, acc);
        assert!((l3 - loss).abs() < 1e-9);
    }

    #[test]
    fn noise_decay_checkpoint_roundtrip_is_bit_exact() {
        // Resume must re-derive the policy from the checkpointed config
        // and continue mid-schedule with the exact same per-epoch knobs.
        let mut cfg = base_cfg();
        cfg.policy = "noise_decay".into();
        cfg.noise_final = 1.2;
        cfg.clip_final = 0.5;
        let (exec, tr, va) = fixtures(&cfg);

        let mut full = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        full.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (full_record, full_weights, mut full_acc) = full.finish();

        let mut first = TrainSession::builder(cfg.clone()).build(&exec, &tr).unwrap();
        for _ in 0..2 {
            first.step_epoch(&exec, &tr, &va, &mut NullSink).unwrap();
        }
        let text = first.checkpoint_text();
        let ckpt = Checkpoint::from_json_text(&text).unwrap();
        assert_eq!(ckpt.config().policy, "noise_decay");
        let mut resumed = TrainSession::resume_from(ckpt, &exec).unwrap();
        resumed.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (record, weights, mut acc) = resumed.finish();

        assert_eq!(weights, full_weights);
        assert_eq!(record.final_epsilon.to_bits(), full_record.final_epsilon.to_bits());
        assert_eq!(acc.epsilon(1e-5), full_acc.epsilon(1e-5));
        // The decay left one Training block per distinct sigma (4 epochs,
        // all sigmas distinct) plus the interleaved analysis blocks.
        let train_blocks = full_acc
            .history()
            .iter()
            .filter(|r| r.mechanism == Mechanism::Training)
            .count();
        assert_eq!(train_blocks, cfg.epochs);
    }

    #[test]
    fn layer_lr_policy_is_pure_post_processing() {
        // Per-layer lr from the privatized EMA must change the trained
        // weights without moving the composed epsilon by a single bit.
        let cfg_static = base_cfg();
        let mut cfg_lr = base_cfg();
        cfg_lr.policy = "layer_lr".into();
        cfg_lr.layer_lr_strength = 1.0;
        let (exec, tr, va) = fixtures(&cfg_static);

        let mut a = TrainSession::builder(cfg_static).build(&exec, &tr).unwrap();
        a.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (_, weights_a, mut acc_a) = a.finish();

        let mut b = TrainSession::builder(cfg_lr).build(&exec, &tr).unwrap();
        b.run(&exec, &tr, &va, &mut NullSink).unwrap();
        let (_, weights_b, mut acc_b) = b.finish();

        let (eps_a, _) = acc_a.epsilon(1e-5);
        let (eps_b, _) = acc_b.epsilon(1e-5);
        assert_eq!(eps_a.to_bits(), eps_b.to_bits(), "layer_lr must cost zero extra eps");
        assert_ne!(weights_a, weights_b, "layer_lr must actually steer training");
    }

    #[test]
    fn set_epochs_extends_a_finished_session() {
        let mut cfg = base_cfg();
        cfg.epochs = 2;
        let (exec, tr, va) = fixtures(&cfg);
        let mut s = TrainSession::builder(cfg).build(&exec, &tr).unwrap();
        s.run(&exec, &tr, &va, &mut NullSink).unwrap();
        assert!(s.is_finished());
        assert_eq!(s.epochs_completed(), 2);
        s.set_epochs(3);
        assert!(!s.is_finished());
        s.run(&exec, &tr, &va, &mut NullSink).unwrap();
        assert_eq!(s.epochs_completed(), 3);
    }
}
