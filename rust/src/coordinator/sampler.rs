//! Algorithm 2 (SELECTTARGETS): probabilistic layer sampling with
//! loss-aware prioritization.
//!
//!   v  <- normalize(EMA scores)          (min-max to [0,1])
//!   π  <- softmax(-β · v)                (low impact ⇒ high probability)
//!   Q  <- Multinomial(π, m, without replacement)
//!
//! β (the temperature, §A.7) interpolates between uniform rotation
//! (β→0, pure PLS) and greedy lowest-impact selection (β→∞).

use crate::util::rng::Xoshiro256;

/// Min-max normalize to [0, 1]; constant vectors map to all-zeros.
pub fn normalize(v: &[f64]) -> Vec<f64> {
    let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !(hi - lo).is_finite() || hi - lo < 1e-12 {
        return vec![0.0; v.len()];
    }
    v.iter().map(|&x| (x - lo) / (hi - lo)).collect()
}

/// Stable softmax of `-beta * v`.
pub fn softmax_neg(v: &[f64], beta: f64) -> Vec<f64> {
    let scaled: Vec<f64> = v.iter().map(|&x| -beta * x).collect();
    let m = scaled.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = scaled.iter().map(|&x| (x - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.iter().map(|&e| e / z).collect()
}

/// Sample `m` distinct indices without replacement from the categorical
/// distribution `probs` (sequential draws with renormalization — the
/// semantics of `torch.multinomial(..., replacement=False)` the paper's
/// implementation uses).
///
/// When the remaining weight mass is zero — at large β (≳ 745 after
/// min-max normalization) `softmax` underflows every non-minimum entry
/// to exactly 0.0, so once the positive-weight indices are exhausted
/// the renormalized distribution is 0/0 — the draw falls back to
/// **uniform** over the remaining set. Without the fallback, `r` starts
/// at 0 and the first `r <= 0.0` test fires immediately, so every
/// zero-mass draw deterministically picked the first remaining slot:
/// high-β runs silently stopped rotating their extra quantization
/// targets. Each draw consumes exactly one `next_f64` on either path,
/// so fixed-seed runs that never hit the zero-mass case are unchanged.
pub fn multinomial_without_replacement(
    rng: &mut Xoshiro256,
    probs: &[f64],
    m: usize,
) -> Vec<usize> {
    assert!(m <= probs.len());
    let mut available: Vec<usize> = (0..probs.len()).collect();
    let weights: Vec<f64> = probs.to_vec();
    let mut picked = Vec::with_capacity(m);
    for _ in 0..m {
        let total: f64 = available.iter().map(|&i| weights[i]).sum();
        let u = rng.next_f64();
        let chosen_pos = if total > 0.0 {
            let mut r = u * total;
            let mut chosen = available.len() - 1;
            for (pos, &i) in available.iter().enumerate() {
                r -= weights[i];
                if r <= 0.0 {
                    chosen = pos;
                    break;
                }
            }
            chosen
        } else {
            // Degenerate mass: uniform over what's left.
            ((u * available.len() as f64) as usize).min(available.len() - 1)
        };
        picked.push(available.swap_remove(chosen_pos));
    }
    picked.sort_unstable();
    picked
}

/// SELECTTARGETS: pick `k` layers to quantize from per-layer EMA scores.
pub fn select_targets(rng: &mut Xoshiro256, ema_scores: &[f64], beta: f64, k: usize) -> Vec<usize> {
    let v = normalize(ema_scores);
    let pi = softmax_neg(&v, beta);
    multinomial_without_replacement(rng, &pi, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_bounds() {
        let v = normalize(&[3.0, 1.0, 2.0]);
        assert_eq!(v, vec![1.0, 0.0, 0.5]);
        assert_eq!(normalize(&[2.0, 2.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn softmax_prefers_low_scores() {
        let pi = softmax_neg(&[0.0, 1.0], 2.0);
        assert!(pi[0] > pi[1]);
        assert!((pi.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn beta_zero_is_uniform() {
        let pi = softmax_neg(&[0.0, 0.3, 1.0], 0.0);
        for &p in &pi {
            assert!((p - 1.0 / 3.0).abs() < 1e-12);
        }
    }

    #[test]
    fn sampling_without_replacement_distinct_and_sized() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..200 {
            let s = select_targets(&mut rng, &[0.1, 0.9, 0.5, 0.2, 0.7], 3.0, 3);
            assert_eq!(s.len(), 3);
            let mut d = s.clone();
            d.dedup();
            assert_eq!(d.len(), 3);
        }
    }

    #[test]
    fn high_beta_avoids_high_impact_layers() {
        // Layer 0 has by far the highest loss impact; with large β it
        // should almost never be quantized.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let scores = [10.0, 0.1, 0.2, 0.05, 0.15];
        let mut hit0 = 0;
        let trials = 500;
        for _ in 0..trials {
            let s = select_targets(&mut rng, &scores, 50.0, 3);
            if s.contains(&0) {
                hit0 += 1;
            }
        }
        assert!(hit0 < trials / 20, "layer 0 picked {hit0}/{trials}");
    }

    #[test]
    fn low_beta_rotates_roughly_uniformly() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let scores = [10.0, 0.1, 0.2, 0.05, 0.15];
        let mut counts = [0usize; 5];
        let trials = 2000;
        for _ in 0..trials {
            for l in select_targets(&mut rng, &scores, 0.0, 2) {
                counts[l] += 1;
            }
        }
        // Expected 2*2000/5 = 800 per layer.
        for &c in &counts {
            assert!((c as f64 - 800.0).abs() < 120.0, "{counts:?}");
        }
    }

    #[test]
    fn marginals_follow_softmax_for_k1() {
        // k=1 sampling frequency must match π within sampling error.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let scores = [0.0, 0.5, 1.0];
        let pi = softmax_neg(&normalize(&scores), 3.0);
        let mut counts = [0usize; 3];
        let trials = 30_000;
        for _ in 0..trials {
            counts[select_targets(&mut rng, &scores, 3.0, 1)[0]] += 1;
        }
        for i in 0..3 {
            let freq = counts[i] as f64 / trials as f64;
            assert!((freq - pi[i]).abs() < 0.01, "i={i} freq={freq} pi={}", pi[i]);
        }
    }

    #[test]
    fn huge_beta_still_rotates_zero_mass_targets() {
        // β = 2000 underflows every non-minimum softmax weight to 0.0,
        // so after the single minimum-score layer is drawn the
        // remaining mass is exactly zero. Pre-fix, the zero-mass draws
        // deterministically picked the first remaining slot (indices
        // {6, 7} after the swap_remove shuffle), never the others; the
        // fix draws uniformly, so across seeds every layer must appear.
        let scores = [0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0];
        let mut seen = [0usize; 8];
        for seed in 0..400 {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let s = select_targets(&mut rng, &scores, 2000.0, 3);
            assert_eq!(s.len(), 3);
            assert!(s.contains(&0), "the minimum-score layer has all the mass");
            for l in s {
                seen[l] += 1;
            }
        }
        for (l, &c) in seen.iter().enumerate().skip(1) {
            assert!(c > 0, "layer {l} never selected across seeds: {seen:?}");
            // 2 uniform picks among 7 zero-mass layers × 400 seeds
            // ≈ 114 expected hits each; fail far outside that.
            assert!(c > 40 && c < 250, "layer {l} frequency off: {seen:?}");
        }
    }

    #[test]
    fn k_equals_n_selects_everything() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let s = select_targets(&mut rng, &[0.3, 0.1, 0.9], 7.0, 3);
        assert_eq!(s, vec![0, 1, 2]);
    }
}
