//! Algorithm 1 — COMPUTELOSSIMPACT: the differentially-private loss
//! sensitivity estimator.
//!
//! For each candidate policy p (here: each single-layer policy) and the
//! full-precision baseline p0, run `R` repetitions of DP-SGD updates on a
//! subsampled probe batch set under p from a restored model, record the
//! average loss, difference against p0, **clip the difference vector to
//! norm C_measure and add `N(0, σ_measure² C_measure²)`** (step 3 — this
//! is what makes the whole estimator a Sampled Gaussian Mechanism,
//! Prop. 2), account one SGM step, and fold into the per-layer EMA
//! (step 4, post-processing).

use super::ema::EmaScores;
use super::executor::StepExecutor;
use super::optimizer::DpOptimizer;
use super::policy::Policy;
use crate::config::TrainConfig;
use crate::data::Batch;
use crate::privacy::RdpAccountant;
use crate::util::error::Result;
use crate::util::gaussian::GaussianSampler;

/// Outcome of one analysis invocation.
pub struct AnalysisReport {
    /// Privatized per-layer loss-impact estimates R̂ (before EMA).
    pub privatized_impacts: Vec<f64>,
    /// Wall-clock seconds spent.
    pub seconds: f64,
}

/// Run Algorithm 1 and fold the result into `ema`.
///
/// `probe_batches` is the subsample B (already Poisson-drawn by the
/// caller at rate |B|/|D|); `weights` is the *current* model, restored
/// after every probe.
#[allow(clippy::too_many_arguments)]
pub fn compute_loss_impact<E: StepExecutor + ?Sized>(
    exec: &E,
    cfg: &TrainConfig,
    weights: &[Vec<f32>],
    probe_batches: &[Batch],
    ema: &mut EmaScores,
    accountant: &mut RdpAccountant,
    noise: &mut GaussianSampler,
    seed_base: f32,
) -> Result<AnalysisReport> {
    let t0 = std::time::Instant::now();
    let n_layers = exec.n_quant_layers();

    // Policies: one per layer (P), plus the no-quantization baseline p0.
    let mut policies: Vec<Policy> = (0..n_layers)
        .map(|l| Policy::single(n_layers, l))
        .collect();
    policies.push(Policy::baseline(n_layers));

    // Probe-step seed strides. The old fixed strides (1000 per policy,
    // 100 per rep) collided as soon as a run used ≥ 100 probe batches
    // or ≥ 10 reps — two different (pi, rep, bi) probes would then
    // share a quantization-noise seed and the estimator silently lost
    // rank resolution. Deriving the strides from the actual loop
    // extents keeps every (pi, rep, bi) seed distinct; clamping to the
    // old constants keeps default-range runs (bi < 100, rep < 10)
    // bit-identical to checkpoints taken before the fix.
    let stride_rep = probe_batches.len().max(100);
    let stride_pi = (cfg.analysis_reps * stride_rep).max(1000);

    let mut avg_losses = vec![0f64; policies.len()];
    for (pi, policy) in policies.iter().enumerate() {
        let mask = policy.mask();
        let mut total_loss = 0f64;
        for rep in 0..cfg.analysis_reps {
            // RESTOREMODEL: every repetition probes from the same state.
            let mut probe_weights: Vec<Vec<f32>> = weights.to_vec();
            let mut probe_opt = DpOptimizer::new(
                cfg.optimizer,
                cfg.lr,
                cfg.noise_multiplier,
                cfg.clip_norm,
                cfg.batch_size as f64,
                &exec.param_sizes(),
                noise.clone(),
            );
            let mut rep_loss = 0f64;
            let mut rep_count = 0f64;
            for (bi, batch) in probe_batches.iter().enumerate() {
                let seed = seed_base + (pi * stride_pi + rep * stride_rep + bi) as f32;
                let mut out = exec.train_step(
                    &probe_weights,
                    &batch.x,
                    &batch.y,
                    &batch.mask,
                    &mask,
                    seed,
                )?;
                rep_loss += out.loss_sum as f64;
                rep_count += batch.real as f64;
                probe_opt.update(&mut probe_weights, &mut out.grad_sums);
            }
            total_loss += rep_loss / rep_count.max(1.0);
        }
        avg_losses[pi] = total_loss / cfg.analysis_reps as f64;
    }

    // Step 2: loss differences from the baseline (last entry).
    let baseline = avg_losses[n_layers];
    let mut r: Vec<f64> = avg_losses[..n_layers]
        .iter()
        .map(|&l| l - baseline)
        .collect();

    // Step 3: privatize — clip the vector to C_measure, add Gaussian
    // noise of std σ_measure · C_measure per coordinate.
    let norm: f64 = r.iter().map(|&x| x * x).sum::<f64>().sqrt();
    let scale = (cfg.clip_measure / norm.max(1e-12)).min(1.0);
    for x in r.iter_mut() {
        *x = *x * scale + noise.normal(0.0, cfg.sigma_measure * cfg.clip_measure);
    }

    // UPDATEPRIVACY(rate = |B|/|D|, steps = 1, noise = σ_measure).
    let probe_examples: usize = probe_batches.iter().map(|b| b.real).sum();
    let rate = (probe_examples as f64 / cfg.dataset_size as f64).min(1.0);
    accountant.step_analysis(rate, cfg.sigma_measure);

    // Step 4: EMA update (post-processing; no privacy cost).
    ema.update(&r);

    Ok(AnalysisReport {
        privatized_impacts: r,
        seconds: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::MockExecutor;
    use crate::data::{make_batches, Dataset};
    use crate::privacy::Mechanism;
    use crate::util::rng::Xoshiro256;

    fn toy_dataset(n: usize, feats: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let c = rng.next_below(classes as u64) as i32;
            for f in 0..feats {
                xs.push(rng.next_f32() + if f == c as usize { 1.5 } else { 0.0 });
            }
            ys.push(c);
        }
        Dataset {
            xs,
            ys,
            example_numel: feats,
            n_classes: classes,
        }
    }

    fn run_once(sigma_measure: f64, seed: u64) -> (Vec<f64>, RdpAccountant) {
        let exec = MockExecutor::new(6, 3, 4, 8);
        let cfg = TrainConfig {
            analysis_reps: 2,
            sigma_measure,
            clip_measure: 0.05,
            dataset_size: 64,
            batch_size: 8,
            noise_multiplier: 0.0,
            lr: 0.05,
            ..TrainConfig::default()
        };
        let ds = toy_dataset(64, 6, 3, seed);
        let probes = make_batches(&ds, &(0..8).collect::<Vec<_>>(), 8);
        let weights = exec.initial_weights();
        let mut ema = EmaScores::new(4, 0.3, true);
        let mut acc = RdpAccountant::new();
        let mut noise = GaussianSampler::seed_from_u64(seed);
        let rep = compute_loss_impact(
            &exec, &cfg, &weights, &probes, &mut ema, &mut acc, &mut noise, 0.0,
        )
        .unwrap();
        (rep.privatized_impacts, acc)
    }

    #[test]
    fn produces_per_layer_estimates_and_accounts() {
        let (impacts, mut acc) = run_once(0.5, 1);
        assert_eq!(impacts.len(), 4);
        assert_eq!(acc.steps_of(Mechanism::Analysis), 1);
        assert_eq!(acc.steps_of(Mechanism::Training), 0);
        let (eps, _) = acc.epsilon_of(Mechanism::Analysis, 1e-5);
        assert!(eps > 0.0 && eps.is_finite());
    }

    #[test]
    fn privatized_vector_bounded_by_clip_plus_noise() {
        // With tiny noise the output norm can't exceed C_measure much.
        let (impacts, _) = run_once(1e-6, 2);
        let norm: f64 = impacts.iter().map(|&x| x * x).sum::<f64>().sqrt();
        assert!(norm <= 0.05 * 1.001, "norm={norm}");
    }

    #[test]
    fn ranking_reflects_mock_sensitivity_with_low_noise() {
        // MockExecutor's layer_sensitivity increases with index, so with
        // negligible measurement noise the privatized impacts should
        // (weakly) rank later layers as more harmful on average over
        // several invocations.
        let mut acc_impacts = vec![0f64; 4];
        for seed in 0..8 {
            let (impacts, _) = run_once(1e-6, 100 + seed);
            for (a, &b) in acc_impacts.iter_mut().zip(&impacts) {
                *a += b;
            }
        }
        assert!(
            acc_impacts[3] >= acc_impacts[0],
            "expected layer 3 ≥ layer 0: {acc_impacts:?}"
        );
    }

    #[test]
    fn probe_seeds_are_injective_and_back_compatible() {
        // Mirror of the stride derivation in compute_loss_impact.
        let strides = |n_batches: usize, reps: usize| {
            let stride_rep = n_batches.max(100);
            let stride_pi = (reps * stride_rep).max(1000);
            (stride_pi, stride_rep)
        };
        // Large extents (the pre-fix collision zone: bi ≥ 100, rep ≥ 10)
        // must still yield pairwise-distinct seed offsets.
        let (n_batches, reps, n_policies) = (120, 12, 3);
        let (stride_pi, stride_rep) = strides(n_batches, reps);
        let mut seen = std::collections::HashSet::new();
        for pi in 0..n_policies {
            for rep in 0..reps {
                for bi in 0..n_batches {
                    assert!(
                        seen.insert(pi * stride_pi + rep * stride_rep + bi),
                        "seed collision at pi={pi} rep={rep} bi={bi}"
                    );
                }
            }
        }
        // The old constants collide in exactly this zone: (pi=0, rep=10,
        // bi=0) and (pi=1, rep=0, bi=0) both hit seed offset 1000.
        assert_eq!(10 * 100, 1000);
        // Default-range runs (bi < 100, rep < 10) keep the old strides,
        // so pre-fix checkpoints replay bit-identically.
        assert_eq!(strides(8, 2), (1000, 100));
        assert_eq!(strides(100, 10), (1000, 100));
    }

    #[test]
    fn empty_probe_set_is_a_privacy_noop() {
        // A Poisson draw can legitimately select zero probe examples;
        // the estimator must not panic, must emit per-layer numbers
        // (pure noise), and must account NO analysis step — rate 0
        // touches nobody's data.
        let exec = MockExecutor::new(6, 3, 4, 8);
        let cfg = TrainConfig {
            analysis_reps: 2,
            sigma_measure: 0.5,
            clip_measure: 0.05,
            dataset_size: 64,
            batch_size: 8,
            noise_multiplier: 0.0,
            lr: 0.05,
            ..TrainConfig::default()
        };
        let weights = exec.initial_weights();
        let mut ema = EmaScores::new(4, 0.3, true);
        let mut acc = RdpAccountant::new();
        let mut noise = GaussianSampler::seed_from_u64(7);
        let rep =
            compute_loss_impact(&exec, &cfg, &weights, &[], &mut ema, &mut acc, &mut noise, 0.0)
                .unwrap();
        assert_eq!(rep.privatized_impacts.len(), 4);
        assert!(rep.privatized_impacts.iter().all(|x| x.is_finite()));
        assert_eq!(acc.steps_of(Mechanism::Analysis), 0);
        let (eps, _) = acc.epsilon_of(Mechanism::Analysis, 1e-5);
        assert_eq!(eps, 0.0);
    }

    #[test]
    fn noise_scale_matters() {
        // Larger σ_measure must produce noisier (different) outputs.
        let (a, _) = run_once(10.0, 3);
        let (b, _) = run_once(1e-6, 3);
        let diff: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "noise should dominate: diff={diff}");
    }
}
