//! DP optimizers: the noise-and-update half of Def. 2.
//!
//! The compiled graph returns Σ of clipped per-sample gradients; here the
//! coordinator adds `N(0, σ²C²)` **in fp32/fp64, before any quantized
//! computation** (paper §A.17 — the privacy-critical step keeps the same
//! vulnerability profile as standard fp32 DP-SGD), divides by the
//! *expected* batch size (Poisson sampling's lot size), and applies
//! SGD / Adam / AdamW.

use crate::config::OptimizerKind;
use crate::util::gaussian::GaussianSampler;

/// Per-step statistics the experiment harness taps (Fig. 1b/1c).
#[derive(Clone, Copy, Debug, Default)]
pub struct NoiseStats {
    /// L∞ of the (summed, clipped) gradient before noise.
    pub grad_linf: f64,
    /// L2 of the gradient before noise.
    pub grad_l2: f64,
    /// L∞ of the injected noise.
    pub noise_linf: f64,
    /// L2 of the injected noise.
    pub noise_l2: f64,
}

/// DP optimizer state over a list of parameter tensors.
pub struct DpOptimizer {
    kind: OptimizerKind,
    lr: f64,
    /// Noise std per coordinate on the *sum*: σ·C.
    noise_std: f64,
    /// Expected lot size B = q·|D|.
    expected_batch: f64,
    /// Clip-then-rescale factor C(t)/C₀ applied to the summed clipped
    /// gradients before noising (adaptive clip schedules; DESIGN.md
    /// §16.2). 1.0 — the static value — is bit-exact: x·1.0 ≡ x.
    grad_scale: f64,
    /// Per-tensor learning-rate factors (policy = "layer_lr").
    /// `None` keeps the exact single-lr code path.
    lr_scales: Option<Vec<f64>>,
    beta1: f64,
    beta2: f64,
    eps: f64,
    weight_decay: f64,
    step: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    sampler: GaussianSampler,
}

impl DpOptimizer {
    /// Build the optimizer for `shapes`-sized parameters: `kind` selects
    /// SGD/Adam/AdamW state, `sampler` provides the DP noise stream.
    pub fn new(
        kind: OptimizerKind,
        lr: f64,
        noise_multiplier: f64,
        clip_norm: f64,
        expected_batch: f64,
        shapes: &[usize],
        sampler: GaussianSampler,
    ) -> Self {
        let (m, v) = match kind {
            OptimizerKind::Sgd => (Vec::new(), Vec::new()),
            _ => (
                shapes.iter().map(|&n| vec![0f32; n]).collect(),
                shapes.iter().map(|&n| vec![0f32; n]).collect(),
            ),
        };
        Self {
            kind,
            lr,
            noise_std: noise_multiplier * clip_norm,
            expected_batch,
            grad_scale: 1.0,
            lr_scales: None,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: if kind == OptimizerKind::AdamW { 0.01 } else { 0.0 },
            step: 0,
            m,
            v,
            sampler,
        }
    }

    /// Steps taken so far (drives Adam's bias correction).
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// First/second moment tensors (empty for SGD), for checkpointing.
    pub fn moments(&self) -> (&[Vec<f32>], &[Vec<f32>]) {
        (&self.m, &self.v)
    }

    /// The optimizer's Gaussian noise stream, for checkpointing.
    pub fn sampler(&self) -> &GaussianSampler {
        &self.sampler
    }

    /// Re-aim the DP mechanism at this epoch's (σ_t, C_t): the noise
    /// std on the sum becomes σ_t·C_t and the C₀-clipped gradient sums
    /// are rescaled by C_t/C₀ (`grad_scale`), which realizes
    /// sensitivity C_t without touching the executor's baked-in clip.
    /// With the base knobs this recomputes the identical product and a
    /// scale of exactly 1.0, so static runs cannot drift by a bit.
    pub fn set_dp_params(&mut self, noise_multiplier: f64, clip_norm: f64, grad_scale: f64) {
        self.noise_std = noise_multiplier * clip_norm;
        self.grad_scale = grad_scale;
    }

    /// Re-aim the normalization at this epoch's expected lot size
    /// B̄_t = q_t·|D| (policy = "rate_schedule"). Not called on the
    /// static path, which keeps the constructor's exact value.
    pub fn set_expected_batch(&mut self, expected_batch: f64) {
        self.expected_batch = expected_batch;
    }

    /// Install per-tensor learning-rate factors (policy = "layer_lr",
    /// post-processing of the privatized EMA scores). `None` restores
    /// the exact single-lr code path; factors missing for a tensor
    /// default to 1.0.
    pub fn set_lr_scales(&mut self, scales: Option<Vec<f64>>) {
        self.lr_scales = scales;
    }

    /// The learning rate for tensor `ti`: `lr` itself (bit-exact) when
    /// no factors are installed, otherwise `lr · scale[ti]`.
    fn tensor_lr(&self, ti: usize) -> f64 {
        match &self.lr_scales {
            None => self.lr,
            Some(s) => self.lr * s.get(ti).copied().unwrap_or(1.0),
        }
    }

    /// Restore moments + step count captured from another optimizer
    /// with the same configuration (checkpoint resume). Hyperparameters
    /// and the noise sampler are not part of this call — they are
    /// supplied to `new` (the sampler with its checkpointed state).
    pub fn restore(&mut self, step: u64, m: Vec<Vec<f32>>, v: Vec<Vec<f32>>) {
        assert_eq!(m.len(), self.m.len(), "moment tensor count mismatch");
        assert_eq!(v.len(), self.v.len(), "moment tensor count mismatch");
        for (restored, fresh) in m.iter().zip(&self.m).chain(v.iter().zip(&self.v)) {
            assert_eq!(restored.len(), fresh.len(), "moment tensor shape mismatch");
        }
        self.step = step;
        self.m = m;
        self.v = v;
    }

    /// Add noise to the clipped-grad sums and update weights in place.
    /// Returns the step's gradient/noise norm statistics.
    pub fn update(&mut self, weights: &mut [Vec<f32>], grad_sums: &mut [Vec<f32>]) -> NoiseStats {
        assert_eq!(weights.len(), grad_sums.len());
        self.step += 1;
        let mut stats = NoiseStats::default();

        // Noise + normalize: u = (C_t/C₀·Σ clipped + N(0, σ_t²C_t²)) / B̄,
        // tracked in fp64 accumulators for the norms.
        for g in grad_sums.iter_mut() {
            for x in g.iter_mut() {
                let gx = *x as f64 * self.grad_scale;
                stats.grad_l2 += gx * gx;
                stats.grad_linf = stats.grad_linf.max(gx.abs());
                let n = self.noise_std * self.sampler.standard();
                stats.noise_l2 += n * n;
                stats.noise_linf = stats.noise_linf.max(n.abs());
                *x = ((gx + n) / self.expected_batch) as f32;
            }
        }
        stats.grad_l2 = stats.grad_l2.sqrt();
        stats.noise_l2 = stats.noise_l2.sqrt();

        match self.kind {
            OptimizerKind::Sgd => {
                for (ti, (w, g)) in weights.iter_mut().zip(grad_sums.iter()).enumerate() {
                    let lr = self.tensor_lr(ti) as f32;
                    for (wi, gi) in w.iter_mut().zip(g) {
                        *wi -= lr * gi;
                    }
                }
            }
            OptimizerKind::Adam | OptimizerKind::AdamW => {
                let b1 = self.beta1;
                let b2 = self.beta2;
                let bc1 = 1.0 - b1.powi(self.step as i32);
                let bc2 = 1.0 - b2.powi(self.step as i32);
                for (ti, ((w, g), (m, v))) in weights
                    .iter_mut()
                    .zip(grad_sums.iter())
                    .zip(self.m.iter_mut().zip(self.v.iter_mut()))
                    .enumerate()
                {
                    let lr = self.tensor_lr(ti);
                    for i in 0..w.len() {
                        let gi = g[i] as f64;
                        let mi = b1 * m[i] as f64 + (1.0 - b1) * gi;
                        let vi = b2 * v[i] as f64 + (1.0 - b2) * gi * gi;
                        m[i] = mi as f32;
                        v[i] = vi as f32;
                        let mhat = mi / bc1;
                        let vhat = vi / bc2;
                        let mut upd = lr * mhat / (vhat.sqrt() + self.eps);
                        if self.weight_decay > 0.0 {
                            upd += lr * self.weight_decay * w[i] as f64;
                        }
                        w[i] = (w[i] as f64 - upd) as f32;
                    }
                }
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler() -> GaussianSampler {
        GaussianSampler::seed_from_u64(42)
    }

    #[test]
    fn sgd_noiseless_matches_reference() {
        let mut opt = DpOptimizer::new(
            OptimizerKind::Sgd,
            0.5,
            0.0, // no noise
            1.0,
            2.0,
            &[3],
            sampler(),
        );
        let mut w = vec![vec![1.0f32, 2.0, 3.0]];
        let mut g = vec![vec![0.2f32, -0.4, 0.0]];
        opt.update(&mut w, &mut g);
        // u = g / 2; w -= 0.5 * u
        assert!((w[0][0] - (1.0 - 0.5 * 0.1)).abs() < 1e-6);
        assert!((w[0][1] - (2.0 + 0.5 * 0.2)).abs() < 1e-6);
        assert!((w[0][2] - 3.0).abs() < 1e-7);
    }

    #[test]
    fn adam_noiseless_first_step_is_lr_sign() {
        // After bias correction, Adam's first step ≈ lr * sign(g).
        let mut opt = DpOptimizer::new(
            OptimizerKind::Adam,
            0.01,
            0.0,
            1.0,
            1.0,
            &[2],
            sampler(),
        );
        let mut w = vec![vec![0.0f32, 0.0]];
        let mut g = vec![vec![0.3f32, -0.7]];
        opt.update(&mut w, &mut g);
        assert!((w[0][0] + 0.01).abs() < 1e-4, "{}", w[0][0]);
        assert!((w[0][1] - 0.01).abs() < 1e-4, "{}", w[0][1]);
    }

    #[test]
    fn adamw_decays_weights() {
        let mut opt = DpOptimizer::new(
            OptimizerKind::AdamW,
            0.01,
            0.0,
            1.0,
            1.0,
            &[1],
            sampler(),
        );
        let mut w = vec![vec![10.0f32]];
        let mut g = vec![vec![0.0f32]];
        opt.update(&mut w, &mut g);
        // Zero grad: only decay acts: w -= lr*wd*w = 10 - 0.01*0.01*10
        assert!((w[0][0] - (10.0 - 0.001)).abs() < 1e-5, "{}", w[0][0]);
    }

    #[test]
    fn noise_stats_match_configuration() {
        let mut opt = DpOptimizer::new(
            OptimizerKind::Sgd,
            0.0, // lr 0: weights untouched, isolate noise
            1.5,
            2.0, // noise std = 3.0
            1.0,
            &[10_000],
            sampler(),
        );
        let mut w = vec![vec![0f32; 10_000]];
        let mut g = vec![vec![0f32; 10_000]];
        let stats = opt.update(&mut w, &mut g);
        // E[noise_l2] = σC √n = 3·100 = 300.
        assert!((stats.noise_l2 - 300.0).abs() < 10.0, "{}", stats.noise_l2);
        // L∞ of 10k gaussians ≈ 3·3.7 ≈ 11; bounds loose.
        assert!(stats.noise_linf > 3.0 * 2.5 && stats.noise_linf < 3.0 * 6.0);
        assert_eq!(stats.grad_l2, 0.0);
    }

    #[test]
    fn grad_scale_rescales_clipped_sums() {
        let mut opt =
            DpOptimizer::new(OptimizerKind::Sgd, 1.0, 0.0, 1.0, 1.0, &[2], sampler());
        // Clip schedule halves C: sums clipped at C₀ rescale by 0.5.
        opt.set_dp_params(0.0, 0.5, 0.5);
        let mut w = vec![vec![0.0f32, 0.0]];
        let mut g = vec![vec![1.0f32, -2.0]];
        let stats = opt.update(&mut w, &mut g);
        assert!((w[0][0] + 0.5).abs() < 1e-6, "{}", w[0][0]);
        assert!((w[0][1] - 1.0).abs() < 1e-6, "{}", w[0][1]);
        // Norm stats see the rescaled (sensitivity-C_t) gradient.
        assert!((stats.grad_linf - 1.0).abs() < 1e-9, "{}", stats.grad_linf);
    }

    #[test]
    fn per_tensor_lr_scales_apply_only_where_installed() {
        let mut opt =
            DpOptimizer::new(OptimizerKind::Sgd, 1.0, 0.0, 1.0, 1.0, &[1, 1], sampler());
        opt.set_lr_scales(Some(vec![0.5, 2.0]));
        let mut w = vec![vec![0.0f32], vec![0.0f32]];
        let mut g = vec![vec![1.0f32], vec![1.0f32]];
        opt.update(&mut w, &mut g);
        assert!((w[0][0] + 0.5).abs() < 1e-6, "{}", w[0][0]);
        assert!((w[1][0] + 2.0).abs() < 1e-6, "{}", w[1][0]);
        // None restores the single-lr path.
        opt.set_lr_scales(None);
        let mut g = vec![vec![1.0f32], vec![1.0f32]];
        opt.update(&mut w, &mut g);
        assert!((w[0][0] + 1.5).abs() < 1e-6, "{}", w[0][0]);
        assert!((w[1][0] + 3.0).abs() < 1e-6, "{}", w[1][0]);
    }

    #[test]
    fn noise_dominates_clipped_grads_in_high_dims() {
        // The paper's core observation (Eq. 2): ||n||∞ ≈ ||ḡ||₂ ≫ ||ḡ||∞
        // when σ ≥ 1 and dims are high. Simulate a clipped grad with
        // ||g||₂ = C = 1 spread over n coords.
        let n = 20_000;
        let mut opt = DpOptimizer::new(
            OptimizerKind::Sgd,
            0.0,
            1.0,
            1.0,
            1.0,
            &[n],
            sampler(),
        );
        let per = (1.0 / (n as f64).sqrt()) as f32;
        let mut w = vec![vec![0f32; n]];
        let mut g = vec![vec![per; n]];
        let stats = opt.update(&mut w, &mut g);
        assert!((stats.grad_l2 - 1.0).abs() < 1e-3);
        assert!(
            stats.noise_linf > 10.0 * stats.grad_linf,
            "noise_linf={} grad_linf={}",
            stats.noise_linf,
            stats.grad_linf
        );
    }
}
