//! Rényi Differential Privacy of the Sampled Gaussian Mechanism.
//!
//! Implements the analysis of Mironov, Talwar & Zhang (2019), *Rényi
//! differential privacy of the sampled Gaussian mechanism* — the same math
//! Opacus's RDP accountant uses, which the paper relies on (§5.4, Prop. 2,
//! §A.14). For sampling rate `q` and noise multiplier `σ`, one SGM step
//! satisfies `(α, ρ(α))`-RDP with
//!
//! `ρ(α) = log A(α) / (α − 1)`,  `A(α) = E_{z∼ν₀}[(ν(z)/ν₀(z))^α]`
//!
//! where `ν₀ = N(0, σ²)` and `ν = (1−q)·N(0, σ²) + q·N(1, σ²)`.
//! Integer α admits a closed-form binomial sum; fractional α uses the
//! two-sided series with Gaussian tail integrals (both computed in log
//! space). Composition is additive in ρ; conversion to (ε, δ) uses the
//! improved bound (see [`rdp_to_epsilon`]).

use crate::util::special::{log_add_exp, log_binom, log_erfc, log_sub_exp, logsumexp};

/// Default α grid (matches Opacus: 1.1..10.9 step 0.1, then 12..63).
pub fn default_alphas() -> Vec<f64> {
    let mut alphas: Vec<f64> = (1..100).map(|x| 1.0 + x as f64 / 10.0).collect();
    alphas.extend((12..64).map(|x| x as f64));
    alphas
}

/// `log A(α)` for integer α ≥ 2: the closed-form binomial expansion
/// `A(α) = Σ_{i=0}^{α} C(α,i) (1−q)^{α−i} q^i · exp((i²−i)/(2σ²))`.
fn compute_log_a_int(q: f64, sigma: f64, alpha: u64) -> f64 {
    let terms: Vec<f64> = (0..=alpha)
        .map(|i| {
            log_binom(alpha, i)
                + (i as f64) * q.ln()
                + (alpha - i) as f64 * (1.0 - q).ln_1p_zero()
                + ((i * i) as f64 - i as f64) / (2.0 * sigma * sigma)
        })
        .collect();
    logsumexp(&terms)
}

trait Ln1pZero {
    fn ln_1p_zero(self) -> f64;
}
impl Ln1pZero for f64 {
    /// `ln(self)` that treats `self == 0` multiplied by a zero count as 0
    /// contribution; here used as `ln(1-q)` with `(alpha - i)` possibly 0.
    #[inline]
    fn ln_1p_zero(self) -> f64 {
        if self <= 0.0 {
            f64::NEG_INFINITY
        } else {
            self.ln()
        }
    }
}

/// Generalized binomial coefficient iterator: yields
/// `(ln|C(α,i)|, sign)` for i = 0, 1, 2, … via the recurrence
/// `C(α,i+1) = C(α,i) · (α−i)/(i+1)`. Works for real α.
struct LogBinomIter {
    alpha: f64,
    i: u64,
    log_abs: f64,
    sign: f64,
}

impl LogBinomIter {
    fn new(alpha: f64) -> Self {
        Self {
            alpha,
            i: 0,
            log_abs: 0.0,
            sign: 1.0,
        }
    }
}

impl Iterator for LogBinomIter {
    type Item = (f64, f64); // (ln|C|, sign)
    fn next(&mut self) -> Option<(f64, f64)> {
        let out = (self.log_abs, self.sign);
        let factor = (self.alpha - self.i as f64) / (self.i as f64 + 1.0);
        if factor == 0.0 {
            self.log_abs = f64::NEG_INFINITY;
        } else {
            self.log_abs += factor.abs().ln();
            if factor < 0.0 {
                self.sign = -self.sign;
            }
        }
        self.i += 1;
        Some(out)
    }
}

/// `log A(α)` for fractional α: the two-sided infinite series of
/// Mironov et al. §3.3 with Gaussian tail terms, accumulated with signed
/// log-space addition until terms fall below `exp(-30)` of the total.
fn compute_log_a_frac(q: f64, sigma: f64, alpha: f64) -> f64 {
    // Signed accumulators for the two half-line integrals.
    let mut log_a0 = f64::NEG_INFINITY;
    let mut log_a1 = f64::NEG_INFINITY;
    let z0 = sigma * sigma * (1.0 / q - 1.0).ln() + 0.5;
    let s2 = 2.0 * sigma * sigma;
    let sqrt2sigma = std::f64::consts::SQRT_2 * sigma;

    let mut binoms = LogBinomIter::new(alpha);
    let mut i: u64 = 0;
    loop {
        let (log_coef, sign) = binoms.next().unwrap();
        let j = alpha - i as f64;

        let log_t0 = log_coef + i as f64 * q.ln() + j * (1.0 - q).ln();
        let log_t1 = log_coef + j * q.ln() + i as f64 * (1.0 - q).ln();

        let log_e0 = (0.5f64).ln() + log_erfc((i as f64 - z0) / sqrt2sigma);
        let log_e1 = (0.5f64).ln() + log_erfc((z0 - j) / sqrt2sigma);

        let log_s0 = log_t0 + (i as f64 * i as f64 - i as f64) / s2 + log_e0;
        let log_s1 = log_t1 + (j * j - j) / s2 + log_e1;

        if sign > 0.0 {
            log_a0 = log_add_exp(log_a0, log_s0);
            log_a1 = log_add_exp(log_a1, log_s1);
        } else {
            // The alternating tail terms are strictly smaller than the
            // accumulated sums (A(α) > 0), so subtraction is safe.
            log_a0 = log_sub_exp(log_a0, log_s0);
            log_a1 = log_sub_exp(log_a1, log_s1);
        }

        i += 1;
        if log_s0.max(log_s1) < log_a0.max(log_a1) - 40.0 || i > 10_000 {
            break;
        }
    }
    log_add_exp(log_a0, log_a1)
}

/// RDP `ρ(α)` of one SGM step with sampling rate `q` and noise
/// multiplier `σ`.
///
/// Edge cases follow Opacus: `q = 0` is free (no data touched); `q = 1`
/// is the plain Gaussian mechanism with `ρ(α) = α/(2σ²)`.
pub fn rdp_sgm_step(q: f64, sigma: f64, alpha: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "sampling rate q={q}");
    assert!(sigma > 0.0, "sigma={sigma}");
    assert!(alpha > 1.0, "alpha={alpha}");
    if q == 0.0 {
        return 0.0;
    }
    if q == 1.0 {
        return alpha / (2.0 * sigma * sigma);
    }
    let log_a = if alpha.fract() == 0.0 {
        compute_log_a_int(q, sigma, alpha as u64)
    } else {
        compute_log_a_frac(q, sigma, alpha)
    };
    log_a / (alpha - 1.0)
}

/// RDP vector over a grid of α values for `steps` identical SGM steps
/// (RDP composes additively).
pub fn rdp_sgm(q: f64, sigma: f64, steps: u64, alphas: &[f64]) -> Vec<f64> {
    alphas
        .iter()
        .map(|&a| steps as f64 * rdp_sgm_step(q, sigma, a))
        .collect()
}

/// Convert an RDP curve to `(ε, δ)`-DP using the improved conversion
/// (Balle et al. 2020, as implemented by Opacus):
///
/// `ε = min_α [ ρ(α) + log((α−1)/α) − (log δ + log α)/(α−1) ]`
///
/// Returns `(ε, best_α)`.
pub fn rdp_to_epsilon(alphas: &[f64], rdp: &[f64], delta: f64) -> (f64, f64) {
    assert_eq!(alphas.len(), rdp.len());
    assert!(delta > 0.0 && delta < 1.0);
    let mut best = (f64::INFINITY, alphas[0]);
    for (&a, &r) in alphas.iter().zip(rdp) {
        if a <= 1.0 || !r.is_finite() {
            continue;
        }
        let eps = r + ((a - 1.0) / a).ln() - (delta.ln() + a.ln()) / (a - 1.0);
        if eps < best.0 {
            best = (eps, a);
        }
    }
    (best.0.max(0.0), best.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_batch_is_plain_gaussian() {
        // q = 1 → ρ(α) = α / (2σ²) exactly.
        for &(sigma, alpha) in &[(1.0, 2.0), (2.0, 8.0), (0.7, 3.5)] {
            let got = rdp_sgm_step(1.0, sigma, alpha);
            let want = alpha / (2.0 * sigma * sigma);
            assert!((got - want).abs() < 1e-12, "σ={sigma} α={alpha}");
        }
    }

    #[test]
    fn zero_rate_is_free() {
        assert_eq!(rdp_sgm_step(0.0, 1.0, 5.0), 0.0);
    }

    #[test]
    fn monotone_in_alpha_q_sigma() {
        // ρ is nondecreasing in α and q, nonincreasing in σ.
        let base = rdp_sgm_step(0.01, 1.0, 8.0);
        assert!(rdp_sgm_step(0.01, 1.0, 16.0) >= base);
        assert!(rdp_sgm_step(0.02, 1.0, 8.0) >= base);
        assert!(rdp_sgm_step(0.01, 2.0, 8.0) <= base);
    }

    #[test]
    fn int_frac_continuity() {
        // The fractional-α series must agree with the integer closed form
        // in the limit; test at α = k ± 1e-4.
        for &(q, sigma) in &[(0.01, 1.0), (0.1, 2.0), (0.004, 0.8)] {
            for &k in &[2u64, 3, 5, 10, 32] {
                let at_int = rdp_sgm_step(q, sigma, k as f64);
                let below = rdp_sgm_step(q, sigma, k as f64 - 1e-4);
                let above = rdp_sgm_step(q, sigma, k as f64 + 1e-4);
                let tol = 1e-3 * at_int.abs().max(1e-6);
                assert!(
                    (at_int - below).abs() < tol && (at_int - above).abs() < tol,
                    "q={q} σ={sigma} α={k}: int={at_int} below={below} above={above}"
                );
            }
        }
    }

    #[test]
    fn small_q_quadratic_regime() {
        // For small q and moderate α: ρ(α) ≈ 2 q² α / σ² (known small-q
        // behaviour, up to constants) — sanity check the order of magnitude.
        let q = 1e-3;
        let sigma = 1.0;
        let rho = rdp_sgm_step(q, sigma, 4.0);
        assert!(rho > 0.0 && rho < 1e-3, "rho={rho}");
    }

    #[test]
    fn composition_additive() {
        let alphas = default_alphas();
        let one = rdp_sgm(0.01, 1.1, 1, &alphas);
        let ten = rdp_sgm(0.01, 1.1, 10, &alphas);
        for (a, b) in one.iter().zip(&ten) {
            assert!((10.0 * a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn epsilon_decreases_with_sigma_increases_with_steps() {
        let alphas = default_alphas();
        let delta = 1e-5;
        let e1 = rdp_to_epsilon(&alphas, &rdp_sgm(0.01, 1.0, 1000, &alphas), delta).0;
        let e2 = rdp_to_epsilon(&alphas, &rdp_sgm(0.01, 2.0, 1000, &alphas), delta).0;
        let e3 = rdp_to_epsilon(&alphas, &rdp_sgm(0.01, 1.0, 4000, &alphas), delta).0;
        assert!(e2 < e1, "σ↑ ⇒ ε↓: {e1} vs {e2}");
        assert!(e3 > e1, "steps↑ ⇒ ε↑: {e1} vs {e3}");
        assert!(e1.is_finite() && e1 > 0.0);
    }

    #[test]
    fn plain_gaussian_epsilon_formula() {
        // For q=1, σ, one step: ε(δ) from RDP should be close to (and an
        // upper bound versa) the classical analytic Gaussian mechanism.
        // Check it's in a sane band for σ=5, δ=1e-5: classical ≈ 0.9-1.1.
        let alphas = default_alphas();
        let (eps, _) = rdp_to_epsilon(&alphas, &rdp_sgm(1.0, 5.0, 1, &alphas), 1e-5);
        assert!(eps > 0.5 && eps < 2.0, "eps={eps}");
    }

    #[test]
    fn known_dpsgd_config_band() {
        // A canonical config from the DP-SGD literature: q=256/60000,
        // σ=1.1, T=60 epochs ≈ 14062 steps, δ=1e-5 → ε ≈ 3 (Opacus
        // tutorial ballpark). Accept a generous band; the oracle test in
        // python/tests pins this tighter.
        let q = 256.0 / 60_000.0;
        let steps = (60.0 * 60_000.0 / 256.0) as u64;
        let alphas = default_alphas();
        let (eps, _) = rdp_to_epsilon(&alphas, &rdp_sgm(q, 1.1, steps, &alphas), 1e-5);
        assert!(eps > 2.0 && eps < 4.5, "eps={eps}");
    }
}
