//! Multi-mechanism privacy accountant.
//!
//! DPQuant spends privacy budget on two kinds of Sampled Gaussian
//! Mechanism steps (paper §5.4, Prop. 2, §A.14):
//!
//! * **training** steps: rate `q = B/|D|`, noise multiplier `σ_train`,
//!   one per DP-SGD iteration;
//! * **analysis** steps: rate `q = |B_meas|/|D|`, noise `σ_measure`, one
//!   per invocation of Algorithm 1 (COMPUTELOSSIMPACT).
//!
//! RDP composes additively over a shared α-grid, giving the "much tighter
//! upper bound on the total privacy expenditure" the paper gets from
//! advanced composition via Opacus. The accountant tracks each mechanism
//! separately so Figure 3 ("fraction of privacy spent on analysis") can be
//! regenerated exactly.

use super::rdp::{default_alphas, rdp_sgm_step, rdp_to_epsilon};

/// Which subsystem consumed the step (used for the Fig-3 breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Mechanism {
    /// DP-SGD / DP-Adam training iterations.
    Training,
    /// Loss-impact analysis (Algorithm 1).
    Analysis,
}

/// A homogeneous block of SGM steps.
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Training step or analysis probe.
    pub mechanism: Mechanism,
    /// Poisson sampling rate q.
    pub sample_rate: f64,
    /// Noise multiplier σ.
    pub noise_multiplier: f64,
    /// How many identical steps this block covers.
    pub steps: u64,
}

/// RDP accountant over the default α grid.
///
/// `step()` is O(1) amortized: identical consecutive configurations are
/// coalesced, and per-(q, σ) RDP curves are cached.
#[derive(Clone, Debug)]
pub struct RdpAccountant {
    alphas: Vec<f64>,
    history: Vec<StepRecord>,
    /// Cached per-step RDP curve keyed by (q, σ) bits.
    cache: std::collections::HashMap<(u64, u64), Vec<f64>>,
}

impl Default for RdpAccountant {
    fn default() -> Self {
        Self::new()
    }
}

impl RdpAccountant {
    /// An empty accountant over the default α grid.
    pub fn new() -> Self {
        Self {
            alphas: default_alphas(),
            history: Vec::new(),
            cache: std::collections::HashMap::new(),
        }
    }

    /// An accountant pre-loaded with `records`, replayed through
    /// [`RdpAccountant::record`] in order — so skip-zero and coalescing
    /// semantics (and therefore the float-sum order of every later
    /// `epsilon()` call) match a live accountant that recorded the same
    /// blocks. This is the one way to re-instantiate an accountant from
    /// persisted history: checkpoint resume, ledger spend replay, and
    /// `dpquant audit replay` all ride on it.
    pub fn from_records<'a, I>(records: I) -> Self
    where
        I: IntoIterator<Item = &'a StepRecord>,
    {
        let mut acc = Self::new();
        for r in records {
            acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
        }
        acc
    }

    /// Record `steps` SGM steps for `mechanism`.
    pub fn record(
        &mut self,
        mechanism: Mechanism,
        sample_rate: f64,
        noise_multiplier: f64,
        steps: u64,
    ) {
        if steps == 0 || sample_rate == 0.0 {
            return;
        }
        if let Some(last) = self.history.last_mut() {
            if last.mechanism == mechanism
                && last.sample_rate == sample_rate
                && last.noise_multiplier == noise_multiplier
            {
                last.steps += steps;
                return;
            }
        }
        self.history.push(StepRecord {
            mechanism,
            sample_rate,
            noise_multiplier,
            steps,
        });
    }

    /// Convenience: one training step (call per DP-SGD iteration or batch
    /// thereof).
    pub fn step_training(&mut self, sample_rate: f64, noise_multiplier: f64, steps: u64) {
        self.record(Mechanism::Training, sample_rate, noise_multiplier, steps);
    }

    /// Convenience: one analysis invocation (Algorithm 1 line
    /// `UPDATEPRIVACY(rate=|B|/|D|, steps=1, noise_scale=σ)`).
    pub fn step_analysis(&mut self, sample_rate: f64, noise_multiplier: f64) {
        self.record(Mechanism::Analysis, sample_rate, noise_multiplier, 1);
    }

    fn per_step_curve(&mut self, q: f64, sigma: f64) -> Vec<f64> {
        let key = (q.to_bits(), sigma.to_bits());
        if let Some(c) = self.cache.get(&key) {
            return c.clone();
        }
        let curve: Vec<f64> = self
            .alphas
            .iter()
            .map(|&a| rdp_sgm_step(q, sigma, a))
            .collect();
        self.cache.insert(key, curve.clone());
        curve
    }

    /// Total RDP curve, optionally filtered to one mechanism.
    pub fn rdp_curve(&mut self, only: Option<Mechanism>) -> Vec<f64> {
        let mut total = vec![0.0; self.alphas.len()];
        let history = self.history.clone();
        for rec in &history {
            if let Some(m) = only {
                if rec.mechanism != m {
                    continue;
                }
            }
            let curve = self.per_step_curve(rec.sample_rate, rec.noise_multiplier);
            for (t, c) in total.iter_mut().zip(&curve) {
                *t += rec.steps as f64 * c;
            }
        }
        total
    }

    /// `(ε, best α)` for the composed mechanisms at the given `δ`.
    pub fn epsilon(&mut self, delta: f64) -> (f64, f64) {
        let curve = self.rdp_curve(None);
        rdp_to_epsilon(&self.alphas, &curve, delta)
    }

    /// ε attributable to one mechanism alone (if it ran by itself).
    pub fn epsilon_of(&mut self, mechanism: Mechanism, delta: f64) -> (f64, f64) {
        let curve = self.rdp_curve(Some(mechanism));
        if curve.iter().all(|&r| r == 0.0) {
            return (0.0, f64::NAN);
        }
        rdp_to_epsilon(&self.alphas, &curve, delta)
    }

    /// Figure-3b style breakdown: fraction of the composed ε that the
    /// analysis adds on top of training-only ε.
    pub fn analysis_fraction(&mut self, delta: f64) -> f64 {
        let total = self.epsilon(delta).0;
        if total == 0.0 {
            return 0.0;
        }
        let train_only = {
            let curve = self.rdp_curve(Some(Mechanism::Training));
            if curve.iter().all(|&r| r == 0.0) {
                0.0
            } else {
                rdp_to_epsilon(&self.alphas, &curve, delta).0
            }
        };
        ((total - train_only) / total).max(0.0)
    }

    /// Const-input cost estimator: the `(ε, best α)` a fresh accountant
    /// would report after composing `train_steps` training SGM steps at
    /// `(sample_rate, noise_multiplier)` with `analysis_steps` analysis
    /// SGM steps at `(analysis_rate, analysis_sigma)`, converted at
    /// `delta` — the same math [`RdpAccountant::epsilon`] composes on a
    /// live run. Builds a scratch accountant internally, so callers
    /// (the serve ledger's admission check, `dpquant cost`) can quote a
    /// job's cost without mutating — or even owning — a live one. The
    /// analysis block carries its own rate and σ because the live path
    /// probes at `analysis_samples/|D|` with `σ_measure`, not the
    /// training rate/σ (paper Fig. 3).
    ///
    /// Note on bit-level agreement: a live run *interleaves* training
    /// and analysis records, while `predict` composes two homogeneous
    /// blocks. RDP addition is exact per record, so the predicted ε is
    /// the correct composed value for those step counts, but it is an
    /// *estimate* of a live run (which may also skip empty Poisson
    /// probes); reconciliation against actual spend uses the run's real
    /// history, not this function.
    pub fn predict(
        sample_rate: f64,
        noise_multiplier: f64,
        train_steps: u64,
        analysis_rate: f64,
        analysis_sigma: f64,
        analysis_steps: u64,
        delta: f64,
    ) -> (f64, f64) {
        Self::predict_schedule(
            &[
                StepRecord {
                    mechanism: Mechanism::Training,
                    sample_rate,
                    noise_multiplier,
                    steps: train_steps,
                },
                StepRecord {
                    mechanism: Mechanism::Analysis,
                    sample_rate: analysis_rate,
                    noise_multiplier: analysis_sigma,
                    steps: analysis_steps,
                },
            ],
            delta,
        )
    }

    /// Heterogeneous-schedule cost estimator: the `(ε, best α)` a fresh
    /// accountant would report after replaying `schedule` through
    /// [`RdpAccountant::record`] in order. This is the generalization of
    /// [`RdpAccountant::predict`] that adaptive policies need — a
    /// noise-decay or rate-schedule job is a *sequence* of `(q_t, σ_t)`
    /// blocks, not a single triple, and its composed ε must be quoted
    /// block-by-block for the serve ledger to admit it correctly.
    ///
    /// Because the replay goes through `record()`, zero-step and
    /// zero-rate blocks are skipped and adjacent identical blocks
    /// coalesce exactly as on a live run, so a prediction over the same
    /// per-step schedule a live session records matches that session's
    /// composed ε bit-for-bit (RDP addition is per-record exact and the
    /// summation order is the schedule order).
    pub fn predict_schedule(schedule: &[StepRecord], delta: f64) -> (f64, f64) {
        let mut scratch = Self::new();
        for rec in schedule {
            scratch.record(rec.mechanism, rec.sample_rate, rec.noise_multiplier, rec.steps);
        }
        scratch.epsilon(delta)
    }

    /// Total recorded steps per mechanism.
    pub fn steps_of(&self, mechanism: Mechanism) -> u64 {
        self.history
            .iter()
            .filter(|r| r.mechanism == mechanism)
            .map(|r| r.steps)
            .sum()
    }

    /// The Rényi orders the accountant tracks.
    pub fn alphas(&self) -> &[f64] {
        &self.alphas
    }

    /// The coalesced step history, oldest first.
    pub fn history(&self) -> &[StepRecord] {
        &self.history
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_accountant_zero_epsilon() {
        let mut acc = RdpAccountant::new();
        // No steps: rdp curve all-zero; ε should be ~0 (clamped).
        let (eps, _) = acc.epsilon(1e-5);
        assert!(eps >= 0.0 && eps < 1e-9 + 12.0); // conversion of zero-rdp can still pay log terms
        assert_eq!(acc.steps_of(Mechanism::Training), 0);
    }

    #[test]
    fn coalesces_identical_steps() {
        let mut acc = RdpAccountant::new();
        for _ in 0..100 {
            acc.step_training(0.01, 1.0, 1);
        }
        assert_eq!(acc.history().len(), 1);
        assert_eq!(acc.steps_of(Mechanism::Training), 100);
    }

    #[test]
    fn analysis_adds_little_when_noisy_or_rare() {
        // Paper Fig. 3: analysis cost is a small fraction of training cost.
        let mut acc = RdpAccountant::new();
        let q_train = 1024.0 / 26_640.0; // GTSRB-ish
        acc.step_training(q_train, 1.0, 1560); // 60 epochs × 26 steps
        let eps_train_only = acc.epsilon(1e-5).0;
        // Analysis every 2 epochs: 30 invocations, σ_measure = 0.5 but tiny
        // sample rate (1 batch of the dataset).
        for _ in 0..30 {
            acc.step_analysis(1024.0 / 26_640.0, 0.5);
        }
        let eps_total = acc.epsilon(1e-5).0;
        assert!(eps_total > eps_train_only);
        let frac = acc.analysis_fraction(1e-5);
        assert!(frac > 0.0 && frac < 0.35, "analysis fraction = {frac}");
    }

    #[test]
    fn epsilon_monotone_in_recorded_steps() {
        let mut acc = RdpAccountant::new();
        let mut prev = 0.0;
        for _ in 0..5 {
            acc.step_training(0.02, 1.1, 200);
            let (eps, _) = acc.epsilon(1e-5);
            assert!(eps >= prev, "ε must grow with steps");
            prev = eps;
        }
    }

    #[test]
    fn mechanism_split_consistent() {
        let mut acc = RdpAccountant::new();
        acc.step_training(0.01, 1.0, 500);
        acc.step_analysis(0.01, 0.5);
        let (et, _) = acc.epsilon_of(Mechanism::Training, 1e-5);
        let (ea, _) = acc.epsilon_of(Mechanism::Analysis, 1e-5);
        let (etot, _) = acc.epsilon(1e-5);
        // Composition: total ≤ sum of parts (RDP adds, conversion is
        // subadditive-ish) and ≥ each part.
        assert!(etot >= et.max(ea));
        assert!(etot <= et + ea + 1e-9);
    }

    #[test]
    fn from_records_matches_a_live_accountant_bitwise() {
        let mut live = RdpAccountant::new();
        live.step_training(0.02, 0.8, 100);
        live.step_analysis(0.004, 0.5);
        live.step_training(0.02, 0.8, 50);
        let rebuilt = RdpAccountant::from_records(live.history());
        assert_eq!(rebuilt.history().len(), live.history().len());
        let mut live = live;
        let mut rebuilt = rebuilt;
        let (el, al) = live.epsilon(1e-5);
        let (er, ar) = rebuilt.epsilon(1e-5);
        assert_eq!(el.to_bits(), er.to_bits());
        assert_eq!(al.to_bits(), ar.to_bits());
    }

    #[test]
    fn predict_matches_a_live_block_composition_bitwise() {
        // predict() is defined as "what a fresh accountant would say
        // after recording the same two blocks" — hold it to that
        // bit-for-bit, since the serve ledger's admission math and
        // `GET /v1/tenants/{id}` both ride on it.
        let (eps, alpha) = RdpAccountant::predict(0.02, 1.1, 300, 0.004, 0.5, 6, 1e-5);
        let mut acc = RdpAccountant::new();
        acc.step_training(0.02, 1.1, 300);
        for _ in 0..6 {
            acc.step_analysis(0.004, 0.5);
        }
        let (eps_live, alpha_live) = acc.epsilon(1e-5);
        assert_eq!(eps.to_bits(), eps_live.to_bits());
        assert_eq!(alpha.to_bits(), alpha_live.to_bits());
    }

    #[test]
    fn predict_handles_degenerate_blocks() {
        // Zero analysis steps: pure training cost, identical to a
        // training-only accountant.
        let (eps, _) = RdpAccountant::predict(0.02, 1.0, 500, 0.01, 0.5, 0, 1e-5);
        let mut acc = RdpAccountant::new();
        acc.step_training(0.02, 1.0, 500);
        assert_eq!(eps.to_bits(), acc.epsilon(1e-5).0.to_bits());
        // More steps cost more ε (monotone in both blocks).
        let (more, _) = RdpAccountant::predict(0.02, 1.0, 1000, 0.01, 0.5, 0, 1e-5);
        assert!(more > eps);
        let (with_analysis, _) = RdpAccountant::predict(0.02, 1.0, 500, 0.01, 0.5, 10, 1e-5);
        assert!(with_analysis > eps);
    }

    #[test]
    fn predict_schedule_replays_like_a_live_run() {
        // A heterogeneous (σ_t, q_t) schedule must compose bit-for-bit
        // like the same blocks recorded on a live accountant, including
        // the skip-zero and coalescing semantics of `record()`.
        let schedule = vec![
            StepRecord {
                mechanism: Mechanism::Training,
                sample_rate: 0.02,
                noise_multiplier: 0.8,
                steps: 100,
            },
            StepRecord {
                mechanism: Mechanism::Training,
                sample_rate: 0.0, // skipped: empty Poisson epoch
                noise_multiplier: 1.0,
                steps: 50,
            },
            StepRecord {
                mechanism: Mechanism::Training,
                sample_rate: 0.02,
                noise_multiplier: 0.8, // coalesces with block 0
                steps: 25,
            },
            StepRecord {
                mechanism: Mechanism::Training,
                sample_rate: 0.01,
                noise_multiplier: 1.2,
                steps: 100,
            },
            StepRecord {
                mechanism: Mechanism::Analysis,
                sample_rate: 0.004,
                noise_multiplier: 0.5,
                steps: 3,
            },
        ];
        let (eps, alpha) = RdpAccountant::predict_schedule(&schedule, 1e-5);
        let mut acc = RdpAccountant::new();
        for r in &schedule {
            acc.record(r.mechanism, r.sample_rate, r.noise_multiplier, r.steps);
        }
        assert_eq!(acc.history().len(), 3, "skip + coalesce must apply");
        let (eps_live, alpha_live) = acc.epsilon(1e-5);
        assert_eq!(eps.to_bits(), eps_live.to_bits());
        assert_eq!(alpha.to_bits(), alpha_live.to_bits());
        // And the homogeneous special case still routes through the same
        // path as the legacy 7-arg signature.
        let (e7, a7) = RdpAccountant::predict(0.02, 0.8, 100, 0.004, 0.5, 3, 1e-5);
        let (es, as_) = RdpAccountant::predict_schedule(
            &[
                StepRecord {
                    mechanism: Mechanism::Training,
                    sample_rate: 0.02,
                    noise_multiplier: 0.8,
                    steps: 100,
                },
                StepRecord {
                    mechanism: Mechanism::Analysis,
                    sample_rate: 0.004,
                    noise_multiplier: 0.5,
                    steps: 3,
                },
            ],
            1e-5,
        );
        assert_eq!(e7.to_bits(), es.to_bits());
        assert_eq!(a7.to_bits(), as_.to_bits());
    }

    #[test]
    fn truncation_search_inverse() {
        // Find steps that hit ε ≈ 4 then verify ε(steps) is ~4 — models the
        // paper's "truncate training at the privacy budget".
        let mut lo = 1u64;
        let mut hi = 200_000u64;
        let q = 0.02;
        let target = 4.0;
        while lo < hi {
            let mid = (lo + hi).div_ceil(2);
            let mut acc = RdpAccountant::new();
            acc.step_training(q, 1.0, mid);
            if acc.epsilon(1e-5).0 <= target {
                lo = mid;
                if lo == hi {
                    break;
                }
            } else {
                hi = mid - 1;
            }
            if hi - lo <= 1 {
                break;
            }
        }
        let mut acc = RdpAccountant::new();
        acc.step_training(q, 1.0, lo);
        let eps = acc.epsilon(1e-5).0;
        assert!((eps - target).abs() < 0.1, "eps={eps} steps={lo}");
    }
}
