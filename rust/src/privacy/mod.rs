//! Differential-privacy accounting for DPQuant.
//!
//! Both DP-SGD training and the loss-impact analysis (Algorithm 1) are
//! Sampled Gaussian Mechanisms (paper Prop. 2); [`rdp`] implements the
//! per-step Rényi-DP analysis and [`accountant`] composes the two
//! mechanisms over a shared α-grid, exactly as the paper does through
//! Opacus (§5.4, §A.14).

pub mod accountant;
pub mod rdp;

pub use accountant::{Mechanism, RdpAccountant, StepRecord};
pub use rdp::{default_alphas, rdp_sgm, rdp_sgm_step, rdp_to_epsilon};
