//! Grid specifications: the cartesian product of per-key value lists,
//! expanded into concrete validated [`TrainConfig`]s.
//!
//! Two front-ends feed the same [`GridSpec`]:
//!
//! * CLI: `--grid "quantizer=fp8,luq4;quant_fraction=0.25,0.5;seed=0..2"`
//!   — axes in spec order, `;`-separated, values `,`-separated, with
//!   `lo..hi` an **inclusive** integer range;
//! * config: a `[sweep]` section whose entries become axes (arrays are
//!   multi-value axes, scalars single-value pins). Section keys iterate
//!   alphabetically, so the axis order from a file is the sorted key
//!   order — deterministic either way.
//!
//! Expansion is row-major with the **last axis fastest** (an odometer),
//! so the grid index of every point is a pure function of the spec —
//! the anchor for the sweep's "`--jobs N` ≡ `--jobs 1`" determinism
//! contract.

use crate::cli::nearest;
use crate::config::{ConfigFile, OptimizerKind, TrainConfig, Value};
use crate::coordinator::session::validate_config;
use crate::util::error::{ensure, err, Context, Result};

/// Hard cap on expanded grid size: a typo like `seed=0..999999` should
/// fail fast, not enqueue a year of work.
pub const MAX_GRID_POINTS: usize = 10_000;

/// One sweep dimension: a config key and the values it takes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Axis {
    /// Normalized key (hyphens folded to underscores).
    pub key: String,
    /// Values this axis takes, in declaration order.
    pub values: Vec<String>,
}

/// An ordered list of axes; expansion is their cartesian product.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GridSpec {
    /// Axes in declaration order (outermost varies slowest).
    pub axes: Vec<Axis>,
}

/// One expanded grid point: its flat index, the `key=value` assignments
/// that produced it (in axis order), and the resulting config.
#[derive(Clone, Debug)]
pub struct GridPoint {
    /// Flat index in expansion order.
    pub index: usize,
    /// The `key=value` assignments that produced this point.
    pub params: Vec<(String, String)>,
    /// The fully-resolved config for this point.
    pub cfg: TrainConfig,
}

impl GridPoint {
    /// Human-readable `key=value key=value` label for logs and errors.
    pub fn label(&self) -> String {
        self.params
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Every key a sweep axis may vary, i.e. the `TrainConfig` fields.
/// (`epochs`-style counts, DP knobs, substrate selectors, the seed.)
pub const SWEEP_KEYS: &[&str] = &[
    "model",
    "dataset",
    "quantizer",
    "scheduler",
    "optimizer",
    "backend",
    "epochs",
    "batch_size",
    "noise_multiplier",
    "clip_norm",
    "lr",
    "quant_fraction",
    "beta",
    "analysis_interval",
    "analysis_reps",
    "analysis_samples",
    "sigma_measure",
    "clip_measure",
    "ema_alpha",
    "ema_enabled",
    "dataset_size",
    "val_size",
    "seed",
    "target_epsilon",
    "delta",
    "physical_batch",
    "policy",
    "noise_final",
    "clip_final",
    "rate_final",
    "decay_shape",
    "layer_lr_strength",
];

impl GridSpec {
    /// Parse the CLI grid string (see module docs for the grammar).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut out = GridSpec::default();
        for part in spec.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, vals) = part
                .split_once('=')
                .ok_or_else(|| err!("grid axis '{part}': expected key=value[,value...]"))?;
            let key = normalize_key(key.trim());
            check_key(&key)?;
            let mut values = Vec::new();
            for v in vals.split(',') {
                let v = v.trim();
                ensure!(!v.is_empty(), "grid axis '{key}': empty value");
                values.extend(expand_range(v)?);
            }
            ensure!(!values.is_empty(), "grid axis '{key}': no values");
            out.push_axis(Axis { key, values })?;
        }
        Ok(out)
    }

    /// Build from a config file's `[sweep]` section (arrays become
    /// multi-value axes, scalars single-value pins). Empty if the file
    /// has no such section.
    pub fn from_config(cf: &ConfigFile) -> Result<Self> {
        let mut out = GridSpec::default();
        for ((section, key), value) in &cf.entries {
            if section != "sweep" {
                continue;
            }
            let key = normalize_key(key);
            check_key(&key).with_context(|| format!("config section [sweep], key '{key}'"))?;
            let values = match value {
                Value::Array(items) => {
                    ensure!(!items.is_empty(), "[sweep] {key}: empty value array");
                    items.iter().map(scalar_to_string).collect::<Result<Vec<_>>>()?
                }
                v => vec![scalar_to_string(v)?],
            };
            out.push_axis(Axis { key, values })?;
        }
        Ok(out)
    }

    fn push_axis(&mut self, axis: Axis) -> Result<()> {
        ensure!(
            !self.axes.iter().any(|a| a.key == axis.key),
            "grid axis '{}' is given twice",
            axis.key
        );
        self.axes.push(axis);
        Ok(())
    }

    /// Overlay `other`'s axes on top of these: a same-key axis from
    /// `other` replaces ours (CLI `--grid` wins over the `[sweep]`
    /// section), new keys append in `other`'s order.
    pub fn merge(&mut self, other: GridSpec) {
        for axis in other.axes {
            match self.axes.iter_mut().find(|a| a.key == axis.key) {
                Some(existing) => *existing = axis,
                None => self.axes.push(axis),
            }
        }
    }

    /// Number of points the expansion will produce, saturating at
    /// `usize::MAX` — a wrapped product must trip the cap in
    /// [`GridSpec::points`], not slip under it.
    pub fn len(&self) -> usize {
        if self.axes.is_empty() {
            0
        } else {
            self.axes
                .iter()
                .try_fold(1usize, |acc, a| acc.checked_mul(a.values.len()))
                .unwrap_or(usize::MAX)
        }
    }

    /// Does the spec have no axes at all?
    pub fn is_empty(&self) -> bool {
        self.axes.is_empty()
    }

    /// Expand into concrete grid points over `base`, validating every
    /// resulting config (so a hostile cell fails here, before any run
    /// spends wall-clock or privacy budget).
    pub fn points(&self, base: &TrainConfig) -> Result<Vec<GridPoint>> {
        ensure!(
            !self.axes.is_empty(),
            "empty sweep grid: pass --grid \"key=v1,v2;...\" or a [sweep] config section"
        );
        let total = self.len();
        ensure!(
            total <= MAX_GRID_POINTS,
            "sweep grid has {total} points, more than the {MAX_GRID_POINTS} cap"
        );
        let mut points = Vec::with_capacity(total);
        // Odometer over axis value indices, last axis fastest.
        let mut digits = vec![0usize; self.axes.len()];
        for index in 0..total {
            let mut cfg = base.clone();
            let mut params = Vec::with_capacity(self.axes.len());
            for (axis, &d) in self.axes.iter().zip(&digits) {
                let value = &axis.values[d];
                apply_key(&mut cfg, &axis.key, value)
                    .with_context(|| format!("grid point #{index}"))?;
                params.push((axis.key.clone(), value.clone()));
            }
            // Same validation the session builder performs, against the
            // training-set size this config will generate.
            validate_config(&cfg, cfg.dataset_size).with_context(|| {
                format!(
                    "grid point #{index} ({}) is invalid",
                    params
                        .iter()
                        .map(|(k, v)| format!("{k}={v}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                )
            })?;
            points.push(GridPoint { index, params, cfg });
            for d in (0..digits.len()).rev() {
                digits[d] += 1;
                if digits[d] < self.axes[d].values.len() {
                    break;
                }
                digits[d] = 0;
            }
        }
        Ok(points)
    }
}

fn normalize_key(key: &str) -> String {
    key.replace('-', "_")
}

fn check_key(key: &str) -> Result<()> {
    if SWEEP_KEYS.contains(&key) {
        return Ok(());
    }
    let mut msg = format!("unknown sweep key '{key}'");
    if let Some(near) = nearest(key, SWEEP_KEYS.iter().copied()) {
        msg.push_str(&format!(" (did you mean '{near}'?)"));
    } else {
        msg.push_str(&format!(" (valid keys: {})", SWEEP_KEYS.join(", ")));
    }
    Err(err!("{msg}"))
}

/// `lo..hi` expands to the inclusive integer range; anything else is a
/// single literal value.
fn expand_range(v: &str) -> Result<Vec<String>> {
    if let Some((lo, hi)) = v.split_once("..") {
        if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<i64>(), hi.trim().parse::<i64>()) {
            ensure!(lo <= hi, "range '{v}': start exceeds end");
            // checked_sub: hi - lo can overflow i64 for hostile ranges,
            // which must hit the cap error, not wrap past it.
            let width_ok = hi
                .checked_sub(lo)
                .is_some_and(|w| w < MAX_GRID_POINTS as i64);
            ensure!(
                width_ok,
                "range '{v}' expands to more than {MAX_GRID_POINTS} values"
            );
            return Ok((lo..=hi).map(|x| x.to_string()).collect());
        }
        return Err(err!("range '{v}': both ends must be integers (inclusive lo..hi)"));
    }
    Ok(vec![v.to_string()])
}

fn scalar_to_string(v: &Value) -> Result<String> {
    Ok(match v {
        Value::Str(s) => s.clone(),
        Value::Bool(b) => b.to_string(),
        Value::Int(i) => i.to_string(),
        // f64 Display is shortest-roundtrip, so parsing it back in
        // `apply_key` recovers the identical double.
        Value::Float(f) => format!("{f}"),
        Value::Array(_) => return Err(err!("[sweep] arrays cannot nest")),
    })
}

/// Set one config field from its string form. Key set mirrors the
/// `[train]` section / CLI flags (hyphens already normalized away).
pub fn apply_key(cfg: &mut TrainConfig, key: &str, value: &str) -> Result<()> {
    fn num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        value.parse().map_err(|e| err!("sweep key {key}='{value}': {e}"))
    }
    match key {
        "model" => cfg.model = value.to_string(),
        "dataset" => cfg.dataset = value.to_string(),
        "quantizer" => cfg.quantizer = value.to_string(),
        "scheduler" => cfg.scheduler = value.to_string(),
        "backend" => cfg.backend = value.to_string(),
        "optimizer" => cfg.optimizer = OptimizerKind::parse(value)?,
        "epochs" => cfg.epochs = num(key, value)?,
        "batch_size" => cfg.batch_size = num(key, value)?,
        "noise_multiplier" => cfg.noise_multiplier = num(key, value)?,
        "clip_norm" => cfg.clip_norm = num(key, value)?,
        "lr" => cfg.lr = num(key, value)?,
        "quant_fraction" => cfg.quant_fraction = num(key, value)?,
        "beta" => cfg.beta = num(key, value)?,
        "analysis_interval" => cfg.analysis_interval = num(key, value)?,
        "analysis_reps" => cfg.analysis_reps = num(key, value)?,
        "analysis_samples" => cfg.analysis_samples = num(key, value)?,
        "sigma_measure" => cfg.sigma_measure = num(key, value)?,
        "clip_measure" => cfg.clip_measure = num(key, value)?,
        "ema_alpha" => cfg.ema_alpha = num(key, value)?,
        "ema_enabled" => cfg.ema_enabled = num(key, value)?,
        "dataset_size" => cfg.dataset_size = num(key, value)?,
        "val_size" => cfg.val_size = num(key, value)?,
        "seed" => cfg.seed = num(key, value)?,
        "delta" => cfg.delta = num(key, value)?,
        "physical_batch" => cfg.physical_batch = num(key, value)?,
        "policy" => cfg.policy = value.to_string(),
        "noise_final" => cfg.noise_final = num(key, value)?,
        "clip_final" => cfg.clip_final = num(key, value)?,
        "rate_final" => cfg.rate_final = num(key, value)?,
        "decay_shape" => cfg.decay_shape = value.to_string(),
        "layer_lr_strength" => cfg.layer_lr_strength = num(key, value)?,
        "target_epsilon" => {
            cfg.target_epsilon = if value == "none" { None } else { Some(num(key, value)?) }
        }
        other => return Err(err!("unknown sweep key '{other}'")),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_axes_values_and_ranges() {
        let g = GridSpec::parse("quantizer=fp8,luq4;quant_fraction=0.25,0.5,0.75;seed=0..4")
            .unwrap();
        assert_eq!(g.axes.len(), 3);
        assert_eq!(g.axes[0].key, "quantizer");
        assert_eq!(g.axes[0].values, vec!["fp8", "luq4"]);
        assert_eq!(g.axes[2].values, vec!["0", "1", "2", "3", "4"]);
        assert_eq!(g.len(), 2 * 3 * 5);
    }

    #[test]
    fn hyphenated_keys_normalize() {
        let g = GridSpec::parse("quant-fraction=0.5;noise-multiplier=1.0,2.0").unwrap();
        assert_eq!(g.axes[0].key, "quant_fraction");
        assert_eq!(g.axes[1].key, "noise_multiplier");
    }

    #[test]
    fn policy_axis_parses_and_applies() {
        let g = GridSpec::parse("policy=static,noise_decay,rate_schedule,layer_lr").unwrap();
        assert_eq!(g.axes[0].key, "policy");
        assert_eq!(g.axes[0].values.len(), 4);
        let mut cfg = TrainConfig::default();
        apply_key(&mut cfg, "policy", "noise_decay").unwrap();
        apply_key(&mut cfg, "noise_final", "1.5").unwrap();
        apply_key(&mut cfg, "clip_final", "0.25").unwrap();
        apply_key(&mut cfg, "rate_final", "0.01").unwrap();
        apply_key(&mut cfg, "decay_shape", "exp").unwrap();
        apply_key(&mut cfg, "layer_lr_strength", "0.75").unwrap();
        assert_eq!(cfg.policy, "noise_decay");
        assert_eq!(cfg.noise_final, 1.5);
        assert_eq!(cfg.clip_final, 0.25);
        assert_eq!(cfg.rate_final, 0.01);
        assert_eq!(cfg.decay_shape, "exp");
        assert_eq!(cfg.layer_lr_strength, 0.75);
    }

    #[test]
    fn unknown_key_suggests_nearest() {
        let e = GridSpec::parse("quant_fracton=0.5").unwrap_err().to_string();
        assert!(e.contains("unknown sweep key"), "{e}");
        assert!(e.contains("quant_fraction"), "{e}");
    }

    #[test]
    fn duplicate_axis_rejected() {
        let e = GridSpec::parse("seed=0,1;seed=2").unwrap_err().to_string();
        assert!(e.contains("twice"), "{e}");
    }

    #[test]
    fn malformed_specs_rejected() {
        assert!(GridSpec::parse("seed").is_err());
        assert!(GridSpec::parse("seed=").is_err());
        assert!(GridSpec::parse("seed=4..1").is_err());
        assert!(GridSpec::parse("seed=a..b").is_err());
    }

    #[test]
    fn expansion_is_odometer_last_axis_fastest() {
        let g = GridSpec::parse("quantizer=fp8,luq4;seed=0..1").unwrap();
        let pts = g.points(&TrainConfig::default()).unwrap();
        let labels: Vec<String> = pts.iter().map(GridPoint::label).collect();
        assert_eq!(
            labels,
            vec![
                "quantizer=fp8 seed=0",
                "quantizer=fp8 seed=1",
                "quantizer=luq4 seed=0",
                "quantizer=luq4 seed=1",
            ]
        );
        assert_eq!(pts[2].cfg.quantizer, "luq4");
        assert_eq!(pts[2].cfg.seed, 0);
        assert_eq!(pts[3].index, 3);
    }

    #[test]
    fn invalid_cell_fails_at_expansion_with_the_point_named() {
        let g = GridSpec::parse("quant_fraction=0.5,1.5").unwrap();
        let e = g.points(&TrainConfig::default()).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("grid point #1"), "{msg}");
        assert!(msg.contains("quant_fraction=1.5"), "{msg}");
    }

    #[test]
    fn from_config_sweep_section() {
        let cf = ConfigFile::parse(
            "[train]\nepochs = 3\n[sweep]\nquantizer = [\"luq4\", \"fp8\"]\nseed = [0, 1, 2]\nlr = 0.25\n",
        )
        .unwrap();
        let g = GridSpec::from_config(&cf).unwrap();
        // BTreeMap order: lr, quantizer, seed.
        assert_eq!(g.axes[0].key, "lr");
        assert_eq!(g.axes[0].values, vec!["0.25"]);
        assert_eq!(g.axes[1].values, vec!["luq4", "fp8"]);
        assert_eq!(g.len(), 6);
    }

    #[test]
    fn merge_cli_overrides_config() {
        let mut base = GridSpec::parse("seed=0..2;lr=0.1").unwrap();
        base.merge(GridSpec::parse("lr=0.5,0.9;beta=1.0").unwrap());
        assert_eq!(base.axes.len(), 3);
        assert_eq!(base.axes[1].key, "lr");
        assert_eq!(base.axes[1].values, vec!["0.5", "0.9"]);
        assert_eq!(base.axes[2].key, "beta");
    }

    #[test]
    fn target_epsilon_none_and_values() {
        let mut cfg = TrainConfig::default();
        apply_key(&mut cfg, "target_epsilon", "4.5").unwrap();
        assert_eq!(cfg.target_epsilon, Some(4.5));
        apply_key(&mut cfg, "target_epsilon", "none").unwrap();
        assert_eq!(cfg.target_epsilon, None);
        assert!(apply_key(&mut cfg, "target_epsilon", "abc").is_err());
    }

    #[test]
    fn oversized_grid_rejected() {
        let g = GridSpec::parse("seed=0..9999;epochs=1,2").unwrap();
        let e = g.points(&TrainConfig::default()).unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");
    }

    #[test]
    fn hostile_range_width_cannot_overflow_past_the_cap() {
        // hi - lo overflows i64; the checked width must hit the cap
        // error, not wrap negative and pass it.
        let e = GridSpec::parse("seed=-9000000000000000000..9000000000000000000")
            .unwrap_err()
            .to_string();
        assert!(e.contains("more than"), "{e}");
    }

    #[test]
    fn wrapped_axis_product_saturates_and_hits_the_cap() {
        // 8192^5 = 2^65 wraps usize on 64-bit; len() must saturate so
        // points() rejects the grid instead of running a tiny subset.
        let axis = |key: &str| Axis {
            key: key.into(),
            values: (0..8192).map(|i| i.to_string()).collect(),
        };
        let g = GridSpec {
            axes: vec![
                axis("seed"),
                axis("epochs"),
                axis("batch_size"),
                axis("dataset_size"),
                axis("val_size"),
            ],
        };
        assert_eq!(g.len(), usize::MAX);
        let e = g.points(&TrainConfig::default()).unwrap_err().to_string();
        assert!(e.contains("cap"), "{e}");
    }
}
