//! Sweep results: a deterministic, machine-readable JSON report plus
//! the stdout Pareto view.
//!
//! The report is a pure function of the grid spec and the per-point
//! training outcomes — ordered by grid index, never by completion time,
//! with no timestamps, hostnames, job counts, or output paths inside.
//! The only nondeterministic fields are the wall-clock measurements
//! (`wall_seconds`, `steps_per_sec`); `timing: false` zeroes them so
//! two reports from the same grid diff byte-identically regardless of
//! `--jobs` (the contract CI's `sweep-smoke` job and `tests/sweep.rs`
//! enforce).

use super::grid::GridPoint;
use crate::exp::tables::{pareto_table, SweepRow};
use crate::util::error::{Context, Result};
use crate::util::json::{self, Json};

/// `format` tag every sweep report JSON carries.
pub const REPORT_FORMAT: &str = "dpquant-sweep-report";
/// Sweep-report schema version this build reads and writes.
pub const REPORT_VERSION: u64 = 1;

/// Outcome of one grid point's training run.
#[derive(Clone, Debug)]
pub struct PointResult {
    /// Flat grid index of the point.
    pub index: usize,
    /// `key=value` assignments, in axis order.
    pub params: Vec<(String, String)>,
    /// The run record's name (`model_dataset_quantizer_scheduler_k_seed`).
    pub name: String,
    /// Validation accuracy after the last epoch.
    pub final_accuracy: f64,
    /// Best validation accuracy over the run.
    pub best_accuracy: f64,
    /// Total ε consumed (training + analysis).
    pub final_epsilon: f64,
    /// ε attributable to analysis probes alone.
    pub analysis_epsilon: f64,
    /// Epochs actually run (budget truncation can stop a run early).
    pub epochs_run: usize,
    /// Did the privacy budget stop the run early?
    pub truncated: bool,
    /// Optimizer steps taken (non-empty Poisson batches only).
    pub steps: usize,
    /// Per-epoch quantized-layer schedule.
    pub schedule: Vec<Vec<usize>>,
    /// Wall-clock seconds for the run (0 under `--no-timing`).
    pub wall_seconds: f64,
    /// Optimizer steps per second (0 under `--no-timing`).
    pub steps_per_sec: f64,
}

/// A finished sweep, ready to render and serialize.
pub struct SweepReport {
    /// The expanded grid's axes: (key, values).
    pub axes: Vec<(String, Vec<String>)>,
    /// One entry per grid point, ordered by grid index.
    pub points: Vec<PointResult>,
}

impl SweepReport {
    /// Serialize. With `timing: false` the wall-clock fields are zeroed,
    /// making the output a deterministic function of the grid alone.
    pub fn to_json(&self, timing: bool) -> Json {
        let axes = self
            .axes
            .iter()
            .map(|(key, values)| {
                json::obj(vec![
                    ("key", json::s(key)),
                    ("values", Json::Arr(values.iter().map(|v| json::s(v)).collect())),
                ])
            })
            .collect();
        let points = self
            .points
            .iter()
            .map(|p| {
                json::obj(vec![
                    ("index", json::num(p.index as f64)),
                    (
                        "params",
                        Json::Obj(
                            p.params
                                .iter()
                                .map(|(k, v)| (k.clone(), json::s(v)))
                                .collect(),
                        ),
                    ),
                    ("name", json::s(&p.name)),
                    ("final_accuracy", json::num(p.final_accuracy)),
                    ("best_accuracy", json::num(p.best_accuracy)),
                    ("final_epsilon", json::num(p.final_epsilon)),
                    ("analysis_epsilon", json::num(p.analysis_epsilon)),
                    ("epochs_run", json::num(p.epochs_run as f64)),
                    ("truncated", Json::Bool(p.truncated)),
                    ("steps", json::num(p.steps as f64)),
                    (
                        "schedule",
                        Json::Arr(
                            p.schedule
                                .iter()
                                .map(|epoch| {
                                    Json::Arr(
                                        epoch.iter().map(|&l| json::num(l as f64)).collect(),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "wall_seconds",
                        json::num(if timing { p.wall_seconds } else { 0.0 }),
                    ),
                    (
                        "steps_per_sec",
                        json::num(if timing { p.steps_per_sec } else { 0.0 }),
                    ),
                ])
            })
            .collect();
        json::obj(vec![
            ("format", json::s(REPORT_FORMAT)),
            ("version", json::num(REPORT_VERSION as f64)),
            ("axes", Json::Arr(axes)),
            ("points", Json::Arr(points)),
        ])
    }

    /// Write the JSON report to `path` (creating parent directories),
    /// returning the path for the "saved ..." line.
    pub fn write(&self, path: &str, timing: bool) -> Result<String> {
        let parent = std::path::Path::new(path).parent();
        if let Some(dir) = parent.filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating report directory {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json(timing).to_string())
            .with_context(|| format!("writing sweep report {path}"))?;
        Ok(path.to_string())
    }

    /// The stdout Pareto view over (best accuracy ↑, final ε ↓) — the
    /// sweep-level rendering of the paper's Fig. 4 frontier.
    pub fn render_pareto(&self) -> String {
        let rows: Vec<SweepRow> = self
            .points
            .iter()
            .map(|p| SweepRow {
                label: label_of(p),
                accuracy: p.best_accuracy,
                epsilon: p.final_epsilon,
            })
            .collect();
        pareto_table(&rows).render()
    }
}

fn label_of(p: &PointResult) -> String {
    let params = p
        .params
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(" ");
    format!("#{} {params}", p.index)
}

/// Attach the expanded grid's axes to the results (axis metadata travels
/// from the [`GridPoint`]s so the report never disagrees with what ran).
pub fn build_report(points: &[GridPoint], results: Vec<PointResult>) -> SweepReport {
    let mut axes: Vec<(String, Vec<String>)> = Vec::new();
    for point in points {
        for (key, value) in &point.params {
            match axes.iter_mut().find(|(k, _)| k == key) {
                Some((_, values)) => {
                    if !values.contains(value) {
                        values.push(value.clone());
                    }
                }
                None => axes.push((key.clone(), vec![value.clone()])),
            }
        }
    }
    SweepReport { axes, points: results }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(i: usize, acc: f64, eps: f64, wall: f64) -> PointResult {
        PointResult {
            index: i,
            params: vec![("seed".into(), i.to_string())],
            name: format!("run{i}"),
            final_accuracy: acc,
            best_accuracy: acc,
            final_epsilon: eps,
            analysis_epsilon: 0.1,
            epochs_run: 2,
            truncated: false,
            steps: 8,
            schedule: vec![vec![0, 2], vec![1]],
            wall_seconds: wall,
            steps_per_sec: 8.0 / wall,
        }
    }

    #[test]
    fn no_timing_strips_the_only_nondeterministic_fields() {
        let mk = |wall| SweepReport {
            axes: vec![("seed".into(), vec!["0".into(), "1".into()])],
            points: vec![point(0, 0.8, 2.0, wall), point(1, 0.7, 1.0, wall * 3.0)],
        };
        let a = mk(0.5).to_json(false).to_string();
        let b = mk(9.25).to_json(false).to_string();
        assert_eq!(a, b, "timing-stripped reports must be identical");
        let c = mk(0.5).to_json(true).to_string();
        assert_ne!(a, c);
        assert!(a.contains("\"wall_seconds\":0"), "{a}");
    }

    #[test]
    fn report_json_roundtrips_and_orders_points() {
        let r = SweepReport {
            axes: vec![("seed".into(), vec!["0".into()])],
            points: vec![point(0, 0.5, 1.0, 1.0), point(1, 0.6, 2.0, 1.0)],
        };
        let parsed = crate::util::json::parse(&r.to_json(true).to_string()).unwrap();
        assert_eq!(parsed.get("format").unwrap().as_str().unwrap(), REPORT_FORMAT);
        let pts = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].get("index").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(
            pts[0].get("params").unwrap().get("seed").unwrap().as_str().unwrap(),
            "0"
        );
        assert_eq!(
            pts[0].get("schedule").unwrap().as_arr().unwrap()[0]
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn pareto_render_marks_frontier() {
        let r = SweepReport {
            axes: vec![],
            points: vec![
                point(0, 0.9, 2.0, 1.0), // frontier
                point(1, 0.5, 3.0, 1.0), // dominated by #0
                point(2, 0.4, 1.0, 1.0), // frontier (cheapest eps)
            ],
        };
        let table = r.render_pareto();
        let lines: Vec<&str> = table.lines().collect();
        let row = |needle: &str| {
            lines
                .iter()
                .find(|l| l.contains(needle))
                .unwrap_or_else(|| panic!("missing {needle} in\n{table}"))
                .to_string()
        };
        assert!(row("#0").contains('*'), "{table}");
        assert!(!row("#1 ").contains('*'), "{table}");
        assert!(row("#2").contains('*'), "{table}");
    }
}
