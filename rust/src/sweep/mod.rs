//! Parallel sweep orchestrator: one command runs the paper's evaluation
//! *grids* (Fig. 4, Tab. 8: quantizer × quant_fraction × scheduler ×
//! seed) instead of dozens of serial `train` invocations.
//!
//! * [`grid`]   — grid specs (`--grid "k=v1,v2;..."` / `[sweep]` config
//!   section) expanded into validated `TrainConfig`s with stable grid
//!   indices;
//! * [`pool`]   — the work-stealing `std::thread` pool (a generalization
//!   of `backend/parallel.rs` from microbatch chunks to whole runs);
//! * [`report`] — the deterministic JSON report (`BENCH_sweep.json`) and
//!   the stdout Pareto table.
//!
//! **Thread ownership** (DESIGN.md §11): every worker owns its own
//! executor and `TrainSession`; datasets are generated once per distinct
//! (dataset, sizes, seed) tuple and shared immutably via `Arc`; the only
//! shared mutable state is the pool's job counter, its result slots, and
//! the `Progress` collector that per-run [`TrainEvent`] streams drain
//! into (a `Mutex` around counters + stdout).
//!
//! **Determinism contract**: a grid point's result is a pure function of
//! its config — workers never share RNGs, native executors are pinned to
//! one internal thread, and results aggregate by grid index. Hence
//! `--jobs N` produces a byte-identical report to `--jobs 1`; only the
//! wall-clock fields differ, and `--no-timing` zeroes those so
//! whole-file diffs work (what CI's `sweep-smoke` job checks).

pub mod grid;
pub mod pool;
pub mod report;

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::backend;
use crate::cli::Args;
use crate::config::{ConfigFile, TrainConfig};
use crate::coordinator::{train_with_sink, EventSink, MultiSink, TrainEvent};
use crate::data::{self, Dataset};
use crate::obs::{JsonlSink, TraceWriter};
use crate::util::error::{ensure, err, Context, Result};
use self::grid::{GridPoint, GridSpec};
use self::report::{PointResult, SweepReport};

/// CLI entry point: `dpquant sweep --grid "..." [--jobs N] [--out P]`.
pub fn run(args: &Args) -> Result<()> {
    // One parse of --config feeds both the [train] base and the [sweep]
    // axes; flag overrides land on top of the base as everywhere else.
    let (base, mut spec) = match args.get("config") {
        Some(path) => {
            let cf = ConfigFile::load(path)?;
            (TrainConfig::from_file(&cf)?, GridSpec::from_config(&cf)?)
        }
        None => (TrainConfig::default(), GridSpec::default()),
    };
    let base = base.with_arg_overrides(args)?;
    if let Some(g) = args.get("grid") {
        spec.merge(GridSpec::parse(g)?);
    }
    let points = spec.points(&base)?;
    let jobs = args.usize_or("jobs", backend::parallel::default_threads())?;
    ensure!(jobs >= 1, "--jobs must be at least 1");
    let quiet = args.has_flag("quiet");
    if !quiet {
        println!(
            "sweep: {} grid points over {} axes ({}), --jobs {}",
            points.len(),
            spec.axes.len(),
            spec.axes
                .iter()
                .map(|a| format!("{}×{}", a.key, a.values.len()))
                .collect::<Vec<_>>()
                .join(" "),
            jobs
        );
    }

    let timing = !args.has_flag("no-timing");
    let obs = SweepObs {
        trace_out: args.get("trace-out"),
        timing,
    };
    let sweep_report = run_sweep_obs(&points, jobs, !quiet, &obs)?;
    if !quiet {
        println!("\nPareto view (best accuracy vs final ε; * = frontier):");
        print!("{}", sweep_report.render_pareto());
    }
    if let (Some(prefix), Some(first)) = (&obs.trace_out, points.first()) {
        println!(
            "traces written: {} per-point files ({}, ...)",
            points.len(),
            point_trace_path(prefix, first)
        );
    }
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(&path, format!("{}\n", crate::obs::metrics_doc()))?;
        println!("[sweep metrics -> {path}]");
    }
    let out = args.str_or("out", "BENCH_sweep.json");
    let path = sweep_report.write(&out, timing)?;
    println!("saved {path}");
    Ok(())
}

/// Observability options threaded from the CLI into the sweep workers.
pub struct SweepObs {
    /// `--trace-out PREFIX`: write one `dpquant-trace` v1 file per grid
    /// point, named by index and sanitized point label.
    pub trace_out: Option<String>,
    /// Keep wall-clock payloads (`--no-timing` absent). With timing off
    /// the per-point trace files are byte-deterministic, like every
    /// other `--no-timing` artifact.
    pub timing: bool,
}

/// Per-point trace path: `PREFIX.NNN.key_value_key_value.jsonl`. The
/// grid-point label is sanitized to filename-safe characters; the index
/// keeps names unique even for colliding labels.
fn point_trace_path(prefix: &str, p: &GridPoint) -> String {
    let label: String = p
        .label()
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '.' || c == '-' { c } else { '_' })
        .collect();
    let stem = prefix.strip_suffix(".jsonl").unwrap_or(prefix);
    format!("{stem}.{:03}.{label}.jsonl", p.index)
}

/// (dataset name, dataset_size, val_size, seed) — the tuple that fully
/// determines the generated train/val pair (mirrors the CLI's
/// `open_data`).
type DataKey = (String, usize, usize, u64);

fn data_key(cfg: &TrainConfig) -> DataKey {
    (cfg.dataset.clone(), cfg.dataset_size, cfg.val_size, cfg.seed)
}

/// Run every grid point on a `jobs`-wide work-stealing pool and collect
/// the results ordered by grid index. Fails loudly — naming the grid
/// point — on the first worker error or panic.
pub fn run_sweep(points: &[GridPoint], jobs: usize, verbose: bool) -> Result<SweepReport> {
    run_sweep_obs(
        points,
        jobs,
        verbose,
        &SweepObs {
            trace_out: None,
            timing: true,
        },
    )
}

/// [`run_sweep`] with observability wired in: when `obs.trace_out` is
/// set, each worker writes its point's full [`TrainEvent`] stream to a
/// per-point trace file. Tracing happens inside the worker that owns
/// the run, so the files are as parallel-safe as the runs themselves,
/// and the determinism contract extends to them: with `obs.timing`
/// off, the per-point files are byte-identical across reruns and
/// across `--jobs` settings.
pub fn run_sweep_obs(
    points: &[GridPoint],
    jobs: usize,
    verbose: bool,
    obs: &SweepObs,
) -> Result<SweepReport> {
    // Generate each distinct dataset once, up front, and share it
    // immutably across workers.
    let mut datasets: BTreeMap<DataKey, Arc<(Dataset, Dataset)>> = BTreeMap::new();
    for p in points {
        let key = data_key(&p.cfg);
        if !datasets.contains_key(&key) {
            let full = data::generate(
                &p.cfg.dataset,
                p.cfg.dataset_size + p.cfg.val_size,
                p.cfg.seed,
            )
            .with_context(|| format!("grid point #{} ({})", p.index, p.label()))?;
            datasets.insert(key, Arc::new(full.split(p.cfg.val_size)));
        }
    }

    let progress = Progress::new(points.len(), verbose);
    let results = pool::run_ordered(points.len(), jobs, |i| {
        let p = &points[i];
        let ds = datasets.get(&data_key(&p.cfg)).expect("dataset precomputed");
        let (train_ds, val_ds) = &**ds;
        let exec =
            backend::open_sweep_executor(&p.cfg, train_ds.example_numel, train_ds.n_classes)?;
        let t0 = std::time::Instant::now();
        let mut sink = RunSink {
            progress: &progress,
            steps: 0,
            truncated: false,
        };
        // Per-point trace file, created and owned by this worker.
        let trace = match &obs.trace_out {
            Some(prefix) => {
                let path = point_trace_path(prefix, p);
                if let Some(dir) = std::path::Path::new(&path).parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir)
                            .with_context(|| format!("creating trace dir for {path}"))?;
                    }
                }
                Some(TraceWriter::create(&path, obs.timing)?)
            }
            None => None,
        };
        let (record, _weights, _accountant) = match &trace {
            Some(w) => {
                let mut jsonl = JsonlSink::new(w);
                let mut multi = MultiSink::new(vec![&mut jsonl, &mut sink]);
                train_with_sink(exec.as_ref(), &p.cfg, train_ds, val_ds, &mut multi)?
            }
            None => train_with_sink(exec.as_ref(), &p.cfg, train_ds, val_ds, &mut sink)?,
        };
        if let Some(w) = trace {
            w.finish()?;
        }
        let wall = t0.elapsed().as_secs_f64();
        let result = PointResult {
            index: p.index,
            params: p.params.clone(),
            name: record.name.clone(),
            final_accuracy: record.final_accuracy,
            best_accuracy: record.best_accuracy,
            final_epsilon: record.final_epsilon,
            analysis_epsilon: record.analysis_epsilon,
            epochs_run: record.epochs.len(),
            truncated: sink.truncated,
            steps: sink.steps,
            schedule: record
                .epochs
                .iter()
                .map(|e| e.quantized_layers.clone())
                .collect(),
            wall_seconds: wall,
            steps_per_sec: if wall > 0.0 { sink.steps as f64 / wall } else { 0.0 },
        };
        progress.run_done(&result, &p.label());
        Ok(result)
    })
    .map_err(|e| {
        let p = &points[e.index];
        err!(
            "sweep failed at grid point #{} ({}): {}",
            p.index,
            p.label(),
            e.message
        )
    })?;

    let (epochs, steps) = progress.totals();
    if verbose {
        let runs = points.len();
        println!("sweep complete: {runs} runs, {epochs} epochs, {steps} optimizer steps");
    }
    Ok(report::build_report(points, results))
}

/// The thread-safe collector every worker's [`TrainEvent`] stream drains
/// into: aggregate counters plus serialized progress lines. (The report
/// itself aggregates through the pool's index-ordered slots, so nothing
/// here can reorder results.)
struct Progress {
    total_runs: usize,
    verbose: bool,
    state: Mutex<ProgressState>,
}

#[derive(Default)]
struct ProgressState {
    runs_done: usize,
    epochs: usize,
    steps: usize,
}

impl Progress {
    fn new(total_runs: usize, verbose: bool) -> Self {
        Self {
            total_runs,
            verbose,
            state: Mutex::new(ProgressState::default()),
        }
    }

    /// Fold one streamed event into the sweep-wide counters.
    fn observe(&self, event: &TrainEvent<'_>) {
        let mut st = self.state.lock().unwrap();
        match event {
            TrainEvent::EpochCompleted { .. } => st.epochs += 1,
            TrainEvent::StepCompleted { .. } => st.steps += 1,
            _ => {}
        }
    }

    fn run_done(&self, r: &PointResult, label: &str) {
        let mut st = self.state.lock().unwrap();
        st.runs_done += 1;
        if self.verbose {
            println!(
                "[{}/{}] #{} {label}: acc={:.4} eps={:.3} ({} steps, {:.2}s)",
                st.runs_done, self.total_runs, r.index, r.best_accuracy, r.final_epsilon,
                r.steps, r.wall_seconds
            );
        }
    }

    fn totals(&self) -> (usize, usize) {
        let st = self.state.lock().unwrap();
        (st.epochs, st.steps)
    }
}

/// Per-worker sink: keeps the run-local stats the report needs and
/// forwards every event to the shared [`Progress`] collector.
struct RunSink<'a> {
    progress: &'a Progress,
    steps: usize,
    truncated: bool,
}

impl EventSink for RunSink<'_> {
    fn on_event(&mut self, event: &TrainEvent<'_>) {
        match event {
            TrainEvent::StepCompleted { .. } => self.steps += 1,
            TrainEvent::Truncated { .. } => self.truncated = true,
            _ => {}
        }
        self.progress.observe(event);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Compile-time proof that sessions and sweep results may cross
    // threads: the pool moves `PointResult`s out of workers, and any
    // future session-migrating scheduler relies on `TrainSession: Send`.
    #[test]
    fn session_and_results_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<crate::coordinator::TrainSession>();
        assert_send::<PointResult>();
        assert_send::<SweepReport>();
    }

    #[test]
    fn mock_backend_sweep_is_jobs_invariant() {
        // A tiny grid on the mock executor: byte-identical timing-free
        // reports for 1 vs 3 jobs. (The full native-backend 12-point
        // grid lives in tests/sweep.rs.)
        let base = TrainConfig {
            backend: "mock".into(),
            dataset_size: 96,
            val_size: 32,
            batch_size: 16,
            epochs: 2,
            physical_batch: 32,
            ..TrainConfig::default()
        };
        let spec = GridSpec::parse("scheduler=static_random,pls;seed=0..1").unwrap();
        let points = spec.points(&base).unwrap();
        assert_eq!(points.len(), 4);
        let a = run_sweep(&points, 1, false).unwrap().to_json(false).to_string();
        let b = run_sweep(&points, 3, false).unwrap().to_json(false).to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn per_point_traces_are_valid_and_deterministic() {
        let base = TrainConfig {
            backend: "mock".into(),
            dataset_size: 96,
            val_size: 32,
            batch_size: 16,
            epochs: 2,
            physical_batch: 32,
            ..TrainConfig::default()
        };
        let spec = GridSpec::parse("seed=0..1").unwrap();
        let points = spec.points(&base).unwrap();
        assert_eq!(points.len(), 2);
        let prefix = std::env::temp_dir()
            .join(format!("dpquant_sweep_trace_{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let obs = SweepObs {
            trace_out: Some(prefix.clone()),
            timing: false,
        };
        run_sweep_obs(&points, 2, false, &obs).unwrap();
        let first: Vec<String> = points
            .iter()
            .map(|p| {
                let path = point_trace_path(&prefix, p);
                crate::obs::trace::check(&path).unwrap();
                std::fs::read_to_string(&path).unwrap()
            })
            .collect();
        // Rerun with different parallelism: same bytes per point.
        run_sweep_obs(&points, 1, false, &obs).unwrap();
        for (i, p) in points.iter().enumerate() {
            let path = point_trace_path(&prefix, p);
            assert_eq!(first[i], std::fs::read_to_string(&path).unwrap(), "{path}");
            std::fs::remove_file(&path).ok();
        }
    }
}
