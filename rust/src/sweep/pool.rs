//! Work-stealing job pool for sweep workers.
//!
//! This generalizes `backend/parallel.rs`: where `map_chunks` statically
//! partitions the rows of one physical batch (microsecond-scale work,
//! deterministic per thread count), sweep jobs are whole training runs
//! with wildly different durations — so workers *steal* the next grid
//! index from a shared atomic counter instead of owning a fixed slice.
//! Determinism still holds because every job is self-contained (its own
//! executor, session, and RNG streams seeded from its config) and
//! results land in the slot of their **job index**, never in completion
//! order.
//!
//! Failure contract: the first job that returns an error **or panics**
//! aborts the pool — no new jobs are issued, in-flight jobs finish, and
//! the caller gets a [`PoolError`] naming the offending job index. A
//! sweep must fail loudly, not return a report with silent holes.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::error::Result;

/// A failed pool run: the index of the first failing job plus its error
/// (or panic) message.
#[derive(Debug)]
pub struct PoolError {
    pub index: usize,
    pub message: String,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job #{}: {}", self.index, self.message)
    }
}

impl std::error::Error for PoolError {}

/// Run `f(0), f(1), .., f(jobs - 1)` on up to `threads` worker threads,
/// returning the results **ordered by job index**. Workers pull the next
/// index from a shared counter (work stealing), so long and short jobs
/// pack tightly; `threads <= 1` degenerates to a serial loop on the
/// current thread with identical semantics.
pub fn run_ordered<T, F>(
    jobs: usize,
    threads: usize,
    f: F,
) -> std::result::Result<Vec<T>, PoolError>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if jobs == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.max(1).min(jobs);
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<T>>> = Mutex::new((0..jobs).map(|_| None).collect());
    let failure: Mutex<Option<PoolError>> = Mutex::new(None);

    // One worker loop, shared by the serial and threaded paths. Returns
    // when the queue drains or a failure has been recorded.
    let worker = || loop {
        if failure.lock().unwrap().is_some() {
            return;
        }
        let i = next.fetch_add(1, Ordering::SeqCst);
        if i >= jobs {
            return;
        }
        match catch_unwind(AssertUnwindSafe(|| f(i))) {
            Ok(Ok(v)) => slots.lock().unwrap()[i] = Some(v),
            Ok(Err(e)) => {
                record_failure(&failure, i, format!("{e:#}"));
                return;
            }
            Err(payload) => {
                record_failure(&failure, i, format!("worker panicked: {}", panic_text(payload)));
                return;
            }
        }
    };

    if threads == 1 {
        worker();
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads).map(|_| s.spawn(&worker)).collect();
            for h in handles {
                // Workers catch job panics themselves; a join error here
                // would mean the pool machinery itself panicked.
                h.join().expect("sweep pool worker infrastructure panicked");
            }
        });
    }

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let out = slots.into_inner().unwrap();
    Ok(out
        .into_iter()
        .map(|v| v.expect("pool finished without failure; every slot must be filled"))
        .collect())
}

/// Record the first failure only (later ones raced with the abort).
fn record_failure(failure: &Mutex<Option<PoolError>>, index: usize, message: String) {
    let mut slot = failure.lock().unwrap();
    if slot.is_none() {
        *slot = Some(PoolError { index, message });
    }
}

fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::err;

    #[test]
    fn results_ordered_by_index_not_completion() {
        for threads in [1usize, 2, 4, 16] {
            let out = run_ordered(20, threads, |i| {
                // Earlier indices sleep longer, so completion order is
                // roughly reversed — output order must not be.
                if threads > 1 {
                    std::thread::sleep(std::time::Duration::from_micros(
                        (20 - i as u64) * 50,
                    ));
                }
                Ok(i * 3)
            })
            .unwrap();
            assert_eq!(out, (0..20).map(|i| i * 3).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversubscribed() {
        let empty: Vec<usize> = run_ordered(0, 8, |i| Ok(i)).unwrap();
        assert!(empty.is_empty());
        // More threads than jobs clamps down.
        let out = run_ordered(3, 64, Ok).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn error_names_the_job_and_aborts() {
        let ran = AtomicUsize::new(0);
        let e = run_ordered(100, 1, |i| {
            ran.fetch_add(1, Ordering::SeqCst);
            if i == 5 {
                return Err(err!("deliberate failure"));
            }
            Ok(i)
        })
        .unwrap_err();
        assert_eq!(e.index, 5);
        assert!(e.message.contains("deliberate failure"), "{e}");
        // Serial path: jobs 0..=5 ran, nothing after the failure.
        assert_eq!(ran.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn panic_is_captured_with_its_index() {
        for threads in [1usize, 3] {
            let e = run_ordered(8, threads, |i| {
                if i == 6 {
                    panic!("boom at six");
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(e.index, 6, "threads={threads}");
            assert!(e.message.contains("panicked"), "{e}");
            assert!(e.message.contains("boom at six"), "{e}");
        }
    }
}
