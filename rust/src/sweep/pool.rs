//! Work pools for coarse-grained jobs (whole training runs).
//!
//! Two pools live here, one per job-arrival shape:
//!
//! * [`run_ordered`] — a **fixed batch**: all `jobs` indices are known up
//!   front, scoped worker threads steal the next index from a shared
//!   atomic counter, and the call returns when the batch drains. This is
//!   what `sweep/` uses; it generalizes `backend/parallel.rs` from
//!   statically-chunked microbatch rows to work-stolen whole runs.
//! * [`WorkerPool`] — the **long-lived** generalization of `run_ordered`
//!   for job *streams*: `threads` workers outlive any one batch, jobs
//!   are submitted after the pool starts (and keep arriving while it
//!   runs), and each job owns its error reporting. This is what the
//!   serving daemon's job manager (`serve/jobs.rs`) schedules training
//!   sessions on.
//!
//! Determinism holds in both because every job is self-contained (its
//! own executor, session, and RNG streams seeded from its config);
//! `run_ordered` additionally lands results in the slot of their **job
//! index**, never in completion order.
//!
//! Failure contracts differ with the shape. A fixed batch is all-or-
//! nothing: the first job that returns an error **or panics** aborts
//! `run_ordered` — no new jobs are issued, in-flight jobs finish, and
//! the caller gets a [`PoolError`] naming the offending job index (a
//! sweep must fail loudly, not return a report with silent holes). A
//! long-lived pool must *survive* bad jobs: [`WorkerPool`] catches each
//! job's panic, keeps the worker alive, and leaves failure bookkeeping
//! to the submitter (the job manager marks the job failed).

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::obs;
use crate::util::error::Result;

/// A failed pool run: the index of the first failing job plus its error
/// (or panic) message.
#[derive(Debug)]
pub struct PoolError {
    /// Index of the first failing job.
    pub index: usize,
    /// Its error (or panic) message.
    pub message: String,
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "job #{}: {}", self.index, self.message)
    }
}

impl std::error::Error for PoolError {}

/// Run `f(0), f(1), .., f(jobs - 1)` on up to `threads` worker threads,
/// returning the results **ordered by job index**. Workers pull the next
/// index from a shared counter (work stealing), so long and short jobs
/// pack tightly; `threads <= 1` degenerates to a serial loop on the
/// current thread with identical semantics.
pub fn run_ordered<T, F>(
    jobs: usize,
    threads: usize,
    f: F,
) -> std::result::Result<Vec<T>, PoolError>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    if jobs == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.max(1).min(jobs);
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<Option<T>>> = Mutex::new((0..jobs).map(|_| None).collect());
    let failure: Mutex<Option<PoolError>> = Mutex::new(None);

    // One worker loop, shared by the serial and threaded paths. Returns
    // when the queue drains or a failure has been recorded.
    let worker = || loop {
        if failure.lock().unwrap().is_some() {
            return;
        }
        let i = next.fetch_add(1, Ordering::SeqCst);
        if i >= jobs {
            return;
        }
        match catch_unwind(AssertUnwindSafe(|| f(i))) {
            Ok(Ok(v)) => slots.lock().unwrap()[i] = Some(v),
            Ok(Err(e)) => {
                record_failure(&failure, i, format!("{e:#}"));
                return;
            }
            Err(payload) => {
                record_failure(&failure, i, format!("worker panicked: {}", panic_text(payload)));
                return;
            }
        }
    };

    if threads == 1 {
        worker();
    } else {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads).map(|_| s.spawn(&worker)).collect();
            for h in handles {
                // Workers catch job panics themselves; a join error here
                // would mean the pool machinery itself panicked.
                h.join().expect("sweep pool worker infrastructure panicked");
            }
        });
    }

    if let Some(e) = failure.into_inner().unwrap() {
        return Err(e);
    }
    let out = slots.into_inner().unwrap();
    Ok(out
        .into_iter()
        .map(|v| v.expect("pool finished without failure; every slot must be filled"))
        .collect())
}

/// Record the first failure only (later ones raced with the abort).
fn record_failure(failure: &Mutex<Option<PoolError>>, index: usize, message: String) {
    let mut slot = failure.lock().unwrap();
    if slot.is_none() {
        *slot = Some(PoolError { index, message });
    }
}

// ---------------------------------------------------------------------
// Long-lived worker pool
// ---------------------------------------------------------------------

/// A queued job plus its submission instant, so workers can report how
/// long it waited before running (`pool.queue_wait_ns`).
struct PoolJob {
    run: Box<dyn FnOnce() + Send + 'static>,
    enqueued: Instant,
}

/// A fixed set of long-lived worker threads draining an unbounded job
/// queue — the submit-after-start generalization of [`run_ordered`].
///
/// * Jobs run in submission order (FIFO pop), up to `threads` at a time.
/// * A panicking job is caught and logged; the worker thread survives
///   and moves on to the next job. Result/error delivery is the job's
///   own business (e.g. via state the closure captures) — a stream has
///   no single return value to abort.
/// * [`WorkerPool::shutdown`] (and `Drop`) stops accepting the question
///   of new work, lets workers **drain the queue**, then joins them.
///   Callers that want to abandon queued work cancel it at their own
///   layer first (the job manager's cancel flag) — the pool never drops
///   a job on the floor silently.
/// * Every worker reports utilization into the global
///   [`MetricsRegistry`](crate::obs::MetricsRegistry): per-job
///   queue-wait and busy-time histograms (`pool.queue_wait_ns`,
///   `pool.busy_ns`), completion/panic counters, and a cumulative
///   per-worker busy counter (`pool.worker<i>.busy_ns`). Recording is
///   unconditional — one registry touch per *job*, not per kernel — so
///   `GET /v1/metrics` always has live pool data.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

struct PoolShared {
    queue: Mutex<PoolQueue>,
    wake: Condvar,
}

struct PoolQueue {
    jobs: VecDeque<PoolJob>,
    shutting_down: bool,
}

impl WorkerPool {
    /// Spawn `threads` (min 1) workers, all idle until the first
    /// [`WorkerPool::submit`].
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(PoolQueue {
                jobs: VecDeque::new(),
                shutting_down: false,
            }),
            wake: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, i))
            })
            .collect();
        Self { shared, workers }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a job; returns immediately. Jobs submitted after
    /// shutdown began are impossible by construction (`shutdown`
    /// consumes the pool).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.jobs.push_back(PoolJob {
                run: Box::new(job),
                enqueued: Instant::now(),
            });
        }
        self.shared.wake.notify_one();
    }

    /// Jobs waiting in the queue (excludes jobs currently running).
    pub fn pending(&self) -> usize {
        self.shared.queue.lock().unwrap().jobs.len()
    }

    /// Drain the queue, then stop and join every worker.
    pub fn shutdown(mut self) {
        self.join_workers();
    }

    fn join_workers(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            if q.shutting_down {
                return;
            }
            q.shutting_down = true;
        }
        self.shared.wake.notify_all();
        for h in self.workers.drain(..) {
            // Workers catch job panics; a join error means the pool
            // machinery itself panicked.
            h.join().expect("worker pool infrastructure panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.join_workers();
    }
}

fn worker_loop(shared: &PoolShared, worker: usize) {
    let reg = obs::global();
    let queue_wait = reg.histogram_ns("pool.queue_wait_ns");
    let busy = reg.histogram_ns("pool.busy_ns");
    let completed = reg.counter("pool.jobs_completed");
    let panicked = reg.counter("pool.jobs_panicked");
    let worker_busy = reg.counter(&format!("pool.worker{worker}.busy_ns"));
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutting_down {
                    return;
                }
                q = shared.wake.wait(q).unwrap();
            }
        };
        queue_wait.record_duration(job.enqueued.elapsed());
        let t0 = Instant::now();
        let outcome = catch_unwind(AssertUnwindSafe(job.run));
        let spent = t0.elapsed();
        busy.record_duration(spent);
        worker_busy.add(u64::try_from(spent.as_nanos()).unwrap_or(u64::MAX));
        match outcome {
            Ok(()) => completed.inc(),
            Err(payload) => {
                panicked.inc();
                // The job's own error channel is responsible for marking
                // it failed; this line is the backstop so a panic is
                // never fully silent.
                eprintln!("worker pool: job panicked: {}", panic_text(payload));
            }
        }
    }
}

/// Best-effort text of a caught panic payload (shared with the serve
/// job manager, which converts job panics into failed-job records).
pub(crate) fn panic_text(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::err;

    #[test]
    fn results_ordered_by_index_not_completion() {
        for threads in [1usize, 2, 4, 16] {
            let out = run_ordered(20, threads, |i| {
                // Earlier indices sleep longer, so completion order is
                // roughly reversed — output order must not be.
                if threads > 1 {
                    std::thread::sleep(std::time::Duration::from_micros(
                        (20 - i as u64) * 50,
                    ));
                }
                Ok(i * 3)
            })
            .unwrap();
            assert_eq!(out, (0..20).map(|i| i * 3).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_and_oversubscribed() {
        let empty: Vec<usize> = run_ordered(0, 8, |i| Ok(i)).unwrap();
        assert!(empty.is_empty());
        // More threads than jobs clamps down.
        let out = run_ordered(3, 64, Ok).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn error_names_the_job_and_aborts() {
        let ran = AtomicUsize::new(0);
        let e = run_ordered(100, 1, |i| {
            ran.fetch_add(1, Ordering::SeqCst);
            if i == 5 {
                return Err(err!("deliberate failure"));
            }
            Ok(i)
        })
        .unwrap_err();
        assert_eq!(e.index, 5);
        assert!(e.message.contains("deliberate failure"), "{e}");
        // Serial path: jobs 0..=5 ran, nothing after the failure.
        assert_eq!(ran.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn panic_is_captured_with_its_index() {
        for threads in [1usize, 3] {
            let e = run_ordered(8, threads, |i| {
                if i == 6 {
                    panic!("boom at six");
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(e.index, 6, "threads={threads}");
            assert!(e.message.contains("panicked"), "{e}");
            assert!(e.message.contains("boom at six"), "{e}");
        }
    }

    // -- WorkerPool (the long-lived stream pool) ----------------------

    use std::sync::atomic::AtomicBool;
    use std::sync::Arc as StdArc;
    use std::time::Duration;

    #[test]
    fn worker_pool_runs_jobs_submitted_after_start() {
        let pool = WorkerPool::new(4);
        let count = StdArc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        // Let the first wave start (and likely finish), then keep
        // submitting — the long-lived contract run_ordered cannot offer.
        std::thread::sleep(Duration::from_millis(20));
        for _ in 0..8 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn worker_pool_shutdown_drains_the_queue() {
        // One worker, a slow head-of-line job, then a burst: shutdown
        // must still run everything before joining.
        let pool = WorkerPool::new(1);
        let count = StdArc::new(AtomicUsize::new(0));
        {
            let count = count.clone();
            pool.submit(move || {
                std::thread::sleep(Duration::from_millis(30));
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        for _ in 0..10 {
            let count = count.clone();
            pool.submit(move || {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        assert_eq!(count.load(Ordering::SeqCst), 11);
    }

    #[test]
    fn worker_pool_concurrency_is_bounded_by_threads() {
        let pool = WorkerPool::new(2);
        let running = StdArc::new(AtomicUsize::new(0));
        let peak = StdArc::new(AtomicUsize::new(0));
        for _ in 0..12 {
            let running = running.clone();
            let peak = peak.clone();
            pool.submit(move || {
                let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(5));
                running.fetch_sub(1, Ordering::SeqCst);
            });
        }
        pool.shutdown();
        let peak = peak.load(Ordering::SeqCst);
        assert!((1..=2).contains(&peak), "peak concurrency {peak}");
    }

    #[test]
    fn worker_pool_survives_a_panicking_job() {
        let pool = WorkerPool::new(1);
        let ran_after = StdArc::new(AtomicBool::new(false));
        pool.submit(|| panic!("job goes boom"));
        {
            let ran_after = ran_after.clone();
            pool.submit(move || ran_after.store(true, Ordering::SeqCst));
        }
        pool.shutdown();
        assert!(
            ran_after.load(Ordering::SeqCst),
            "the worker must survive a panicking job and run the next one"
        );
    }

    #[test]
    fn worker_pool_drop_without_shutdown_joins() {
        let count = StdArc::new(AtomicUsize::new(0));
        {
            let pool = WorkerPool::new(3);
            for _ in 0..6 {
                let count = count.clone();
                pool.submit(move || {
                    count.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Dropped here: Drop must drain + join, not leak workers.
        }
        assert_eq!(count.load(Ordering::SeqCst), 6);
    }
}
