//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §6 for the index).
//!
//! Each experiment prints the paper's rows/series to stdout and writes a
//! JSON artifact under `results/`. Absolute numbers come from the scaled
//! substrate (synthetic data, mini models — DESIGN.md §2); the *shape*
//! (who wins, by how much, where crossovers fall) is the reproduction
//! target. ε columns and Fig 3/6 are exact math and reproduce directly.
//!
//! Common flags: `--scale f` multiplies dataset sizes/epochs (default 1,
//! keeps every experiment minutes-scale on CPU), `--seeds n` baseline
//! replicates, `--model/--dataset` to switch the substrate.

pub mod figs;
pub mod perf;
pub mod tables;
pub mod trend;

use crate::backend;
use crate::cli::Args;
use crate::config::TrainConfig;
use crate::coordinator::{train_with_sink, NullSink, StepExecutor, TraceSink, TrainResult};
use crate::data::{self, Dataset};
use crate::util::error::{err, Result};

/// Dispatch `dpquant exp <id>` to its figure/table generator.
pub fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("fig1a") => figs::fig1a(args),
        Some("fig1b") => figs::fig1b(args),
        Some("fig1c") => figs::fig1c(args),
        Some("fig3") => figs::fig3(args),
        Some("fig4") => figs::fig4(args),
        Some("fig5") => figs::fig5(args),
        Some("fig6") => perf::fig6(args),
        Some("tab1") => tables::tab1(args),
        Some("tab2") => tables::tab2(args),
        Some("tab4") => tables::tab4(args),
        Some("tab6") => tables::tab6(args),
        Some("tab8") => tables::tab8(args),
        Some("tab9") => tables::tab9(args),
        Some("tab10") => tables::tab10(args),
        Some("tab11") => tables::tab11(args),
        Some("tab12") => tables::tab12(args),
        Some("tab14") => perf::tab14(args),
        Some("policy") => tables::policy(args),
        Some("all") => {
            // Everything, cheapest first.
            for id in [
                "fig3", "fig6", "fig1b", "fig1c", "tab2", "fig1a", "fig4", "fig5", "policy",
                "tab1", "tab4", "tab6", "tab8", "tab9", "tab10", "tab11", "tab12", "tab14",
            ] {
                println!("\n================ exp {id} ================");
                let mut sub = args.clone();
                sub.positional = vec!["exp".into(), id.into()];
                run(&sub)?;
            }
            Ok(())
        }
        Some(other) => Err(err!("unknown experiment '{other}'")),
        None => Err(err!(
            "usage: dpquant exp <fig1a|fig1b|fig1c|fig3|fig4|fig5|fig6|tab1|tab2|tab4|tab6|tab8|tab9|tab10|tab11|tab12|tab14|policy|all>"
        )),
    }
}

/// Shared experiment context: one executor (native by default, PJRT or
/// mock via `--backend`) + datasets, reused across the (many) runs of
/// one experiment.
pub struct ExpCtx {
    /// The opened executor (native unless `--backend` says otherwise).
    pub exec: Box<dyn StepExecutor>,
    /// Training split.
    pub train_ds: Dataset,
    /// Validation split.
    pub val_ds: Dataset,
    /// The base config experiment variants derive from.
    pub base: TrainConfig,
    /// Replicates per baseline (`--seeds`).
    pub seeds: u64,
    /// Dataset/epoch scale factor (`--scale`).
    pub scale: f64,
}

impl ExpCtx {
    /// Open the default (or flag-selected) substrate with scaled sizes.
    pub fn open(args: &Args, model: &str, dataset: &str, quantizer: &str) -> Result<Self> {
        let scale = args.f64_or("scale", 1.0)?;
        let seeds = args.u64_or("seeds", 3)?;
        let model = args.str_or("model", model);
        let dataset = args.str_or("dataset", dataset);
        let quantizer = args.str_or("quantizer", quantizer);

        let mut base = TrainConfig {
            model: model.clone(),
            dataset: dataset.clone(),
            quantizer: quantizer.clone(),
            dataset_size: ((1024.0 * scale) as usize).max(256),
            val_size: 256,
            batch_size: 64,
            epochs: ((8.0 * scale) as usize).max(3),
            noise_multiplier: 1.0,
            lr: 0.5,
            ..TrainConfig::default()
        };
        base.epochs = args.usize_or("epochs", base.epochs)?;
        base.dataset_size = args.usize_or("dataset-size", base.dataset_size)?;
        base.noise_multiplier = args.f64_or("noise-multiplier", base.noise_multiplier)?;
        base.lr = args.f64_or("lr", base.lr)?;
        base.backend = args.str_or("backend", &base.backend);

        let full = data::generate(&dataset, base.dataset_size + base.val_size, 12345)?;
        let (train_ds, val_ds) = full.split(base.val_size);
        let exec = backend::open_executor(
            &base,
            train_ds.example_numel,
            train_ds.n_classes,
            &args.str_or("artifacts", "artifacts"),
        )?;
        Ok(Self {
            exec,
            train_ds,
            val_ds,
            base,
            seeds,
            scale,
        })
    }

    /// One training run under a config derived from the base, through
    /// the session API: a `TraceSink` taps per-step stats when asked
    /// (the typed replacement for the old `collect_step_stats` flag).
    pub fn run_cfg(&self, cfg: &TrainConfig, stats: bool) -> Result<TrainResult> {
        let mut trace_sink = TraceSink::default();
        let mut null_sink = NullSink;
        let sink: &mut dyn crate::coordinator::EventSink =
            if stats { &mut trace_sink } else { &mut null_sink };
        let (record, final_weights, accountant) =
            train_with_sink(self.exec.as_ref(), cfg, &self.train_ds, &self.val_ds, sink)?;
        Ok(TrainResult {
            record,
            trace: trace_sink.into_trace(),
            final_weights,
            accountant,
        })
    }

    /// Baseline sweep: `seeds` runs of `scheduler`, returning best
    /// accuracies per seed and the last run's ε.
    pub fn sweep(
        &self,
        scheduler: &str,
        quant_fraction: f64,
        extra: impl Fn(&mut TrainConfig),
    ) -> Result<(Vec<f64>, f64)> {
        let mut accs = Vec::new();
        let mut eps = 0.0;
        for seed in 0..self.seeds {
            let mut cfg = self.base.clone();
            cfg.scheduler = scheduler.into();
            cfg.quant_fraction = quant_fraction;
            cfg.seed = seed;
            extra(&mut cfg);
            let res = self.run_cfg(&cfg, false)?;
            accs.push(res.record.best_accuracy);
            eps = res.record.final_epsilon;
        }
        Ok((accs, eps))
    }

    /// Quantizable layer count of the opened model.
    pub fn n_layers(&self) -> usize {
        self.exec.n_quant_layers()
    }
}

/// Write an experiment's JSON blob under results/.
pub fn save_json(name: &str, json: crate::util::json::Json) -> Result<()> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.json");
    std::fs::write(&path, json.to_string())?;
    println!("[saved {path}]");
    Ok(())
}
