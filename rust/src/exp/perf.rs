//! Performance experiments: Figure 6 (theoretical speedup) and Table 14
//! (runtime decomposition / overhead), plus the measured decomposition of
//! *our* stack feeding back into the same cost model.

use super::{save_json, ExpCtx};
use crate::cli::Args;
use crate::coordinator::StepExecutor;
use crate::metrics::Table;
use crate::perfmodel::{Decomposition, SpeedupModel, PAPER_TABLE14};
use crate::util::error::Result;
use crate::util::json::{self, Json};

/// Fig 6: theoretical speedup at 90% quantization via the paper's linear
/// cost model — exact from the paper's own Table-14 decomposition, plus
/// the same model over our measured decomposition.
pub fn fig6(args: &Args) -> Result<()> {
    let p = args.f64_or("fraction", 0.9)?;
    let s = args.f64_or("speedup-factor", 4.0)?;
    // Analysis cost amortized per iteration: (n_layers+1)·R probe steps
    // every n_interval epochs — with n_sample=1 probes the paper treats
    // it as ~1-2% of an iteration; expose as a flag.
    let analysis_frac = args.f64_or("analysis-frac", 0.02)?;

    let mut table = Table::new(&["config", "overhead %", "T_ours/T_base", "speedup"]);
    let mut rows = Vec::new();
    for &(name, total, _good, overhead) in PAPER_TABLE14 {
        let m = SpeedupModel::from_table14(total, overhead, analysis_frac * total, s);
        let sp = m.speedup(p);
        table.row(vec![
            name.into(),
            format!("{:.2}", 100.0 * overhead / total),
            format!("{:.3}", 1.0 / sp),
            format!("{sp:.2}x"),
        ]);
        rows.push(json::obj(vec![
            ("config", json::s(name)),
            ("speedup", json::num(sp)),
        ]));
    }
    println!("Fig 6 — theoretical speedup at p = {p} with {s}x low-precision ops");
    table.print();
    println!("paper band: 1.75x – 2.21x at p = 0.9 (matches the shape above)");
    save_json("fig6", Json::Arr(rows))
}

/// Measure our own runtime decomposition (Table 14 analogue): time the
/// executor's fused step (fwd+bwd+clip), the noise draw, the optimizer
/// update, and batch assembly, then feed the same Fig-6 model.
pub fn tab14(args: &Args) -> Result<()> {
    let ctx = ExpCtx::open(args, "miniconvnet", "gtsrb", "luq4")?;
    let exec = ctx.exec.as_ref();
    let b = exec.physical_batch();
    let batches = crate::data::eval_batches(&ctx.train_ds, b);
    let batch = &batches[0];
    let mask = vec![1f32; exec.n_quant_layers()];
    let reps = args.usize_or("reps", 10)?;

    // Step time (forward + backward + per-sample clip, inside the
    // executor — XLA for pjrt, the pure-Rust engine for native).
    let w = exec.initial_weights();
    exec.train_step(&w, &batch.x, &batch.y, &batch.mask, &mask, 0.0)?; // warmup
    let t0 = std::time::Instant::now();
    for i in 0..reps {
        exec.train_step(&w, &batch.x, &batch.y, &batch.mask, &mask, i as f32)?;
    }
    let t_graph = t0.elapsed().as_secs_f64() / reps as f64;

    // Noise generation over all params (the DP mechanism).
    let sizes = exec.param_sizes();
    let mut gaus = crate::util::gaussian::GaussianSampler::seed_from_u64(1);
    let mut bufs: Vec<Vec<f32>> = sizes.iter().map(|&n| vec![0f32; n]).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for buf in bufs.iter_mut() {
            gaus.add_noise_f32(buf, 1.0);
        }
    }
    let t_noise = t0.elapsed().as_secs_f64() / reps as f64;

    // Optimizer scale + update (SGD arithmetic).
    let mut weights = exec.initial_weights();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for (wt, g) in weights.iter_mut().zip(&bufs) {
            for (wi, gi) in wt.iter_mut().zip(g) {
                *wi -= 0.5 * gi / 64.0;
            }
        }
    }
    let t_update = t0.elapsed().as_secs_f64() / reps as f64;

    // Batch assembly (data movement "other").
    let idx: Vec<usize> = (0..b.min(ctx.train_ds.len())).collect();
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        let _ = crate::data::make_batches(&ctx.train_ds, &idx, b);
    }
    let t_other = t0.elapsed().as_secs_f64() / reps as f64;

    // The compiled graph fuses fwd/bwd/clip; split by the paper's typical
    // 1:2 fwd:bwd ratio with clip ~5% for reporting.
    let d = Decomposition {
        forward: t_graph * 0.32,
        backward: t_graph * 0.63,
        optimizer_clip: t_graph * 0.05,
        optimizer_noise: t_noise,
        optimizer_scale: t_update * 0.5,
        other_optimizer: t_update * 0.5,
        other: t_other,
    };
    let mut table = Table::new(&["stage", "ms/iter", "low-precision speedup?"]);
    for (name, v, good) in [
        ("forward", d.forward, true),
        ("backward", d.backward, true),
        ("optimizer clip", d.optimizer_clip, true),
        ("optimizer noise", d.optimizer_noise, false),
        ("optimizer scale", d.optimizer_scale, true),
        ("other optimizer", d.other_optimizer, false),
        ("other (data)", d.other, false),
    ] {
        table.row(vec![
            name.into(),
            format!("{:.3}", v * 1e3),
            if good { "yes" } else { "no" }.into(),
        ]);
    }
    println!("Table 14 (ours) — measured decomposition per iteration (batch {b})");
    table.print();
    println!(
        "total {:.2} ms, overhead {:.2}% (paper overheads: 4.6–19.8%)",
        d.total() * 1e3,
        d.overhead_pct()
    );
    let m = SpeedupModel::from_decomposition(&d, 0.02 * d.total(), 4.0);
    println!(
        "cost-model speedup at p=0.9 on OUR decomposition: {:.2}x (paper: 1.75–2.21x)",
        m.speedup(0.9)
    );
    save_json(
        "tab14",
        json::obj(vec![
            ("graph_ms", json::num(t_graph * 1e3)),
            ("noise_ms", json::num(t_noise * 1e3)),
            ("update_ms", json::num(t_update * 1e3)),
            ("other_ms", json::num(t_other * 1e3)),
            ("overhead_pct", json::num(d.overhead_pct())),
            ("model_speedup_p09", json::num(m.speedup(0.9))),
        ]),
    )
}
